#!/usr/bin/env bash
# CI gate: format, lints, offline release build, tests, and a check that
# the pjrt feature still typechecks against the vendored xla stub.
# Everything runs offline (dependencies are vendored under rust/vendor/).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
# Includes the sharded solve suite: the prop_sharded bit-exactness
# properties and the integration_solver TCP tests are registered
# [[test]] targets, so the full run covers them.
cargo test -q

echo "==> cargo check --features pjrt (stub xla)"
cargo check --features pjrt

echo "==> solve-bench --shards/--packed/--rtl gate (BENCH_solver.json must carry sharded + packed + rtl rows)"
./target/release/onn-scale solve-bench --sizes 12,16 --replicas 4 --periods 32 \
  --instances 1 --shards 2 --packed 4 --rtl --out BENCH_solver.json
grep -q '"engine":"native"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the native rows"; exit 1; }
grep -q '"engine":"sharded"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the sharded rows"; exit 1; }
grep -q '"packed_replica_periods_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the packed serving row"; exit 1; }
grep -q '"unpacked_replica_periods_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the one-engine-per-request baseline row"; exit 1; }
grep -q '"engine":"rtl"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the bit-true rtl rows"; exit 1; }

echo "==> solve-report renders the recorded trajectory"
./target/release/onn-scale solve-report --path BENCH_solver.json >/dev/null

echo "CI OK"
