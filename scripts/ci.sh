#!/usr/bin/env bash
# CI gate: format, lints, offline release build, tests, and a check that
# the pjrt feature still typechecks against the vendored xla stub.
# Everything runs offline (dependencies are vendored under rust/vendor/).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo check --features pjrt (stub xla)"
cargo check --features pjrt

echo "CI OK"
