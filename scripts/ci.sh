#!/usr/bin/env bash
# CI gate: format, lints, offline release build, tests, and a check that
# the pjrt feature still typechecks against the vendored xla stub.
# Everything runs offline (dependencies are vendored under rust/vendor/).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
# Includes the sharded solve suite: the prop_sharded bit-exactness
# properties and the integration_solver TCP tests are registered
# [[test]] targets, so the full run covers them.
cargo test -q

echo "==> cargo check --features pjrt (stub xla)"
cargo check --features pjrt

echo "==> solve-bench --shards/--packed/--rtl/--connections/--sparse/--associative gate (BENCH_solver.json must carry sharded + packed + rtl + rtl-packed + rtl-cluster + connection-scale + sparse + associative rows)"
./target/release/onn-scale solve-bench --sizes 12,16 --replicas 4 --periods 32 \
  --instances 1 --shards 2 --packed 4 --rtl --rtl-packed --rtl-cluster \
  --connections 64 --sparse --associative --out BENCH_solver.json
grep -q '"engine":"native"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the native rows"; exit 1; }
grep -q '"engine":"sharded"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the sharded rows"; exit 1; }
grep -q '"packed_replica_periods_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the packed serving row"; exit 1; }
grep -q '"unpacked_replica_periods_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the one-engine-per-request baseline row"; exit 1; }
grep -q '"engine":"rtl"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the bit-true rtl rows"; exit 1; }
grep -q '"p50_ms"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the latency percentile rows"; exit 1; }
grep -q '"convergence"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the convergence trace section"; exit 1; }
# The connection-scale row (evented front end vs thread-per-connection
# baseline at 64 concurrent streaming clients) must be present and
# carry the speedup + arena hit-rate fields the issue gates on.
grep -q '"connection_scale"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the connection-scale section"; exit 1; }
grep -q '"clients":64' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the 64-client connection-scale row"; exit 1; }
grep -q '"speedup"' BENCH_solver.json \
  || { echo "BENCH_solver.json connection-scale row is missing the speedup field"; exit 1; }
# The sparse section (dense vs CSR coupling fabric on bit-identical
# work, fixed density plus the G(n, 4/n) sweep) must be present and
# carry the throughput + nnz fields the issue gates on.  The CSR kernel
# itself is proven bit-exact by the prop_sparse [[test]] suite above.
grep -q '"sparse"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the sparse fabric section"; exit 1; }
grep -q '"sparse_replica_periods_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json sparse rows are missing the CSR throughput field"; exit 1; }
grep -q '"sparse_speedup"' BENCH_solver.json \
  || { echo "BENCH_solver.json sparse rows are missing the dense-vs-CSR speedup field"; exit 1; }
grep -q '"avg_row_nnz"' BENCH_solver.json \
  || { echo "BENCH_solver.json sparse rows are missing the nonzeros-per-row field"; exit 1; }
# The rtl lane-bank packing row (shared emulated fabric vs one device
# per request, bit-exactness and exact cycle parity asserted inside the
# harness) and the emulated multi-FPGA cluster row (an n past the
# single Zynq-7020 fit, with the per-period phase all-gather priced)
# must both be present.  The throughput/fit field names only appear
# when the rows exist — the section keys alone are emitted even empty.
grep -q '"packed_emulated_solves_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the rtl lane-bank packing row"; exit 1; }
grep -q '"solo_emulated_solves_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json rtl_packed row is missing the solo baseline field"; exit 1; }
grep -q '"single_device_fit"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the emulated rtl cluster row"; exit 1; }
grep -q '"sync_fast_cycles"' BENCH_solver.json \
  || { echo "BENCH_solver.json rtl_cluster row is missing the priced all-gather cycles"; exit 1; }
# The associative section (online-learning store/recall/forget traffic:
# delta-reprogrammed warm engines vs cold retrain+rebuild) must be
# present and carry both throughput fields.  Delta-vs-cold bit-identity
# is asserted inside the harness row itself and again by the
# prop_assoc [[test]] suite above.
grep -q '"associative"' BENCH_solver.json \
  || { echo "BENCH_solver.json is missing the associative-memory section"; exit 1; }
grep -q '"delta_recalls_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json associative row is missing the delta-reprogram throughput field"; exit 1; }
grep -q '"rebuild_recalls_per_sec"' BENCH_solver.json \
  || { echo "BENCH_solver.json associative row is missing the full-rebuild baseline field"; exit 1; }

echo "==> solve-report renders the recorded trajectory"
./target/release/onn-scale solve-report --path BENCH_solver.json >/dev/null

echo "==> solve --trace exports a schema-valid JSONL lifecycle trace"
TRACE_FILE="${TMPDIR:-/tmp}/onn_trace_ci_$$.jsonl"
trap 'rm -f "$TRACE_FILE"' EXIT
./target/release/onn-scale solve --problem maxcut --nodes 24 --replicas 8 \
  --periods 64 --seed 7 --trace "$TRACE_FILE" >/dev/null
# trace-check validates field presence per event and monotonic
# seq/t_us ordering — the telemetry contract of DESIGN_SOLVER.md §9.
./target/release/onn-scale trace-check --path "$TRACE_FILE"
grep -q '"event":"solve_start"' "$TRACE_FILE" \
  || { echo "trace is missing the solve_start record"; exit 1; }
grep -q '"event":"chunk"' "$TRACE_FILE" \
  || { echo "trace is missing per-chunk convergence records"; exit 1; }

echo "==> solve --rtl precision sweep + emulated cluster smoke"
# A non-paper sweep point (4-bit weights, 4-bit phases) must serve end
# to end on the bit-true engine, and --rtl --shards 2 must route to the
# emulated cluster engine instead of erroring as it did before the
# cluster front end existed.
./target/release/onn-scale solve --problem maxcut --nodes 16 --replicas 4 \
  --periods 32 --seed 11 --rtl --weight-bits 4 --phase-bits 4 >/dev/null
./target/release/onn-scale solve --problem maxcut --nodes 16 --replicas 4 \
  --periods 32 --seed 11 --rtl --shards 2 >/dev/null

echo "==> assoc-smoke: live store -> recall -> forget -> recall over TCP"
# Drives the online-learning wire commands end to end through the
# evented front end and asserts every reply plus the metrics counters
# (patterns_stored / patterns_forgotten / recalls_matched).
./target/release/onn-scale assoc-smoke

echo "CI OK"
