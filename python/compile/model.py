"""L2 — the JAX ONN model (build-time only; never on the request path).

Composes the L1 Pallas coupling kernel into the full period step and the
CHUNK-period scan that gets AOT-lowered to HLO text by aot.py.  The Rust
coordinator executes the lowered artifact through PJRT.

Semantics are defined by kernels/ref.py (the oracle); this module must
agree with it bit-exactly — pytest enforces that.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import onn_step, ref


@dataclasses.dataclass(frozen=True)
class OnnConfig:
    """Static shape/precision configuration of one AOT artifact."""

    n: int  # number of oscillators
    batch: int  # trials per call
    phase_bits: int = 4  # P = 2^phase_bits sub-steps per period
    weight_bits: int = 5  # informational: weights are integers in [-16, 15]
    chunk: int = 16  # periods per artifact call

    @property
    def p(self) -> int:
        return 1 << self.phase_bits

    @property
    def name(self) -> str:
        return f"onn_n{self.n}_b{self.batch}_p{self.p}_c{self.chunk}"


def onn_period_step(w: jax.Array, phases: jax.Array, cfg: OnnConfig) -> jax.Array:
    """One period update using the Pallas coupling kernel.

    Identical math to ref.onn_period_step_ref but with the weighted sum
    routed through the tiled Pallas matmul: s is flattened (B,N,P)->(N,B*P)
    so the kernel sees one big (N,N)x(N,B*P) contraction.
    """
    b, n = phases.shape
    p = cfg.p
    s = ref.square_wave(phases, p)  # [B, N, P]
    s2 = jnp.transpose(s, (1, 0, 2)).reshape(n, b * p)
    su2 = onn_step.coupling_matmul(w, s2)  # [N, B*P]
    su = jnp.transpose(su2.reshape(n, b, p), (1, 0, 2))  # [B, N, P]
    refsig = jnp.where(su > 0, 1.0, jnp.where(su < 0, -1.0, s))
    score = jnp.einsum("bit,kt->bik", refsig, ref.templates(p))
    return ref.snap_phase(score, phases, p)


def onn_chunk(
    w: jax.Array,
    phases: jax.Array,
    settled: jax.Array,
    period0: jax.Array,
    cfg: OnnConfig,
):
    """CHUNK-period scan — the unit of work one PJRT call performs.

    Args:
      w: f32[N, N] quantized weights.
      phases: int32[B, N].
      settled: int32[B], absolute period of first fixed point or -1.
      period0: int32 scalar, absolute period index of this chunk's start.

    Returns:
      (phases', settled') — same shapes/dtypes.
    """

    def body(carry, k):
        ph, st = carry
        nph = onn_period_step(w, ph, cfg)
        fixed = jnp.all(nph == ph, axis=-1)
        st = jnp.where((st < 0) & fixed, period0 + k, st)
        return (nph, st), None

    (phases, settled), _ = jax.lax.scan(
        body, (phases, settled), jnp.arange(cfg.chunk, dtype=jnp.int32)
    )
    return phases, settled


def chunk_fn(cfg: OnnConfig):
    """The callable that aot.py lowers (donation-friendly positional args)."""

    @functools.partial(jax.jit, donate_argnums=(1, 2))
    def fn(w, phases, settled, period0):
        return onn_chunk(w, phases, settled, period0, cfg)

    return fn


def step_fn(cfg: OnnConfig):
    """Single-period artifact used by quickstart/tests."""

    @jax.jit
    def fn(w, phases):
        return (onn_period_step(w, phases, cfg),)

    return fn


def example_args(cfg: OnnConfig, *, for_step: bool = False):
    """ShapeDtypeStructs matching chunk_fn/step_fn signatures."""
    w = jax.ShapeDtypeStruct((cfg.n, cfg.n), jnp.float32)
    phases = jax.ShapeDtypeStruct((cfg.batch, cfg.n), jnp.int32)
    if for_step:
        return (w, phases)
    settled = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    period0 = jax.ShapeDtypeStruct((), jnp.int32)
    return (w, phases, settled, period0)
