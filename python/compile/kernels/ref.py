"""Pure-jnp correctness oracle for the ONN step (no Pallas).

This file is the single source of truth for the functional (period-level)
ONN dynamics — the hybrid-architecture semantics of DESIGN.md section 3:

  1. sample every oscillator phase at the period boundary;
  2. synthesize the +-1 square waveforms over one period (P sub-steps);
  3. weighted sums  S[b,i,t] = sum_j W[i,j] * s[b,j,t];
  4. reference signal R = sign(S), ties keep the oscillator's own amplitude;
  5. snap each phase to the square-wave template that best correlates
     with its reference waveform.  Score ties are broken toward the
     candidate with the smallest forward rotation from the current phase
     (i.e. "move least, and stay put when ambiguous"), which keeps the
     update equivariant under a global phase rotation — the digital
     analogue of the physical system's rotational symmetry.

The Rust mirror (`rust/src/onn/dynamics.rs`) implements the identical
integer algorithm; all f32 intermediates here are exact integers, so the
two are bit-exact regardless of reduction order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def square_wave(phase: jax.Array, p: int) -> jax.Array:
    """+-1 amplitudes over one period.

    Args:
      phase: int32[...] phases in [0, P).
      p: period length (= 2^phase_bits registers).

    Returns:
      f32[..., P] with s[..., t] = +1 if (phase+t) mod P < P/2 else -1.
    """
    t = jnp.arange(p, dtype=jnp.int32)
    pos = jnp.mod(phase[..., None] + t, p) < (p // 2)
    return jnp.where(pos, 1.0, -1.0).astype(jnp.float32)


def templates(p: int) -> jax.Array:
    """f32[P, P] matrix of all P phase-shifted square-wave templates."""
    return square_wave(jnp.arange(p, dtype=jnp.int32), p)


def coupling_matmul_ref(w: jax.Array, s: jax.Array) -> jax.Array:
    """Oracle for kernels.onn_step.coupling_matmul: plain W @ s."""
    return jnp.dot(w, s, preferred_element_type=jnp.float32)


def onn_period_step_ref(w: jax.Array, phases: jax.Array, p: int) -> jax.Array:
    """One oscillation-period phase update (batched), pure jnp.

    Args:
      w: f32[N, N] integer-valued quantized weights (W[i,j]: j -> i).
      phases: int32[B, N] phases in [0, P).
      p: period length.

    Returns:
      int32[B, N] updated phases.
    """
    s = square_wave(phases, p)  # [B, N, P]
    # S[b,i,t] = sum_j W[i,j] s[b,j,t]
    su = jnp.einsum("ij,bjt->bit", w, s)
    ref = jnp.where(su > 0, 1.0, jnp.where(su < 0, -1.0, s))  # [B, N, P]
    # score[b,i,k] = sum_t ref[b,i,t] * template_k[t]
    score = jnp.einsum("bit,kt->bik", ref, templates(p))
    return snap_phase(score, phases, p)


def snap_phase(score: jax.Array, phases: jax.Array, p: int) -> jax.Array:
    """argmax_k score with rotation-equivariant tie-break (see module doc).

    Lexicographic key: maximize integer score, then minimize the forward
    rotation (k - phase) mod P.  Scores are integer-valued f32 in [-P, P],
    so `score * 2P + (P - rel)` is an exact collision-free int32 key.
    """
    k = jnp.arange(p, dtype=jnp.int32)
    rel = jnp.mod(k - phases[..., None], p)  # [B, N, P]
    key = score.astype(jnp.int32) * (2 * p) + (p - rel)
    return jnp.argmax(key, axis=-1).astype(jnp.int32)


def onn_chunk_ref(
    w: jax.Array,
    phases: jax.Array,
    settled: jax.Array,
    period0: jax.Array,
    *,
    p: int,
    chunk: int,
):
    """Scan `chunk` period steps, tracking the first fixed-point period.

    settled[b] is the absolute period index at which trial b first reached
    a fixed point, or -1.  Once a synchronous update reaches a fixed point
    it stays there, so later steps are no-ops for that trial.
    """

    def body(carry, k):
        ph, st = carry
        nph = onn_period_step_ref(w, ph, p)
        fixed = jnp.all(nph == ph, axis=-1)
        st = jnp.where((st < 0) & fixed, period0 + k, st)
        return (nph, st), None

    (phases, settled), _ = jax.lax.scan(
        body, (phases, settled), jnp.arange(chunk, dtype=jnp.int32)
    )
    return phases, settled
