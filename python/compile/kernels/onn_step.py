"""L1 — Pallas kernel for the ONN coupling hot-spot.

The compute hot-spot of the digital ONN step is the weighted-sum

    S[b, i, t] = sum_j W[i, j] * s[b, j, t]

where ``s`` are the +-1 square-wave amplitudes of every oscillator at every
sub-step ``t`` of one oscillation period.  Flattened over the (batch, time)
axes this is a plain (N, N) x (N, B*P) matmul with sign inputs.

Hardware adaptation (paper FPGA -> TPU), per DESIGN.md section 10: the
paper's hybrid architecture shares ONE multiply-accumulate per oscillator
and streams weights out of BRAM; on TPU the shared MAC is the MXU systolic
array and BRAM becomes VMEM.  The BlockSpec index maps below express the
HBM->VMEM weight-tile schedule that the FPGA design expressed with BRAM
addressing, and the f32 scratch accumulator carried across the K grid axis
plays the role of the DSP accumulate register.

``interpret=True`` is mandatory in this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute.  The kernel is
still written with production tiling so the VMEM/MXU analysis in DESIGN.md
applies unchanged on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_tiles: int):
    """One (TM, TN) output tile; grid axis 2 walks the K dimension.

    acc_ref is VMEM scratch that persists across the K axis of the grid
    (sequential on TPU), mirroring the DSP48 accumulate register of the
    paper's serial MAC.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_tiles - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# Production (real-TPU) tile: 128x128x128 feeds the MXU at full rate
# and keeps ~1.3 MiB of VMEM live per grid step.
TPU_TILE = 128
# Interpret-mode (CPU PJRT) tile cap: each grid step of the interpret
# lowering becomes an XLA while-loop iteration with dynamic slices, so
# the grid itself is the bottleneck — one big tile per call is ~9x
# faster at N=484 and bit-identical (integer values).  See
# EXPERIMENTS.md section Perf (L1).
INTERPRET_TILE_CAP = 1024


def coupling_matmul(
    w: jax.Array,
    s: jax.Array,
    *,
    tile_m: int | None = None,
    tile_n: int | None = None,
    tile_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """S2 = W @ s2 with Pallas tiling.

    Args:
      w:  f32[N, N] quantized coupling weights (integer-valued).
      s:  f32[N, M] +-1 amplitude matrix, M = B * P after flattening.
      tile_*: explicit tile sizes; default picks the interpret-mode
        single-tile policy on CPU and TPU_TILE for compile targets.

    Returns:
      f32[N, M] weighted sums.  All values are exact integers (|S| <=
      N * 2^(wb-1) << 2^24) so f32 accumulation order cannot change the
      result — this is what makes the Rust mirror bit-exact.
    """
    n, k = w.shape
    k2, m = s.shape
    assert k == k2, (w.shape, s.shape)

    if tile_m is None:
        tile_m = min(_ceil_to(n, 8), INTERPRET_TILE_CAP) if interpret else TPU_TILE
    if tile_n is None:
        tile_n = min(_ceil_to(m, 8), INTERPRET_TILE_CAP) if interpret else TPU_TILE
    if tile_k is None:
        tile_k = min(_ceil_to(k, 8), INTERPRET_TILE_CAP) if interpret else TPU_TILE

    # Pad every axis up to the tile grid; zero-padding K contributes zero
    # to the accumulator, padded M/N rows are sliced off below.
    tm = min(tile_m, _ceil_to(n, 8))
    tn = min(tile_n, _ceil_to(m, 8))
    tk = min(tile_k, _ceil_to(k, 8))
    np_, kp, mp = _ceil_to(n, tm), _ceil_to(k, tk), _ceil_to(m, tn)
    wp = jnp.pad(w, ((0, np_ - n), (0, kp - k)))
    sp = jnp.pad(s, ((0, kp - k), (0, mp - m)))
    k_tiles = kp // tk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=k_tiles),
        grid=(np_ // tm, mp // tn, k_tiles),
        in_specs=[
            # Weight tiles stream through VMEM row-block by K-block —
            # the BRAM-addressing schedule of the hybrid architecture.
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        # The f32 accumulator tile in VMEM — the DSP accumulate register
        # of the paper's serial MAC, persisted across the K grid axis.
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=interpret,
    )(wp, sp)
    return out[:n, :m]


def vmem_footprint_bytes(tile_m: int, tile_n: int, tile_k: int) -> int:
    """VMEM bytes live per grid step (w tile + s tile + acc + out tile).

    Used by DESIGN.md section Perf to check the production tiling fits the
    ~16 MiB/core VMEM budget at N=506.
    """
    f32 = 4
    return f32 * (tile_m * tile_k + tile_k * tile_n + 2 * tile_m * tile_n)


def mxu_utilization_estimate(n: int, tile_m: int, tile_n: int, tile_k: int) -> float:
    """Fraction of MXU work that is useful (non-padding) for an N-osc net."""
    np_, kp = _ceil_to(n, tile_m), _ceil_to(n, tile_k)
    useful = n * n
    issued = np_ * kp
    return useful / issued
