"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as `python -m compile.aot --out-dir ../artifacts` (the Makefile does).
Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from compile.model import OnnConfig, chunk_fn, example_args, step_fn

# One artifact per benchmark network size (DESIGN.md section 6):
#   9 = 3x3, 20 = 5x4, 42 = 7x6, 100 = 10x10, 484 = 22x22 pattern datasets,
#   506 = the paper's headline maximum network, 48 = RA maximum,
#   8/B4 = tiny config exercised by Rust unit tests.
CONFIGS = [
    OnnConfig(n=8, batch=4),
    OnnConfig(n=9, batch=64),
    OnnConfig(n=20, batch=64),
    OnnConfig(n=42, batch=64),
    OnnConfig(n=48, batch=64),
    OnnConfig(n=100, batch=64),
    OnnConfig(n=484, batch=32),
    OnnConfig(n=506, batch=32),
]

# Single-period step artifacts (quickstart + cross-validation tests).
STEP_CONFIGS = [OnnConfig(n=8, batch=4), OnnConfig(n=42, batch=64)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: OnnConfig, out_dir: pathlib.Path) -> list[dict]:
    """Lower chunk (and optionally step) artifacts for one config."""
    entries = []
    jobs = [("chunk", chunk_fn(cfg), example_args(cfg))]
    if cfg in STEP_CONFIGS:
        jobs.append(("step", step_fn(cfg), example_args(cfg, for_step=True)))
    for kind, fn, args in jobs:
        hlo = to_hlo_text(fn.lower(*args))
        name = f"{cfg.name}_{kind}.hlo.txt"
        path = out_dir / name
        path.write_text(hlo)
        entries.append(
            {
                "kind": kind,
                "file": name,
                "n": cfg.n,
                "batch": cfg.batch,
                "phase_bits": cfg.phase_bits,
                "weight_bits": cfg.weight_bits,
                "p": cfg.p,
                "chunk": cfg.chunk if kind == "chunk" else 1,
                "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            }
        )
        print(f"  {name}: {len(hlo)} chars")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only-n", type=int, default=None, help="lower a single network size"
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"format": "hlo-text", "version": 1, "artifacts": []}
    for cfg in CONFIGS:
        if args.only_n is not None and cfg.n != args.only_n:
            continue
        print(f"lowering {cfg.name} ...")
        manifest["artifacts"].extend(lower_config(cfg, out_dir))

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
