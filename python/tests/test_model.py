"""L2 model tests: Pallas-backed step vs pure-jnp oracle, dynamics laws."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import OnnConfig, onn_chunk, onn_period_step

jax.config.update("jax_platform_name", "cpu")

P = 16


def _rand_net(rng, n, b):
    w = rng.integers(-16, 16, size=(n, n)).astype(np.float32)
    ph = rng.integers(0, P, size=(b, n)).astype(np.int32)
    return jnp.array(w), jnp.array(ph)


class TestStepVsOracle:
    @pytest.mark.parametrize("n,b", [(4, 2), (9, 8), (20, 4), (42, 3)])
    def test_step_bit_exact(self, n, b):
        rng = np.random.default_rng(n * 100 + b)
        w, ph = _rand_net(rng, n, b)
        cfg = OnnConfig(n=n, batch=b)
        got = onn_period_step(w, ph, cfg)
        want = ref.onn_period_step_ref(w, ph, P)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 24), b=st.integers(1, 6), seed=st.integers(0, 999))
    def test_step_bit_exact_hypothesis(self, n, b, seed):
        rng = np.random.default_rng(seed)
        w, ph = _rand_net(rng, n, b)
        cfg = OnnConfig(n=n, batch=b)
        got = onn_period_step(w, ph, cfg)
        want = ref.onn_period_step_ref(w, ph, P)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_chunk_matches_ref_scan(self):
        rng = np.random.default_rng(3)
        w, ph = _rand_net(rng, 12, 5)
        cfg = OnnConfig(n=12, batch=5, chunk=8)
        st0 = jnp.full((5,), -1, jnp.int32)
        p0 = jnp.int32(0)
        got_ph, got_st = onn_chunk(w, ph, st0, p0, cfg)
        want_ph, want_st = ref.onn_chunk_ref(w, ph, st0, p0, p=P, chunk=8)
        np.testing.assert_array_equal(np.asarray(got_ph), np.asarray(want_ph))
        np.testing.assert_array_equal(np.asarray(got_st), np.asarray(want_st))


class TestDynamicsLaws:
    """Physics/algorithm invariants of the functional model."""

    def test_hopfield_equivalence_binary_phases(self):
        """At phases {0, P/2} the step IS a synchronous Hopfield update."""
        rng = np.random.default_rng(11)
        n, b = 15, 16
        w = rng.integers(-16, 16, size=(n, n)).astype(np.float32)
        sigma = rng.choice([1, -1], size=(b, n))
        ph = jnp.array(np.where(sigma == 1, 0, P // 2).astype(np.int32))
        nph = np.asarray(ref.onn_period_step_ref(jnp.array(w), ph, P))
        h = sigma @ w.T  # h[b,i] = sum_j W[i,j] sigma[b,j]
        want_sigma = np.where(h > 0, 1, np.where(h < 0, -1, sigma))
        want = np.where(want_sigma == 1, 0, P // 2)
        np.testing.assert_array_equal(nph, want)

    def test_binary_phases_stay_binary(self):
        rng = np.random.default_rng(12)
        n, b = 10, 8
        w = rng.integers(-16, 16, size=(n, n)).astype(np.float32)
        sigma = rng.choice([1, -1], size=(b, n))
        ph = jnp.array(np.where(sigma == 1, 0, P // 2).astype(np.int32))
        for _ in range(4):
            ph = ref.onn_period_step_ref(jnp.array(w), ph, P)
        vals = np.unique(np.asarray(ph))
        assert set(vals.tolist()) <= {0, P // 2}

    def test_global_phase_equivariance(self):
        """Rotating every phase by d rotates the update by d."""
        rng = np.random.default_rng(13)
        w, ph = _rand_net(rng, 12, 4)
        base = np.asarray(ref.onn_period_step_ref(w, ph, P))
        for d in [1, 5, 9]:
            rot = jnp.mod(ph + d, P)
            got = np.asarray(ref.onn_period_step_ref(w, rot, P))
            np.testing.assert_array_equal(got, (base + d) % P)

    def test_zero_weights_keep_phase(self):
        """With W=0 every sum ties, the reference equals the oscillator's
        own waveform, and the phase must not move."""
        rng = np.random.default_rng(14)
        n, b = 9, 6
        w = jnp.zeros((n, n), jnp.float32)
        ph = jnp.array(rng.integers(0, P, size=(b, n)).astype(np.int32))
        nph = ref.onn_period_step_ref(w, ph, P)
        np.testing.assert_array_equal(np.asarray(nph), np.asarray(ph))

    def test_ferromagnetic_consensus(self):
        """All-to-all positive coupling snaps scattered phases to the
        weighted-majority phase.  (A 2-oscillator pure-cross pair is the
        degenerate synchronous exchange map and 2-cycles — that behaviour
        is pinned by test_pure_cross_pair_is_exchange_map below.)"""
        n = 3
        w = jnp.array(8.0 * (np.ones((n, n)) - np.eye(n)), jnp.float32)
        ph = jnp.array([[0, 1, 2]], jnp.int32)
        for _ in range(4):
            ph = ref.onn_period_step_ref(w, ph, P)
        vals = np.unique(np.asarray(ph))
        assert len(vals) == 1, f"no consensus: {np.asarray(ph)}"

    def test_antiferromagnetic_follower_locks_out_of_phase(self):
        """Asymmetric coupling: osc0 pinned by self-coupling, osc1 follows
        a negative weight -> locks exactly P/2 away."""
        w = jnp.array([[15.0, 0.0], [-8.0, 0.0]], jnp.float32)
        ph = jnp.array([[3, 7]], jnp.int32)
        for _ in range(3):
            ph = ref.onn_period_step_ref(w, ph, P)
        a, b = int(ph[0, 0]), int(ph[0, 1])
        assert a == 3  # pinned
        assert (b - a) % P == P // 2

    def test_pure_cross_pair_is_exchange_map(self):
        """Documents the known degenerate case: a 2-oscillator network with
        pure cross coupling swaps phases each synchronous period."""
        w = jnp.array([[0.0, 8.0], [8.0, 0.0]], jnp.float32)
        ph0 = jnp.array([[0, 5]], jnp.int32)
        ph1 = ref.onn_period_step_ref(w, ph0, P)
        ph2 = ref.onn_period_step_ref(w, ph1, P)
        np.testing.assert_array_equal(np.asarray(ph1), [[5, 0]])
        np.testing.assert_array_equal(np.asarray(ph2), np.asarray(ph0))

    def test_settled_monotone_and_sticky(self):
        """Fixed points persist: settled is set once and phases freeze."""
        rng = np.random.default_rng(15)
        n, b = 8, 10
        # symmetric ferromagnetic-ish weights converge fast
        a = rng.integers(0, 8, size=(n, n))
        w = jnp.array(((a + a.T) // 2).astype(np.float32))
        sigma = rng.choice([1, -1], size=(b, n))
        ph = jnp.array(np.where(sigma == 1, 0, P // 2).astype(np.int32))
        st0 = jnp.full((b,), -1, jnp.int32)
        ph1, st1 = ref.onn_chunk_ref(w, ph, st0, jnp.int32(0), p=P, chunk=32)
        ph2, st2 = ref.onn_chunk_ref(w, ph1, st1, jnp.int32(32), p=P, chunk=32)
        st1n, st2n = np.asarray(st1), np.asarray(st2)
        # settles found in chunk 1 are unchanged by chunk 2
        mask = st1n >= 0
        np.testing.assert_array_equal(st2n[mask], st1n[mask])
        # settled trials have frozen phases
        np.testing.assert_array_equal(
            np.asarray(ph2)[mask], np.asarray(ph1)[mask]
        )


class TestTemplates:
    def test_templates_shape_and_values(self):
        t = np.asarray(ref.templates(P))
        assert t.shape == (P, P)
        assert set(np.unique(t).tolist()) == {-1.0, 1.0}

    def test_template_autocorrelation_peak(self):
        """Each template correlates maximally (=P) only with itself."""
        t = np.asarray(ref.templates(P))
        g = t @ t.T
        assert np.all(np.diag(g) == P)
        off = g[~np.eye(P, dtype=bool)]
        assert off.max() < P

    def test_square_wave_half_duty(self):
        s = np.asarray(ref.square_wave(jnp.arange(P, dtype=jnp.int32), P))
        np.testing.assert_array_equal(s.sum(axis=-1), np.zeros(P))
