"""Kernel-vs-oracle tests — the CORE correctness signal for L1.

hypothesis sweeps shapes and value ranges of the Pallas coupling matmul
against the pure-jnp oracle; everything must match exactly (integer-valued
f32, see ref.py docstring).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import onn_step, ref

jax.config.update("jax_platform_name", "cpu")


def _int_weights(rng, n, k, lo=-16, hi=15):
    return rng.integers(lo, hi + 1, size=(n, k)).astype(np.float32)


def _signs(rng, k, m):
    return rng.choice([-1.0, 1.0], size=(k, m)).astype(np.float32)


class TestCouplingMatmul:
    def test_identity(self):
        w = np.eye(8, dtype=np.float32)
        s = np.arange(8 * 8, dtype=np.float32).reshape(8, 8)
        out = onn_step.coupling_matmul(jnp.array(w), jnp.array(s))
        np.testing.assert_array_equal(np.asarray(out), s)

    def test_matches_ref_square(self):
        rng = np.random.default_rng(0)
        w, s = _int_weights(rng, 16, 16), _signs(rng, 16, 32)
        got = onn_step.coupling_matmul(jnp.array(w), jnp.array(s))
        want = ref.coupling_matmul_ref(jnp.array(w), jnp.array(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("n", [1, 3, 8, 9, 20, 42, 100, 130])
    def test_matches_ref_ragged_n(self, n):
        """Sizes that do NOT divide the tile exercise the padding path."""
        rng = np.random.default_rng(n)
        m = 48
        w, s = _int_weights(rng, n, n), _signs(rng, n, m)
        got = onn_step.coupling_matmul(jnp.array(w), jnp.array(s))
        want = ref.coupling_matmul_ref(jnp.array(w), jnp.array(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 64),
        m=st.integers(1, 96),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, m, seed):
        rng = np.random.default_rng(seed)
        w, s = _int_weights(rng, n, n), _signs(rng, n, m)
        got = onn_step.coupling_matmul(jnp.array(w), jnp.array(s))
        want = ref.coupling_matmul_ref(jnp.array(w), jnp.array(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=10, deadline=None)
    @given(
        tm=st.sampled_from([8, 16, 32, 128]),
        tk=st.sampled_from([8, 16, 128]),
        seed=st.integers(0, 999),
    )
    def test_hypothesis_tilings(self, tm, tk, seed):
        """All tile choices compute the same integers."""
        rng = np.random.default_rng(seed)
        n, m = 24, 40
        w, s = _int_weights(rng, n, n), _signs(rng, n, m)
        got = onn_step.coupling_matmul(
            jnp.array(w), jnp.array(s), tile_m=tm, tile_n=tm, tile_k=tk
        )
        want = ref.coupling_matmul_ref(jnp.array(w), jnp.array(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_values_are_exact_integers(self):
        rng = np.random.default_rng(7)
        w, s = _int_weights(rng, 50, 50), _signs(rng, 50, 64)
        out = np.asarray(onn_step.coupling_matmul(jnp.array(w), jnp.array(s)))
        np.testing.assert_array_equal(out, np.round(out))
        assert np.abs(out).max() <= 50 * 16

    def test_dtype_f32(self):
        rng = np.random.default_rng(1)
        w, s = _int_weights(rng, 8, 8), _signs(rng, 8, 8)
        out = onn_step.coupling_matmul(jnp.array(w), jnp.array(s))
        assert out.dtype == jnp.float32


class TestPerfModelHelpers:
    def test_vmem_footprint_production_tile_fits(self):
        # 128x128x128 f32 tiles must sit far under the ~16 MiB VMEM budget.
        assert onn_step.vmem_footprint_bytes(128, 128, 128) < 2 * 2**20

    def test_mxu_utilization_bounds(self):
        u = onn_step.mxu_utilization_estimate(506, 128, 128, 128)
        assert 0.0 < u <= 1.0
        # 506 pads to 512: utilization should be high.
        assert u > 0.9

    def test_mxu_utilization_tiny_net_is_low(self):
        assert onn_step.mxu_utilization_estimate(9, 128, 128, 128) < 0.02
