//! Functional (period-level) ONN dynamics — the bit-exact Rust mirror of
//! the JAX model in `python/compile/kernels/ref.py`.
//!
//! Semantics (hybrid-architecture, synchronous — DESIGN.md section 3):
//! per oscillation period, phases are sampled once; each oscillator
//! derives its reference square wave from the sign of the weighted sum of
//! everyone's waveforms over the period, then snaps its phase to the
//! best-correlating template.  Ties break toward the smallest forward
//! rotation from the current phase, which keeps the update equivariant
//! under global phase rotation.
//!
//! All arithmetic is integer (weights i8, sums i32), matching the JAX
//! artifact exactly: there the same values are integer-valued f32s, which
//! are exact for |S| <= N * 16 << 2^24 regardless of reduction order.
//!
//! The weighted sums are computed *incrementally*: a square wave flips
//! twice per period, so `S_i(t)` is updated from `S_i(t-1)` with only the
//! flipping oscillators' columns — O(3 N^2) per period instead of the
//! naive O(N^2 P).  (This is the §Perf L3-native optimization; see
//! EXPERIMENTS.md.)

use crate::onn::config::NetworkConfig;
use crate::onn::phase::{amplitude, wrap};
use crate::onn::sparse::SparseWeights;
use crate::onn::weights::WeightMatrix;
use crate::util::rng::Rng;

/// Stochastic phase-kick model for annealed optimization (see
/// `solver::anneal`): after each synchronous period update, every
/// oscillator independently receives, with probability `amplitude`, a
/// uniform phase kick of up to `ceil(amplitude * P/2)` steps in either
/// direction.  Amplitude 0 restores the deterministic dynamics;
/// amplitude 1 nearly re-randomizes the state each period.  This models
/// the injected phase noise a physical oscillator array would use to
/// escape local minima, and is the hook the annealing schedules drive.
///
/// The kick stream is *counter-indexed*, not sequential: the draw for
/// oscillator `i` at period `tick` is a pure function of
/// `(seed, tick, i)` and never depends on any other oscillator's draws.
/// That makes the stream decomposable under row partitioning — a
/// sharded engine (`runtime::sharded`) reproduces the single-engine
/// kicks exactly by indexing with its global row numbers, which is what
/// keeps the multi-device solve bit-exact with the native one.
#[derive(Debug, Clone)]
pub struct PhaseNoise {
    amplitude: f64,
    seed: u64,
    /// Periods elapsed since this stream was installed (the `tick` half
    /// of the kick-stream index).
    tick: u64,
}

impl PhaseNoise {
    pub fn new(amplitude: f64, seed: u64) -> Self {
        Self {
            amplitude: amplitude.clamp(0.0, 1.0),
            seed,
            tick: 0,
        }
    }

    pub fn amplitude(&self) -> f64 {
        self.amplitude
    }

    /// Periods consumed from this stream so far.  A fresh stream starts
    /// at 0 — the lane-block engines rebuild their `PhaseNoise` on every
    /// (re)programming, which is what guarantees a backfilled lane never
    /// inherits a retired problem's tick counter.
    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// The pure kick function: maybe kick `phi` of oscillator `osc` at
    /// period `tick`.  Identity when the amplitude is zero.  Exposed so
    /// row-sharded engines can replay the exact per-oscillator stream
    /// from `(seed, tick, global row index)`.
    pub fn kick_at(seed: u64, tick: u64, osc: usize, amplitude: f64, phi: i32, p: i32) -> i32 {
        if amplitude <= 0.0 {
            return phi;
        }
        // Two fork steps mix (tick, osc) into an independent stream per
        // kick-site; each draws at most three values.
        let mut rng = Rng::new(seed).fork(tick).fork(osc as u64);
        if rng.f64() >= amplitude {
            return phi;
        }
        let max_kick = ((amplitude * (p / 2) as f64).ceil() as i64).max(1);
        let mag = rng.range_i64(1, max_kick + 1) as i32;
        let kick = if rng.bool() { mag } else { -mag };
        wrap(phi + kick, p)
    }

    /// Maybe kick oscillator `osc` at the current period.
    fn kick(&self, osc: usize, phi: i32, p: i32) -> i32 {
        Self::kick_at(self.seed, self.tick, osc, self.amplitude, phi, p)
    }

    /// Advance to the next period's slice of the kick stream.
    fn end_period(&mut self) {
        self.tick += 1;
    }
}

/// Outcome of running one trial to a fixed point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SettleOutcome {
    pub phases: Vec<i32>,
    /// Period index at which the state first reproduced itself, or None
    /// if `max_periods` elapsed first (e.g. a synchronous 2-cycle).
    pub settled: Option<usize>,
}

/// Weight storage behind the period kernel.  Both variants feed the
/// *same* incremental update — an order-independent integer sum over a
/// column's entries — so a sparse fabric that only visits the stored
/// entries of column `j` produces bit-identical `S_i(t)` (zero entries
/// contribute exactly 0 to an i32 sum).
#[derive(Debug, Clone)]
enum Fabric {
    Dense {
        /// Column-major copy: wt[j * n + i] = W[i][j].
        wt: Vec<i32>,
    },
    /// CSR nonzeros only.  The matrix must be symmetric so row `j`
    /// doubles as column `j` (asserted at construction).
    Sparse(SparseWeights),
}

/// Reusable engine for one (config, weights) pair.
///
/// Dense fabrics hold the transposed weight matrix so the incremental
/// column updates are cache-friendly; sparse fabrics walk CSR rows.
/// Scratch buffers keep the hot loop allocation-free either way.
#[derive(Debug, Clone)]
pub struct FunctionalEngine {
    pub cfg: NetworkConfig,
    fabric: Fabric,
    /// templates[k * P + t] = +-1 square wave of phase k at tick t —
    /// precomputed so the snap loop avoids per-element rem_euclid.
    templates: Vec<i8>,
    // scratch
    sums: Vec<i32>,     // S_i(t) for current t
    refsig: Vec<i8>,    // ref_i(t) flattened [i * P + t]
    flips: Vec<Vec<(usize, i32)>>, // per t: (oscillator, new sign)
    /// Optional annealing noise applied after each period update.
    noise: Option<PhaseNoise>,
}

impl FunctionalEngine {
    pub fn new(cfg: NetworkConfig, w: WeightMatrix) -> Self {
        assert_eq!(cfg.n, w.n, "config/weights size mismatch");
        let n = cfg.n;
        let mut wt = vec![0i32; n * n];
        for i in 0..n {
            for j in 0..n {
                wt[j * n + i] = w.get(i, j) as i32;
            }
        }
        Self::with_fabric(cfg, Fabric::Dense { wt })
    }

    /// Sparse-fabric engine: per-period work scales with the stored
    /// nonzeros instead of n^2.  Requires a symmetric matrix — the
    /// incremental kernel reads *columns*, and symmetry is what lets it
    /// read CSR rows instead.
    pub fn new_sparse(cfg: NetworkConfig, w: SparseWeights) -> Self {
        assert_eq!(cfg.n, w.n(), "config/weights size mismatch");
        assert!(
            w.is_symmetric(),
            "sparse fabric requires a symmetric matrix"
        );
        Self::with_fabric(cfg, Fabric::Sparse(w))
    }

    fn with_fabric(cfg: NetworkConfig, fabric: Fabric) -> Self {
        let n = cfg.n;
        let p = cfg.period();
        let mut templates = vec![0i8; p * p];
        for k in 0..p {
            for t in 0..p {
                templates[k * p + t] = amplitude(k as i32, t as i64, p as i32) as i8;
            }
        }
        Self {
            cfg,
            fabric,
            templates,
            sums: vec![0; n],
            refsig: vec![0; n * p],
            flips: vec![Vec::new(); p],
            noise: None,
        }
    }

    /// True when this engine runs on the CSR fabric.
    pub fn is_sparse(&self) -> bool {
        matches!(self.fabric, Fabric::Sparse(_))
    }

    /// Install (or clear, with `None`) the annealing phase noise.  The
    /// deterministic contract of every other test and the PJRT
    /// cross-validation hold only with noise off.
    pub fn set_noise(&mut self, noise: Option<PhaseNoise>) {
        self.noise = noise;
    }

    /// Current noise amplitude (0 when no noise is installed).
    pub fn noise_amplitude(&self) -> f64 {
        self.noise.as_ref().map_or(0.0, PhaseNoise::amplitude)
    }

    /// Tick of the installed kick stream (0 when no noise is installed).
    /// The tick advances once per period *in batch-walk order*, so a
    /// batch of `b` slots stepped through one chunk of `c` periods gives
    /// slot `s` the ticks `[s * c, (s + 1) * c)` — the per-lane indexing
    /// the packed solve driver relies on being position-independent.
    pub fn noise_tick(&self) -> u64 {
        self.noise.as_ref().map_or(0, PhaseNoise::tick)
    }

    /// One synchronous period update, in place.
    pub fn period_step(&mut self, phases: &mut [i32]) {
        let n = self.cfg.n;
        let p = self.cfg.period() as i32;
        assert_eq!(phases.len(), n);

        // --- 1. initial sums S_i(0) = sum_j W[i][j] * s_j(0)
        self.sums.iter_mut().for_each(|s| *s = 0);
        for j in 0..n {
            let sj = amplitude(phases[j], 0, p);
            match &self.fabric {
                Fabric::Dense { wt, .. } => {
                    let col = &wt[j * n..(j + 1) * n];
                    if sj > 0 {
                        for i in 0..n {
                            self.sums[i] += col[i];
                        }
                    } else {
                        for i in 0..n {
                            self.sums[i] -= col[i];
                        }
                    }
                }
                Fabric::Sparse(sw) => {
                    // Column j == row j (symmetric fabric); only the
                    // stored entries can move an integer sum.
                    let (cols, vals) = sw.row(j);
                    if sj > 0 {
                        for (&i, &v) in cols.iter().zip(vals) {
                            self.sums[i as usize] += v as i32;
                        }
                    } else {
                        for (&i, &v) in cols.iter().zip(vals) {
                            self.sums[i as usize] -= v as i32;
                        }
                    }
                }
            }
        }

        // --- 2. flip schedule: oscillator j flips where (t + phi_j) mod P
        // hits 0 (-> +1) and P/2 (-> -1).
        for f in self.flips.iter_mut() {
            f.clear();
        }
        for (j, &phi) in phases.iter().enumerate() {
            let t_up = wrap(-phi, p) as usize; // becomes +1
            let t_dn = wrap(p / 2 - phi, p) as usize; // becomes -1
            if t_up != 0 {
                self.flips[t_up].push((j, 1));
            }
            if t_dn != 0 {
                self.flips[t_dn].push((j, -1));
            }
        }

        // --- 3. walk the period, recording ref_i(t)
        let pu = p as usize;
        for t in 0..pu {
            if t != 0 {
                // apply flips scheduled at t: s_j jumps by 2*newsign
                // Split borrows: fabric/flips are read, sums is written.
                let (sums, flips) = (&mut self.sums, &self.flips[t]);
                for &(j, news) in flips {
                    match &self.fabric {
                        Fabric::Dense { wt, .. } => {
                            let col = &wt[j * n..(j + 1) * n];
                            if news > 0 {
                                for i in 0..n {
                                    sums[i] += 2 * col[i];
                                }
                            } else {
                                for i in 0..n {
                                    sums[i] -= 2 * col[i];
                                }
                            }
                        }
                        Fabric::Sparse(sw) => {
                            let (cols, vals) = sw.row(j);
                            if news > 0 {
                                for (&i, &v) in cols.iter().zip(vals) {
                                    sums[i as usize] += 2 * v as i32;
                                }
                            } else {
                                for (&i, &v) in cols.iter().zip(vals) {
                                    sums[i as usize] -= 2 * v as i32;
                                }
                            }
                        }
                    }
                }
            }
            for i in 0..n {
                let s = self.sums[i];
                self.refsig[i * pu + t] = if s > 0 {
                    1
                } else if s < 0 {
                    -1
                } else {
                    amplitude(phases[i], t as i64, p) as i8
                };
            }
        }

        // --- 4. snap each phase to the best template
        for i in 0..n {
            phases[i] = snap_phase_with_templates(
                &self.refsig[i * pu..(i + 1) * pu],
                phases[i],
                p,
                &self.templates,
            );
        }

        // --- 5. optional annealing kicks (identity when noise is off)
        if let Some(noise) = self.noise.as_mut() {
            for (i, phi) in phases.iter_mut().enumerate() {
                *phi = noise.kick(i, *phi, p);
            }
            noise.end_period();
        }
    }

    /// Batched chunk with settle tracking — the same contract as the AOT
    /// artifact (`onn_chunk`): `settled[b]` is the absolute period index
    /// of the first fixed point or -1.
    pub fn run_chunk(
        &mut self,
        phases: &mut [i32],
        settled: &mut [i32],
        period0: i32,
        chunk: usize,
    ) {
        let n = self.cfg.n;
        let b = phases.len() / n;
        assert_eq!(phases.len(), b * n);
        assert_eq!(settled.len(), b);
        let mut prev = vec![0i32; n];
        for bi in 0..b {
            let ph = &mut phases[bi * n..(bi + 1) * n];
            for k in 0..chunk {
                prev.copy_from_slice(ph);
                self.period_step(ph);
                if settled[bi] < 0 && ph == &prev[..] {
                    settled[bi] = period0 + k as i32;
                }
            }
        }
    }

    /// Run a single trial until fixed point or `max_periods`.
    pub fn run_to_settle(&mut self, init: &[i32], max_periods: usize) -> SettleOutcome {
        let mut ph = init.to_vec();
        let mut prev = vec![0i32; ph.len()];
        for k in 0..max_periods {
            prev.copy_from_slice(&ph);
            self.period_step(&mut ph);
            if ph == prev {
                return SettleOutcome {
                    phases: ph,
                    settled: Some(k),
                };
            }
        }
        SettleOutcome {
            phases: ph,
            settled: None,
        }
    }
}

/// Snap to the template maximizing correlation with `refsig`, tie-broken
/// toward the smallest forward rotation from `current` (then identity).
/// Exactly mirrors `ref.snap_phase` in the JAX oracle.
pub fn snap_phase(refsig: &[i8], current: i32, p: i32) -> i32 {
    let pu = p as usize;
    let mut templates = vec![0i8; pu * pu];
    for k in 0..pu {
        for t in 0..pu {
            templates[k * pu + t] = amplitude(k as i32, t as i64, p) as i8;
        }
    }
    snap_phase_with_templates(refsig, current, p, &templates)
}

/// Hot-path variant with a precomputed `templates[k * P + t]` table
/// (avoids rem_euclid in the inner correlation loop — §Perf).
fn snap_phase_with_templates(refsig: &[i8], current: i32, p: i32, templates: &[i8]) -> i32 {
    let pu = p as usize;
    debug_assert_eq!(refsig.len(), pu);
    let mut best_key = i32::MIN;
    let mut best_k = 0i32;
    for k in 0..p {
        let row = &templates[k as usize * pu..(k as usize + 1) * pu];
        let mut score = 0i32;
        for (&r, &tmpl) in refsig.iter().zip(row) {
            score += r as i32 * tmpl as i32;
        }
        let rel = wrap(k - current, p);
        let key = score * 2 * p + (p - rel);
        if key > best_key {
            best_key = key;
            best_k = k;
        }
    }
    best_k
}

/// Naive reference implementation of one period step (O(N^2 P)); kept as
/// an in-crate oracle for the incremental engine.
pub fn period_step_naive(cfg: &NetworkConfig, w: &WeightMatrix, phases: &[i32]) -> Vec<i32> {
    let n = cfg.n;
    let p = cfg.period() as i32;
    let pu = cfg.period();
    let mut out = vec![0i32; n];
    for i in 0..n {
        let mut refsig = vec![0i8; pu];
        for (t, r) in refsig.iter_mut().enumerate() {
            let mut s = 0i32;
            for j in 0..n {
                s += w.get(i, j) as i32 * amplitude(phases[j], t as i64, p);
            }
            *r = if s > 0 {
                1
            } else if s < 0 {
                -1
            } else {
                amplitude(phases[i], t as i64, p) as i8
            };
        }
        out[i] = snap_phase(&refsig, phases[i], p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_weights(rng: &mut Rng, n: usize) -> WeightMatrix {
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-16, 16) as i8);
            }
        }
        w
    }

    fn rand_phases(rng: &mut Rng, n: usize, p: i32) -> Vec<i32> {
        (0..n).map(|_| rng.range_i64(0, p as i64) as i32).collect()
    }

    #[test]
    fn incremental_matches_naive() {
        let mut rng = Rng::new(21);
        for n in [1, 2, 5, 9, 20, 33] {
            let cfg = NetworkConfig::paper(n);
            let w = rand_weights(&mut rng, n);
            let mut eng = FunctionalEngine::new(cfg, w.clone());
            for _ in 0..5 {
                let ph0 = rand_phases(&mut rng, n, 16);
                let want = period_step_naive(&cfg, &w, &ph0);
                let mut got = ph0.clone();
                eng.period_step(&mut got);
                assert_eq!(got, want, "n={n} ph0={ph0:?}");
            }
        }
    }

    #[test]
    fn zero_weights_freeze() {
        let cfg = NetworkConfig::paper(7);
        let mut eng = FunctionalEngine::new(cfg, WeightMatrix::zeros(7));
        let mut rng = Rng::new(3);
        let ph0 = rand_phases(&mut rng, 7, 16);
        let mut ph = ph0.clone();
        eng.period_step(&mut ph);
        assert_eq!(ph, ph0);
    }

    #[test]
    fn rotation_equivariance() {
        let mut rng = Rng::new(4);
        let cfg = NetworkConfig::paper(11);
        let w = rand_weights(&mut rng, 11);
        let mut eng = FunctionalEngine::new(cfg, w);
        let ph0 = rand_phases(&mut rng, 11, 16);
        let mut base = ph0.clone();
        eng.period_step(&mut base);
        for d in [1, 7, 15] {
            let mut rot: Vec<i32> = ph0.iter().map(|&x| wrap(x + d, 16)).collect();
            eng.period_step(&mut rot);
            let want: Vec<i32> = base.iter().map(|&x| wrap(x + d, 16)).collect();
            assert_eq!(rot, want, "d={d}");
        }
    }

    #[test]
    fn hopfield_equivalence_on_binary_states() {
        // At phases {0, P/2} the step is a synchronous Hopfield update.
        let mut rng = Rng::new(5);
        let n = 13;
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let mut eng = FunctionalEngine::new(cfg, w.clone());
        for _ in 0..20 {
            let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            let mut ph: Vec<i32> = spins
                .iter()
                .map(|&s| if s > 0 { 0 } else { 8 })
                .collect();
            eng.period_step(&mut ph);
            for i in 0..n {
                let h: i32 = (0..n).map(|j| w.get(i, j) as i32 * spins[j] as i32).sum();
                let want = if h > 0 {
                    0
                } else if h < 0 {
                    8
                } else if spins[i] > 0 {
                    0
                } else {
                    8
                };
                assert_eq!(ph[i], want, "i={i} h={h}");
            }
        }
    }

    #[test]
    fn run_to_settle_fixed_point_detected() {
        // A stored pattern (strongly ferro diag) settles immediately.
        let n = 6;
        let cfg = NetworkConfig::paper(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            w.set(i, i, 15);
        }
        let mut eng = FunctionalEngine::new(cfg, w);
        let out = eng.run_to_settle(&[0, 8, 0, 8, 3, 12], 10);
        assert_eq!(out.settled, Some(0));
        assert_eq!(out.phases, vec![0, 8, 0, 8, 3, 12]);
    }

    #[test]
    fn run_to_settle_two_cycle_times_out() {
        // Pure cross pair: synchronous exchange map never settles.
        let cfg = NetworkConfig::paper(2);
        let mut w = WeightMatrix::zeros(2);
        w.set(0, 1, 8);
        w.set(1, 0, 8);
        let mut eng = FunctionalEngine::new(cfg, w);
        let out = eng.run_to_settle(&[0, 5], 20);
        assert_eq!(out.settled, None);
    }

    #[test]
    fn run_chunk_matches_run_to_settle() {
        let mut rng = Rng::new(6);
        let n = 10;
        let cfg = NetworkConfig::paper(n);
        let w = {
            // symmetric-ish weights converge
            let mut w = WeightMatrix::zeros(n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.range_i64(-8, 9) as i8;
                    w.set(i, j, v);
                    w.set(j, i, v);
                }
            }
            w
        };
        let b = 8;
        let mut eng = FunctionalEngine::new(cfg, w);
        let mut phases = Vec::new();
        let mut inits = Vec::new();
        for _ in 0..b {
            let ph = rand_phases(&mut rng, n, 16);
            inits.push(ph.clone());
            phases.extend(ph);
        }
        let mut settled = vec![-1i32; b];
        eng.run_chunk(&mut phases, &mut settled, 0, 64);
        for bi in 0..b {
            let solo = eng.run_to_settle(&inits[bi], 64);
            match solo.settled {
                Some(k) => {
                    assert_eq!(settled[bi], k as i32, "trial {bi}");
                    assert_eq!(&phases[bi * n..(bi + 1) * n], &solo.phases[..]);
                }
                None => assert_eq!(settled[bi], -1),
            }
        }
    }

    #[test]
    fn zero_amplitude_noise_is_identity() {
        let mut rng = Rng::new(71);
        let n = 9;
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let mut plain = FunctionalEngine::new(cfg, w.clone());
        let mut noisy = FunctionalEngine::new(cfg, w);
        noisy.set_noise(Some(PhaseNoise::new(0.0, 5)));
        let ph0 = rand_phases(&mut rng, n, 16);
        let (mut a, mut b) = (ph0.clone(), ph0);
        for _ in 0..4 {
            plain.period_step(&mut a);
            noisy.period_step(&mut b);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn full_noise_keeps_phases_in_range() {
        let mut rng = Rng::new(72);
        let n = 7;
        let cfg = NetworkConfig::paper(n);
        let mut eng = FunctionalEngine::new(cfg, rand_weights(&mut rng, n));
        eng.set_noise(Some(PhaseNoise::new(1.0, 9)));
        assert!((eng.noise_amplitude() - 1.0).abs() < 1e-12);
        let mut ph = rand_phases(&mut rng, n, 16);
        for _ in 0..16 {
            eng.period_step(&mut ph);
            assert!(ph.iter().all(|&x| (0..16).contains(&x)), "{ph:?}");
        }
    }

    #[test]
    fn noise_tick_advances_in_batch_walk_order() {
        // The tick index the lane-block engines depend on: one step per
        // period in batch-walk order, restarted by every reinstall.
        let cfg = NetworkConfig::paper(4);
        let mut eng = FunctionalEngine::new(cfg, WeightMatrix::zeros(4));
        assert_eq!(eng.noise_tick(), 0, "no stream installed");
        eng.set_noise(Some(PhaseNoise::new(0.5, 3)));
        assert_eq!(eng.noise_tick(), 0, "fresh stream");
        let mut phases = vec![0i32; 3 * 4];
        let mut settled = vec![-1i32; 3];
        eng.run_chunk(&mut phases, &mut settled, 0, 5);
        assert_eq!(eng.noise_tick(), 15, "3 slots x 5 periods");
        eng.set_noise(Some(PhaseNoise::new(0.5, 3)));
        assert_eq!(eng.noise_tick(), 0, "reinstall restarts the stream");
    }

    fn rand_symmetric_sparse(rng: &mut Rng, n: usize, density: f64) -> WeightMatrix {
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..i {
                if rng.f64() < density {
                    let v = rng.range_i64(-16, 16) as i8;
                    w.set(i, j, v);
                    w.set(j, i, v);
                }
            }
        }
        w
    }

    #[test]
    fn sparse_fabric_matches_dense_every_period() {
        let mut rng = Rng::new(91);
        for n in [1, 2, 7, 19, 40] {
            for density in [0.0, 0.05, 0.3, 1.0] {
                let cfg = NetworkConfig::paper(n);
                let w = rand_symmetric_sparse(&mut rng, n, density);
                let sw = crate::onn::sparse::SparseWeights::from_dense(&w);
                let mut dense = FunctionalEngine::new(cfg, w);
                let mut sparse = FunctionalEngine::new_sparse(cfg, sw);
                assert!(sparse.is_sparse() && !dense.is_sparse());
                let ph0 = rand_phases(&mut rng, n, 16);
                let (mut a, mut b) = (ph0.clone(), ph0);
                for step in 0..6 {
                    dense.period_step(&mut a);
                    sparse.period_step(&mut b);
                    assert_eq!(a, b, "n={n} density={density} step={step}");
                }
            }
        }
    }

    #[test]
    fn sparse_fabric_matches_dense_under_noise() {
        let mut rng = Rng::new(92);
        let n = 17;
        let cfg = NetworkConfig::paper(n);
        let w = rand_symmetric_sparse(&mut rng, n, 0.2);
        let sw = crate::onn::sparse::SparseWeights::from_dense(&w);
        let mut dense = FunctionalEngine::new(cfg, w);
        let mut sparse = FunctionalEngine::new_sparse(cfg, sw);
        let seed = rng.next_u64();
        dense.set_noise(Some(PhaseNoise::new(0.7, seed)));
        sparse.set_noise(Some(PhaseNoise::new(0.7, seed)));
        let ph0 = rand_phases(&mut rng, n, 16);
        let (mut a, mut b) = (ph0.clone(), ph0);
        for step in 0..12 {
            dense.period_step(&mut a);
            sparse.period_step(&mut b);
            assert_eq!(a, b, "step={step}");
        }
        assert_eq!(dense.noise_tick(), sparse.noise_tick());
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn sparse_fabric_rejects_asymmetry() {
        let sw = crate::onn::sparse::SparseWeights::from_triplets(3, &[(0, 1, 4)]).unwrap();
        let _ = FunctionalEngine::new_sparse(NetworkConfig::paper(3), sw);
    }

    #[test]
    fn settled_trials_have_frozen_phases() {
        let mut rng = Rng::new(61);
        let n = 8;
        let cfg = NetworkConfig::paper(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.range_i64(0, 6) as i8;
                w.set(i, j, v);
                w.set(j, i, v);
            }
        }
        let mut eng = FunctionalEngine::new(cfg, w);
        let out = eng.run_to_settle(&rand_phases(&mut rng, n, 16), 128);
        if let Some(_) = out.settled {
            let mut again = out.phases.clone();
            eng.period_step(&mut again);
            assert_eq!(again, out.phases);
        }
    }
}
