//! Domain core: quantized ONN state, learning rules, pattern datasets,
//! and the functional (period-level) dynamics engine.

pub mod config;
pub mod dynamics;
pub mod energy;
pub mod learning;
pub mod patterns;
pub mod phase;
pub mod sparse;
pub mod weights;
