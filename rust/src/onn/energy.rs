//! Ising / phase-domain energy bookkeeping.
//!
//! ONNs are energy-minimizing networks: Eq. (1) of the paper is the Ising
//! Hamiltonian `H = -sum_ij J_ij s_i s_j - mu sum_i h_i s_i`.  For phase
//! states, square waveforms give the pairwise interaction
//! `C_ij = (1/P) sum_t s_i(t) s_j(t) = 1 - 4 d(phi_i, phi_j)/P`
//! (a triangular function of the circular phase distance), so the
//! phase-domain energy generalizes the binary Hamiltonian and coincides
//! with it at phases {0, P/2}.

use crate::onn::phase::distance;
use crate::onn::weights::WeightMatrix;

/// Binary Ising energy `H = -1/2 sum_{i != j} W_ij s_i s_j` (the 1/2
/// undoes double counting of symmetric pairs; self-coupling contributes a
/// state-independent constant and is excluded).
pub fn ising_energy(w: &WeightMatrix, spins: &[i8]) -> f64 {
    let n = w.n;
    assert_eq!(spins.len(), n);
    let mut e = 0f64;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                e -= 0.5 * w.get(i, j) as f64 * spins[i] as f64 * spins[j] as f64;
            }
        }
    }
    e
}

/// Ising energy with external fields: `H = -1/2 sum W s s - sum h s`.
pub fn ising_energy_with_field(w: &WeightMatrix, h: &[f64], spins: &[i8]) -> f64 {
    let base = ising_energy(w, spins);
    let field: f64 = h
        .iter()
        .zip(spins)
        .map(|(&hi, &s)| hi * s as f64)
        .sum();
    base - field
}

/// Square-waveform correlation of two phases: `1 - 4 d / P` in [-1, 1].
pub fn waveform_correlation(phi_i: i32, phi_j: i32, p: i32) -> f64 {
    1.0 - 4.0 * distance(phi_i, phi_j, p) as f64 / p as f64
}

/// Phase-domain energy `-1/2 sum_{i != j} W_ij C(phi_i, phi_j)`.
pub fn phase_energy(w: &WeightMatrix, phases: &[i32], p: i32) -> f64 {
    let n = w.n;
    assert_eq!(phases.len(), n);
    let mut e = 0f64;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                e -= 0.5 * w.get(i, j) as f64 * waveform_correlation(phases[i], phases[j], p);
            }
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::config::NetworkConfig;
    use crate::onn::dynamics::FunctionalEngine;
    use crate::onn::phase::spin_to_phase;
    use crate::util::rng::Rng;

    #[test]
    fn ising_energy_ferro_pair() {
        let mut w = WeightMatrix::zeros(2);
        w.set(0, 1, 4);
        w.set(1, 0, 4);
        assert_eq!(ising_energy(&w, &[1, 1]), -4.0);
        assert_eq!(ising_energy(&w, &[1, -1]), 4.0);
    }

    #[test]
    fn field_term() {
        let w = WeightMatrix::zeros(2);
        let e = ising_energy_with_field(&w, &[1.0, -2.0], &[1, 1]);
        assert_eq!(e, 1.0); // -(1*1 + -2*1) = 1
    }

    #[test]
    fn waveform_correlation_extremes() {
        assert_eq!(waveform_correlation(0, 0, 16), 1.0);
        assert_eq!(waveform_correlation(0, 8, 16), -1.0);
        assert_eq!(waveform_correlation(0, 4, 16), 0.0);
    }

    #[test]
    fn phase_energy_matches_ising_on_binary_states() {
        let mut rng = Rng::new(40);
        let n = 9;
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-5, 6) as i8);
            }
        }
        let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        let phases: Vec<i32> = spins.iter().map(|&s| spin_to_phase(s, 16)).collect();
        let ei = ising_energy(&w, &spins);
        let ep = phase_energy(&w, &phases, 16);
        assert!((ei - ep).abs() < 1e-9, "{ei} vs {ep}");
    }

    #[test]
    fn settling_runs_end_at_or_below_initial_energy() {
        // Synchronous updates are not monotone step-by-step (the sync
        // Lyapunov function couples consecutive states), but a run that
        // settles must end at an energy no higher than where it started —
        // the property the max-cut solver relies on.
        let mut rng = Rng::new(41);
        let n = 12;
        let cfg = NetworkConfig::paper(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..i {
                let v = rng.range_i64(-6, 7) as i8;
                w.set(i, j, v);
                w.set(j, i, v);
            }
        }
        let mut eng = FunctionalEngine::new(cfg, w.clone());
        for trial in 0..20 {
            let ph0: Vec<i32> = (0..n).map(|_| spin_to_phase(rng.spin(), 16)).collect();
            let e0 = phase_energy(&w, &ph0, 16);
            let out = eng.run_to_settle(&ph0, 100);
            if out.settled.is_some() {
                let e1 = phase_energy(&w, &out.phases, 16);
                assert!(e1 <= e0 + 1e-9, "trial {trial}: {e0} -> {e1}");
            }
        }
    }
}
