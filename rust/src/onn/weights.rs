//! Coupling-weight matrix with the paper's 5-bit signed quantization.
//!
//! `W[i][j]` is the coupling strength *from oscillator j to oscillator i*
//! (Eq. 2 of the paper).  The architectures allow asymmetric coupling, so
//! all N^2 entries are stored (Table 1: memory cells cannot drop below
//! N^2).  Quantized weights are `i8` in the configured two's-complement
//! range; the f32 view handed to the PJRT engine is integer-valued.

use crate::onn::config::NetworkConfig;

#[derive(Debug, Clone, PartialEq)]
pub struct WeightMatrix {
    pub n: usize,
    w: Vec<i8>, // row-major: w[i * n + j]
}

impl WeightMatrix {
    pub fn zeros(n: usize) -> Self {
        Self { n, w: vec![0; n * n] }
    }

    pub fn from_rows(rows: &[Vec<i8>]) -> Self {
        let n = rows.len();
        assert!(rows.iter().all(|r| r.len() == n), "non-square weights");
        let mut w = Vec::with_capacity(n * n);
        for r in rows {
            w.extend_from_slice(r);
        }
        Self { n, w }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        self.w[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: i8) {
        self.w[i * self.n + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        &self.w[i * self.n..(i + 1) * self.n]
    }

    pub fn as_slice(&self) -> &[i8] {
        &self.w
    }

    /// Integer-valued f32 copy in the layout the AOT artifact expects.
    pub fn to_f32(&self) -> Vec<f32> {
        self.w.iter().map(|&x| x as f32).collect()
    }

    /// Quantize a float matrix to the configured signed range, scaling so
    /// the largest magnitude maps to the positive limit (the symmetric
    /// scheme used when programming the FPGA weight memories).
    pub fn quantize(master: &[f32], n: usize, cfg: &NetworkConfig) -> Self {
        Self::quantize_with_error(master, n, cfg).0
    }

    /// [`Self::quantize`] plus the rounding loss it introduced: the RMS
    /// deviation between the scaled master and the quantized entries, as
    /// a fraction of the positive quantization limit (so 0 means the
    /// couplings were representable exactly; pure rounding is bounded by
    /// `0.5 / hi`).  The solver reports this per solve — the precision
    /// cost of running on the bit-true hardware fabric.
    pub fn quantize_with_error(master: &[f32], n: usize, cfg: &NetworkConfig) -> (Self, f64) {
        assert_eq!(master.len(), n * n);
        let (lo, hi) = cfg.weight_range();
        let max_abs = master.iter().fold(0f32, |m, x| m.max(x.abs()));
        let scale = if max_abs > 0.0 {
            hi as f32 / max_abs
        } else {
            0.0
        };
        let mut sq = 0f64;
        let w: Vec<i8> = master
            .iter()
            .map(|&x| {
                let q = ((x * scale).round() as i32).clamp(lo, hi);
                let err = q as f64 - (x * scale) as f64;
                sq += err * err;
                q as i8
            })
            .collect();
        let rms = if n > 0 && hi > 0 {
            (sq / (n * n) as f64).sqrt() / hi as f64
        } else {
            0.0
        };
        (Self { n, w }, rms)
    }

    /// Reprogram this matrix in place from an updated float master,
    /// returning `(changed_entries, rms_error)`.
    ///
    /// The symmetric quantization scale is *global* (`hi / max|master|`),
    /// so a single store/forget can legally move every entry — per-entry
    /// incremental deltas are unsound whenever `max|master|` shifts.  The
    /// delta path therefore requantizes from the full master and reports
    /// which entries actually changed: `changed_entries` is the exact
    /// write set a hardware weight-memory reprogram would issue (and what
    /// the associative metrics surface as `delta_entries`), while the
    /// resulting matrix is bit-identical to `quantize(master)` by
    /// construction — the delta-vs-cold-rebuild identity the property
    /// tests pin down.
    pub fn apply_delta(&mut self, master: &[f32], cfg: &NetworkConfig) -> (usize, f64) {
        let (fresh, rms) = Self::quantize_with_error(master, self.n, cfg);
        let changed = self
            .w
            .iter()
            .zip(&fresh.w)
            .filter(|(old, new)| old != new)
            .count();
        self.w = fresh.w;
        (changed, rms)
    }

    /// True when W[i][j] == W[j][i] for all pairs.
    pub fn is_symmetric(&self) -> bool {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.get(i, j) != self.get(j, i) {
                    return false;
                }
            }
        }
        true
    }

    /// Largest |W| entry (used by resource models for width checks).
    pub fn max_abs(&self) -> i32 {
        self.w.iter().map(|&x| (x as i32).abs()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize) -> NetworkConfig {
        NetworkConfig::paper(n)
    }

    #[test]
    fn index_layout() {
        let mut w = WeightMatrix::zeros(3);
        w.set(1, 2, 7);
        assert_eq!(w.get(1, 2), 7);
        assert_eq!(w.get(2, 1), 0);
        assert_eq!(w.row(1), &[0, 0, 7]);
    }

    #[test]
    fn quantize_maps_extremes() {
        let master = vec![0.0, 1.0, -1.0, 0.5];
        let w = WeightMatrix::quantize(&master, 2, &cfg(2));
        assert_eq!(w.get(0, 1), 15); // +max -> +15
        assert_eq!(w.get(1, 0), -15); // -max -> -15 (symmetric scale)
        assert_eq!(w.get(1, 1), 8); // 0.5 -> round(7.5) = 8
        assert_eq!(w.get(0, 0), 0);
    }

    #[test]
    fn quantize_with_error_reports_rounding_loss() {
        let (w, err) = WeightMatrix::quantize_with_error(&[0.0, 1.0, -1.0, 0.5], 2, &cfg(2));
        assert_eq!(w.get(1, 1), 8);
        // Only 0.5 rounds (7.5 -> 8): RMS = sqrt(0.25 / 4) over 15.
        let want = (0.25f64 / 4.0).sqrt() / 15.0;
        assert!((err - want).abs() < 1e-9, "err = {err}, want {want}");
        // Exactly representable matrices report zero loss.
        let (_, exact) = WeightMatrix::quantize_with_error(&[0.0, 1.0, -1.0, 0.0], 2, &cfg(2));
        assert_eq!(exact, 0.0);
        let (_, zeros) = WeightMatrix::quantize_with_error(&[0.0; 4], 2, &cfg(2));
        assert_eq!(zeros, 0.0);
    }

    #[test]
    fn quantize_zero_matrix() {
        let w = WeightMatrix::quantize(&[0.0; 4], 2, &cfg(2));
        assert_eq!(w.as_slice(), &[0, 0, 0, 0]);
    }

    #[test]
    fn quantize_respects_range() {
        let mut c = cfg(2);
        c.weight_bits = 3; // [-4, 3]
        let w = WeightMatrix::quantize(&[3.0, -3.0, 1.0, 0.2], 2, &c);
        assert!(w.as_slice().iter().all(|&x| (-4..=3).contains(&(x as i32))));
        assert_eq!(w.get(0, 0), 3);
        assert_eq!(w.get(0, 1), -3);
    }

    #[test]
    fn apply_delta_matches_cold_quantize_and_counts_writes() {
        let c = cfg(2);
        let mut w = WeightMatrix::quantize(&[0.0, 1.0, -1.0, 0.5], 2, &c);
        // New master rescales everything: the global scale halves, so the
        // delta write set covers every nonzero entry.
        let master = vec![0.0, 2.0, -1.0, 0.5];
        let (changed, rms) = w.apply_delta(&master, &c);
        let (cold, cold_rms) = WeightMatrix::quantize_with_error(&master, 2, &c);
        assert_eq!(w, cold, "delta reprogram != cold quantize");
        assert_eq!(rms, cold_rms);
        assert_eq!(changed, 2); // -15 -> -8 and 8 -> 4; the new max stays 15
        // Reapplying the same master is a zero-entry write.
        let (again, _) = w.apply_delta(&master, &c);
        assert_eq!(again, 0);
    }

    #[test]
    fn symmetry_check() {
        let w = WeightMatrix::from_rows(&[vec![0, 1], vec![1, 0]]);
        assert!(w.is_symmetric());
        let w2 = WeightMatrix::from_rows(&[vec![0, 1], vec![2, 0]]);
        assert!(!w2.is_symmetric());
    }

    #[test]
    fn f32_view_is_integer_valued() {
        let w = WeightMatrix::from_rows(&[vec![-16, 15], vec![3, 0]]);
        let f = w.to_f32();
        assert_eq!(f, vec![-16.0, 15.0, 3.0, 0.0]);
    }
}
