//! Letter-pattern datasets, corruption, and retrieval scoring.
//!
//! The paper benchmarks five datasets of pixel patterns: 3x3 (2 patterns)
//! and 5x4, 7x6, 10x10, 22x22 (5 letter patterns each).  Pixels map to
//! spins (+1 = black, -1 = white) and spins to oscillator phases
//! (0 / 180 degrees).  Corruption flips a given percentage of randomly
//! chosen pixels; the two larger sizes are nearest-neighbour upscales of
//! the 7x6 glyphs, mirroring how such demo datasets are produced.

use crate::util::rng::Rng;

/// One stored pattern: a named spin image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pattern {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub spins: Vec<i8>, // row-major, +1/-1
}

impl Pattern {
    pub fn from_art(name: &str, art: &[&str]) -> Self {
        let rows = art.len();
        let cols = art[0].len();
        assert!(art.iter().all(|r| r.len() == cols), "ragged art: {name}");
        let spins = art
            .iter()
            .flat_map(|r| r.bytes().map(|b| if b == b'#' { 1i8 } else { -1i8 }))
            .collect();
        Self {
            name: name.to_string(),
            rows,
            cols,
            spins,
        }
    }

    pub fn len(&self) -> usize {
        self.spins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spins.is_empty()
    }

    /// Nearest-neighbour resample to a new grid.
    pub fn upscale(&self, rows: usize, cols: usize) -> Pattern {
        let mut spins = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let sr = r * self.rows / rows;
            for c in 0..cols {
                let sc = c * self.cols / cols;
                spins.push(self.spins[sr * self.cols + sc]);
            }
        }
        Pattern {
            name: self.name.clone(),
            rows,
            cols,
            spins,
        }
    }

    /// Flip `count` distinct random pixels.
    pub fn corrupt(&self, count: usize, rng: &mut Rng) -> Pattern {
        let mut out = self.clone();
        for idx in rng.choose_distinct(self.len(), count) {
            out.spins[idx] = -out.spins[idx];
        }
        out.name = format!("{}~{}", self.name, count);
        out
    }

    /// Number of pixels the paper flips for a percentage level, following
    /// its example ("corrupting a 10x10 pattern by 10% means flipping the
    /// color on 10 pixels"): round-half-up of pct * npixels.
    pub fn corruption_count(&self, pct: f64) -> usize {
        ((self.len() as f64 * pct / 100.0) + 0.5).floor() as usize
    }

    /// Hamming overlap in [−1, 1]: fraction of matching pixels scaled.
    pub fn overlap(&self, other: &[i8]) -> f64 {
        assert_eq!(self.len(), other.len());
        let dot: i32 = self
            .spins
            .iter()
            .zip(other)
            .map(|(&a, &b)| a as i32 * b as i32)
            .sum();
        dot as f64 / self.len() as f64
    }

    /// Exact match up to the global Z2 inversion symmetry of the Ising
    /// energy (the paper reads phases out *relative to each other*).
    pub fn matches_up_to_inversion(&self, other: &[i8]) -> bool {
        let o = self.overlap(other);
        o == 1.0 || o == -1.0
    }

    /// Render as ASCII art (for Figure-8-style output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                s.push(if self.spins[r * self.cols + c] > 0 {
                    '#'
                } else {
                    '.'
                });
            }
            s.push('\n');
        }
        s
    }
}

/// [`Pattern::matches_up_to_inversion`] for raw spin slices: exact
/// equality up to the global Z2 inversion of the Ising energy.  The
/// associative-memory path compares settled recall states (and detects
/// duplicate stores — an inverted pattern's outer product is identical,
/// so it must count as the same memory) without wrapping slices in
/// [`Pattern`]s.
pub fn spins_match_up_to_inversion(a: &[i8], b: &[i8]) -> bool {
    a.len() == b.len()
        && !a.is_empty()
        && (a.iter().zip(b).all(|(&x, &y)| x == y) || a.iter().zip(b).all(|(&x, &y)| x == -y))
}

/// A benchmark dataset: all patterns share one grid size.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub patterns: Vec<Pattern>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.rows * self.cols
    }
}

const GLYPH_7X6: &[(&str, [&str; 7])] = &[
    (
        "A",
        [
            "..##..", ".#..#.", "#....#", "#....#", "######", "#....#", "#....#",
        ],
    ),
    (
        "C",
        [
            ".####.", "#....#", "#.....", "#.....", "#.....", "#....#", ".####.",
        ],
    ),
    (
        "H",
        [
            "#....#", "#....#", "#....#", "######", "#....#", "#....#", "#....#",
        ],
    ),
    (
        "T",
        [
            "######", "..##..", "..##..", "..##..", "..##..", "..##..", "..##..",
        ],
    ),
    (
        "Z",
        [
            "######", "....#.", "...#..", "..#...", ".#....", "#.....", "######",
        ],
    ),
];

const GLYPH_5X4: &[(&str, [&str; 5])] = &[
    ("A", [".##.", "#..#", "####", "#..#", "#..#"]),
    ("C", [".###", "#...", "#...", "#...", ".###"]),
    ("T", ["####", ".#..", ".#..", ".#..", ".#.."]),
    ("X", ["#..#", "#..#", ".##.", "#..#", "#..#"]),
    ("Z", ["####", "..#.", ".#..", "#...", "####"]),
];

/// The five benchmark datasets of the paper (section 4.3).
pub fn paper_datasets() -> Vec<Dataset> {
    vec![
        dataset_3x3(),
        dataset_from_glyphs("5x4", 5, 4, GLYPH_5X4.iter().map(|(n, a)| (*n, &a[..]))),
        dataset_from_glyphs("7x6", 7, 6, GLYPH_7X6.iter().map(|(n, a)| (*n, &a[..]))),
        upscaled_dataset("10x10", 10, 10),
        upscaled_dataset("22x22", 22, 22),
    ]
}

pub fn dataset_by_name(name: &str) -> Option<Dataset> {
    paper_datasets().into_iter().find(|d| d.name == name)
}

/// 3x3 dataset: two letter patterns ("T", "L") — the paper's 3x3 set
/// also stores just two patterns.  (A plus/cross pair would be almost
/// perfectly anti-correlated: with zero self-coupling no weight matrix
/// can store both, since the second is the first's inverse everywhere
/// except the center pixel.)
pub fn dataset_3x3() -> Dataset {
    Dataset {
        name: "3x3".to_string(),
        rows: 3,
        cols: 3,
        patterns: vec![
            Pattern::from_art("T", &["###", ".#.", ".#."]),
            Pattern::from_art("L", &["#..", "#..", "###"]),
        ],
    }
}

fn dataset_from_glyphs<'a>(
    name: &str,
    rows: usize,
    cols: usize,
    glyphs: impl Iterator<Item = (&'a str, &'a [&'a str])>,
) -> Dataset {
    let patterns = glyphs
        .map(|(n, art)| {
            let p = Pattern::from_art(n, art);
            assert_eq!((p.rows, p.cols), (rows, cols));
            p
        })
        .collect();
    Dataset {
        name: name.to_string(),
        rows,
        cols,
        patterns,
    }
}

fn upscaled_dataset(name: &str, rows: usize, cols: usize) -> Dataset {
    let base = dataset_from_glyphs("7x6", 7, 6, GLYPH_7X6.iter().map(|(n, a)| (*n, &a[..])));
    Dataset {
        name: name.to_string(),
        rows,
        cols,
        patterns: base.patterns.iter().map(|p| p.upscale(rows, cols)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dataset_inventory() {
        let ds = paper_datasets();
        let sizes: Vec<(usize, usize, usize)> = ds
            .iter()
            .map(|d| (d.rows, d.cols, d.patterns.len()))
            .collect();
        assert_eq!(
            sizes,
            vec![(3, 3, 2), (5, 4, 5), (7, 6, 5), (10, 10, 5), (22, 22, 5)]
        );
        // network sizes used for the artifacts
        let ns: Vec<usize> = ds.iter().map(|d| d.n()).collect();
        assert_eq!(ns, vec![9, 20, 42, 100, 484]);
    }

    #[test]
    fn patterns_distinct_within_dataset() {
        for d in paper_datasets() {
            for i in 0..d.patterns.len() {
                for j in (i + 1)..d.patterns.len() {
                    let o = d.patterns[i].overlap(&d.patterns[j].spins);
                    assert!(
                        o.abs() < 1.0,
                        "{}: {} == {} (overlap {o})",
                        d.name,
                        d.patterns[i].name,
                        d.patterns[j].name
                    );
                }
            }
        }
    }

    #[test]
    fn from_art_roundtrip() {
        let p = Pattern::from_art("t", &["#.", ".#"]);
        assert_eq!(p.spins, vec![1, -1, -1, 1]);
        assert_eq!(p.render(), "#.\n.#\n");
    }

    #[test]
    fn corrupt_flips_exact_count() {
        let mut rng = Rng::new(1);
        let d = dataset_by_name("7x6").unwrap();
        let p = &d.patterns[0];
        for count in [0, 1, 4, 10, 21] {
            let c = p.corrupt(count, &mut rng);
            let diff: usize = p
                .spins
                .iter()
                .zip(&c.spins)
                .filter(|(a, b)| a != b)
                .count();
            assert_eq!(diff, count);
        }
    }

    #[test]
    fn corruption_count_matches_paper_example() {
        let d = dataset_by_name("10x10").unwrap();
        let p = &d.patterns[0];
        assert_eq!(p.corruption_count(10.0), 10);
        assert_eq!(p.corruption_count(25.0), 25);
        assert_eq!(p.corruption_count(50.0), 50);
        // 3x3: 10% of 9 = 0.9 -> 1 pixel
        let d3 = dataset_3x3();
        assert_eq!(d3.patterns[0].corruption_count(10.0), 1);
        assert_eq!(d3.patterns[0].corruption_count(25.0), 2);
        assert_eq!(d3.patterns[0].corruption_count(50.0), 5);
    }

    #[test]
    fn upscale_preserves_shape() {
        let d = dataset_by_name("22x22").unwrap();
        for p in &d.patterns {
            assert_eq!(p.len(), 484);
            // Upscaled glyph keeps roughly the same ink fraction as base.
            let base = dataset_by_name("7x6")
                .unwrap()
                .patterns
                .iter()
                .find(|b| b.name == p.name)
                .unwrap()
                .clone();
            let ink_base = base.spins.iter().filter(|&&s| s > 0).count() as f64 / 42.0;
            let ink_up = p.spins.iter().filter(|&&s| s > 0).count() as f64 / 484.0;
            assert!((ink_base - ink_up).abs() < 0.15, "{}", p.name);
        }
    }

    #[test]
    fn overlap_and_inversion_match() {
        let p = Pattern::from_art("t", &["##", ".."]);
        let inv: Vec<i8> = p.spins.iter().map(|&x| -x).collect();
        assert_eq!(p.overlap(&p.spins), 1.0);
        assert_eq!(p.overlap(&inv), -1.0);
        assert!(p.matches_up_to_inversion(&inv));
        let near = vec![1i8, 1, -1, 1];
        assert!(!p.matches_up_to_inversion(&near));
    }

    #[test]
    fn spins_match_helper_agrees_with_pattern_method() {
        let p = Pattern::from_art("t", &["##", ".."]);
        let inv: Vec<i8> = p.spins.iter().map(|&x| -x).collect();
        assert!(spins_match_up_to_inversion(&p.spins, &p.spins));
        assert!(spins_match_up_to_inversion(&p.spins, &inv));
        assert!(!spins_match_up_to_inversion(&p.spins, &[1, 1, -1, 1]));
        assert!(!spins_match_up_to_inversion(&p.spins, &[1, 1, -1]), "length mismatch");
        assert!(!spins_match_up_to_inversion(&[], &[]), "empty never matches");
    }
}
