//! Quantized phase arithmetic and the square-wave oscillator waveform.
//!
//! A phase is an integer in `[0, P)` where `P = 2^phase_bits`.  An
//! oscillator with phase `phi` outputs the square wave
//! `s(t) = +1 if (t + phi) mod P < P/2 else -1` — exactly the circular
//! shift register of Figure 3 of the paper with the mux tap at `phi`.

/// +1/-1 square-wave amplitude of an oscillator with phase `phi` at tick
/// `t` (both in units of the phase-update clock).
#[inline]
pub fn amplitude(phi: i32, t: i64, p: i32) -> i32 {
    debug_assert!(p > 0 && p % 2 == 0);
    let idx = (t + phi as i64).rem_euclid(p as i64) as i32;
    if idx < p / 2 {
        1
    } else {
        -1
    }
}

/// Wrap any integer into `[0, P)`.
#[inline]
pub fn wrap(phi: i32, p: i32) -> i32 {
    phi.rem_euclid(p)
}

/// Circular distance between two phases (shortest way round), in steps.
pub fn distance(a: i32, b: i32, p: i32) -> i32 {
    let d = (a - b).rem_euclid(p);
    d.min(p - d)
}

/// Map a binary spin (+1/-1) to the canonical phase (0 or P/2).
#[inline]
pub fn spin_to_phase(spin: i8, p: i32) -> i32 {
    if spin > 0 {
        0
    } else {
        p / 2
    }
}

/// Binarize a phase relative to a reference phase: +1 when closer to the
/// reference than to its antiphase.  Ties (exactly 90 degrees away) snap
/// to +1 deterministically.
pub fn phase_to_spin(phi: i32, reference: i32, p: i32) -> i8 {
    let d = distance(phi, reference, p);
    let d_anti = distance(phi, wrap(reference + p / 2, p), p);
    if d <= d_anti {
        1
    } else {
        -1
    }
}

/// Read out a whole state as spins relative to oscillator 0.
pub fn state_to_spins(phases: &[i32], p: i32) -> Vec<i8> {
    let r = *phases.first().unwrap_or(&0);
    phases.iter().map(|&phi| phase_to_spin(phi, r, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: i32 = 16;

    #[test]
    fn amplitude_square_wave() {
        // phi = 0: +1 for t in [0, 8), -1 for [8, 16).
        for t in 0..8 {
            assert_eq!(amplitude(0, t, P), 1);
        }
        for t in 8..16 {
            assert_eq!(amplitude(0, t, P), -1);
        }
        // periodicity
        assert_eq!(amplitude(0, 16, P), 1);
        assert_eq!(amplitude(0, -1, P), -1);
    }

    #[test]
    fn amplitude_phase_shift() {
        for phi in 0..P {
            for t in 0..(2 * P as i64) {
                assert_eq!(amplitude(phi, t, P), amplitude(0, t + phi as i64, P));
            }
        }
    }

    #[test]
    fn wrap_negative() {
        assert_eq!(wrap(-1, P), 15);
        assert_eq!(wrap(16, P), 0);
        assert_eq!(wrap(-17, P), 15);
    }

    #[test]
    fn distance_symmetric_and_bounded() {
        for a in 0..P {
            for b in 0..P {
                let d = distance(a, b, P);
                assert_eq!(d, distance(b, a, P));
                assert!(d <= P / 2);
            }
        }
        assert_eq!(distance(0, 15, P), 1);
        assert_eq!(distance(0, 8, P), 8);
    }

    #[test]
    fn spin_roundtrip() {
        assert_eq!(spin_to_phase(1, P), 0);
        assert_eq!(spin_to_phase(-1, P), 8);
        assert_eq!(phase_to_spin(0, 0, P), 1);
        assert_eq!(phase_to_spin(8, 0, P), -1);
        // Near-canonical phases binarize correctly.
        assert_eq!(phase_to_spin(1, 0, P), 1);
        assert_eq!(phase_to_spin(7, 0, P), -1);
        assert_eq!(phase_to_spin(15, 0, P), 1);
    }

    #[test]
    fn state_to_spins_relative() {
        // Global rotation must not change the readout.
        let base = vec![0, 8, 0, 8];
        let spins = state_to_spins(&base, P);
        assert_eq!(spins, vec![1, -1, 1, -1]);
        let rotated: Vec<i32> = base.iter().map(|x| wrap(x + 5, P)).collect();
        assert_eq!(state_to_spins(&rotated, P), spins);
    }
}
