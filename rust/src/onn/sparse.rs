//! CSR sparse coupling fabric — the quantized weight store behind the
//! sparse period kernel (DESIGN_SOLVER.md §11).
//!
//! Every dense engine pays O(N^2) memory and per-period work even when
//! the coupling graph is sparse, which is the regime real optimization
//! traffic lives in (the wire format accepts `"edges"` input).  This
//! module stores only the nonzeros in compressed-sparse-row form: for
//! row `i`, `cols[row_ptr[i]..row_ptr[i+1]]` are the column indices
//! (sorted ascending) and `vals[..]` the matching quantized couplings.
//! It is the software analog of the tunable-topology coupled-oscillator
//! ICs (Neyaz et al., PAPERS.md): only the routed couplings exist.
//!
//! The engines require the matrix to be **symmetric** (structure and
//! values).  That is what lets one CSR serve both access patterns the
//! kernels need: the incremental engine walks *column* `j` when
//! oscillator `j` flips, and for a symmetric matrix column `j` is row
//! `j`.  Quantized Ising embeddings are always symmetric (the problem
//! IR validates `J_ik == J_ki`, and quantization maps equal entries to
//! equal codes), so the requirement costs nothing on the solve path.
//!
//! Explicit zeros are allowed and kept: an edge whose master coupling
//! rounds to 0 at the configured precision stays a *structural* nonzero,
//! so the sparsity pattern is a property of the problem graph, not of
//! the quantization scale.

use anyhow::{anyhow, Result};

use crate::onn::weights::WeightMatrix;

/// Quantized couplings in compressed-sparse-row form.  Row-major entry
/// order (row, then ascending column) is part of the contract: the
/// quantization-error accumulation in
/// `solver::problem::IsingProblem::embed_sparse_with_error` relies on it
/// to reproduce the dense reduction order bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseWeights {
    n: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes row i's entries; len n + 1.
    row_ptr: Vec<usize>,
    /// Column indices, ascending within each row (u32: the wire caps n
    /// far below 2^32, and half-width indices halve the index memory —
    /// the point of the exercise).
    cols: Vec<u32>,
    vals: Vec<i8>,
}

impl SparseWeights {
    /// Build from (row, col, value) triplets.  Triplets may arrive in
    /// any order; they are sorted row-major internally.  Duplicate
    /// (row, col) coordinates and out-of-range indices are rejected.
    /// Symmetry is NOT implied — callers that hand the result to an
    /// engine must supply both orientations ([`Self::is_symmetric`]
    /// gates that at install time).
    pub fn from_triplets(n: usize, triplets: &[(usize, usize, i8)]) -> Result<Self> {
        let mut sorted: Vec<(usize, usize, i8)> = Vec::with_capacity(triplets.len());
        for &(i, j, v) in triplets {
            if i >= n || j >= n {
                return Err(anyhow!("sparse entry ({i}, {j}) outside {n}x{n}"));
            }
            sorted.push((i, j, v));
        }
        sorted.sort_by_key(|&(i, j, _)| (i, j));
        for w in sorted.windows(2) {
            if w[0].0 == w[1].0 && w[0].1 == w[1].1 {
                return Err(anyhow!(
                    "duplicate sparse entry ({}, {})",
                    w[0].0,
                    w[0].1
                ));
            }
        }
        let mut row_ptr = vec![0usize; n + 1];
        let mut cols = Vec::with_capacity(sorted.len());
        let mut vals = Vec::with_capacity(sorted.len());
        for &(i, j, v) in &sorted {
            row_ptr[i + 1] += 1;
            cols.push(j as u32);
            vals.push(v);
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        Ok(Self {
            n,
            row_ptr,
            cols,
            vals,
        })
    }

    /// Capture a dense matrix's nonzeros (row-major order).  Test and
    /// migration helper — production sparse paths never densify.
    pub fn from_dense(w: &WeightMatrix) -> Self {
        let n = w.n;
        let mut row_ptr = vec![0usize; n + 1];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..n {
            for (j, &v) in w.row(i).iter().enumerate() {
                if v != 0 {
                    cols.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr[i + 1] = cols.len();
        }
        Self {
            n,
            row_ptr,
            cols,
            vals,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored entries (structural nonzeros, both orientations counted).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Row i's (columns, values) slices, columns ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[i8]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.cols[a..b], &self.vals[a..b])
    }

    /// Entry (i, j), 0 when not stored (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> i8 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0,
        }
    }

    /// Stored fraction of the full n x n matrix.
    pub fn density(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.n * self.n) as f64
        }
    }

    /// Mean stored entries per row — what the serial-MAC cost model
    /// prices instead of N (`fpga::timing::oscillation_frequency_hybrid_sparse`).
    pub fn avg_row_nnz(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n as f64
        }
    }

    /// Largest row span (worst-case serial-MAC latency across devices).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.n)
            .map(|i| self.row_ptr[i + 1] - self.row_ptr[i])
            .max()
            .unwrap_or(0)
    }

    /// Largest |value| (resource-model width checks).
    pub fn max_abs(&self) -> i32 {
        self.vals.iter().map(|&v| (v as i32).abs()).max().unwrap_or(0)
    }

    /// Bytes held by the CSR arrays — the memory the bench compares
    /// against the dense fabric's `n^2 * (1 + 4)` (i8 matrix + i32
    /// transpose).
    pub fn memory_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.cols.len() * std::mem::size_of::<u32>()
            + self.vals.len() * std::mem::size_of::<i8>()
    }

    /// True when entry (i, j) == entry (j, i) for every stored
    /// coordinate — the engine-install precondition (one CSR serves as
    /// both row and column store).
    pub fn is_symmetric(&self) -> bool {
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if self.get(c as usize, i) != v {
                    return false;
                }
            }
        }
        true
    }

    /// Every stored value, with its coordinates, in row-major order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, i8)> + '_ {
        (0..self.n).flat_map(move |i| {
            let (cols, vals) = self.row(i);
            cols.iter()
                .zip(vals)
                .map(move |(&c, &v)| (i, c as usize, v))
        })
    }

    /// Densify (tests and the dense-fallback embed path).
    pub fn to_dense(&self) -> WeightMatrix {
        let mut w = WeightMatrix::zeros(self.n);
        for (i, j, v) in self.iter() {
            w.set(i, j, v);
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_layout_and_lookup() {
        let sw = SparseWeights::from_triplets(
            4,
            &[(2, 0, -3), (0, 2, -3), (1, 3, 7), (3, 1, 7), (0, 3, 1), (3, 0, 1)],
        )
        .unwrap();
        assert_eq!(sw.n(), 4);
        assert_eq!(sw.nnz(), 6);
        assert_eq!(sw.get(0, 2), -3);
        assert_eq!(sw.get(2, 0), -3);
        assert_eq!(sw.get(1, 3), 7);
        assert_eq!(sw.get(0, 1), 0, "unstored entry reads 0");
        let (cols, vals) = sw.row(0);
        assert_eq!(cols, &[2, 3], "columns ascend within a row");
        assert_eq!(vals, &[-3, 1]);
        assert!(sw.is_symmetric());
        assert_eq!(sw.max_row_nnz(), 2);
        assert!((sw.density() - 6.0 / 16.0).abs() < 1e-12);
        assert!((sw.avg_row_nnz() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_duplicates_and_out_of_range() {
        assert!(SparseWeights::from_triplets(3, &[(0, 1, 1), (0, 1, 2)]).is_err());
        assert!(SparseWeights::from_triplets(3, &[(0, 3, 1)]).is_err());
        assert!(SparseWeights::from_triplets(3, &[(3, 0, 1)]).is_err());
        // Same coordinate pair in both orientations is fine (symmetry).
        assert!(SparseWeights::from_triplets(3, &[(0, 1, 1), (1, 0, 1)]).is_ok());
    }

    #[test]
    fn asymmetry_detected() {
        let sw = SparseWeights::from_triplets(3, &[(0, 1, 1)]).unwrap();
        assert!(!sw.is_symmetric(), "missing transpose entry");
        let sw = SparseWeights::from_triplets(3, &[(0, 1, 1), (1, 0, 2)]).unwrap();
        assert!(!sw.is_symmetric(), "value mismatch");
    }

    #[test]
    fn dense_round_trip() {
        let mut w = WeightMatrix::zeros(5);
        w.set(0, 4, -16);
        w.set(4, 0, -16);
        w.set(2, 3, 15);
        w.set(3, 2, 15);
        w.set(1, 1, 5);
        let sw = SparseWeights::from_dense(&w);
        assert_eq!(sw.nnz(), 5);
        assert!(sw.is_symmetric());
        assert_eq!(sw.to_dense(), w);
        assert_eq!(sw.max_abs(), 16);
    }

    #[test]
    fn explicit_zeros_are_structural() {
        let sw = SparseWeights::from_triplets(2, &[(0, 1, 0), (1, 0, 0)]).unwrap();
        assert_eq!(sw.nnz(), 2, "quantized-to-zero edges keep their slot");
        assert_eq!(sw.get(0, 1), 0);
        assert!(sw.is_symmetric());
    }

    #[test]
    fn memory_is_linear_in_nnz() {
        let sw = SparseWeights::from_triplets(1000, &[(0, 999, 1), (999, 0, 1)]).unwrap();
        let dense_bytes = 1000 * 1000 * (1 + 4);
        assert!(sw.memory_bytes() * 100 < dense_bytes, "CSR must be tiny here");
    }
}
