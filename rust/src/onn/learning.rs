//! Learning rules for embedding patterns in the coupling weights.
//!
//! The paper trains every dataset with the **Diederich-Opper I** rule
//! [Diederich & Opper 1987]: an iterative perceptron-like local rule that
//! keeps strengthening a pattern's couplings until every bit of every
//! pattern is stable with a margin.  Plain Hebbian learning is included as
//! the baseline (and the DO-I initial condition).

use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;

/// Hebbian outer-product weights: `W_ij = (1/N) sum_mu xi_i xi_j`.
///
/// Returned as the float master matrix (quantize separately).  The
/// diagonal is left at zero: the architectures *support* self-coupling
/// (the N x N memory stores W_ii), but associative-memory training keeps
/// it zero — a non-zero diagonal merely freezes corrupted pixels.
///
/// An empty pattern slice is a valid (empty) memory and yields an empty
/// matrix — the wire-reachable `store`/`forget` path can drain a memory
/// space to zero patterns, which used to panic on `patterns[0]`.
///
/// Internally the sum is accumulated as exact integer co-occurrence
/// counts and divided by N once at the end ([`hebbian_counts`] /
/// [`counts_to_master`]).  Integer adds commute and invert exactly, so
/// the coordinator's *incremental* master (counts mutated by
/// `accumulate_outer` on every store/forget) is bit-identical to
/// retraining from the surviving pattern set — the associative-memory
/// delta-reprogram contract (DESIGN_SOLVER.md §13).
pub fn hebbian(patterns: &[Vec<i8>]) -> Vec<f32> {
    let n = patterns.first().map_or(0, Vec::len);
    counts_to_master(&hebbian_counts(patterns), n)
}

/// Exact integer Hebbian co-occurrence counts: `C_ij = sum_mu xi_i xi_j`
/// for `i != j`, diagonal zero.  Each ±1 pattern contributes ±1 per
/// off-diagonal pair, so counts are order-independent and a pattern's
/// contribution is removed exactly by [`accumulate_outer`] with sign -1.
pub fn hebbian_counts(patterns: &[Vec<i8>]) -> Vec<i32> {
    let n = patterns.first().map_or(0, Vec::len);
    assert!(patterns.iter().all(|p| p.len() == n), "ragged patterns");
    let mut counts = vec![0i32; n * n];
    for p in patterns {
        accumulate_outer(&mut counts, p, 1);
    }
    counts
}

/// Add (`sign` = 1) or exactly remove (`sign` = -1) one ±1 pattern's
/// outer product from an integer count matrix, diagonal untouched.
pub fn accumulate_outer(counts: &mut [i32], pattern: &[i8], sign: i32) {
    let n = pattern.len();
    assert_eq!(counts.len(), n * n, "counts/pattern size mismatch");
    for i in 0..n {
        for j in 0..n {
            if i != j {
                counts[i * n + j] += sign * pattern[i] as i32 * pattern[j] as i32;
            }
        }
    }
}

/// The float master matrix of an integer count matrix: one `C_ij / N`
/// divide per entry (a single rounding, so equal counts always produce
/// bit-equal masters regardless of the store/forget history).
pub fn counts_to_master(counts: &[i32], n: usize) -> Vec<f32> {
    assert_eq!(counts.len(), n * n, "counts are not n x n");
    if n == 0 {
        return Vec::new();
    }
    counts.iter().map(|&c| c as f32 / n as f32).collect()
}

/// Result of Diederich-Opper-I training.
#[derive(Debug, Clone)]
pub struct DoiResult {
    /// Float master weights (row-major N x N).
    pub weights: Vec<f32>,
    /// Sweeps over the pattern set until all margins held.
    pub epochs: usize,
    /// Whether every pattern reached the margin (false = hit max_epochs).
    pub converged: bool,
}

/// Diederich-Opper I: repeat over patterns; whenever bit i of pattern mu
/// has local field alignment `xi_i * h_i <= margin`, reinforce
/// `W_ij += xi_i xi_j / N` for all `j != i`.  Guarantees stored patterns
/// become fixed points (with margin) when capacity permits.  The diagonal
/// is excluded — including it lets the rule "converge" on any load by
/// self-stabilizing every bit, which destroys retrieval.
pub fn diederich_opper_i(
    patterns: &[Vec<i8>],
    margin: f32,
    max_epochs: usize,
) -> DoiResult {
    let n = patterns.first().map_or(0, Vec::len);
    assert!(patterns.iter().all(|p| p.len() == n), "ragged patterns");
    if patterns.is_empty() {
        // An empty memory is trivially converged (no margins to hold) —
        // reachable over the wire once `forget` drains a space.
        return DoiResult {
            weights: Vec::new(),
            epochs: 0,
            converged: true,
        };
    }
    let mut w = vec![0f32; n * n];
    let inv_n = 1.0 / n as f32;

    for epoch in 0..max_epochs {
        let mut updates = 0usize;
        for p in patterns {
            for i in 0..n {
                let h: f32 = (0..n).map(|j| w[i * n + j] * p[j] as f32).sum();
                if (p[i] as f32) * h <= margin {
                    for j in 0..n {
                        if j != i {
                            w[i * n + j] += (p[i] as f32) * (p[j] as f32) * inv_n;
                        }
                    }
                    updates += 1;
                }
            }
        }
        if updates == 0 {
            return DoiResult {
                weights: w,
                epochs: epoch,
                converged: true,
            };
        }
    }
    DoiResult {
        weights: w,
        epochs: max_epochs,
        converged: false,
    }
}

/// Train with DO-I and quantize to the configured precision — the full
/// pipeline the paper uses before programming the FPGA.
pub fn train_quantized(patterns: &[Vec<i8>], cfg: &NetworkConfig) -> WeightMatrix {
    let res = diederich_opper_i(patterns, 0.5, 1000);
    WeightMatrix::quantize(&res.weights, cfg.n, cfg)
}

/// Check that `pattern` is a fixed point of the sign dynamics under
/// integer weights (the property DO-I must deliver after quantization for
/// retrieval to work).  Zero fields count as stable (tie keeps state).
pub fn is_fixed_point(w: &WeightMatrix, pattern: &[i8]) -> bool {
    let n = w.n;
    (0..n).all(|i| {
        let h: i32 = (0..n).map(|j| w.get(i, j) as i32 * pattern[j] as i32).sum();
        h == 0 || (h > 0) == (pattern[i] > 0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_patterns(rng: &mut Rng, count: usize, n: usize) -> Vec<Vec<i8>> {
        (0..count)
            .map(|_| (0..n).map(|_| rng.spin()).collect())
            .collect()
    }

    #[test]
    fn hebbian_single_pattern_outer_product() {
        let p = vec![1i8, -1, 1];
        let w = hebbian(&[p.clone()]);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j {
                    0.0 // diagonal excluded (see hebbian doc)
                } else {
                    (p[i] as f32) * (p[j] as f32) / 3.0
                };
                assert!((w[i * 3 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn empty_pattern_slice_does_not_panic() {
        // Both rules used to index patterns[0]; a drained memory space
        // hits this path over the wire.
        assert!(hebbian(&[]).is_empty());
        assert!(hebbian_counts(&[]).is_empty());
        let res = diederich_opper_i(&[], 0.5, 100);
        assert!(res.weights.is_empty());
        assert!(res.converged);
        assert_eq!(res.epochs, 0);
    }

    #[test]
    fn incremental_counts_bit_identical_to_retrain() {
        // The store/forget contract: mutating counts with accumulate_outer
        // and dividing once matches hebbian() over the survivors bit for
        // bit, for any interleaving.
        let mut rng = Rng::new(42);
        let pats = random_patterns(&mut rng, 5, 12);
        let n = 12;
        let mut counts = vec![0i32; n * n];
        for p in &pats {
            accumulate_outer(&mut counts, p, 1);
        }
        accumulate_outer(&mut counts, &pats[1], -1);
        accumulate_outer(&mut counts, &pats[3], -1);
        let survivors = vec![pats[0].clone(), pats[2].clone(), pats[4].clone()];
        let retrained = hebbian(&survivors);
        let incremental = counts_to_master(&counts, n);
        assert!(
            incremental
                .iter()
                .zip(&retrained)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "incremental master diverged from retrain"
        );
    }

    #[test]
    fn doi_converges_and_stabilizes() {
        let mut rng = Rng::new(100);
        let pats = random_patterns(&mut rng, 3, 20);
        let res = diederich_opper_i(&pats, 0.5, 1000);
        assert!(res.converged, "DO-I did not converge");
        // All patterns are strict fixed points of the float dynamics.
        for p in &pats {
            for i in 0..20 {
                let h: f32 = (0..20).map(|j| res.weights[i * 20 + j] * p[j] as f32).sum();
                assert!(
                    (p[i] as f32) * h > 0.5,
                    "margin violated at i={i}: {}",
                    (p[i] as f32) * h
                );
            }
        }
    }

    #[test]
    fn doi_quantized_patterns_remain_fixed_points() {
        let mut rng = Rng::new(7);
        let cfg = NetworkConfig::paper(25);
        let pats = random_patterns(&mut rng, 4, 25);
        let w = train_quantized(&pats, &cfg);
        for p in &pats {
            assert!(is_fixed_point(&w, p), "pattern lost after quantization");
        }
    }

    #[test]
    fn doi_inverse_patterns_also_fixed() {
        // Z2 symmetry: -xi is a fixed point whenever xi is.
        let mut rng = Rng::new(8);
        let cfg = NetworkConfig::paper(16);
        let pats = random_patterns(&mut rng, 2, 16);
        let w = train_quantized(&pats, &cfg);
        for p in &pats {
            let inv: Vec<i8> = p.iter().map(|&x| -x).collect();
            assert!(is_fixed_point(&w, &inv));
        }
    }

    #[test]
    fn doi_zero_margin_faster_than_large_margin() {
        let mut rng = Rng::new(9);
        let pats = random_patterns(&mut rng, 3, 15);
        let small = diederich_opper_i(&pats, 0.1, 1000);
        let large = diederich_opper_i(&pats, 2.0, 1000);
        assert!(small.epochs <= large.epochs);
    }

    #[test]
    fn doi_duplicate_patterns_ok() {
        let p = vec![1i8, 1, -1, -1, 1, -1];
        let res = diederich_opper_i(&[p.clone(), p.clone()], 0.5, 500);
        assert!(res.converged);
    }

    #[test]
    fn capacity_overload_does_not_converge() {
        // Way past DO-I capacity (~2N): must report non-convergence
        // rather than pretending.
        let mut rng = Rng::new(10);
        let pats = random_patterns(&mut rng, 30, 10);
        let res = diederich_opper_i(&pats, 0.5, 50);
        assert!(!res.converged);
    }
}
