//! Learning rules for embedding patterns in the coupling weights.
//!
//! The paper trains every dataset with the **Diederich-Opper I** rule
//! [Diederich & Opper 1987]: an iterative perceptron-like local rule that
//! keeps strengthening a pattern's couplings until every bit of every
//! pattern is stable with a margin.  Plain Hebbian learning is included as
//! the baseline (and the DO-I initial condition).

use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;

/// Hebbian outer-product weights: `W_ij = (1/N) sum_mu xi_i xi_j`.
///
/// Returned as the float master matrix (quantize separately).  The
/// diagonal is left at zero: the architectures *support* self-coupling
/// (the N x N memory stores W_ii), but associative-memory training keeps
/// it zero — a non-zero diagonal merely freezes corrupted pixels.
pub fn hebbian(patterns: &[Vec<i8>]) -> Vec<f32> {
    let n = patterns[0].len();
    assert!(patterns.iter().all(|p| p.len() == n));
    let mut w = vec![0f32; n * n];
    for p in patterns {
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    w[i * n + j] += (p[i] as f32) * (p[j] as f32) / n as f32;
                }
            }
        }
    }
    w
}

/// Result of Diederich-Opper-I training.
#[derive(Debug, Clone)]
pub struct DoiResult {
    /// Float master weights (row-major N x N).
    pub weights: Vec<f32>,
    /// Sweeps over the pattern set until all margins held.
    pub epochs: usize,
    /// Whether every pattern reached the margin (false = hit max_epochs).
    pub converged: bool,
}

/// Diederich-Opper I: repeat over patterns; whenever bit i of pattern mu
/// has local field alignment `xi_i * h_i <= margin`, reinforce
/// `W_ij += xi_i xi_j / N` for all `j != i`.  Guarantees stored patterns
/// become fixed points (with margin) when capacity permits.  The diagonal
/// is excluded — including it lets the rule "converge" on any load by
/// self-stabilizing every bit, which destroys retrieval.
pub fn diederich_opper_i(
    patterns: &[Vec<i8>],
    margin: f32,
    max_epochs: usize,
) -> DoiResult {
    let n = patterns[0].len();
    assert!(patterns.iter().all(|p| p.len() == n), "ragged patterns");
    let mut w = vec![0f32; n * n];
    let inv_n = 1.0 / n as f32;

    for epoch in 0..max_epochs {
        let mut updates = 0usize;
        for p in patterns {
            for i in 0..n {
                let h: f32 = (0..n).map(|j| w[i * n + j] * p[j] as f32).sum();
                if (p[i] as f32) * h <= margin {
                    for j in 0..n {
                        if j != i {
                            w[i * n + j] += (p[i] as f32) * (p[j] as f32) * inv_n;
                        }
                    }
                    updates += 1;
                }
            }
        }
        if updates == 0 {
            return DoiResult {
                weights: w,
                epochs: epoch,
                converged: true,
            };
        }
    }
    DoiResult {
        weights: w,
        epochs: max_epochs,
        converged: false,
    }
}

/// Train with DO-I and quantize to the configured precision — the full
/// pipeline the paper uses before programming the FPGA.
pub fn train_quantized(patterns: &[Vec<i8>], cfg: &NetworkConfig) -> WeightMatrix {
    let res = diederich_opper_i(patterns, 0.5, 1000);
    WeightMatrix::quantize(&res.weights, cfg.n, cfg)
}

/// Check that `pattern` is a fixed point of the sign dynamics under
/// integer weights (the property DO-I must deliver after quantization for
/// retrieval to work).  Zero fields count as stable (tie keeps state).
pub fn is_fixed_point(w: &WeightMatrix, pattern: &[i8]) -> bool {
    let n = w.n;
    (0..n).all(|i| {
        let h: i32 = (0..n).map(|j| w.get(i, j) as i32 * pattern[j] as i32).sum();
        h == 0 || (h > 0) == (pattern[i] > 0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_patterns(rng: &mut Rng, count: usize, n: usize) -> Vec<Vec<i8>> {
        (0..count)
            .map(|_| (0..n).map(|_| rng.spin()).collect())
            .collect()
    }

    #[test]
    fn hebbian_single_pattern_outer_product() {
        let p = vec![1i8, -1, 1];
        let w = hebbian(&[p.clone()]);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j {
                    0.0 // diagonal excluded (see hebbian doc)
                } else {
                    (p[i] as f32) * (p[j] as f32) / 3.0
                };
                assert!((w[i * 3 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn doi_converges_and_stabilizes() {
        let mut rng = Rng::new(100);
        let pats = random_patterns(&mut rng, 3, 20);
        let res = diederich_opper_i(&pats, 0.5, 1000);
        assert!(res.converged, "DO-I did not converge");
        // All patterns are strict fixed points of the float dynamics.
        for p in &pats {
            for i in 0..20 {
                let h: f32 = (0..20).map(|j| res.weights[i * 20 + j] * p[j] as f32).sum();
                assert!(
                    (p[i] as f32) * h > 0.5,
                    "margin violated at i={i}: {}",
                    (p[i] as f32) * h
                );
            }
        }
    }

    #[test]
    fn doi_quantized_patterns_remain_fixed_points() {
        let mut rng = Rng::new(7);
        let cfg = NetworkConfig::paper(25);
        let pats = random_patterns(&mut rng, 4, 25);
        let w = train_quantized(&pats, &cfg);
        for p in &pats {
            assert!(is_fixed_point(&w, p), "pattern lost after quantization");
        }
    }

    #[test]
    fn doi_inverse_patterns_also_fixed() {
        // Z2 symmetry: -xi is a fixed point whenever xi is.
        let mut rng = Rng::new(8);
        let cfg = NetworkConfig::paper(16);
        let pats = random_patterns(&mut rng, 2, 16);
        let w = train_quantized(&pats, &cfg);
        for p in &pats {
            let inv: Vec<i8> = p.iter().map(|&x| -x).collect();
            assert!(is_fixed_point(&w, &inv));
        }
    }

    #[test]
    fn doi_zero_margin_faster_than_large_margin() {
        let mut rng = Rng::new(9);
        let pats = random_patterns(&mut rng, 3, 15);
        let small = diederich_opper_i(&pats, 0.1, 1000);
        let large = diederich_opper_i(&pats, 2.0, 1000);
        assert!(small.epochs <= large.epochs);
    }

    #[test]
    fn doi_duplicate_patterns_ok() {
        let p = vec![1i8, 1, -1, -1, 1, -1];
        let res = diederich_opper_i(&[p.clone(), p.clone()], 0.5, 500);
        assert!(res.converged);
    }

    #[test]
    fn capacity_overload_does_not_converge() {
        // Way past DO-I capacity (~2N): must report non-convergence
        // rather than pretending.
        let mut rng = Rng::new(10);
        let pats = random_patterns(&mut rng, 30, 10);
        let res = diederich_opper_i(&pats, 0.5, 50);
        assert!(!res.converged);
    }
}
