//! Network configuration: size and numerical precision.

/// Static configuration of one ONN instance.
///
/// The paper's headline precision is 5 weight bits (signed, so values in
/// `[-16, 15]`) and 4 phase bits (16 phase steps per period) — the same
/// precision [Abernot et al. 2023] found sufficient for pattern retrieval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetworkConfig {
    /// Number of oscillators (= pixels for pattern tasks).
    pub n: usize,
    /// Bits representing the oscillator phase; period = 2^phase_bits.
    pub phase_bits: u32,
    /// Bits representing a signed coupling weight (including sign).
    pub weight_bits: u32,
}

impl NetworkConfig {
    /// Paper-standard precision (5 weight bits / 4 phase bits).
    pub fn paper(n: usize) -> Self {
        Self {
            n,
            phase_bits: 4,
            weight_bits: 5,
        }
    }

    /// Explicit-precision configuration — the serve path's precision
    /// sweep (`solve --rtl --weight-bits B --phase-bits P`) builds its
    /// engines through this instead of [`NetworkConfig::paper`].
    pub fn with_precision(n: usize, weight_bits: u32, phase_bits: u32) -> Self {
        Self {
            n,
            phase_bits,
            weight_bits,
        }
    }

    /// Number of phase steps per oscillation period (shift-register taps).
    pub fn period(&self) -> usize {
        1usize << self.phase_bits
    }

    /// Phase value representing 180 degrees.
    pub fn half_period(&self) -> i32 {
        (self.period() / 2) as i32
    }

    /// Inclusive weight bounds for two's-complement `weight_bits`.
    pub fn weight_range(&self) -> (i32, i32) {
        let hi = (1i32 << (self.weight_bits - 1)) - 1;
        (-hi - 1, hi)
    }

    /// Degrees per phase step — Eq. (5) of the paper.
    pub fn phase_step_degrees(&self) -> f64 {
        360.0 / self.period() as f64
    }

    /// Total coupling elements in a fully connected network (incl.
    /// self-coupling) — Table 1 of the paper.
    pub fn coupling_elements(&self) -> usize {
        self.n * self.n
    }

    /// Total weight-memory bits — Table 1 of the paper.
    pub fn weight_memory_bits(&self) -> usize {
        self.n * self.n * self.weight_bits as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_precision() {
        let c = NetworkConfig::paper(48);
        assert_eq!(c.period(), 16);
        assert_eq!(c.weight_range(), (-16, 15));
        assert_eq!(c.half_period(), 8);
        assert!((c.phase_step_degrees() - 22.5).abs() < 1e-12);
    }

    #[test]
    fn table1_scaling_orders() {
        // Table 1: oscillators ~ N, coupling elements & memory cells ~ N^2.
        let a = NetworkConfig::paper(10);
        let b = NetworkConfig::paper(20);
        assert_eq!(b.coupling_elements(), 4 * a.coupling_elements());
        assert_eq!(b.weight_memory_bits(), 4 * a.weight_memory_bits());
    }

    #[test]
    fn weight_range_other_widths() {
        let mut c = NetworkConfig::paper(4);
        c.weight_bits = 3;
        assert_eq!(c.weight_range(), (-4, 3));
        c.weight_bits = 8;
        assert_eq!(c.weight_range(), (-128, 127));
    }
}
