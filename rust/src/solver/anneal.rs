//! Phase-noise annealing schedules for the batched portfolio solver.
//!
//! A schedule maps a chunk index to the noise amplitude handed to the
//! engine's phase-noise hook (`ChunkEngine::set_noise`).  Every schedule
//! guarantees two invariants the solver and the property tests rely on:
//! levels are monotone non-increasing over the run, and the final
//! quarter of the chunks (at least one) is noise-free (amplitude 0) so
//! the portfolio ends with a deterministic relaxation whose settle
//! flags mean something — and whose plateau/all-settled early exit can
//! actually fire before the budget is exhausted.

/// Noise-amplitude schedule over a fixed number of chunks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// `start * factor^k`, `factor` clamped into `[0, 1]`.
    Geometric { start: f64, factor: f64 },
    /// Linear ramp from `start` down to zero.
    Linear { start: f64 },
    /// Constant level with a final noise-free chunk.
    Constant { level: f64 },
}

impl Schedule {
    /// Parse a schedule name with a shared starting amplitude
    /// (the CLI/wire spelling).
    pub fn parse(name: &str, start: f64) -> Option<Schedule> {
        match name {
            "geometric" => Some(Schedule::Geometric {
                start,
                factor: 0.8,
            }),
            "linear" => Some(Schedule::Linear { start }),
            "constant" => Some(Schedule::Constant { level: start }),
            _ => None,
        }
    }

    /// Wire/CLI name of this schedule family.
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Geometric { .. } => "geometric",
            Schedule::Linear { .. } => "linear",
            Schedule::Constant { .. } => "constant",
        }
    }

    /// Chunks at the end of a `total`-chunk run that are always
    /// noise-free: the final quarter, at least one.  This is the
    /// deterministic relaxation tail where settle flags are meaningful
    /// and the portfolio's plateau/all-settled early exit can trigger.
    pub fn noise_free_tail(total: usize) -> usize {
        (total / 4).max(1)
    }

    /// Noise amplitude for chunk `k` of `total` (in `[0, 1]`); zero
    /// throughout the noise-free tail regardless of family.  The ramp
    /// families decay over the noisy prefix only, so e.g. a linear
    /// schedule reaches zero exactly where the tail begins instead of
    /// holding residual noise until the last chunk.
    pub fn level(&self, k: usize, total: usize) -> f64 {
        let tail = Self::noise_free_tail(total);
        if total == 0 || k + tail >= total {
            return 0.0;
        }
        let noisy = total - tail; // >= 1, and k < noisy here
        let a = match *self {
            Schedule::Geometric { start, factor } => {
                start.max(0.0) * factor.clamp(0.0, 1.0).powi(k as i32)
            }
            Schedule::Linear { start } => {
                start.max(0.0) * (1.0 - k as f64 / noisy as f64)
            }
            Schedule::Constant { level } => level.max(0.0),
        };
        a.clamp(0.0, 1.0)
    }

    /// The full level sequence for a run of `total` chunks.
    pub fn levels(&self, total: usize) -> Vec<f64> {
        (0..total).map(|k| self.level(k, total)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for name in ["geometric", "linear", "constant"] {
            let s = Schedule::parse(name, 0.4).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(Schedule::parse("bogus", 0.4).is_none());
    }

    #[test]
    fn all_schedules_end_noise_free() {
        for s in [
            Schedule::Geometric { start: 0.9, factor: 0.5 },
            Schedule::Linear { start: 0.7 },
            Schedule::Constant { level: 0.3 },
        ] {
            for total in [1usize, 2, 5, 33] {
                let levels = s.levels(total);
                assert_eq!(levels.len(), total);
                assert_eq!(*levels.last().unwrap(), 0.0, "{s:?} total={total}");
            }
        }
    }

    #[test]
    fn geometric_decays_monotonically() {
        let s = Schedule::Geometric { start: 0.8, factor: 0.6 };
        let l = s.levels(10);
        for w in l.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "{l:?}");
        }
        assert!((l[0] - 0.8).abs() < 1e-12);
        assert!((l[1] - 0.48).abs() < 1e-12);
    }

    #[test]
    fn final_quarter_is_noise_free() {
        assert_eq!(Schedule::noise_free_tail(1), 1);
        assert_eq!(Schedule::noise_free_tail(8), 2);
        assert_eq!(Schedule::noise_free_tail(32), 8);
        let s = Schedule::Constant { level: 0.5 };
        let levels = s.levels(32);
        assert!(levels[..24].iter().all(|&l| l == 0.5), "{levels:?}");
        assert!(levels[24..].iter().all(|&l| l == 0.0), "{levels:?}");
        // Linear ramps hit zero exactly where the tail begins.
        let s = Schedule::Linear { start: 0.6 };
        let levels = s.levels(32);
        assert!(levels[23] > 0.0);
        assert_eq!(levels[24], 0.0);
    }

    #[test]
    fn levels_clamped_to_unit_interval() {
        let s = Schedule::Constant { level: 7.0 };
        assert_eq!(s.level(0, 3), 1.0);
        let s = Schedule::Linear { start: -2.0 };
        assert_eq!(s.level(0, 3), 0.0);
    }
}
