//! Simulated-annealing baseline on the [`IsingProblem`] IR —
//! single-spin-flip Metropolis with cached local fields, the reference
//! every ONN-portfolio result is judged against (`harness::solverbench`).

use crate::solver::problem::IsingProblem;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SaResult {
    pub spins: Vec<i8>,
    /// Energy of the best state seen (the problem's `energy`, offset
    /// excluded).
    pub energy: f64,
    pub sweeps: usize,
}

/// Local fields `f_i = sum_{j != i} J_ij s_j + h_i`; flipping spin `i`
/// changes the energy by `2 s_i f_i`.  Shared by the annealer, the
/// descent polish, and the local-minimum predicate so they can never
/// disagree about what a field is.  Sparse-form problems iterate their
/// CSR rows — the skipped terms are exact zeros, so fields (and every
/// downstream flip decision) match the dense-form walk.
fn local_fields(problem: &IsingProblem, spins: &[i8]) -> Vec<f64> {
    let n = problem.n;
    if let Some(sp) = problem.sparse.as_ref() {
        return (0..n)
            .map(|i| {
                let mut v = problem.h[i];
                let (cols, vals) = sp.row(i);
                for (&k, &jv) in cols.iter().zip(vals) {
                    v += jv * spins[k as usize] as f64;
                }
                v
            })
            .collect();
    }
    (0..n)
        .map(|i| {
            let mut v = problem.h[i];
            for j in 0..n {
                if j != i {
                    v += problem.get_j(i, j) * spins[j] as f64;
                }
            }
            v
        })
        .collect()
}

/// Propagate a flip of spin `i` (new value `si`) into the cached fields:
/// `f_j += 2 J_ji si` for every neighbor `j`.  J is symmetric (enforced
/// by `IsingProblem::validate`), so a sparse problem's CSR row `i` *is*
/// its column `i`.
fn apply_flip_to_fields(problem: &IsingProblem, f: &mut [f64], i: usize, si: f64) {
    if let Some(sp) = problem.sparse.as_ref() {
        let (cols, vals) = sp.row(i);
        for (&j, &jv) in cols.iter().zip(vals) {
            f[j as usize] += 2.0 * jv * si;
        }
        return;
    }
    for j in 0..problem.n {
        if j != i {
            // f_j changes by J_ji * (s_i_new - s_i_old)
            f[j] += 2.0 * problem.get_j(j, i) * si;
        }
    }
}

/// Anneal with a geometric temperature ramp scaled to the instance's
/// coupling magnitudes.  `sweeps * n` single-flip attempts total; the
/// best state seen anywhere along the walk is returned.
pub fn anneal(problem: &IsingProblem, sweeps: usize, seed: u64) -> SaResult {
    let n = problem.n;
    let mut rng = Rng::new(seed);
    let mut spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
    let mut f = local_fields(problem, &spins);
    let mut energy = problem.energy(&spins);
    let mut best = spins.clone();
    let mut best_energy = energy;

    // Temperature scale from the worst-case local field magnitude.
    let row_magnitude = |i: usize| -> f64 {
        let couplings = match problem.sparse.as_ref() {
            Some(sp) => sp.row(i).1.iter().map(|v| v.abs()).sum::<f64>(),
            None => (0..n)
                .filter(|&j| j != i)
                .map(|j| problem.get_j(i, j).abs())
                .sum::<f64>(),
        };
        couplings + problem.h[i].abs()
    };
    let scale = (0..n)
        .map(row_magnitude)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let (t0, t1) = (0.8 * scale, 0.01 * scale);

    for s in 0..sweeps {
        let temp = t0 * (t1 / t0).powf(s as f64 / sweeps.max(1) as f64);
        for _ in 0..n {
            let i = rng.usize_below(n);
            let delta = 2.0 * spins[i] as f64 * f[i];
            if delta <= 0.0 || rng.f64() < (-delta / temp).exp() {
                spins[i] = -spins[i];
                energy += delta;
                apply_flip_to_fields(problem, &mut f, i, spins[i] as f64);
                if energy < best_energy {
                    best_energy = energy;
                    best.copy_from_slice(&spins);
                }
            }
        }
    }
    SaResult {
        spins: best,
        energy: best_energy,
        sweeps,
    }
}

/// Greedy single-flip descent to a strict local minimum: align each spin
/// with its local field until a full sweep makes no change.  This is the
/// deterministic readout polish the portfolio applies to every replica
/// (physical Ising machines do the same at readout), and the reason a
/// portfolio result can never be worse than its best initial replica.
pub fn greedy_descent(problem: &IsingProblem, spins: &mut [i8]) {
    let n = problem.n;
    assert_eq!(spins.len(), n);
    let mut f = local_fields(problem, spins);
    // Strict descent terminates (energy decreases by a positive amount
    // each flip — at least 2 on integer-valued instances, whose energy
    // span is O(n^2 * |J|_max)); the quadratic sweep cap comfortably
    // exceeds any productive-sweep count, so the local-minimum
    // postcondition holds whenever the loop exits.
    for _ in 0..(4 * n * n + 16) {
        let mut changed = false;
        for i in 0..n {
            let target = if f[i] > 0.0 {
                1
            } else if f[i] < 0.0 {
                -1
            } else {
                spins[i]
            };
            if target != spins[i] {
                spins[i] = target;
                changed = true;
                apply_flip_to_fields(problem, &mut f, i, spins[i] as f64);
            }
        }
        if !changed {
            break;
        }
    }
}

/// True when no single flip strictly lowers the energy (the postcondition
/// of [`greedy_descent`]).
pub fn is_local_minimum(problem: &IsingProblem, spins: &[i8]) -> bool {
    let f = local_fields(problem, spins);
    // delta for flipping i is 2 s_i f_i; it must be >= 0 everywhere.
    spins
        .iter()
        .zip(&f)
        .all(|(&s, &fi)| s as f64 * fi >= 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::graph::Graph;
    use crate::solver::reductions::max_cut;
    use crate::util::rng::Rng;

    #[test]
    fn sa_finds_triangle_optimum() {
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 1), (1, 2, 1), (0, 2, 1)],
        };
        let p = max_cut(&g);
        let r = anneal(&p, 50, 3);
        assert_eq!(g.cut_value(&r.spins), 2);
        assert!((r.energy - p.energy(&r.spins)).abs() < 1e-9);
    }

    #[test]
    fn descent_reaches_local_minimum_and_never_worsens() {
        let mut rng = Rng::new(61);
        for _ in 0..20 {
            let g = Graph::random(12, 0.4, &mut rng);
            let p = max_cut(&g);
            let mut spins: Vec<i8> = (0..g.n).map(|_| rng.spin()).collect();
            let before = p.energy(&spins);
            greedy_descent(&p, &mut spins);
            let after = p.energy(&spins);
            assert!(after <= before + 1e-9);
            assert!(is_local_minimum(&p, &spins));
        }
    }

    #[test]
    fn descent_solves_odd_part_complete_bipartite() {
        // Complete bipartite graphs with odd parts have no non-optimal
        // strict local minima under single-flip max-cut descent, so the
        // polish alone must find the full cut from any start.
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let mut rng = Rng::new(62);
        for _ in 0..16 {
            let mut spins: Vec<i8> = (0..g.n).map(|_| rng.spin()).collect();
            greedy_descent(&p, &mut spins);
            assert_eq!(g.cut_value(&spins), 9, "spins {spins:?}");
        }
    }

    #[test]
    fn sparse_form_walk_matches_dense_form_bitwise() {
        let mut rng = Rng::new(64);
        let n = 14;
        let mut edges = Vec::new();
        for i in 0..n {
            for k in (i + 1)..n {
                if rng.f64() < 0.3 {
                    edges.push((i, k, rng.range_i64(-3, 4) as f64));
                }
            }
        }
        let sp = IsingProblem::from_edges(n, &edges).unwrap();
        let mut dp = IsingProblem::new(n);
        for &(i, k, v) in &edges {
            dp.set_j(i, k, v);
        }
        // Same seed, same flip decisions, same best state: the CSR walk
        // only skips exact-zero terms.
        let rs = anneal(&sp, 40, 7);
        let rd = anneal(&dp, 40, 7);
        assert_eq!(rs.spins, rd.spins);
        assert_eq!(rs.energy.to_bits(), rd.energy.to_bits());
        for _ in 0..8 {
            let mut s1: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            let mut s2 = s1.clone();
            greedy_descent(&sp, &mut s1);
            greedy_descent(&dp, &mut s2);
            assert_eq!(s1, s2);
            assert!(is_local_minimum(&sp, &s1));
            assert!(is_local_minimum(&dp, &s2));
        }
    }

    #[test]
    fn sa_tracks_best_seen_not_final() {
        let mut rng = Rng::new(63);
        let g = Graph::random(16, 0.4, &mut rng);
        let p = max_cut(&g);
        let r = anneal(&p, 120, 9);
        // The reported energy must be consistent and locally plausible:
        // recomputing from the spins gives the same number.
        assert!((p.energy(&r.spins) - r.energy).abs() < 1e-9);
    }
}
