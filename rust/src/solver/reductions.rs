//! Textbook reductions onto the [`IsingProblem`] IR, plus the matching
//! decoders.  Each reduction is exact (Lucas 2014-style formulations):
//! the Hamiltonian's ground state is an optimal solution of the source
//! problem, and the decoder includes the cheap deterministic repair a
//! physical Ising machine would apply at readout.

use crate::solver::graph::Graph;
use crate::solver::problem::{IsingProblem, Qubo};

/// Max-cut: `J_ij = -w_ij` (antiferromagnetic).  With that sign,
/// `H(s) = sum_edges w_ij s_i s_j` and `cut(s) = (W_total - H(s)) / 2`,
/// so lower energy is exactly a larger cut.
pub fn max_cut(graph: &Graph) -> IsingProblem {
    let mut p = IsingProblem::new(graph.n).with_kind("max-cut");
    for &(i, j, w) in &graph.edges {
        p.add_j(i, j, -(w as f64));
    }
    p
}

/// Max-cut in sparse coupling form: same Hamiltonian as [`max_cut`]
/// (`J_ij = -w_ij`), but the couplings are stored CSR so the solver can
/// route the instance onto a sparse engine fabric (DESIGN_SOLVER.md
/// §11).  Requires a simple graph — `IsingProblem::from_edges` rejects
/// duplicate pairs and self-loops, the same contract the wire protocol
/// enforces on `"edges"` requests.
pub fn max_cut_sparse(graph: &Graph) -> IsingProblem {
    let edges: Vec<(usize, usize, f64)> = graph
        .edges
        .iter()
        .map(|&(i, j, w)| (i, j, -(w as f64)))
        .collect();
    IsingProblem::from_edges(graph.n, &edges)
        .expect("max_cut_sparse needs a simple graph (no duplicate or self-loop edges)")
        .with_kind("max-cut")
}

/// Cut value recovered from the max-cut Hamiltonian's energy.
pub fn cut_from_energy(graph: &Graph, energy: f64) -> f64 {
    (graph.total_weight() as f64 - energy) / 2.0
}

/// k-coloring via multi-phase sectors: antiferromagnetic couplings push
/// adjacent vertices into different phase sectors; `sectors = k` tells
/// the solver/decoder to read out `k` equally spaced sectors instead of
/// binary spins ("surpassing binary limitations", paper section 1).
pub fn coloring(graph: &Graph, k: usize) -> IsingProblem {
    assert!(k >= 2, "coloring needs k >= 2");
    let mut p = max_cut(graph).with_kind("k-coloring");
    p.sectors = k;
    p
}

/// Number partitioning: minimize `(sum_i a_i s_i)^2`, i.e.
/// `J_ij = -a_i a_j` up to a state-independent constant.
pub fn number_partition(weights: &[i64]) -> IsingProblem {
    let n = weights.len();
    let mut p = IsingProblem::new(n).with_kind("number-partition");
    for i in 0..n {
        for j in (i + 1)..n {
            p.set_j(i, j, -(weights[i] as f64 * weights[j] as f64));
        }
    }
    p
}

/// Absolute subset-sum imbalance of a partition assignment.
pub fn partition_imbalance(weights: &[i64], spins: &[i8]) -> i64 {
    assert_eq!(weights.len(), spins.len());
    weights
        .iter()
        .zip(spins)
        .map(|(&a, &s)| a * s as i64)
        .sum::<i64>()
        .abs()
}

/// Minimum vertex cover as a penalized QUBO
/// (`E = sum_i x_i + penalty * sum_edges (1 - x_i)(1 - x_j)`,
/// `x_i = 1` means "in the cover"), converted exactly to Ising.  Any
/// `penalty > 1` makes every uncovered edge cost more than covering it;
/// the conversion introduces external fields, so this reduction also
/// exercises the ancilla embedding.
pub fn min_vertex_cover(graph: &Graph, penalty: f64) -> IsingProblem {
    assert!(penalty > 1.0, "vertex-cover penalty must exceed 1");
    let mut q = Qubo::new(graph.n);
    for i in 0..graph.n {
        q.add_linear(i, 1.0);
    }
    let mut constant = 0.0;
    for &(i, j, _) in &graph.edges {
        // (1 - x_i)(1 - x_j) = 1 - x_i - x_j + x_i x_j
        constant += penalty;
        q.add_linear(i, -penalty);
        q.add_linear(j, -penalty);
        q.add(i, j, penalty);
    }
    let mut p = q.to_ising().with_kind("min-vertex-cover");
    p.metadata.offset += constant;
    p
}

/// Decode spins into a vertex cover (`s_i = +1` -> in cover), then
/// repair: add endpoints until every edge is covered, and drop vertices
/// whose removal keeps the cover valid.  The result is always a valid
/// cover no matter how bad the input spins are.
pub fn decode_cover(graph: &Graph, spins: &[i8]) -> Vec<bool> {
    assert_eq!(spins.len(), graph.n);
    let mut cover: Vec<bool> = spins.iter().map(|&s| s > 0).collect();
    let adj = graph.adjacency();
    // Repair pass 1: cover every uncovered edge via its higher-degree
    // endpoint (classic greedy).
    for &(i, j, _) in &graph.edges {
        if !cover[i] && !cover[j] {
            if adj[i].len() >= adj[j].len() {
                cover[i] = true;
            } else {
                cover[j] = true;
            }
        }
    }
    // Repair pass 2: drop redundant vertices.  Dropping v is safe when
    // every neighbor is (still) in the cover; later candidates see the
    // updated cover, so the result stays valid.
    for v in 0..graph.n {
        if cover[v] && adj[v].iter().all(|&(u, _)| cover[u]) {
            cover[v] = false;
        }
    }
    cover
}

pub fn cover_size(cover: &[bool]) -> usize {
    cover.iter().filter(|&&b| b).count()
}

/// True when every edge has at least one endpoint in the cover.
pub fn is_cover(graph: &Graph, cover: &[bool]) -> bool {
    graph.edges.iter().all(|&(i, j, _)| cover[i] || cover[j])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn max_cut_energy_cut_identity() {
        let mut rng = Rng::new(51);
        let g = Graph::random(10, 0.4, &mut rng);
        let p = max_cut(&g);
        for _ in 0..20 {
            let spins: Vec<i8> = (0..g.n).map(|_| rng.spin()).collect();
            let via_energy = cut_from_energy(&g, p.energy(&spins));
            assert!((via_energy - g.cut_value(&spins) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn max_cut_ground_state_is_max_cut() {
        let g = Graph::complete_bipartite(3, 2);
        let p = max_cut(&g);
        let (spins, e) = p.brute_force();
        assert_eq!(g.cut_value(&spins), 6); // all K_{3,2} edges
        assert!((cut_from_energy(&g, e) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn sparse_max_cut_matches_dense_reduction() {
        let mut rng = Rng::new(53);
        let g = Graph::random(11, 0.35, &mut rng);
        let pd = max_cut(&g);
        let ps = max_cut_sparse(&g);
        assert!(ps.is_sparse());
        assert_eq!(ps.metadata.kind, pd.metadata.kind);
        for i in 0..g.n {
            for j in 0..g.n {
                if i != j {
                    assert_eq!(ps.get_j(i, j), pd.get_j(i, j));
                }
            }
        }
        for _ in 0..10 {
            let spins: Vec<i8> = (0..g.n).map(|_| rng.spin()).collect();
            assert_eq!(ps.energy(&spins).to_bits(), pd.energy(&spins).to_bits());
        }
    }

    #[test]
    fn coloring_sets_sectors() {
        let g = Graph::complete_bipartite(2, 2);
        let p = coloring(&g, 3);
        assert_eq!(p.sectors, 3);
        assert!(p.get_j(0, 2) < 0.0);
    }

    #[test]
    fn partition_ground_state_balances() {
        let weights = [4i64, 3, 2, 2, 1];
        let p = number_partition(&weights);
        let (spins, _) = p.brute_force();
        // 4+2 vs 3+2+1: perfect balance exists.
        assert_eq!(partition_imbalance(&weights, &spins), 0);
    }

    #[test]
    fn vertex_cover_ground_state_is_minimum() {
        // Star K_{1,4}: minimum cover = the hub alone.
        let g = Graph {
            n: 5,
            edges: vec![(0, 1, 1), (0, 2, 1), (0, 3, 1), (0, 4, 1)],
        };
        let p = min_vertex_cover(&g, 2.0);
        assert!(p.has_field(), "VC reduction must produce fields");
        let (spins, e) = p.brute_force();
        let cover = decode_cover(&g, &spins);
        assert!(is_cover(&g, &cover));
        assert_eq!(cover_size(&cover), 1);
        assert!(cover[0]);
        // objective == cover size at the optimum (no penalty active)
        assert!((p.metadata.offset + e - 1.0).abs() < 1e-9);
    }

    #[test]
    fn decode_cover_repairs_invalid_states() {
        let mut rng = Rng::new(52);
        let g = Graph::random(12, 0.3, &mut rng);
        // Worst case: nothing in the cover.
        let cover = decode_cover(&g, &vec![-1i8; g.n]);
        assert!(is_cover(&g, &cover));
        // All-in is pruned to something no larger.
        let full = decode_cover(&g, &vec![1i8; g.n]);
        assert!(is_cover(&g, &full));
        assert!(cover_size(&full) <= g.n);
    }
}
