//! The solver's problem IR: a quantizable Ising Hamiltonian
//! `H(s) = -1/2 sum_{i != j} J_ij s_i s_j - sum_i h_i s_i` with an
//! optional multi-phase (Potts-like) mode for sector-encoded problems
//! such as k-coloring, plus the QUBO <-> Ising converter every textbook
//! reduction routes through.
//!
//! External fields have no direct analog in the coupling-only ONN
//! fabric, so [`IsingProblem::embed`] uses the standard gauge trick: one
//! ancilla oscillator coupled to every biased spin with `J_{i,anc} =
//! h_i`.  The ground state is recovered relative to the ancilla's sign
//! ([`IsingProblem::decode_spins`]), which makes the embedding exact —
//! not a penalty approximation.

use crate::onn::config::NetworkConfig;
use crate::onn::energy::waveform_correlation;
use crate::onn::phase::{phase_to_spin, state_to_spins};
use crate::onn::sparse::SparseWeights;
use crate::onn::weights::WeightMatrix;

/// CSR coupling storage for sparse problems (both orientations stored,
/// rows sorted by column).  Values are exact f64 copies of the edge
/// weights; the undirected edge list it was built from is recoverable
/// as the upper triangle.  Construction is the only mutation path —
/// [`IsingProblem::from_edges`] rejects duplicates and self-loops up
/// front, so a sparse problem is always structurally valid.
#[derive(Debug, Clone)]
pub struct SparseCoupling {
    /// `row_ptr[i]..row_ptr[i+1]` indexes row i's entries; len n + 1.
    row_ptr: Vec<usize>,
    /// Column indices, ascending within each row.
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl SparseCoupling {
    fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Result<Self, String> {
        let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
        let mut seen = std::collections::HashSet::with_capacity(edges.len());
        for &(i, k, v) in edges {
            if i >= n || k >= n {
                return Err(format!("edge ({i}, {k}) outside 0..{n}"));
            }
            if i == k {
                return Err(format!(
                    "self-loop edge ({i}, {i}): diagonal couplings are ignored; use h for biases"
                ));
            }
            // One undirected pair, one entry — (i, k) and (k, i) name
            // the same coupling, so a repeat in either orientation is a
            // contract violation, not an accumulation.
            if !seen.insert((i.min(k), i.max(k))) {
                return Err(format!(
                    "duplicate edge ({i}, {k}): each undirected pair may appear at most once"
                ));
            }
            rows[i].push((k as u32, v));
            rows[k].push((i as u32, v));
        }
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::with_capacity(2 * edges.len());
        let mut vals = Vec::with_capacity(2 * edges.len());
        row_ptr.push(0);
        for mut row in rows {
            row.sort_by_key(|&(c, _)| c);
            for (c, v) in row {
                cols.push(c);
                vals.push(v);
            }
            row_ptr.push(cols.len());
        }
        Ok(Self { row_ptr, cols, vals })
    }

    /// Stored entries — both orientations, i.e. `2 * edges`.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Row i's (columns, values), columns ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.cols[a..b], &self.vals[a..b])
    }

    /// Entry (i, k); 0 when the pair is not an edge.
    pub fn get(&self, i: usize, k: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(k as u32)) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }
}

/// Descriptive metadata carried alongside the Hamiltonian.
#[derive(Debug, Clone, Default)]
pub struct ProblemMeta {
    /// Human-readable problem family ("max-cut", "qubo", ...).
    pub kind: String,
    /// Constant added to `energy` to recover the original objective
    /// (QUBO reductions are energy-equal only up to a constant).
    pub offset: f64,
}

/// An Ising optimization instance.
#[derive(Debug, Clone)]
pub struct IsingProblem {
    pub n: usize,
    /// Symmetric couplings, row-major `j[i * n + k]`; diagonal ignored.
    /// EMPTY when the problem is in sparse form (`sparse` is `Some`) —
    /// sparse problems never materialize the dense matrix.
    pub j: Vec<f64>,
    /// External fields, length `n`.
    pub h: Vec<f64>,
    /// Phase sectors the state is decoded into: 2 = binary Ising,
    /// k > 2 = multi-phase sector encoding (e.g. k-coloring).
    pub sectors: usize,
    /// Sparse (CSR) coupling form; `Some` means `j` is empty and all
    /// coupling access goes through this structure.  Built by
    /// [`Self::from_edges`]; kept sparse end-to-end so that memory and
    /// solve cost scale with the edge count (DESIGN_SOLVER.md §11).
    pub sparse: Option<SparseCoupling>,
    pub metadata: ProblemMeta,
}

impl IsingProblem {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            j: vec![0.0; n * n],
            h: vec![0.0; n],
            sectors: 2,
            sparse: None,
            metadata: ProblemMeta::default(),
        }
    }

    /// Build a *sparse-form* problem from an undirected edge list
    /// `(i, k, J_ik)`.  The couplings stay in CSR form end-to-end — no
    /// n^2 allocation ever happens — which is what lets the solver
    /// route them onto the sparse engine fabric.  Self-loops,
    /// out-of-range indices, and duplicate pairs (in either
    /// orientation) are rejected: an edge list names each undirected
    /// coupling exactly once.
    pub fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Result<Self, String> {
        let sparse = SparseCoupling::from_edges(n, edges)?;
        Ok(Self {
            n,
            j: Vec::new(),
            h: vec![0.0; n],
            sectors: 2,
            sparse: Some(sparse),
            metadata: ProblemMeta::default(),
        })
    }

    /// True for sparse-form (CSR) problems.
    pub fn is_sparse(&self) -> bool {
        self.sparse.is_some()
    }

    /// Fraction of the n x n coupling matrix that is nonzero.  O(1) for
    /// sparse-form problems (stored entries / n^2); O(n^2) for dense
    /// form (only used by benches/reports — the solve path asks
    /// sparse-form problems only).
    pub fn coupling_density(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let nnz = match &self.sparse {
            Some(sp) => sp.nnz(),
            None => self.j.iter().filter(|&&v| v != 0.0).count(),
        };
        nnz as f64 / (self.n * self.n) as f64
    }

    /// True when every coupling AND every field is exactly zero — the
    /// degenerate problem whose every state is a ground state.  The
    /// router answers these trivially instead of annealing noise for
    /// the full period budget.
    pub fn is_zero_interaction(&self) -> bool {
        let no_j = match &self.sparse {
            Some(sp) => sp.vals.iter().all(|&v| v == 0.0),
            None => self.j.iter().all(|&v| v == 0.0),
        };
        no_j && self.h.iter().all(|&x| x == 0.0)
    }

    pub fn with_kind(mut self, kind: &str) -> Self {
        self.metadata.kind = kind.to_string();
        self
    }

    #[inline]
    pub fn get_j(&self, i: usize, k: usize) -> f64 {
        match &self.sparse {
            Some(sp) => sp.get(i, k),
            None => self.j[i * self.n + k],
        }
    }

    /// Symmetric coupling setter (dense form only — sparse problems fix
    /// their couplings at [`Self::from_edges`] time).
    pub fn set_j(&mut self, i: usize, k: usize, v: f64) {
        assert!(
            self.sparse.is_none(),
            "sparse-form couplings are immutable; rebuild via from_edges"
        );
        assert_ne!(i, k, "diagonal couplings are ignored; use h for biases");
        self.j[i * self.n + k] = v;
        self.j[k * self.n + i] = v;
    }

    /// Symmetric coupling increment (reductions accumulate terms;
    /// dense form only).
    pub fn add_j(&mut self, i: usize, k: usize, v: f64) {
        assert!(
            self.sparse.is_none(),
            "sparse-form couplings are immutable; rebuild via from_edges"
        );
        assert_ne!(i, k);
        self.j[i * self.n + k] += v;
        self.j[k * self.n + i] += v;
    }

    pub fn has_field(&self) -> bool {
        self.h.iter().any(|&x| x != 0.0)
    }

    /// Structural validity: square J, matching h, symmetric couplings.
    /// Sparse-form problems check CSR invariants instead (cost O(nnz),
    /// never O(n^2)).
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("empty problem (n = 0)".into());
        }
        if self.h.len() != self.n {
            return Err(format!("h has {} entries, want n = {}", self.h.len(), self.n));
        }
        if self.sectors < 2 {
            return Err(format!("sectors {} < 2", self.sectors));
        }
        if let Some(sp) = &self.sparse {
            if !self.j.is_empty() {
                return Err("sparse-form problem must not carry a dense j".into());
            }
            if sp.row_ptr.len() != self.n + 1 || *sp.row_ptr.last().unwrap() != sp.cols.len() {
                return Err("sparse couplings: malformed row pointers".into());
            }
            for i in 0..self.n {
                if sp.row_ptr[i] > sp.row_ptr[i + 1] {
                    return Err("sparse couplings: malformed row pointers".into());
                }
                let (cols, vals) = sp.row(i);
                for (p, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                    let c = c as usize;
                    if c >= self.n {
                        return Err(format!("sparse coupling ({i}, {c}) outside 0..{}", self.n));
                    }
                    if c == i {
                        return Err(format!("sparse self-coupling at ({i}, {i})"));
                    }
                    if p > 0 && cols[p - 1] >= cols[p] {
                        return Err(format!("sparse couplings: row {i} columns not ascending"));
                    }
                    if sp.get(c, i) != v {
                        return Err(format!("asymmetric coupling at ({i}, {c})"));
                    }
                }
            }
            return Ok(());
        }
        if self.j.len() != self.n * self.n {
            return Err(format!("j has {} entries, want n^2 = {}", self.j.len(), self.n * self.n));
        }
        for i in 0..self.n {
            for k in (i + 1)..self.n {
                if (self.get_j(i, k) - self.get_j(k, i)).abs() > 1e-9 {
                    return Err(format!("asymmetric coupling at ({i}, {k})"));
                }
            }
        }
        Ok(())
    }

    /// `H(s) = -1/2 sum_{i != j} J_ij s_i s_j - sum_i h_i s_i`.
    ///
    /// The sparse branch walks the CSR rows in the same row-major order
    /// the dense loop uses, skipping only exact-zero terms — each
    /// skipped term subtracts a signed zero, which cannot change a
    /// non-negative-zero accumulator — so the two forms agree
    /// bit-for-bit on the same couplings.
    pub fn energy(&self, spins: &[i8]) -> f64 {
        assert_eq!(spins.len(), self.n);
        let mut e = 0.0;
        if let Some(sp) = &self.sparse {
            for i in 0..self.n {
                let (cols, vals) = sp.row(i);
                for (&k, &v) in cols.iter().zip(vals) {
                    e -= 0.5 * v * spins[i] as f64 * spins[k as usize] as f64;
                }
                e -= self.h[i] * spins[i] as f64;
            }
            return e;
        }
        for i in 0..self.n {
            for k in 0..self.n {
                if i != k {
                    e -= 0.5 * self.get_j(i, k) * spins[i] as f64 * spins[k] as f64;
                }
            }
            e -= self.h[i] * spins[i] as f64;
        }
        e
    }

    /// Original objective value (energy plus the reduction offset).
    pub fn objective(&self, spins: &[i8]) -> f64 {
        self.energy(spins) + self.metadata.offset
    }

    /// Phase-domain energy proxy using the square-wave correlation
    /// (coincides with [`Self::energy`] on binary phase states); used to
    /// rank multi-phase (sector) replicas where no spin decode exists.
    pub fn phase_energy(&self, phases: &[i32], p: i32) -> f64 {
        assert_eq!(phases.len(), self.n);
        let mut e = 0.0;
        if let Some(sp) = &self.sparse {
            // Same row-major walk as the dense loop, nonzeros only —
            // bit-identical (see `energy`).
            for i in 0..self.n {
                let (cols, vals) = sp.row(i);
                for (&k, &v) in cols.iter().zip(vals) {
                    e -= 0.5 * v * waveform_correlation(phases[i], phases[k as usize], p);
                }
                e -= self.h[i] * waveform_correlation(phases[i], 0, p);
            }
            return e;
        }
        for i in 0..self.n {
            for k in 0..self.n {
                if i != k {
                    e -= 0.5
                        * self.get_j(i, k)
                        * waveform_correlation(phases[i], phases[k], p);
                }
            }
            // Fields only make sense for binary problems, where the
            // solver evaluates via `energy` on decoded spins instead;
            // include them against phase 0 for completeness.
            e -= self.h[i] * waveform_correlation(phases[i], 0, p);
        }
        e
    }

    /// Number of oscillators the embedded network needs (ancilla
    /// included when fields are present).
    pub fn embed_dim(&self) -> usize {
        self.n + usize::from(self.has_field())
    }

    /// Quantize into the ONN coupling fabric.  Fields become couplings
    /// to one trailing ancilla oscillator (`J_{i,anc} = h_i`); the whole
    /// matrix is scaled so the largest magnitude maps to the positive
    /// quantization limit.
    pub fn embed(&self, cfg: &NetworkConfig) -> WeightMatrix {
        self.embed_with_error(cfg).0
    }

    /// [`Self::embed`] plus the quantization error it cost (RMS rounding
    /// loss as a fraction of the quantization full scale — see
    /// [`WeightMatrix::quantize_with_error`]), which the solver surfaces
    /// per solve outcome.
    pub fn embed_with_error(&self, cfg: &NetworkConfig) -> (WeightMatrix, f64) {
        let m = self.embed_dim();
        assert_eq!(cfg.n, m, "config sized {} but embedding needs {m}", cfg.n);
        let mut master = vec![0f32; m * m];
        match &self.sparse {
            // Dense fallback for a sparse-form problem (rtl engine, or
            // density above the sparse-kernel threshold): scatter the
            // CSR entries — identical master, no n^2 lookups.
            Some(sp) => {
                for i in 0..self.n {
                    let (cols, vals) = sp.row(i);
                    for (&k, &v) in cols.iter().zip(vals) {
                        master[i * m + k as usize] = v as f32;
                    }
                }
            }
            None => {
                for i in 0..self.n {
                    for k in 0..self.n {
                        if i != k {
                            master[i * m + k] = self.get_j(i, k) as f32;
                        }
                    }
                }
            }
        }
        if self.has_field() {
            let anc = self.n;
            for i in 0..self.n {
                master[i * m + anc] = self.h[i] as f32;
                master[anc * m + i] = self.h[i] as f32;
            }
        }
        WeightMatrix::quantize_with_error(&master, m, cfg)
    }

    /// Sparse twin of [`Self::embed_with_error`]: quantize straight
    /// into CSR form without ever materializing the m x m master.
    ///
    /// Bit-exactness contract: the scale factor and the RMS error are
    /// computed over the SAME f32 values, in the SAME row-major order,
    /// as the dense embed — restricted to the structural nonzeros.
    /// Skipped entries are exact zeros, which can neither raise the
    /// max-|x| fold nor change the error accumulator (they contribute
    /// +0.0), so the quantized entries AND the reported error match
    /// the dense path bit-for-bit.  Structural entries that *round* to
    /// zero are kept, so the fabric's sparsity pattern is the problem
    /// graph's regardless of quantization.
    pub fn embed_sparse_with_error(&self, cfg: &NetworkConfig) -> (SparseWeights, f64) {
        let sp = self
            .sparse
            .as_ref()
            .expect("embed_sparse_with_error requires a sparse-form problem");
        let m = self.embed_dim();
        assert_eq!(cfg.n, m, "config sized {} but embedding needs {m}", cfg.n);
        let (lo, hi) = cfg.weight_range();
        let has_field = self.has_field();
        let anc = self.n;
        // Pass 1: max |x| over the structural entries, exactly the f32
        // fold the dense quantizer performs (zeros cannot move it).
        let mut max_abs = 0f32;
        for &v in &sp.vals {
            max_abs = max_abs.max((v as f32).abs());
        }
        if has_field {
            for &h in &self.h {
                // Both orientations fold in the dense master; f32 max
                // is idempotent so folding each value twice is
                // equivalent — fold once per orientation anyway to
                // mirror the dense walk literally.
                max_abs = max_abs.max((h as f32).abs());
                max_abs = max_abs.max((h as f32).abs());
            }
        }
        let scale = if max_abs > 0.0 {
            hi as f32 / max_abs
        } else {
            0.0
        };
        // Pass 2: quantize in dense row-major order (per row: coupling
        // columns ascending, then the trailing ancilla column), so the
        // f64 error accumulation visits entries exactly as the dense
        // quantizer does.
        let mut sq = 0f64;
        let mut quantize = |x: f32| -> i8 {
            let xs = x * scale;
            let q = (xs.round() as i32).clamp(lo, hi);
            let err = q as f64 - xs as f64;
            sq += err * err;
            q as i8
        };
        let mut triplets: Vec<(usize, usize, i8)> =
            Vec::with_capacity(sp.nnz() + if has_field { 2 * self.n } else { 0 });
        for i in 0..self.n {
            let (cols, vals) = sp.row(i);
            for (&k, &v) in cols.iter().zip(vals) {
                triplets.push((i, k as usize, quantize(v as f32)));
            }
            if has_field && self.h[i] != 0.0 {
                triplets.push((i, anc, quantize(self.h[i] as f32)));
            }
        }
        if has_field {
            for i in 0..self.n {
                if self.h[i] != 0.0 {
                    triplets.push((anc, i, quantize(self.h[i] as f32)));
                }
            }
        }
        let w = SparseWeights::from_triplets(m, &triplets)
            .expect("sparse embedding cannot produce duplicates");
        let rms = if m > 0 && hi > 0 {
            (sq / (m * m) as f64).sqrt() / hi as f64
        } else {
            0.0
        };
        (w, rms)
    }

    /// Decode an embedded phase state (length [`Self::embed_dim`]) into
    /// problem spins (length `n`), gauge-fixed to the ancilla when
    /// fields are present.
    pub fn decode_spins(&self, phases: &[i32], p: i32) -> Vec<i8> {
        assert_eq!(phases.len(), self.embed_dim());
        if self.has_field() {
            let anc = phases[self.n];
            (0..self.n)
                .map(|i| phase_to_spin(phases[i], anc, p))
                .collect()
        } else {
            state_to_spins(&phases[..self.n], p)
        }
    }

    /// Exhaustive ground-state search; test-sized instances only.
    pub fn brute_force(&self) -> (Vec<i8>, f64) {
        assert!(self.n <= 24, "brute force capped at n = 24");
        let mut best_spins = vec![1i8; self.n];
        let mut best_e = f64::INFINITY;
        for mask in 0u64..(1u64 << self.n) {
            let spins: Vec<i8> = (0..self.n)
                .map(|i| if mask >> i & 1 == 1 { 1 } else { -1 })
                .collect();
            let e = self.energy(&spins);
            if e < best_e {
                best_e = e;
                best_spins = spins;
            }
        }
        (best_spins, best_e)
    }

    /// Convert to QUBO over `x = (1 + s) / 2`:
    /// `E(x) = sum_ij Q_ij x_i x_j` with `E(x(s)) = energy(s) + C`.
    pub fn to_qubo(&self) -> Qubo {
        let n = self.n;
        let mut q = vec![0.0; n * n];
        for i in 0..n {
            let mut row_off = 0.0;
            for k in 0..n {
                if i != k {
                    q[i * n + k] = -2.0 * self.get_j(i, k);
                    row_off += self.get_j(i, k);
                }
            }
            // h_i = -(sum_k Q_ik) / 2  =>  Q_ii = -2 h_i + 2 sum_{k != i} J_ik
            q[i * n + i] = -2.0 * self.h[i] + 2.0 * row_off;
        }
        Qubo { n, q }
    }
}

/// A QUBO instance: `E(x) = sum_i sum_j Q_ij x_i x_j` over binary
/// `x in {0, 1}^n` (diagonal entries are the linear terms, `x_i^2 = x_i`;
/// off-diagonal entries are stored symmetrically).
#[derive(Debug, Clone)]
pub struct Qubo {
    pub n: usize,
    pub q: Vec<f64>,
}

impl Qubo {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            q: vec![0.0; n * n],
        }
    }

    #[inline]
    pub fn get(&self, i: usize, k: usize) -> f64 {
        self.q[i * self.n + k]
    }

    /// Add `v * x_i * x_j` (split symmetrically for i != j).
    pub fn add(&mut self, i: usize, k: usize, v: f64) {
        if i == k {
            self.q[i * self.n + i] += v;
        } else {
            self.q[i * self.n + k] += v / 2.0;
            self.q[k * self.n + i] += v / 2.0;
        }
    }

    /// Add `v * x_i` (linear term).
    pub fn add_linear(&mut self, i: usize, v: f64) {
        self.q[i * self.n + i] += v;
    }

    pub fn value(&self, x: &[u8]) -> f64 {
        assert_eq!(x.len(), self.n);
        let mut e = 0.0;
        for i in 0..self.n {
            if x[i] == 0 {
                continue;
            }
            for k in 0..self.n {
                if x[k] != 0 {
                    e += self.get(i, k);
                }
            }
        }
        e
    }

    /// Convert to Ising via `x = (1 + s) / 2`; the returned problem's
    /// `metadata.offset` makes `objective(s) == value(x(s))` exactly.
    pub fn to_ising(&self) -> IsingProblem {
        let n = self.n;
        let mut p = IsingProblem::new(n).with_kind("qubo");
        let mut offset = 0.0;
        for i in 0..n {
            let mut row_sum = 0.0;
            for k in 0..n {
                row_sum += self.get(i, k);
                if i != k {
                    p.j[i * n + k] = -self.get(i, k) / 2.0;
                    offset += self.get(i, k) / 4.0;
                }
            }
            p.h[i] = -row_sum / 2.0;
            offset += self.get(i, i) / 2.0;
        }
        p.metadata.offset = offset;
        p
    }
}

/// Map binary spins to QUBO bits (`+1 -> 1`, `-1 -> 0`).
pub fn spins_to_bits(spins: &[i8]) -> Vec<u8> {
    spins.iter().map(|&s| u8::from(s > 0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_problem(rng: &mut Rng, n: usize, with_field: bool) -> IsingProblem {
        let mut p = IsingProblem::new(n);
        for i in 0..n {
            for k in (i + 1)..n {
                p.set_j(i, k, rng.range_i64(-5, 6) as f64);
            }
            if with_field {
                p.h[i] = rng.range_i64(-3, 4) as f64;
            }
        }
        p
    }

    #[test]
    fn energy_matches_onn_energy_module() {
        // The f64 energy must agree with onn::energy on quantized
        // integer couplings.
        use crate::onn::energy::ising_energy;
        let mut rng = Rng::new(31);
        let n = 8;
        let mut p = IsingProblem::new(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for k in (i + 1)..n {
                let v = rng.range_i64(-10, 11);
                p.set_j(i, k, v as f64);
                w.set(i, k, v as i8);
                w.set(k, i, v as i8);
            }
        }
        for _ in 0..10 {
            let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            assert!((p.energy(&spins) - ising_energy(&w, &spins)).abs() < 1e-9);
        }
    }

    #[test]
    fn qubo_ising_energy_identity() {
        let mut rng = Rng::new(32);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(7);
            let mut q = Qubo::new(n);
            for i in 0..n {
                for k in i..n {
                    q.add(i, k, rng.range_i64(-6, 7) as f64);
                }
            }
            let p = q.to_ising();
            let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            let x = spins_to_bits(&spins);
            assert!(
                (q.value(&x) - p.objective(&spins)).abs() < 1e-9,
                "qubo {} vs ising {}",
                q.value(&x),
                p.objective(&spins)
            );
        }
    }

    #[test]
    fn qubo_roundtrip_preserves_couplings() {
        let mut rng = Rng::new(33);
        let p = random_problem(&mut rng, 6, true);
        let back = p.to_qubo().to_ising();
        for i in 0..p.n {
            assert!((p.h[i] - back.h[i]).abs() < 1e-9, "h[{i}]");
            for k in 0..p.n {
                if i != k {
                    assert!((p.get_j(i, k) - back.get_j(i, k)).abs() < 1e-9, "j[{i}][{k}]");
                }
            }
        }
    }

    #[test]
    fn embed_without_field_matches_quantize() {
        let mut rng = Rng::new(34);
        let mut p = random_problem(&mut rng, 5, false);
        p.set_j(0, 1, 5.0); // pin the largest magnitude
        assert_eq!(p.embed_dim(), 5);
        let cfg = NetworkConfig::paper(5);
        let w = p.embed(&cfg);
        assert!(w.is_symmetric());
        assert_eq!(w.max_abs(), 15); // strongest coupling saturates
    }

    #[test]
    fn embed_with_field_adds_ancilla_and_decodes_gauge() {
        let mut rng = Rng::new(35);
        let mut p = random_problem(&mut rng, 4, true);
        p.h[0] = 2.0; // guarantee a field so the ancilla is present
        assert_eq!(p.embed_dim(), 5);
        let cfg = NetworkConfig::paper(5);
        let w = p.embed(&cfg);
        assert!(w.is_symmetric());
        // Decoding is gauge-fixed to the ancilla: flipping the whole
        // embedded state leaves the decoded spins unchanged.
        let phases = vec![0, 8, 0, 8, 0];
        let flipped: Vec<i32> = phases.iter().map(|&x| (x + 8) % 16).collect();
        assert_eq!(p.decode_spins(&phases, 16), p.decode_spins(&flipped, 16));
        assert_eq!(p.decode_spins(&phases, 16), vec![1, -1, 1, -1]);
    }

    #[test]
    fn brute_force_finds_ferro_ground_state() {
        let mut p = IsingProblem::new(3);
        p.set_j(0, 1, 2.0);
        p.set_j(1, 2, 2.0);
        p.h[0] = 0.5; // break the global-flip degeneracy
        let (spins, e) = p.brute_force();
        assert_eq!(spins, vec![1, 1, 1]);
        assert!((e - (-4.5)).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_malformed() {
        let mut p = IsingProblem::new(3);
        assert!(p.validate().is_ok());
        p.j[1] = 3.0; // asymmetric
        assert!(p.validate().is_err());
        let mut p = IsingProblem::new(2);
        p.h.pop();
        assert!(p.validate().is_err());
        assert!(IsingProblem::new(0).validate().is_err());
    }

    fn random_sparse_edges(rng: &mut Rng, n: usize, density: f64) -> Vec<(usize, usize, f64)> {
        let mut edges = Vec::new();
        for i in 0..n {
            for k in (i + 1)..n {
                if rng.f64() < density {
                    // Fractional weights stress the quantization path.
                    edges.push((i, k, rng.range_i64(-50, 51) as f64 / 7.0));
                }
            }
        }
        edges
    }

    #[test]
    fn from_edges_rejects_bad_input() {
        assert!(IsingProblem::from_edges(3, &[(0, 0, 1.0)]).is_err(), "self-loop");
        assert!(IsingProblem::from_edges(3, &[(0, 3, 1.0)]).is_err(), "out of range");
        assert!(
            IsingProblem::from_edges(3, &[(0, 1, 1.0), (0, 1, 1.0)]).is_err(),
            "duplicate pair"
        );
        assert!(
            IsingProblem::from_edges(3, &[(0, 1, 1.0), (1, 0, 1.0)]).is_err(),
            "reversed orientation names the same pair"
        );
        let p = IsingProblem::from_edges(3, &[(0, 1, 1.0), (2, 1, -2.0)]).unwrap();
        assert!(p.is_sparse());
        assert!(p.validate().is_ok());
        assert_eq!(p.get_j(1, 0), 1.0);
        assert_eq!(p.get_j(1, 2), -2.0);
        assert_eq!(p.get_j(0, 2), 0.0);
        assert!((p.coupling_density() - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_form_energy_bitwise_equals_dense_form() {
        let mut rng = Rng::new(41);
        for n in [2usize, 5, 9, 16] {
            let edges = random_sparse_edges(&mut rng, n, 0.3);
            let sp = IsingProblem::from_edges(n, &edges).unwrap();
            let mut dp = IsingProblem::new(n);
            for &(i, k, v) in &edges {
                dp.set_j(i, k, v);
            }
            for _ in 0..8 {
                let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
                assert_eq!(
                    sp.energy(&spins).to_bits(),
                    dp.energy(&spins).to_bits(),
                    "n={n}"
                );
                let phases: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
                assert_eq!(
                    sp.phase_energy(&phases, 16).to_bits(),
                    dp.phase_energy(&phases, 16).to_bits(),
                    "n={n}"
                );
            }
        }
    }

    #[test]
    fn sparse_embed_bitwise_matches_dense_embed() {
        let mut rng = Rng::new(42);
        for with_field in [false, true] {
            for n in [3usize, 8, 14] {
                let edges = random_sparse_edges(&mut rng, n, 0.35);
                let mut sp = IsingProblem::from_edges(n, &edges).unwrap();
                let mut dp = IsingProblem::new(n);
                for &(i, k, v) in &edges {
                    dp.set_j(i, k, v);
                }
                if with_field {
                    for i in 0..n {
                        dp.h[i] = rng.range_i64(-3, 4) as f64;
                    }
                    sp.h = dp.h.clone();
                }
                let cfg = NetworkConfig::paper(sp.embed_dim());
                let (wd, ed) = dp.embed_with_error(&cfg);
                let (ws, es) = sp.embed_sparse_with_error(&cfg);
                assert_eq!(
                    es.to_bits(),
                    ed.to_bits(),
                    "quantization error diverged (n={n} field={with_field})"
                );
                assert_eq!(
                    ws.to_dense(),
                    wd,
                    "quantized entries diverged (n={n} field={with_field})"
                );
                assert!(ws.is_symmetric());
                // The dense fallback of a sparse-form problem (rtl /
                // above-threshold path) matches too.
                let (wf, ef) = sp.embed_with_error(&cfg);
                assert_eq!(wf, wd);
                assert_eq!(ef.to_bits(), ed.to_bits());
            }
        }
    }

    #[test]
    fn zero_interaction_detection() {
        let p = IsingProblem::from_edges(4, &[]).unwrap();
        assert!(p.is_zero_interaction());
        let mut p2 = IsingProblem::from_edges(4, &[]).unwrap();
        p2.h[1] = 0.5;
        assert!(!p2.is_zero_interaction(), "a field is an interaction");
        let p3 = IsingProblem::from_edges(4, &[(0, 1, 0.0)]).unwrap();
        assert!(p3.is_zero_interaction(), "explicit zero-weight edges");
        let p4 = IsingProblem::from_edges(4, &[(0, 1, 1.0)]).unwrap();
        assert!(!p4.is_zero_interaction());
        assert!(IsingProblem::new(3).is_zero_interaction());
        let mut d = IsingProblem::new(3);
        d.set_j(0, 1, 1.0);
        assert!(!d.is_zero_interaction());
    }

    #[test]
    fn sparse_validate_catches_malformed() {
        let mut p = IsingProblem::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        p.j = vec![0.0; 9];
        assert!(p.validate().is_err(), "dense j alongside sparse form");
        let mut p = IsingProblem::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        // Tamper one orientation: symmetry check must catch it.
        p.sparse.as_mut().unwrap().vals[0] = 2.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn phase_energy_matches_energy_on_binary_states() {
        let mut rng = Rng::new(36);
        let p = random_problem(&mut rng, 6, false);
        for _ in 0..10 {
            let spins: Vec<i8> = (0..6).map(|_| rng.spin()).collect();
            let phases: Vec<i32> = spins.iter().map(|&s| if s > 0 { 0 } else { 8 }).collect();
            assert!((p.energy(&spins) - p.phase_energy(&phases, 16)).abs() < 1e-9);
        }
    }
}
