//! The solver's problem IR: a quantizable Ising Hamiltonian
//! `H(s) = -1/2 sum_{i != j} J_ij s_i s_j - sum_i h_i s_i` with an
//! optional multi-phase (Potts-like) mode for sector-encoded problems
//! such as k-coloring, plus the QUBO <-> Ising converter every textbook
//! reduction routes through.
//!
//! External fields have no direct analog in the coupling-only ONN
//! fabric, so [`IsingProblem::embed`] uses the standard gauge trick: one
//! ancilla oscillator coupled to every biased spin with `J_{i,anc} =
//! h_i`.  The ground state is recovered relative to the ancilla's sign
//! ([`IsingProblem::decode_spins`]), which makes the embedding exact —
//! not a penalty approximation.

use crate::onn::config::NetworkConfig;
use crate::onn::energy::waveform_correlation;
use crate::onn::phase::{phase_to_spin, state_to_spins};
use crate::onn::weights::WeightMatrix;

/// Descriptive metadata carried alongside the Hamiltonian.
#[derive(Debug, Clone, Default)]
pub struct ProblemMeta {
    /// Human-readable problem family ("max-cut", "qubo", ...).
    pub kind: String,
    /// Constant added to `energy` to recover the original objective
    /// (QUBO reductions are energy-equal only up to a constant).
    pub offset: f64,
}

/// An Ising optimization instance.
#[derive(Debug, Clone)]
pub struct IsingProblem {
    pub n: usize,
    /// Symmetric couplings, row-major `j[i * n + k]`; diagonal ignored.
    pub j: Vec<f64>,
    /// External fields, length `n`.
    pub h: Vec<f64>,
    /// Phase sectors the state is decoded into: 2 = binary Ising,
    /// k > 2 = multi-phase sector encoding (e.g. k-coloring).
    pub sectors: usize,
    pub metadata: ProblemMeta,
}

impl IsingProblem {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            j: vec![0.0; n * n],
            h: vec![0.0; n],
            sectors: 2,
            metadata: ProblemMeta::default(),
        }
    }

    pub fn with_kind(mut self, kind: &str) -> Self {
        self.metadata.kind = kind.to_string();
        self
    }

    #[inline]
    pub fn get_j(&self, i: usize, k: usize) -> f64 {
        self.j[i * self.n + k]
    }

    /// Symmetric coupling setter.
    pub fn set_j(&mut self, i: usize, k: usize, v: f64) {
        assert_ne!(i, k, "diagonal couplings are ignored; use h for biases");
        self.j[i * self.n + k] = v;
        self.j[k * self.n + i] = v;
    }

    /// Symmetric coupling increment (reductions accumulate terms).
    pub fn add_j(&mut self, i: usize, k: usize, v: f64) {
        assert_ne!(i, k);
        self.j[i * self.n + k] += v;
        self.j[k * self.n + i] += v;
    }

    pub fn has_field(&self) -> bool {
        self.h.iter().any(|&x| x != 0.0)
    }

    /// Structural validity: square J, matching h, symmetric couplings.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("empty problem (n = 0)".into());
        }
        if self.j.len() != self.n * self.n {
            return Err(format!("j has {} entries, want n^2 = {}", self.j.len(), self.n * self.n));
        }
        if self.h.len() != self.n {
            return Err(format!("h has {} entries, want n = {}", self.h.len(), self.n));
        }
        if self.sectors < 2 {
            return Err(format!("sectors {} < 2", self.sectors));
        }
        for i in 0..self.n {
            for k in (i + 1)..self.n {
                if (self.get_j(i, k) - self.get_j(k, i)).abs() > 1e-9 {
                    return Err(format!("asymmetric coupling at ({i}, {k})"));
                }
            }
        }
        Ok(())
    }

    /// `H(s) = -1/2 sum_{i != j} J_ij s_i s_j - sum_i h_i s_i`.
    pub fn energy(&self, spins: &[i8]) -> f64 {
        assert_eq!(spins.len(), self.n);
        let mut e = 0.0;
        for i in 0..self.n {
            for k in 0..self.n {
                if i != k {
                    e -= 0.5 * self.get_j(i, k) * spins[i] as f64 * spins[k] as f64;
                }
            }
            e -= self.h[i] * spins[i] as f64;
        }
        e
    }

    /// Original objective value (energy plus the reduction offset).
    pub fn objective(&self, spins: &[i8]) -> f64 {
        self.energy(spins) + self.metadata.offset
    }

    /// Phase-domain energy proxy using the square-wave correlation
    /// (coincides with [`Self::energy`] on binary phase states); used to
    /// rank multi-phase (sector) replicas where no spin decode exists.
    pub fn phase_energy(&self, phases: &[i32], p: i32) -> f64 {
        assert_eq!(phases.len(), self.n);
        let mut e = 0.0;
        for i in 0..self.n {
            for k in 0..self.n {
                if i != k {
                    e -= 0.5
                        * self.get_j(i, k)
                        * waveform_correlation(phases[i], phases[k], p);
                }
            }
            // Fields only make sense for binary problems, where the
            // solver evaluates via `energy` on decoded spins instead;
            // include them against phase 0 for completeness.
            e -= self.h[i] * waveform_correlation(phases[i], 0, p);
        }
        e
    }

    /// Number of oscillators the embedded network needs (ancilla
    /// included when fields are present).
    pub fn embed_dim(&self) -> usize {
        self.n + usize::from(self.has_field())
    }

    /// Quantize into the ONN coupling fabric.  Fields become couplings
    /// to one trailing ancilla oscillator (`J_{i,anc} = h_i`); the whole
    /// matrix is scaled so the largest magnitude maps to the positive
    /// quantization limit.
    pub fn embed(&self, cfg: &NetworkConfig) -> WeightMatrix {
        self.embed_with_error(cfg).0
    }

    /// [`Self::embed`] plus the quantization error it cost (RMS rounding
    /// loss as a fraction of the quantization full scale — see
    /// [`WeightMatrix::quantize_with_error`]), which the solver surfaces
    /// per solve outcome.
    pub fn embed_with_error(&self, cfg: &NetworkConfig) -> (WeightMatrix, f64) {
        let m = self.embed_dim();
        assert_eq!(cfg.n, m, "config sized {} but embedding needs {m}", cfg.n);
        let mut master = vec![0f32; m * m];
        for i in 0..self.n {
            for k in 0..self.n {
                if i != k {
                    master[i * m + k] = self.get_j(i, k) as f32;
                }
            }
        }
        if self.has_field() {
            let anc = self.n;
            for i in 0..self.n {
                master[i * m + anc] = self.h[i] as f32;
                master[anc * m + i] = self.h[i] as f32;
            }
        }
        WeightMatrix::quantize_with_error(&master, m, cfg)
    }

    /// Decode an embedded phase state (length [`Self::embed_dim`]) into
    /// problem spins (length `n`), gauge-fixed to the ancilla when
    /// fields are present.
    pub fn decode_spins(&self, phases: &[i32], p: i32) -> Vec<i8> {
        assert_eq!(phases.len(), self.embed_dim());
        if self.has_field() {
            let anc = phases[self.n];
            (0..self.n)
                .map(|i| phase_to_spin(phases[i], anc, p))
                .collect()
        } else {
            state_to_spins(&phases[..self.n], p)
        }
    }

    /// Exhaustive ground-state search; test-sized instances only.
    pub fn brute_force(&self) -> (Vec<i8>, f64) {
        assert!(self.n <= 24, "brute force capped at n = 24");
        let mut best_spins = vec![1i8; self.n];
        let mut best_e = f64::INFINITY;
        for mask in 0u64..(1u64 << self.n) {
            let spins: Vec<i8> = (0..self.n)
                .map(|i| if mask >> i & 1 == 1 { 1 } else { -1 })
                .collect();
            let e = self.energy(&spins);
            if e < best_e {
                best_e = e;
                best_spins = spins;
            }
        }
        (best_spins, best_e)
    }

    /// Convert to QUBO over `x = (1 + s) / 2`:
    /// `E(x) = sum_ij Q_ij x_i x_j` with `E(x(s)) = energy(s) + C`.
    pub fn to_qubo(&self) -> Qubo {
        let n = self.n;
        let mut q = vec![0.0; n * n];
        for i in 0..n {
            let mut row_off = 0.0;
            for k in 0..n {
                if i != k {
                    q[i * n + k] = -2.0 * self.get_j(i, k);
                    row_off += self.get_j(i, k);
                }
            }
            // h_i = -(sum_k Q_ik) / 2  =>  Q_ii = -2 h_i + 2 sum_{k != i} J_ik
            q[i * n + i] = -2.0 * self.h[i] + 2.0 * row_off;
        }
        Qubo { n, q }
    }
}

/// A QUBO instance: `E(x) = sum_i sum_j Q_ij x_i x_j` over binary
/// `x in {0, 1}^n` (diagonal entries are the linear terms, `x_i^2 = x_i`;
/// off-diagonal entries are stored symmetrically).
#[derive(Debug, Clone)]
pub struct Qubo {
    pub n: usize,
    pub q: Vec<f64>,
}

impl Qubo {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            q: vec![0.0; n * n],
        }
    }

    #[inline]
    pub fn get(&self, i: usize, k: usize) -> f64 {
        self.q[i * self.n + k]
    }

    /// Add `v * x_i * x_j` (split symmetrically for i != j).
    pub fn add(&mut self, i: usize, k: usize, v: f64) {
        if i == k {
            self.q[i * self.n + i] += v;
        } else {
            self.q[i * self.n + k] += v / 2.0;
            self.q[k * self.n + i] += v / 2.0;
        }
    }

    /// Add `v * x_i` (linear term).
    pub fn add_linear(&mut self, i: usize, v: f64) {
        self.q[i * self.n + i] += v;
    }

    pub fn value(&self, x: &[u8]) -> f64 {
        assert_eq!(x.len(), self.n);
        let mut e = 0.0;
        for i in 0..self.n {
            if x[i] == 0 {
                continue;
            }
            for k in 0..self.n {
                if x[k] != 0 {
                    e += self.get(i, k);
                }
            }
        }
        e
    }

    /// Convert to Ising via `x = (1 + s) / 2`; the returned problem's
    /// `metadata.offset` makes `objective(s) == value(x(s))` exactly.
    pub fn to_ising(&self) -> IsingProblem {
        let n = self.n;
        let mut p = IsingProblem::new(n).with_kind("qubo");
        let mut offset = 0.0;
        for i in 0..n {
            let mut row_sum = 0.0;
            for k in 0..n {
                row_sum += self.get(i, k);
                if i != k {
                    p.j[i * n + k] = -self.get(i, k) / 2.0;
                    offset += self.get(i, k) / 4.0;
                }
            }
            p.h[i] = -row_sum / 2.0;
            offset += self.get(i, i) / 2.0;
        }
        p.metadata.offset = offset;
        p
    }
}

/// Map binary spins to QUBO bits (`+1 -> 1`, `-1 -> 0`).
pub fn spins_to_bits(spins: &[i8]) -> Vec<u8> {
    spins.iter().map(|&s| u8::from(s > 0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_problem(rng: &mut Rng, n: usize, with_field: bool) -> IsingProblem {
        let mut p = IsingProblem::new(n);
        for i in 0..n {
            for k in (i + 1)..n {
                p.set_j(i, k, rng.range_i64(-5, 6) as f64);
            }
            if with_field {
                p.h[i] = rng.range_i64(-3, 4) as f64;
            }
        }
        p
    }

    #[test]
    fn energy_matches_onn_energy_module() {
        // The f64 energy must agree with onn::energy on quantized
        // integer couplings.
        use crate::onn::energy::ising_energy;
        let mut rng = Rng::new(31);
        let n = 8;
        let mut p = IsingProblem::new(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for k in (i + 1)..n {
                let v = rng.range_i64(-10, 11);
                p.set_j(i, k, v as f64);
                w.set(i, k, v as i8);
                w.set(k, i, v as i8);
            }
        }
        for _ in 0..10 {
            let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            assert!((p.energy(&spins) - ising_energy(&w, &spins)).abs() < 1e-9);
        }
    }

    #[test]
    fn qubo_ising_energy_identity() {
        let mut rng = Rng::new(32);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(7);
            let mut q = Qubo::new(n);
            for i in 0..n {
                for k in i..n {
                    q.add(i, k, rng.range_i64(-6, 7) as f64);
                }
            }
            let p = q.to_ising();
            let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            let x = spins_to_bits(&spins);
            assert!(
                (q.value(&x) - p.objective(&spins)).abs() < 1e-9,
                "qubo {} vs ising {}",
                q.value(&x),
                p.objective(&spins)
            );
        }
    }

    #[test]
    fn qubo_roundtrip_preserves_couplings() {
        let mut rng = Rng::new(33);
        let p = random_problem(&mut rng, 6, true);
        let back = p.to_qubo().to_ising();
        for i in 0..p.n {
            assert!((p.h[i] - back.h[i]).abs() < 1e-9, "h[{i}]");
            for k in 0..p.n {
                if i != k {
                    assert!((p.get_j(i, k) - back.get_j(i, k)).abs() < 1e-9, "j[{i}][{k}]");
                }
            }
        }
    }

    #[test]
    fn embed_without_field_matches_quantize() {
        let mut rng = Rng::new(34);
        let mut p = random_problem(&mut rng, 5, false);
        p.set_j(0, 1, 5.0); // pin the largest magnitude
        assert_eq!(p.embed_dim(), 5);
        let cfg = NetworkConfig::paper(5);
        let w = p.embed(&cfg);
        assert!(w.is_symmetric());
        assert_eq!(w.max_abs(), 15); // strongest coupling saturates
    }

    #[test]
    fn embed_with_field_adds_ancilla_and_decodes_gauge() {
        let mut rng = Rng::new(35);
        let mut p = random_problem(&mut rng, 4, true);
        p.h[0] = 2.0; // guarantee a field so the ancilla is present
        assert_eq!(p.embed_dim(), 5);
        let cfg = NetworkConfig::paper(5);
        let w = p.embed(&cfg);
        assert!(w.is_symmetric());
        // Decoding is gauge-fixed to the ancilla: flipping the whole
        // embedded state leaves the decoded spins unchanged.
        let phases = vec![0, 8, 0, 8, 0];
        let flipped: Vec<i32> = phases.iter().map(|&x| (x + 8) % 16).collect();
        assert_eq!(p.decode_spins(&phases, 16), p.decode_spins(&flipped, 16));
        assert_eq!(p.decode_spins(&phases, 16), vec![1, -1, 1, -1]);
    }

    #[test]
    fn brute_force_finds_ferro_ground_state() {
        let mut p = IsingProblem::new(3);
        p.set_j(0, 1, 2.0);
        p.set_j(1, 2, 2.0);
        p.h[0] = 0.5; // break the global-flip degeneracy
        let (spins, e) = p.brute_force();
        assert_eq!(spins, vec![1, 1, 1]);
        assert!((e - (-4.5)).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_malformed() {
        let mut p = IsingProblem::new(3);
        assert!(p.validate().is_ok());
        p.j[1] = 3.0; // asymmetric
        assert!(p.validate().is_err());
        let mut p = IsingProblem::new(2);
        p.h.pop();
        assert!(p.validate().is_err());
        assert!(IsingProblem::new(0).validate().is_err());
    }

    #[test]
    fn phase_energy_matches_energy_on_binary_states() {
        let mut rng = Rng::new(36);
        let p = random_problem(&mut rng, 6, false);
        for _ in 0..10 {
            let spins: Vec<i8> = (0..6).map(|_| rng.spin()).collect();
            let phases: Vec<i32> = spins.iter().map(|&s| if s > 0 { 0 } else { 8 }).collect();
            assert!((p.energy(&spins) - p.phase_energy(&phases, 16)).abs() < 1e-9);
        }
    }
}
