//! The annealed batched portfolio solver: many random-init replicas of
//! one Ising instance run as a single batch on any [`ChunkEngine`],
//! with a phase-noise annealing schedule driving the engine's noise
//! hook, per-chunk best-replica tracking through the problem's energy,
//! an energy-plateau early exit, and a deterministic greedy-descent
//! readout polish.
//!
//! This is the serving path for the paper's target workload
//! (combinatorial optimization): the same batched chunk contract the
//! retrieval coordinator drives, so one engine fabric serves both
//! traffic classes.

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::phase::spin_to_phase;
use crate::runtime::cluster::RtlClusterEngine;
use crate::runtime::native::NativeEngine;
use crate::runtime::rtl::RtlEngine;
use crate::runtime::sharded::ShardedEngine;
use crate::runtime::{ChunkEngine, HardwareCost};
use crate::solver::anneal::Schedule;
use crate::solver::problem::IsingProblem;
use crate::solver::sa::greedy_descent;
use crate::telemetry::{TraceEvent, TraceSink};
use crate::util::rng::Rng;

/// Embedded sizes at or above this many oscillators default to the
/// sharded fabric: a single device tops out near the paper's 506
/// oscillators, so one engine per request stops scaling well before the
/// wire's 4096-oscillator cap.
pub const DEFAULT_SHARD_THRESHOLD: usize = 256;

/// Default cap on shard workers per solve.
pub const DEFAULT_MAX_SHARDS: usize = 8;

/// Coupling densities at or below this fraction route a sparse-form
/// problem onto the engine's CSR fabric (when the engine has one).
/// Above it the dense kernel wins: the sparse inner loop pays an index
/// indirection per nonzero, which a quarter-full matrix already
/// amortizes away, and the dense fabric is the fleet-wide common case
/// the arena keeps warm.
pub const SPARSE_DENSITY_THRESHOLD: f64 = 0.25;

/// True when a solve of `problem` should install a CSR sparse fabric:
/// the problem is in sparse coupling form (built via
/// [`IsingProblem::from_edges`] — the wire's `"edges"` requests) AND its
/// density is at or below [`SPARSE_DENSITY_THRESHOLD`].  Field problems
/// stay eligible: the ancilla row/column adds at most `2n` entries.
/// The answer is a pure function of the problem, so every layer
/// (portfolio install, arena keying, pack planner) agrees on which
/// fabric a request lands on.
pub fn wants_sparse(problem: &IsingProblem) -> bool {
    problem.is_sparse() && problem.coupling_density() <= SPARSE_DENSITY_THRESHOLD
}

/// Replicas driven per engine wave: the solo portfolio caps one batch
/// at this many random-init trials (more replicas run as extra waves),
/// and a packed lane block carries at most this many lanes, so packed
/// and solo runs always share identical wave geometry.
pub const MAX_WAVE_REPLICAS: usize = 64;

/// Default periods per engine chunk — the granularity at which the
/// annealing schedule is stepped and settle flags are read.  Shared by
/// the solo and packed solve paths so a packed lane's chunk walk is
/// identical to its solo run.
pub const DEFAULT_CHUNK: usize = 8;

/// Which engine fabric a solve runs on — the engine-selection layer the
/// coordinator's solver pool and the CLI configure.  Among the float
/// fabrics selection never changes the answer: the sharded engine is
/// bit-exact with the native one (noise included), so that choice is
/// purely capacity/locality.  [`EngineSelect::Rtl`] is different in
/// kind: it runs the *bit-true hardware model* (cycle-accurate serial
/// MACs, RTL settle semantics), deterministic at equal seed but not
/// trajectory-identical to the float fabrics — and it reports the
/// emulated hardware cost in the outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSelect {
    /// Single in-process engine.
    Native,
    /// Row-sharded leader + worker cluster with exactly this many
    /// shards (a count of 1 collapses to the native engine).
    Sharded { shards: usize },
    /// The bit-true emulated-hardware engine (`runtime::rtl`): the
    /// paper's serial-MAC hybrid datapath (paper precision unless the
    /// params carry an explicit precision sweep point).
    Rtl,
    /// An emulated multi-FPGA cluster of this many devices composing
    /// the bit-true hardware engine (`runtime::cluster`): row-split
    /// quantized weight memory, per-device `SerialMac` meters, and a
    /// priced per-period phase all-gather.  Bit-exact with
    /// [`EngineSelect::Rtl`] — only the hardware cost model changes.
    RtlCluster { shards: usize },
    /// Native below `threshold` oscillators; at or above it, one shard
    /// per `threshold` rows (`ceil(m / threshold)`, at least 2), capped
    /// at `max_shards`.  A `max_shards` below 2 disables sharding
    /// entirely (every size runs native).
    Auto { threshold: usize, max_shards: usize },
}

impl Default for EngineSelect {
    fn default() -> Self {
        EngineSelect::Auto {
            threshold: DEFAULT_SHARD_THRESHOLD,
            max_shards: DEFAULT_MAX_SHARDS,
        }
    }
}

impl EngineSelect {
    /// Shard count this selection resolves to for an `m`-oscillator
    /// embedding (1 = single native engine).  Never exceeds `m`: a
    /// shard needs at least one row.
    pub fn shards_for(&self, m: usize) -> usize {
        let k = match *self {
            EngineSelect::Native | EngineSelect::Rtl => 1,
            // One logical fabric: the cluster's device count shapes its
            // hardware model, not the float-side engine topology.
            EngineSelect::RtlCluster { .. } => 1,
            EngineSelect::Sharded { shards } => shards.max(1),
            EngineSelect::Auto { threshold, max_shards } => {
                let t = threshold.max(1);
                if m < t || max_shards < 2 {
                    1
                } else {
                    m.div_ceil(t).clamp(2, max_shards)
                }
            }
        };
        k.min(m.max(1))
    }
}

/// Build the engine a selection resolves to for an `m`-oscillator
/// problem (`batch` replicas per wave, `chunk` periods per engine call)
/// at paper precision.
pub fn build_engine(
    m: usize,
    batch: usize,
    chunk: usize,
    select: EngineSelect,
) -> Result<Box<dyn ChunkEngine>> {
    build_engine_cfg(NetworkConfig::paper(m), batch, chunk, select)
}

/// [`build_engine`] at an explicit network configuration — the serve
/// path's precision sweep constructs engines through this so
/// `--weight-bits`/`--phase-bits` reach every fabric.
pub fn build_engine_cfg(
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    select: EngineSelect,
) -> Result<Box<dyn ChunkEngine>> {
    match select {
        EngineSelect::Rtl => Ok(Box::new(RtlEngine::new(cfg, batch, chunk))),
        EngineSelect::RtlCluster { shards } => {
            Ok(Box::new(RtlClusterEngine::new(cfg, shards, batch, chunk)?))
        }
        _ => {
            let shards = select.shards_for(cfg.n);
            if shards <= 1 {
                Ok(Box::new(NativeEngine::new(cfg, batch, chunk)))
            } else {
                Ok(Box::new(ShardedEngine::unprogrammed(cfg, shards, batch, chunk)?))
            }
        }
    }
}

/// Drive one deterministic retrieval trial on any engine fabric: fill
/// every batch lane with `init_phases`, declare a one-trial wave (the
/// rtl engine's hidden per-lane register state needs the explicit
/// [`ChunkEngine::begin_wave`] — value-sniffing cannot see a warm
/// lane), and run chunks until lane 0 settles, goes hopeless (phases
/// unchanged across a full chunk without settling: a limit cycle whose
/// length divides the chunk), or the period budget runs out.
///
/// Returns lane 0's final phases and its settle period (`None` on
/// timeout).  No noise is installed and none survives from a previous
/// tenant on the serving path (the associative worker never installs
/// any), so the trajectory is a pure function of (weights, init) — the
/// warm-engine recall is bit-identical to a cold build, on every
/// fabric.  The associative-memory recall path
/// (`coordinator/assoc.rs`) and its bit-identity property tests both
/// drive retrievals through this one helper.
pub fn drive_retrieval(
    engine: &mut dyn ChunkEngine,
    init_phases: &[i32],
    max_periods: usize,
) -> Result<(Vec<i32>, Option<usize>)> {
    let n = engine.n();
    if init_phases.len() != n {
        return Err(anyhow!(
            "retrieval init has {} phases, engine wants {n}",
            init_phases.len()
        ));
    }
    let batch = engine.batch();
    let chunk = engine.chunk_len();
    let mut phases = Vec::with_capacity(batch * n);
    for _ in 0..batch {
        phases.extend_from_slice(init_phases);
    }
    let mut settled = vec![-1i32; batch];
    engine.begin_wave(1)?;
    let mut period = 0usize;
    while period < max_periods && settled[0] < 0 {
        let before = phases[..n].to_vec();
        engine.run_chunk(&mut phases, &mut settled, period as i32)?;
        period += chunk;
        if settled[0] < 0 && phases[..n] == before[..] {
            break; // limit cycle: it can never settle, stop burning periods
        }
    }
    let settle = (settled[0] >= 0).then_some(settled[0] as usize);
    Ok((phases[..n].to_vec(), settle))
}

/// Portfolio solve parameters.
#[derive(Debug, Clone, Copy)]
pub struct PortfolioParams {
    /// Random-init trials run as one batch (waves of `engine.batch()`).
    pub replicas: usize,
    /// Periods driven per replica (rounded up to whole chunks).
    pub max_periods: usize,
    pub schedule: Schedule,
    pub seed: u64,
    /// Early exit after this many consecutive noise-free chunks without
    /// a best-energy improvement (0 disables the early exit).
    pub plateau_chunks: usize,
    /// Greedy single-flip readout polish (binary problems only).
    pub polish: bool,
    /// Periods per engine chunk, threaded into the engine the solve
    /// builds.  Packed solves require every co-scheduled lane's params
    /// to match the shared engine's chunk (part of the batching
    /// compatibility rules, DESIGN_SOLVER.md §7).
    pub chunk: usize,
    /// Explicit `(weight_bits, phase_bits)` precision sweep point;
    /// `None` runs the paper's 5w/4p reference point.  Threaded into
    /// engine construction AND problem quantization (they must agree),
    /// which is why the embed sites below go through [`Self::cfg`].
    /// Packed solves require every co-scheduled entry to share it —
    /// precision is part of the engine geometry, like `chunk`.
    pub precision: Option<(u32, u32)>,
}

impl PortfolioParams {
    /// The network configuration this solve quantizes and runs at for
    /// an `m`-oscillator embedding: the paper point, or the explicit
    /// precision sweep point when one is set.
    pub fn cfg(&self, m: usize) -> NetworkConfig {
        match self.precision {
            Some((wb, pb)) => NetworkConfig::with_precision(m, wb, pb),
            None => NetworkConfig::paper(m),
        }
    }
}

impl Default for PortfolioParams {
    fn default() -> Self {
        Self {
            replicas: 32,
            max_periods: 256,
            schedule: Schedule::Geometric {
                start: 0.6,
                factor: 0.8,
            },
            seed: 1,
            plateau_chunks: 3,
            polish: true,
            chunk: DEFAULT_CHUNK,
            precision: None,
        }
    }
}

/// Result of one portfolio solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Best decoded spins (length `problem.n`; for sector problems the
    /// binary decode of the best phase state — use `best_phases`).
    pub best_spins: Vec<i8>,
    /// Best phase state (length `problem.n`, ancilla stripped).
    pub best_phases: Vec<i32>,
    /// `problem.energy` of the best state (offset excluded).  For
    /// sector problems this is the phase-energy proxy.
    pub best_energy: f64,
    /// Best energy among the replicas' *initial* states — the solver
    /// never returns anything worse than this.
    pub initial_best_energy: f64,
    /// Final phase state of every replica (ancilla stripped), for
    /// decoders that rank replicas by their own objective.
    pub replica_phases: Vec<Vec<i32>>,
    /// Total chunk-periods driven by the engine, summed over waves
    /// (each period advances the whole batch of replicas at once).
    pub periods: usize,
    pub chunks: usize,
    pub replicas: usize,
    /// Replicas whose final noise-free chunk reported a fixed point.
    pub settled_replicas: usize,
    pub early_exit: bool,
    /// False when the engine has no noise hook (schedule was skipped).
    pub noise_applied: bool,
    /// Engine kind that ran the solve ("native" / "sharded" / "rtl" /
    /// "rtl-cluster" / "pjrt").
    pub engine: &'static str,
    /// All-gather synchronization rounds the engine performed — the
    /// multi-device sync-cost metric (0 on single-device engines).
    pub sync_rounds: u64,
    /// RMS rounding loss of mapping the problem's couplings through
    /// `WeightMatrix::quantize` at the engine's precision, as a fraction
    /// of the quantization full scale (0 = exactly representable).
    pub quantization_error: f64,
    /// True when the solve ran on the engine's CSR sparse fabric
    /// (sparse-form problem at or under [`SPARSE_DENSITY_THRESHOLD`] on
    /// a sparse-capable engine).  Bit-identical answers either way —
    /// this reports which kernel did the work.
    pub sparse: bool,
    /// Emulated hardware cost of the solve — present only when the
    /// engine models the synthesized design (the rtl engine).
    pub hardware: Option<HardwareCost>,
}

/// Record one lifecycle event when a sink is attached; free when not.
fn trace_event(trace: Option<&TraceSink>, event: TraceEvent) {
    if let Some(sink) = trace {
        sink.borrow_mut().record(event);
    }
}

/// Error message of a cancelled solve.  The vendored `anyhow` stand-in
/// has no typed downcast, so cancellation is signalled by this sentinel
/// message and detected with [`is_cancelled`] — callers must not wrap
/// the error in further context before checking.
pub const CANCELLED_MSG: &str = "solve cancelled: client went away";

/// The error a cancelled solve returns.
pub fn cancelled_err() -> anyhow::Error {
    anyhow!(CANCELLED_MSG)
}

/// Whether an error is the cancellation sentinel (see [`CANCELLED_MSG`]).
pub fn is_cancelled(e: &anyhow::Error) -> bool {
    e.to_string() == CANCELLED_MSG
}

/// Optional per-solve lifecycle hooks threaded from the serving front
/// end into the chunk loop: a cancel flag checked at every chunk
/// boundary (a disconnected client's solve stops mid-anneal instead of
/// burning its full period budget) and a progress callback fired once
/// per chunk with the running best energy and periods driven so far
/// (the `{"type":"progress"}` stream of the evented server).  Both
/// hooks only *observe* values the solve computed anyway — a hooked
/// run that is never cancelled is bit-identical to an unhooked one.
#[derive(Clone, Copy, Default)]
pub struct SolveHooks<'a> {
    pub cancel: Option<&'a AtomicBool>,
    pub progress: Option<&'a dyn Fn(f64, usize)>,
}

impl SolveHooks<'_> {
    pub fn cancelled(&self) -> bool {
        self.cancel.is_some_and(|c| c.load(Ordering::Relaxed))
    }

    fn emit_progress(&self, best_energy: f64, periods: usize) {
        if let Some(f) = self.progress {
            f(best_energy, periods);
        }
    }
}

/// Run the portfolio on an already-constructed engine.  The engine's
/// network size must equal [`IsingProblem::embed_dim`]; weights are
/// installed here.
pub fn solve_portfolio(
    engine: &mut dyn ChunkEngine,
    problem: &IsingProblem,
    params: &PortfolioParams,
) -> Result<SolveOutcome> {
    solve_portfolio_traced(engine, problem, params, None)
}

/// [`solve_portfolio`] with an optional lifecycle trace sink
/// (DESIGN_SOLVER.md §9).  The sink is installed on the engine for the
/// duration of the solve, so engine `engine_chunk` spans interleave
/// with the portfolio's wave/chunk events.  Tracing only *observes*
/// values the solve computed anyway — it draws nothing from the RNG
/// and issues no extra engine calls, so a traced solve is bit-identical
/// to an untraced one at equal seed.
pub fn solve_portfolio_traced(
    engine: &mut dyn ChunkEngine,
    problem: &IsingProblem,
    params: &PortfolioParams,
    trace: Option<&TraceSink>,
) -> Result<SolveOutcome> {
    solve_portfolio_hooked(engine, problem, params, trace, SolveHooks::default())
}

/// [`solve_portfolio_traced`] with serving-lifecycle hooks
/// ([`SolveHooks`]): the cancel flag is checked before every chunk
/// (returning the [`CANCELLED_MSG`] sentinel error when set — the
/// engine is left healthy, weights installed and reusable), and the
/// progress callback fires once per chunk.  With default hooks this is
/// exactly [`solve_portfolio_traced`].
pub fn solve_portfolio_hooked(
    engine: &mut dyn ChunkEngine,
    problem: &IsingProblem,
    params: &PortfolioParams,
    trace: Option<&TraceSink>,
    hooks: SolveHooks<'_>,
) -> Result<SolveOutcome> {
    problem.validate().map_err(|e| anyhow!("bad problem: {e}"))?;
    if params.replicas == 0 {
        return Err(anyhow!("replicas must be positive"));
    }
    let m = problem.embed_dim();
    if engine.n() != m {
        return Err(anyhow!(
            "engine serves n={}, problem embeds into n={m}",
            engine.n()
        ));
    }
    // Quantize at the same precision the engine was built with
    // (paper's 5w/4p unless the params carry a sweep point) — engine
    // construction and problem embedding must agree on the weight range
    // and phase wheel.
    let cfg = params.cfg(m);
    let p = cfg.period() as i32;
    if problem.sectors > cfg.period() {
        return Err(anyhow!(
            "{} sectors exceed the {}-step phase wheel",
            problem.sectors,
            cfg.period()
        ));
    }
    // Fabric selection: sparse-form problems under the density
    // threshold install straight into the engine's CSR kernel — no n^2
    // materialization anywhere on the path.  The sparse quantizer is
    // bit-exact with the dense one (same f32 scale, same row-major
    // rounding walk), and the sparse period kernel is bit-identical to
    // the dense kernel on the same matrix, so this choice never changes
    // an answer (rust/tests/prop_sparse.rs holds the proof obligation).
    let use_sparse = wants_sparse(problem) && engine.supports_sparse();
    let quantization_error = if use_sparse {
        let (sw, qe) = problem.embed_sparse_with_error(&cfg);
        engine.set_weights_sparse(&sw)?;
        qe
    } else {
        let (wq, qe) = problem.embed_with_error(&cfg);
        engine.set_weights(&wq.to_f32())?;
        qe
    };
    // Warm engines carry sync rounds from earlier solves (set_weights
    // reprograms without resetting the counter), so report this solve's
    // delta — on a cold engine the baseline is 0 and nothing changes.
    let sync0 = engine.sync_rounds();
    let noise_applied = engine.supports_noise();
    if let Some(sink) = trace {
        engine.set_trace_sink(Some(sink.clone()));
    }
    trace_event(
        trace,
        TraceEvent::SolveStart {
            n: m,
            engine: engine.kind(),
            replicas: params.replicas,
        },
    );

    let b = engine.batch();
    if b == 0 {
        return Err(anyhow!("engine reports zero batch capacity"));
    }
    let chunk = engine.chunk_len().max(1);
    let chunks_per_wave = params.max_periods.div_ceil(chunk).max(1);
    let binary = problem.sectors == 2;
    let eval = |phases: &[i32]| -> f64 { eval_state(problem, phases, p) };

    let mut rng = Rng::new(params.seed);
    let mut best_energy = f64::INFINITY;
    let mut best_phases = vec![0i32; m];
    let mut initial_best = f64::INFINITY;
    let mut replica_phases: Vec<Vec<i32>> = Vec::with_capacity(params.replicas);
    let mut chunks_run = 0usize;
    let mut settled_replicas = 0usize;
    let mut early_exit = false;
    // Best polished replica (spins, energy) across all waves.
    let mut best_polished: Option<(Vec<i8>, f64)> = None;

    let mut phases = vec![0i32; b * m];
    let mut settled = vec![-1i32; b];
    let mut remaining = params.replicas;
    let mut wave_idx = 0usize;
    while remaining > 0 {
        let real = remaining.min(b);
        // Random init: binary problems start on the binary manifold
        // (the Hopfield submanifold of the phase dynamics), sector
        // problems anywhere on the phase wheel.  Padding slots repeat
        // replica 0 so the batch is well-formed.
        for slot in 0..b {
            let src = slot.min(real - 1);
            if slot < real {
                for i in 0..m {
                    phases[slot * m + i] = if binary {
                        spin_to_phase(rng.spin(), p)
                    } else {
                        rng.range_i64(0, p as i64) as i32
                    };
                }
            } else {
                let copy: Vec<i32> = phases[src * m..(src + 1) * m].to_vec();
                phases[slot * m..(slot + 1) * m].copy_from_slice(&copy);
            }
        }
        settled.iter_mut().for_each(|s| *s = -1);
        // Tell stateful engines the first `real` lanes are fresh trials
        // and the rest is padding (the rtl engine resets those register
        // lanes unconditionally and neither advances nor meters the
        // padding); float fabrics ignore this.
        engine.begin_wave(real)?;
        trace_event(
            trace,
            TraceEvent::WaveStart {
                wave: wave_idx,
                lanes: real,
            },
        );
        for slot in 0..real {
            let e = eval(&phases[slot * m..(slot + 1) * m]);
            initial_best = initial_best.min(e);
            if e < best_energy {
                best_energy = e;
                best_phases.copy_from_slice(&phases[slot * m..(slot + 1) * m]);
            }
        }

        let mut stall = 0usize;
        let mut wave_exit = "completed";
        let mut wave_chunks = 0usize;
        for k in 0..chunks_per_wave {
            if hooks.cancelled() {
                if trace.is_some() {
                    engine.set_trace_sink(None);
                }
                return Err(cancelled_err());
            }
            // On engines without a noise hook no kicks ever happen, so
            // the dynamics are deterministic from chunk 0 and the
            // settle flags / early exits stay live for the whole run.
            let level = if noise_applied {
                params.schedule.level(k, chunks_per_wave)
            } else {
                0.0
            };
            if noise_applied {
                engine.set_noise(level, rng.next_u64())?;
            }
            engine.run_chunk(&mut phases, &mut settled, (k * chunk) as i32)?;
            chunks_run += 1;
            wave_chunks = k + 1;
            if level > 0.0 {
                // Settle flags are meaningless while kicks are active.
                settled.iter_mut().for_each(|s| *s = -1);
            }
            let mut improved = false;
            for slot in 0..real {
                let e = eval(&phases[slot * m..(slot + 1) * m]);
                if e < best_energy - 1e-12 {
                    best_energy = e;
                    best_phases.copy_from_slice(&phases[slot * m..(slot + 1) * m]);
                    improved = true;
                }
            }
            hooks.emit_progress(best_energy, chunks_run * chunk);
            if let Some(sink) = trace {
                let settled_lanes = (0..real).filter(|&slot| settled[slot] >= 0).count();
                sink.borrow_mut().record(TraceEvent::Chunk {
                    wave: wave_idx,
                    chunk: k,
                    noise: level,
                    best_energy,
                    settled_lanes,
                });
            }
            if level == 0.0 {
                let all_settled = (0..real).all(|slot| settled[slot] >= 0);
                if improved {
                    stall = 0;
                } else {
                    stall += 1;
                }
                if all_settled
                    || (params.plateau_chunks > 0 && stall >= params.plateau_chunks)
                {
                    early_exit = k + 1 < chunks_per_wave;
                    wave_exit = if all_settled { "all_settled" } else { "plateau" };
                    break;
                }
            }
        }

        let wave_settled = (0..real).filter(|&slot| settled[slot] >= 0).count();
        settled_replicas += wave_settled;
        trace_event(
            trace,
            TraceEvent::WaveEnd {
                wave: wave_idx,
                lanes: real,
                settled_lanes: wave_settled,
                chunks: wave_chunks,
                exit: wave_exit,
            },
        );
        for slot in 0..real {
            let full = &phases[slot * m..(slot + 1) * m];
            replica_phases.push(full[..problem.n].to_vec());
            if params.polish && binary {
                let post_energy = polish_replica(problem, full, p, &mut best_polished);
                if let Some(sink) = trace {
                    // For binary problems `eval` is exactly the decoded
                    // pre-descent Hamiltonian, so pre/post is the polish
                    // delta.  Computed only when tracing.
                    sink.borrow_mut().record(TraceEvent::Polish {
                        replica: replica_phases.len() - 1,
                        pre_energy: eval(full),
                        post_energy,
                    });
                }
            }
        }
        remaining -= real;
        wave_idx += 1;
    }

    let (best_spins, best_phases, best_energy) =
        finish_readout(problem, params.polish, p, best_energy, best_phases, best_polished);

    trace_event(
        trace,
        TraceEvent::SolveEnd {
            best_energy,
            periods: chunks_run * chunk,
            settled_replicas,
        },
    );
    if trace.is_some() {
        engine.set_trace_sink(None);
    }

    Ok(SolveOutcome {
        best_spins,
        best_phases: best_phases[..problem.n].to_vec(),
        best_energy,
        initial_best_energy: initial_best,
        replica_phases,
        periods: chunks_run * chunk,
        chunks: chunks_run,
        replicas: params.replicas,
        settled_replicas,
        early_exit,
        noise_applied,
        engine: engine.kind(),
        sync_rounds: engine.sync_rounds() - sync0,
        quantization_error,
        sparse: use_sparse,
        hardware: engine.hardware_cost(),
    })
}

/// Replica scoring: the exact Hamiltonian for binary problems (via the
/// gauge decode of the full embedded state), the phase-correlation
/// proxy for sector (Potts-like) problems.  Shared by the solo and
/// packed drivers so both rank replicas identically.
fn eval_state(problem: &IsingProblem, full: &[i32], p: i32) -> f64 {
    if problem.sectors == 2 {
        problem.energy(&problem.decode_spins(full, p))
    } else {
        problem.phase_energy(&full[..problem.n], p)
    }
}

/// Polish one replica's final state (its true ancilla phase still
/// attached — the gauge matters for field problems) and fold it into
/// the running best: strict descent can only improve, so the winner
/// dominates every unpolished replica.  Shared by the solo and packed
/// drivers; callers gate on `polish && binary`.  Returns the polished
/// energy (the trace's `polish.post_energy`).
fn polish_replica(
    problem: &IsingProblem,
    full: &[i32],
    p: i32,
    best_polished: &mut Option<(Vec<i8>, f64)>,
) -> f64 {
    let mut spins = problem.decode_spins(full, p);
    greedy_descent(problem, &mut spins);
    let e = problem.energy(&spins);
    if best_polished.as_ref().map_or(true, |(_, be)| e < *be) {
        *best_polished = Some((spins, e));
    }
    e
}

/// The deterministic readout tail shared by the solo and packed
/// drivers: decode the best tracked state, give it the same polish the
/// replicas got, and let the best polished replica compete —
/// `best_energy` always describes the returned spins.
fn finish_readout(
    problem: &IsingProblem,
    polish: bool,
    p: i32,
    mut best_energy: f64,
    mut best_phases: Vec<i32>,
    best_polished: Option<(Vec<i8>, f64)>,
) -> (Vec<i8>, Vec<i32>, f64) {
    let binary = problem.sectors == 2;
    let mut best_spins = problem.decode_spins(&best_phases, p);
    if polish && binary {
        greedy_descent(problem, &mut best_spins);
        best_energy = problem.energy(&best_spins);
        if let Some((spins, e)) = best_polished {
            if e < best_energy {
                best_energy = e;
                best_spins = spins;
            }
        }
        best_phases = best_spins.iter().map(|&s| spin_to_phase(s, p)).collect();
    }
    (best_spins, best_phases, best_energy)
}

/// Build the selected engine for the problem and run the portfolio on
/// it — the coordinator's solve path.  Batch and chunk geometry are
/// identical across selections, so the outcome is bit-identical whether
/// the fabric is one engine or a shard cluster.
pub fn solve_with(
    problem: &IsingProblem,
    params: &PortfolioParams,
    select: EngineSelect,
) -> Result<SolveOutcome> {
    solve_with_trace(problem, params, select, None)
}

/// [`solve_with`] with an optional lifecycle trace sink — see
/// [`solve_portfolio_traced`] for the tracing contract.
pub fn solve_with_trace(
    problem: &IsingProblem,
    params: &PortfolioParams,
    select: EngineSelect,
    trace: Option<&TraceSink>,
) -> Result<SolveOutcome> {
    if params.chunk == 0 {
        return Err(anyhow!("chunk must be positive"));
    }
    let m = problem.embed_dim();
    let batch = params.replicas.clamp(1, MAX_WAVE_REPLICAS);
    let mut engine = build_engine_cfg(params.cfg(m), batch, params.chunk, select)?;
    solve_portfolio_traced(engine.as_mut(), problem, params, trace)
}

/// Convenience: run the portfolio on a single [`NativeEngine`] sized
/// for the problem.
pub fn solve_native(problem: &IsingProblem, params: &PortfolioParams) -> Result<SolveOutcome> {
    solve_with(problem, params, EngineSelect::Native)
}

// ---- Packed multi-problem solve (DESIGN_SOLVER.md §7) -----------------------

/// First-fit allocator over the engine's batch lanes: tracks free
/// contiguous ranges so retired blocks can be backfilled mid-run.
struct LaneAlloc {
    /// Free `(lane0, len)` ranges, sorted by `lane0`, never adjacent.
    free: Vec<(usize, usize)>,
}

impl LaneAlloc {
    fn new(total: usize) -> Self {
        Self {
            free: vec![(0, total)],
        }
    }

    /// First free range that fits, split on allocation.
    fn alloc(&mut self, lanes: usize) -> Option<usize> {
        debug_assert!(lanes > 0);
        let idx = self.free.iter().position(|&(_, len)| len >= lanes)?;
        let (start, len) = self.free[idx];
        if len == lanes {
            self.free.remove(idx);
        } else {
            self.free[idx] = (start + lanes, len - lanes);
        }
        Some(start)
    }

    /// Return a range, merging with free neighbors.
    fn release(&mut self, lane0: usize, lanes: usize) {
        let idx = self
            .free
            .iter()
            .position(|&(s, _)| s > lane0)
            .unwrap_or(self.free.len());
        self.free.insert(idx, (lane0, lanes));
        if idx + 1 < self.free.len() && self.free[idx].0 + self.free[idx].1 == self.free[idx + 1].0
        {
            self.free[idx].1 += self.free[idx + 1].1;
            self.free.remove(idx + 1);
        }
        if idx > 0 && self.free[idx - 1].0 + self.free[idx - 1].1 == self.free[idx].0 {
            self.free[idx - 1].1 += self.free[idx].1;
            self.free.remove(idx);
        }
    }
}

/// The shared phase/settle buffers plus engine geometry of one packed
/// run (kept separate from the engine so block placement can borrow
/// both without fighting).
struct PackedBuffers {
    phases: Vec<i32>,
    settled: Vec<i32>,
    n: usize,
    p: i32,
    chunk: usize,
}

/// One live lane block inside a packed solve: a problem's replicas
/// occupying lanes `[lane0, lane0 + lanes)` of the shared engine, with
/// exactly the per-problem state the solo portfolio tracks.
struct PackedLane {
    entry: usize,
    lane0: usize,
    lanes: usize,
    /// The problem's embedding size (`<= n`; lanes are zero-padded).
    m: usize,
    /// Private rng replaying the solo draw sequence: replica inits
    /// first, then one kick seed per chunk.
    rng: Rng,
    chunk_idx: usize,
    chunks_per_wave: usize,
    level: f64,
    stall: usize,
    chunks_run: usize,
    best_energy: f64,
    best_phases: Vec<i32>,
    initial_best: f64,
    /// Quantization loss of this problem's embedding (same value its
    /// solo run reports).
    quantization_error: f64,
    /// `Some(early)` once the lane's run is over (plateau/all-settled
    /// early exit, or budget exhausted with `early = false`).
    exit: Option<bool>,
}

/// Program entry `entry` onto lanes `[lane0, lane0 + replicas)`: embed
/// and zero-pad its couplings, draw its replica inits, reset its settle
/// flags.  Padded oscillators are uncoupled (they freeze under the
/// deterministic dynamics, and kicks are per-oscillator independent),
/// so the real oscillators' trajectories are bit-exact with a dedicated
/// engine of size `m` — the lane-packing weight layout's invariant.
fn place_lane(
    engine: &mut dyn ChunkEngine,
    buf: &mut PackedBuffers,
    entries: &[(IsingProblem, PortfolioParams)],
    entry: usize,
    lane0: usize,
) -> Result<PackedLane> {
    let (problem, params) = &entries[entry];
    let (n, p) = (buf.n, buf.p);
    let m = problem.embed_dim();
    let binary = problem.sectors == 2;
    let (wm, quantization_error) = problem.embed_with_error(&params.cfg(m));
    let mut w = vec![0f32; n * n];
    for i in 0..m {
        for j in 0..m {
            w[i * n + j] = wm.get(i, j) as f32;
        }
    }
    engine.set_lane_block(lane0, params.replicas, &w)?;
    let mut rng = Rng::new(params.seed);
    for slot in 0..params.replicas {
        let row = (lane0 + slot) * n;
        for i in 0..m {
            buf.phases[row + i] = if binary {
                spin_to_phase(rng.spin(), p)
            } else {
                rng.range_i64(0, p as i64) as i32
            };
        }
        for i in m..n {
            buf.phases[row + i] = 0;
        }
        buf.settled[lane0 + slot] = -1;
    }
    let mut best_energy = f64::INFINITY;
    let mut best_phases = vec![0i32; m];
    let mut initial_best = f64::INFINITY;
    for slot in 0..params.replicas {
        let row = (lane0 + slot) * n;
        let e = eval_state(problem, &buf.phases[row..row + m], p);
        initial_best = initial_best.min(e);
        if e < best_energy {
            best_energy = e;
            best_phases.copy_from_slice(&buf.phases[row..row + m]);
        }
    }
    Ok(PackedLane {
        entry,
        lane0,
        lanes: params.replicas,
        m,
        rng,
        chunk_idx: 0,
        chunks_per_wave: params.max_periods.div_ceil(buf.chunk).max(1),
        level: 0.0,
        stall: 0,
        chunks_run: 0,
        best_energy,
        best_phases,
        initial_best,
        quantization_error,
        exit: None,
    })
}

/// Read a retired lane block out into a [`SolveOutcome`] — the same
/// readout-polish tail the solo portfolio runs at wave end.
fn finish_lane(
    engine: &dyn ChunkEngine,
    buf: &PackedBuffers,
    entries: &[(IsingProblem, PortfolioParams)],
    lane: &PackedLane,
    early: bool,
    noise_applied: bool,
) -> SolveOutcome {
    let (problem, params) = &entries[lane.entry];
    let (n, p) = (buf.n, buf.p);
    let binary = problem.sectors == 2;
    let mut settled_replicas = 0usize;
    let mut replica_phases = Vec::with_capacity(lane.lanes);
    let mut best_polished: Option<(Vec<i8>, f64)> = None;
    for slot in 0..lane.lanes {
        if buf.settled[lane.lane0 + slot] >= 0 {
            settled_replicas += 1;
        }
        let row = (lane.lane0 + slot) * n;
        let full = &buf.phases[row..row + lane.m];
        replica_phases.push(full[..problem.n].to_vec());
        if params.polish && binary {
            polish_replica(problem, full, p, &mut best_polished);
        }
    }
    let (best_spins, best_phases, best_energy) = finish_readout(
        problem,
        params.polish,
        p,
        lane.best_energy,
        lane.best_phases.clone(),
        best_polished,
    );
    // Attribute only this block's share of the fabric's all-gather
    // rounds: a distributed engine pays one round per period per lane,
    // so the block's own cost is lanes * periods — exactly what a solo
    // run of this problem on the same fabric would report.  (The
    // engine-wide counter spans every co-resident problem.)
    let sync_rounds = if engine.sync_rounds() > 0 {
        (lane.lanes * lane.chunks_run * buf.chunk) as u64
    } else {
        0
    };
    SolveOutcome {
        best_spins,
        best_phases: best_phases[..problem.n].to_vec(),
        best_energy,
        initial_best_energy: lane.initial_best,
        replica_phases,
        periods: lane.chunks_run * buf.chunk,
        chunks: lane.chunks_run,
        replicas: lane.lanes,
        settled_replicas,
        early_exit: early,
        noise_applied,
        engine: engine.kind(),
        sync_rounds,
        quantization_error: lane.quantization_error,
        // Lane blocks carry dense per-block matrices (the zero-padded
        // layout is the packing invariant); sparse problems solve solo.
        sparse: false,
        // On the rtl engine each block meters its own lanes' SerialMac
        // counters, so a packed problem reports exactly the emulated
        // hardware share a solo run of it would; float fabrics: None.
        hardware: engine.lane_block_hardware_cost(lane.lane0),
    }
}

/// Pack several small problems onto one lane-block engine and anneal
/// them concurrently, one contiguous block of `replicas` lanes per
/// problem.  Entries beyond the engine's lane capacity queue up and
/// *backfill* lanes as earlier blocks retire (per-lane plateau /
/// all-settled early exit, or budget exhaustion); a backfilled block
/// always starts a fresh kick stream.
///
/// The load-bearing contract: every returned outcome is **bit-exact**
/// (energies, spins, phases, period counts) with the same problem run
/// through [`solve_with`] solo at the same seed — regardless of which
/// lanes it landed on, what its neighbors were, or whether it was
/// backfilled.  `rust/tests/prop_packed.rs` holds the proof obligation.
///
/// Requirements: the engine supports lane blocks, every entry's
/// `params.chunk` equals the engine's chunk, `replicas` fits both the
/// engine's lanes and [`MAX_WAVE_REPLICAS`] (so solo runs are a single
/// wave), and every embedding fits the engine's oscillator count.
pub fn solve_packed(
    engine: &mut dyn ChunkEngine,
    entries: &[(IsingProblem, PortfolioParams)],
) -> Result<Vec<SolveOutcome>> {
    Ok(solve_packed_hooked(engine, entries, &[])?
        .into_iter()
        .map(|o| o.expect("no hooks were supplied, so no entry can be cancelled"))
        .collect())
}

/// [`solve_packed`] with per-entry serving-lifecycle hooks
/// ([`SolveHooks`]; `hooks` is indexed by entry and may be shorter —
/// missing entries get default no-op hooks).  A cancelled entry's lane
/// block is cleared and its lanes are released for backfill (queued
/// entries are dropped before placement), and its slot in the returned
/// vector is `None`; surviving entries stay bit-exact with their solo
/// runs — cancellation only frees lanes, it never perturbs a
/// neighbor's kick stream or lane assignment order.
pub fn solve_packed_hooked(
    engine: &mut dyn ChunkEngine,
    entries: &[(IsingProblem, PortfolioParams)],
    hooks: &[SolveHooks<'_>],
) -> Result<Vec<Option<SolveOutcome>>> {
    let hook = |entry: usize| hooks.get(entry).copied().unwrap_or_default();
    if !engine.supports_lane_blocks() {
        return Err(anyhow!("{} engine cannot pack lane blocks", engine.kind()));
    }
    let n = engine.n();
    let b = engine.batch();
    let chunk = engine.chunk_len().max(1);
    // The shared engine runs at one precision; every entry must agree
    // (validated below), so the first entry's sweep point stands for
    // the batch — like `chunk`, precision is engine geometry.
    let precision = entries.first().and_then(|(_, params)| params.precision);
    let cfg = entries
        .first()
        .map_or(NetworkConfig::paper(n), |(_, params)| params.cfg(n));
    let p = cfg.period() as i32;
    let noise_applied = engine.supports_noise();
    for (idx, (problem, params)) in entries.iter().enumerate() {
        if params.precision != precision {
            return Err(anyhow!(
                "entry {idx}: precision {:?} != the packed engine's {:?} \
                 (co-scheduled lanes share one quantized fabric)",
                params.precision,
                precision
            ));
        }
        problem
            .validate()
            .map_err(|e| anyhow!("entry {idx}: bad problem: {e}"))?;
        if params.replicas == 0 {
            return Err(anyhow!("entry {idx}: replicas must be positive"));
        }
        if params.replicas > b.min(MAX_WAVE_REPLICAS) {
            return Err(anyhow!(
                "entry {idx}: {} replicas exceed the packable wave \
                 (engine lanes {b}, wave cap {MAX_WAVE_REPLICAS})",
                params.replicas
            ));
        }
        if params.chunk != chunk {
            return Err(anyhow!(
                "entry {idx}: chunk {} != engine chunk {chunk} \
                 (packed lanes must share the solo chunk geometry)",
                params.chunk
            ));
        }
        if problem.embed_dim() > n {
            return Err(anyhow!(
                "entry {idx}: embeds into {} oscillators, engine serves {n}",
                problem.embed_dim()
            ));
        }
        if problem.sectors > cfg.period() {
            return Err(anyhow!(
                "entry {idx}: {} sectors exceed the {}-step phase wheel",
                problem.sectors,
                cfg.period()
            ));
        }
    }
    let mut buf = PackedBuffers {
        phases: vec![0i32; b * n],
        settled: vec![-1i32; b],
        n,
        p,
        chunk,
    };
    let mut outcomes: Vec<Option<SolveOutcome>> = entries.iter().map(|_| None).collect();
    let mut alloc = LaneAlloc::new(b);
    let mut queue: std::collections::VecDeque<usize> = (0..entries.len()).collect();
    let mut active: Vec<PackedLane> = Vec::new();
    let mut gp = 0usize; // engine-global chunk counter (settle-flag base)

    loop {
        // Cancel sweep first: a disconnected client's block is cleared
        // and its lanes free up for this very iteration's backfill.
        let mut keep = Vec::with_capacity(active.len());
        for lane in active.drain(..) {
            if hook(lane.entry).cancelled() {
                engine.clear_lane_block(lane.lane0)?;
                alloc.release(lane.lane0, lane.lanes);
            } else {
                keep.push(lane);
            }
        }
        active = keep;
        // FIFO placement/backfill: strictly in submission order, so the
        // lane assignment is deterministic (not that it matters for the
        // answers — lanes are bit-independent).
        while let Some(&next) = queue.front() {
            if hook(next).cancelled() {
                queue.pop_front();
                continue;
            }
            let lanes = entries[next].1.replicas;
            let Some(lane0) = alloc.alloc(lanes) else { break };
            queue.pop_front();
            active.push(place_lane(engine, &mut buf, entries, next, lane0)?);
        }
        if active.is_empty() {
            break;
        }
        // Per-block annealing level + kick seed for this chunk — each
        // block walks its own schedule exactly as its solo run would.
        for lane in active.iter_mut() {
            let params = &entries[lane.entry].1;
            lane.level = if noise_applied {
                params.schedule.level(lane.chunk_idx, lane.chunks_per_wave)
            } else {
                0.0
            };
            if noise_applied {
                engine.set_lane_block_noise(lane.lane0, lane.level, lane.rng.next_u64())?;
            }
        }
        engine.run_chunk(&mut buf.phases, &mut buf.settled, (gp * chunk) as i32)?;
        gp += 1;
        for lane in active.iter_mut() {
            let (problem, params) = &entries[lane.entry];
            let k = lane.chunk_idx;
            lane.chunk_idx += 1;
            lane.chunks_run += 1;
            if lane.level > 0.0 {
                // Settle flags are meaningless while kicks are active.
                for s in &mut buf.settled[lane.lane0..lane.lane0 + lane.lanes] {
                    *s = -1;
                }
            }
            let mut improved = false;
            for slot in 0..lane.lanes {
                let row = (lane.lane0 + slot) * n;
                let e = eval_state(problem, &buf.phases[row..row + lane.m], p);
                if e < lane.best_energy - 1e-12 {
                    lane.best_energy = e;
                    lane.best_phases
                        .copy_from_slice(&buf.phases[row..row + lane.m]);
                    improved = true;
                }
            }
            if lane.level == 0.0 {
                let all_settled = (0..lane.lanes).all(|s| buf.settled[lane.lane0 + s] >= 0);
                if improved {
                    lane.stall = 0;
                } else {
                    lane.stall += 1;
                }
                if all_settled
                    || (params.plateau_chunks > 0 && lane.stall >= params.plateau_chunks)
                {
                    lane.exit = Some(k + 1 < lane.chunks_per_wave);
                }
            }
            if lane.exit.is_none() && lane.chunk_idx >= lane.chunks_per_wave {
                lane.exit = Some(false);
            }
            hook(lane.entry).emit_progress(lane.best_energy, lane.chunks_run * chunk);
        }
        // Retire finished blocks; their lanes free up and are backfilled
        // from the queue at the top of the next iteration.
        let mut still = Vec::with_capacity(active.len());
        for lane in active.drain(..) {
            match lane.exit {
                Some(early) => {
                    outcomes[lane.entry] =
                        Some(finish_lane(&*engine, &buf, entries, &lane, early, noise_applied));
                    engine.clear_lane_block(lane.lane0)?;
                    alloc.release(lane.lane0, lane.lanes);
                }
                None => still.push(lane),
            }
        }
        active = still;
    }
    // Cancelled entries (swept from the queue or from live lanes) stay
    // `None`; every surviving entry carries its retired outcome.
    Ok(outcomes)
}

/// Build one bucket-sized native lane-block engine and pack `entries`
/// onto it — the coordinator's packed solve path.  `lanes` bounds how
/// many lanes run concurrently; entries beyond the capacity queue and
/// backfill lanes as earlier problems retire.
pub fn solve_packed_native(
    bucket_n: usize,
    lanes: usize,
    chunk: usize,
    entries: &[(IsingProblem, PortfolioParams)],
) -> Result<Vec<SolveOutcome>> {
    if bucket_n == 0 || lanes == 0 || chunk == 0 {
        return Err(anyhow!("degenerate packed engine geometry"));
    }
    let mut engine = NativeEngine::new(NetworkConfig::paper(bucket_n), lanes, chunk);
    solve_packed(&mut engine, entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::graph::Graph;
    use crate::solver::reductions::{self, max_cut};
    use crate::util::rng::Rng;

    fn params(replicas: usize, periods: usize, seed: u64) -> PortfolioParams {
        PortfolioParams {
            replicas,
            max_periods: periods,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn solves_odd_complete_bipartite_exactly() {
        // K_{3,3}: greedy polish alone guarantees the optimum from any
        // start, so this is deterministic regardless of dynamics.
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let out = solve_native(&p, &params(8, 64, 11)).unwrap();
        assert_eq!(g.cut_value(&out.best_spins), 9);
        assert!((reductions::cut_from_energy(&g, out.best_energy) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn never_worse_than_best_initial_replica() {
        let mut rng = Rng::new(71);
        for trial in 0..5 {
            let g = Graph::random(20, 0.25, &mut rng);
            let p = max_cut(&g);
            let out = solve_native(&p, &params(8, 48, 100 + trial)).unwrap();
            assert!(
                out.best_energy <= out.initial_best_energy + 1e-9,
                "trial {trial}: {} vs initial {}",
                out.best_energy,
                out.initial_best_energy
            );
        }
    }

    #[test]
    fn polished_result_is_locally_optimal() {
        use crate::solver::sa::is_local_minimum;
        let mut rng = Rng::new(72);
        let g = Graph::random(18, 0.3, &mut rng);
        let p = max_cut(&g);
        let out = solve_native(&p, &params(6, 48, 5)).unwrap();
        assert!(is_local_minimum(&p, &out.best_spins));
    }

    #[test]
    fn multiwave_handles_replicas_beyond_batch() {
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        // batch caps at 64; 80 replicas forces two waves
        let out = solve_native(&p, &params(80, 16, 2)).unwrap();
        assert_eq!(out.replicas, 80);
        assert_eq!(out.replica_phases.len(), 80);
        assert_eq!(g.cut_value(&out.best_spins), 9);
    }

    #[test]
    fn rejects_mismatched_engine() {
        let g = Graph::complete_bipartite(2, 2);
        let p = max_cut(&g);
        let mut engine = NativeEngine::new(NetworkConfig::paper(7), 4, 8);
        assert!(solve_portfolio(&mut engine, &p, &params(4, 16, 1)).is_err());
    }

    #[test]
    fn rejects_degenerate_params() {
        let g = Graph::complete_bipartite(2, 2);
        let p = max_cut(&g);
        assert!(solve_native(&p, &params(0, 16, 1)).is_err());
        let mut bad = p.clone();
        bad.sectors = 99;
        assert!(solve_native(&bad, &params(4, 16, 1)).is_err());
    }

    #[test]
    fn plateau_exit_waits_for_the_noise_free_tail() {
        // Zero couplings: every state has energy 0, so no chunk ever
        // improves the best energy and a stall counter that ran during
        // noisy chunks would fire after chunk 0 with plateau_chunks = 1.
        // The regression contract: the plateau early exit must not fire
        // while the schedule's amplitude is still above the noise-free
        // tail threshold — only the deterministic tail, where settle
        // flags and plateaus mean something, may stop the run.
        use crate::solver::problem::IsingProblem;
        let problem = IsingProblem::new(5);
        let params = PortfolioParams {
            replicas: 4,
            max_periods: 64, // 8 chunks of 8
            schedule: Schedule::Constant { level: 0.8 },
            seed: 17,
            plateau_chunks: 1,
            polish: false,
            ..Default::default()
        };
        let out = solve_native(&problem, &params).unwrap();
        let chunks_total = 64usize.div_ceil(8);
        let noisy = chunks_total - Schedule::noise_free_tail(chunks_total);
        assert!(out.early_exit, "the tail exit itself must still fire");
        assert!(
            out.chunks > noisy,
            "plateau exit fired during the noisy prefix: {} chunks run, {noisy} noisy",
            out.chunks
        );
        assert_eq!(out.best_energy, 0.0);
    }

    #[test]
    fn engine_selection_resolves_by_threshold() {
        let auto = EngineSelect::Auto { threshold: 100, max_shards: 4 };
        assert_eq!(auto.shards_for(99), 1);
        assert_eq!(auto.shards_for(100), 2);
        assert_eq!(auto.shards_for(250), 3);
        assert_eq!(auto.shards_for(4000), 4, "cap applies");
        let off = EngineSelect::Auto { threshold: 100, max_shards: 1 };
        assert_eq!(off.shards_for(4000), 1, "max_shards < 2 disables sharding");
        assert_eq!(EngineSelect::Native.shards_for(4000), 1);
        assert_eq!(EngineSelect::Rtl.shards_for(4000), 1, "one emulated device");
        assert_eq!(
            EngineSelect::RtlCluster { shards: 4 }.shards_for(4000),
            1,
            "cluster devices shape the hardware model, not the float topology"
        );
        assert_eq!(EngineSelect::Sharded { shards: 5 }.shards_for(64), 5);
        assert_eq!(
            EngineSelect::Sharded { shards: 9 }.shards_for(3),
            3,
            "never more shards than rows"
        );
    }

    #[test]
    fn sharded_selection_solves_bit_identically_to_native() {
        let mut rng = Rng::new(74);
        let g = Graph::random(14, 0.3, &mut rng);
        let p = max_cut(&g);
        let prm = params(6, 48, 19);
        let native = solve_native(&p, &prm).unwrap();
        assert_eq!(native.engine, "native");
        assert_eq!(native.sync_rounds, 0);
        let sharded = solve_with(&p, &prm, EngineSelect::Sharded { shards: 3 }).unwrap();
        assert_eq!(sharded.engine, "sharded");
        assert!(sharded.sync_rounds > 0);
        assert_eq!(sharded.best_energy, native.best_energy);
        assert_eq!(sharded.best_spins, native.best_spins);
        assert_eq!(sharded.best_phases, native.best_phases);
        assert_eq!(sharded.periods, native.periods);
    }

    #[test]
    fn rtl_selection_runs_the_hardware_model() {
        // K_{3,3}: the readout polish alone guarantees the optimum, so
        // the bit-true engine must land on cut 9 like the float one —
        // while additionally reporting the emulated hardware cost.
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let out = solve_with(&p, &params(4, 32, 13), EngineSelect::Rtl).unwrap();
        assert_eq!(out.engine, "rtl");
        assert_eq!(out.sync_rounds, 0);
        assert_eq!(g.cut_value(&out.best_spins), 9);
        assert_eq!(out.quantization_error, 0.0, "±1 couplings scale exactly");
        let hw = out.hardware.expect("rtl solves report hardware cost");
        assert!(hw.fast_cycles > 0);
        assert!(hw.emulated_s > 0.0);
        assert!(hw.fits_device, "a 6-oscillator design fits the device");
        // Float fabrics report the same quantization error but no
        // hardware model.
        let native = solve_native(&p, &params(4, 32, 13)).unwrap();
        assert!(native.hardware.is_none());
        assert_eq!(native.quantization_error, 0.0);
    }

    #[test]
    fn rtl_cluster_selection_matches_solo_and_prices_the_all_gather() {
        // The cluster engine delegates the dynamics to one inner rtl
        // engine, so the answers are bit-identical to the solo fabric;
        // what changes is the hardware model — a per-period all-gather
        // premium on top of the solo compute cycles.
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let prm = params(4, 32, 13);
        let solo = solve_with(&p, &prm, EngineSelect::Rtl).unwrap();
        let cl = solve_with(&p, &prm, EngineSelect::RtlCluster { shards: 2 }).unwrap();
        assert_eq!(cl.engine, "rtl-cluster");
        assert_eq!(cl.best_energy, solo.best_energy);
        assert_eq!(cl.best_spins, solo.best_spins);
        assert_eq!(cl.best_phases, solo.best_phases);
        assert_eq!(cl.periods, solo.periods);
        assert_eq!(cl.replica_phases, solo.replica_phases);
        assert!(cl.sync_rounds > 0, "one all-gather per lane-period");
        assert_eq!(solo.sync_rounds, 0);
        let hs = solo.hardware.unwrap();
        let hc = cl.hardware.unwrap();
        assert!(hc.sync_fast_cycles > 0);
        assert_eq!(hs.sync_fast_cycles, 0);
        assert_eq!(
            hc.fast_cycles,
            hs.fast_cycles + hc.sync_fast_cycles,
            "cluster = lockstep compute (solo cycles) + priced sync"
        );
    }

    #[test]
    fn precision_sweep_threads_into_engine_and_quantizer() {
        // Non-uniform couplings {1, 2, 4}: exactly representable at no
        // precision below full scale, so coarser weight bits must raise
        // the reported quantization error — and a 3-bit phase wheel
        // (period 8) must bound every returned phase.
        use crate::solver::problem::IsingProblem;
        let mut problem = IsingProblem::new(4);
        problem.set_j(0, 1, 1.0);
        problem.set_j(1, 2, 2.0);
        problem.set_j(2, 3, 4.0);
        let paper = solve_with(&problem, &params(4, 32, 9), EngineSelect::Rtl).unwrap();
        let mut prm = params(4, 32, 9);
        prm.precision = Some((3, 3));
        let coarse = solve_with(&problem, &prm, EngineSelect::Rtl).unwrap();
        assert!(
            coarse.quantization_error > paper.quantization_error,
            "3-bit weights must round harder than the paper's 5 ({} vs {})",
            coarse.quantization_error,
            paper.quantization_error
        );
        for phases in &coarse.replica_phases {
            assert!(
                phases.iter().all(|&ph| (0..8).contains(&ph)),
                "phases must live on the 2^3-step wheel"
            );
        }
    }

    #[test]
    fn rtl_hardware_meter_counts_only_real_replicas() {
        // 65 replicas on a 64-lane wave: the second wave carries one
        // real replica plus 63 padding slots.  The emulated cost must
        // price exactly the 65 real lane-runs — padded lanes are
        // declared via begin_wave and neither stepped nor metered.
        use crate::solver::problem::IsingProblem;
        let problem = IsingProblem::new(4);
        let prm = PortfolioParams {
            replicas: 65,
            max_periods: 8, // one chunk per wave (noise-free: tail of 1)
            seed: 31,
            polish: false,
            ..Default::default()
        };
        let out = solve_with(&problem, &prm, EngineSelect::Rtl).unwrap();
        assert_eq!(out.replicas, 65);
        assert_eq!(out.periods, 16, "two waves of one 8-period chunk");
        let hw = out.hardware.unwrap();
        assert_eq!(
            hw.fast_cycles,
            65 * 8 * 16 * (4 + 6),
            "the meter must count 65 real lane-runs, not 128"
        );
    }

    #[test]
    fn quantization_error_is_reported_for_lossy_couplings() {
        // Couplings {1, 3.7} cannot all map exactly onto the 5-bit
        // grid, so the reported rounding loss must be positive (and
        // identical across engine selections — it is a property of the
        // embedding, not the fabric).
        use crate::solver::problem::IsingProblem;
        let mut p = IsingProblem::new(4);
        p.set_j(0, 1, 3.7);
        p.set_j(1, 2, 1.0);
        p.set_j(2, 3, 1.0);
        let prm = params(4, 32, 5);
        let native = solve_native(&p, &prm).unwrap();
        assert!(
            native.quantization_error > 0.0,
            "lossy couplings must report a positive error"
        );
        assert!(native.quantization_error <= 0.5 / 15.0 + 1e-12);
        let rtl = solve_with(&p, &prm, EngineSelect::Rtl).unwrap();
        assert_eq!(rtl.quantization_error, native.quantization_error);
    }

    #[test]
    fn lane_alloc_first_fit_and_merge() {
        let mut a = LaneAlloc::new(10);
        assert_eq!(a.alloc(4), Some(0));
        assert_eq!(a.alloc(4), Some(4));
        assert_eq!(a.alloc(4), None, "only 2 lanes left");
        assert_eq!(a.alloc(2), Some(8));
        a.release(0, 4);
        a.release(8, 2);
        assert_eq!(a.alloc(5), None, "free space is fragmented");
        a.release(4, 4);
        assert_eq!(a.free, vec![(0, 10)], "release merges adjacent ranges");
        assert_eq!(a.alloc(10), Some(0));
    }

    #[test]
    fn chunk_threads_from_params_into_the_engine() {
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let mut prm = params(4, 32, 5);
        prm.chunk = 4;
        let out = solve_native(&p, &prm).unwrap();
        assert_eq!(out.periods, out.chunks * 4, "engine ran 4-period chunks");
        prm.chunk = 0;
        assert!(solve_native(&p, &prm).is_err(), "degenerate chunk rejected");
    }

    #[test]
    fn packed_rejects_incompatible_entries() {
        let g = Graph::complete_bipartite(2, 2);
        let p = max_cut(&g);
        let ok = params(4, 16, 1);
        // chunk mismatch with the shared engine
        let mut bad_chunk = ok;
        bad_chunk.chunk = 4;
        assert!(solve_packed_native(8, 8, 8, &[(p.clone(), bad_chunk)]).is_err());
        // more replicas than the engine has lanes
        assert!(solve_packed_native(8, 2, 8, &[(p.clone(), ok)]).is_err());
        // embedding larger than the bucket
        assert!(solve_packed_native(2, 8, 8, &[(p.clone(), ok)]).is_err());
        // zero replicas
        assert!(solve_packed_native(8, 8, 8, &[(p.clone(), params(0, 16, 1))]).is_err());
        // degenerate engine geometry
        assert!(solve_packed_native(0, 8, 8, &[(p.clone(), ok)]).is_err());
        // empty batch is fine
        assert_eq!(solve_packed_native(8, 8, 8, &[]).unwrap().len(), 0);
        // a non-lane-block engine is rejected outright
        struct NoBlocks;
        impl ChunkEngine for NoBlocks {
            fn n(&self) -> usize {
                4
            }
            fn batch(&self) -> usize {
                4
            }
            fn chunk_len(&self) -> usize {
                8
            }
            fn set_weights(&mut self, _w: &[f32]) -> Result<()> {
                Ok(())
            }
            fn run_chunk(&mut self, _p: &mut [i32], _s: &mut [i32], _p0: i32) -> Result<()> {
                Ok(())
            }
            fn kind(&self) -> &'static str {
                "stub"
            }
        }
        assert!(solve_packed(&mut NoBlocks, &[(p, ok)]).is_err());
    }

    #[test]
    fn packed_pair_matches_solo_runs() {
        // The smallest end-to-end packing: two different max-cut
        // problems sharing one engine, each bit-exact with its solo run.
        let mut rng = Rng::new(75);
        let ga = Graph::random(8, 0.4, &mut rng);
        let gb = Graph::random(11, 0.3, &mut rng);
        let entries = vec![
            (max_cut(&ga), params(4, 48, 21)),
            (max_cut(&gb), params(6, 48, 22)),
        ];
        let packed = solve_packed_native(16, 10, 8, &entries).unwrap();
        for ((problem, prm), out) in entries.iter().zip(&packed) {
            let solo = solve_with(problem, prm, EngineSelect::Native).unwrap();
            assert_eq!(out.best_energy, solo.best_energy);
            assert_eq!(out.best_spins, solo.best_spins);
            assert_eq!(out.best_phases, solo.best_phases);
            assert_eq!(out.periods, solo.periods);
            assert_eq!(out.settled_replicas, solo.settled_replicas);
            assert_eq!(out.replica_phases, solo.replica_phases);
        }
    }

    #[test]
    fn sparse_fabric_solves_bit_identically_to_dense() {
        // Same graph, dense-form vs sparse-form problem, same seed: the
        // CSR fabric must reproduce the dense run bit for bit, on the
        // native engine and on a sharded cluster.
        use crate::solver::reductions::max_cut_sparse;
        let mut rng = Rng::new(76);
        let g = Graph::random(18, 0.15, &mut rng);
        let pd = max_cut(&g);
        let ps = max_cut_sparse(&g);
        assert!(wants_sparse(&ps), "density 0.15 is under the threshold");
        assert!(!wants_sparse(&pd), "dense-form problems never route sparse");
        let prm = params(6, 48, 23);
        let dense = solve_native(&pd, &prm).unwrap();
        let sparse = solve_native(&ps, &prm).unwrap();
        assert!(!dense.sparse);
        assert!(sparse.sparse, "sparse-form problem ran the CSR kernel");
        assert_eq!(sparse.best_energy.to_bits(), dense.best_energy.to_bits());
        assert_eq!(sparse.best_spins, dense.best_spins);
        assert_eq!(sparse.best_phases, dense.best_phases);
        assert_eq!(sparse.replica_phases, dense.replica_phases);
        assert_eq!(sparse.periods, dense.periods);
        assert_eq!(sparse.settled_replicas, dense.settled_replicas);
        assert_eq!(
            sparse.quantization_error.to_bits(),
            dense.quantization_error.to_bits()
        );
        let sharded = solve_with(&ps, &prm, EngineSelect::Sharded { shards: 3 }).unwrap();
        assert!(sharded.sparse);
        assert_eq!(sharded.best_energy.to_bits(), dense.best_energy.to_bits());
        assert_eq!(sharded.best_spins, dense.best_spins);
        assert_eq!(sharded.replica_phases, dense.replica_phases);
    }

    #[test]
    fn dense_sparse_form_problems_fall_back_above_threshold() {
        // A sparse-form problem above the density threshold routes onto
        // the dense fabric — same answer, dense kernel.
        use crate::solver::problem::IsingProblem;
        let n = 8;
        let mut edges = Vec::new();
        for i in 0..n {
            for k in (i + 1)..n {
                edges.push((i, k, if (i + k) % 2 == 0 { 1.0 } else { -1.0 }));
            }
        }
        let ps = IsingProblem::from_edges(n, &edges).unwrap();
        assert!(!wants_sparse(&ps), "complete graph exceeds the threshold");
        let mut pd = IsingProblem::new(n);
        for &(i, k, v) in &edges {
            pd.set_j(i, k, v);
        }
        let prm = params(4, 32, 29);
        let sparse_form = solve_native(&ps, &prm).unwrap();
        let dense_form = solve_native(&pd, &prm).unwrap();
        assert!(!sparse_form.sparse, "above threshold the dense kernel runs");
        assert_eq!(
            sparse_form.best_energy.to_bits(),
            dense_form.best_energy.to_bits()
        );
        assert_eq!(sparse_form.best_spins, dense_form.best_spins);
        // The rtl engine has no sparse fabric; sparse-form problems
        // under the threshold still solve there via the dense fallback.
        let g = Graph::complete_bipartite(3, 3);
        let sp = crate::solver::reductions::max_cut_sparse(&g);
        assert!(wants_sparse(&sp));
        let rtl = solve_with(&sp, &params(4, 32, 13), EngineSelect::Rtl).unwrap();
        assert!(!rtl.sparse, "rtl cannot run CSR; dense fallback");
        assert_eq!(g.cut_value(&rtl.best_spins), 9);
    }

    #[test]
    fn field_problems_run_through_ancilla() {
        // Vertex cover has fields; the whole pipeline must handle the
        // ancilla embed + gauge decode and return a valid cover after
        // repair.
        let mut rng = Rng::new(73);
        let g = Graph::random(10, 0.3, &mut rng);
        let p = reductions::min_vertex_cover(&g, 2.0);
        let out = solve_native(&p, &params(8, 64, 3)).unwrap();
        let cover = reductions::decode_cover(&g, &out.best_spins);
        assert!(reductions::is_cover(&g, &cover));
    }
}
