//! The annealed batched portfolio solver: many random-init replicas of
//! one Ising instance run as a single batch on any [`ChunkEngine`],
//! with a phase-noise annealing schedule driving the engine's noise
//! hook, per-chunk best-replica tracking through the problem's energy,
//! an energy-plateau early exit, and a deterministic greedy-descent
//! readout polish.
//!
//! This is the serving path for the paper's target workload
//! (combinatorial optimization): the same batched chunk contract the
//! retrieval coordinator drives, so one engine fabric serves both
//! traffic classes.

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::phase::spin_to_phase;
use crate::runtime::native::NativeEngine;
use crate::runtime::sharded::ShardedEngine;
use crate::runtime::ChunkEngine;
use crate::solver::anneal::Schedule;
use crate::solver::problem::IsingProblem;
use crate::solver::sa::greedy_descent;
use crate::util::rng::Rng;

/// Embedded sizes at or above this many oscillators default to the
/// sharded fabric: a single device tops out near the paper's 506
/// oscillators, so one engine per request stops scaling well before the
/// wire's 4096-oscillator cap.
pub const DEFAULT_SHARD_THRESHOLD: usize = 256;

/// Default cap on shard workers per solve.
pub const DEFAULT_MAX_SHARDS: usize = 8;

/// Which engine fabric a solve runs on — the engine-selection layer the
/// coordinator's solver pool and the CLI configure.  Selection never
/// changes the answer: the sharded engine is bit-exact with the native
/// one (noise included), so this is purely a capacity/locality choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSelect {
    /// Single in-process engine.
    Native,
    /// Row-sharded leader + worker cluster with exactly this many
    /// shards (a count of 1 collapses to the native engine).
    Sharded { shards: usize },
    /// Native below `threshold` oscillators; at or above it, one shard
    /// per `threshold` rows (`ceil(m / threshold)`, at least 2), capped
    /// at `max_shards`.  A `max_shards` below 2 disables sharding
    /// entirely (every size runs native).
    Auto { threshold: usize, max_shards: usize },
}

impl Default for EngineSelect {
    fn default() -> Self {
        EngineSelect::Auto {
            threshold: DEFAULT_SHARD_THRESHOLD,
            max_shards: DEFAULT_MAX_SHARDS,
        }
    }
}

impl EngineSelect {
    /// Shard count this selection resolves to for an `m`-oscillator
    /// embedding (1 = single native engine).  Never exceeds `m`: a
    /// shard needs at least one row.
    pub fn shards_for(&self, m: usize) -> usize {
        let k = match *self {
            EngineSelect::Native => 1,
            EngineSelect::Sharded { shards } => shards.max(1),
            EngineSelect::Auto { threshold, max_shards } => {
                let t = threshold.max(1);
                if m < t || max_shards < 2 {
                    1
                } else {
                    m.div_ceil(t).clamp(2, max_shards)
                }
            }
        };
        k.min(m.max(1))
    }
}

/// Build the engine a selection resolves to for an `m`-oscillator
/// problem (`batch` replicas per wave, `chunk` periods per engine call).
pub fn build_engine(
    m: usize,
    batch: usize,
    chunk: usize,
    select: EngineSelect,
) -> Result<Box<dyn ChunkEngine>> {
    let cfg = NetworkConfig::paper(m);
    let shards = select.shards_for(m);
    if shards <= 1 {
        Ok(Box::new(NativeEngine::new(cfg, batch, chunk)))
    } else {
        Ok(Box::new(ShardedEngine::unprogrammed(cfg, shards, batch, chunk)?))
    }
}

/// Portfolio solve parameters.
#[derive(Debug, Clone, Copy)]
pub struct PortfolioParams {
    /// Random-init trials run as one batch (waves of `engine.batch()`).
    pub replicas: usize,
    /// Periods driven per replica (rounded up to whole chunks).
    pub max_periods: usize,
    pub schedule: Schedule,
    pub seed: u64,
    /// Early exit after this many consecutive noise-free chunks without
    /// a best-energy improvement (0 disables the early exit).
    pub plateau_chunks: usize,
    /// Greedy single-flip readout polish (binary problems only).
    pub polish: bool,
}

impl Default for PortfolioParams {
    fn default() -> Self {
        Self {
            replicas: 32,
            max_periods: 256,
            schedule: Schedule::Geometric {
                start: 0.6,
                factor: 0.8,
            },
            seed: 1,
            plateau_chunks: 3,
            polish: true,
        }
    }
}

/// Result of one portfolio solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Best decoded spins (length `problem.n`; for sector problems the
    /// binary decode of the best phase state — use `best_phases`).
    pub best_spins: Vec<i8>,
    /// Best phase state (length `problem.n`, ancilla stripped).
    pub best_phases: Vec<i32>,
    /// `problem.energy` of the best state (offset excluded).  For
    /// sector problems this is the phase-energy proxy.
    pub best_energy: f64,
    /// Best energy among the replicas' *initial* states — the solver
    /// never returns anything worse than this.
    pub initial_best_energy: f64,
    /// Final phase state of every replica (ancilla stripped), for
    /// decoders that rank replicas by their own objective.
    pub replica_phases: Vec<Vec<i32>>,
    /// Total chunk-periods driven by the engine, summed over waves
    /// (each period advances the whole batch of replicas at once).
    pub periods: usize,
    pub chunks: usize,
    pub replicas: usize,
    /// Replicas whose final noise-free chunk reported a fixed point.
    pub settled_replicas: usize,
    pub early_exit: bool,
    /// False when the engine has no noise hook (schedule was skipped).
    pub noise_applied: bool,
    /// Engine kind that ran the solve ("native" / "sharded" / "pjrt").
    pub engine: &'static str,
    /// All-gather synchronization rounds the engine performed — the
    /// multi-device sync-cost metric (0 on single-device engines).
    pub sync_rounds: u64,
}

/// Run the portfolio on an already-constructed engine.  The engine's
/// network size must equal [`IsingProblem::embed_dim`]; weights are
/// installed here.
pub fn solve_portfolio(
    engine: &mut dyn ChunkEngine,
    problem: &IsingProblem,
    params: &PortfolioParams,
) -> Result<SolveOutcome> {
    problem.validate().map_err(|e| anyhow!("bad problem: {e}"))?;
    if params.replicas == 0 {
        return Err(anyhow!("replicas must be positive"));
    }
    let m = problem.embed_dim();
    if engine.n() != m {
        return Err(anyhow!(
            "engine serves n={}, problem embeds into n={m}",
            engine.n()
        ));
    }
    let cfg = NetworkConfig::paper(m);
    let p = cfg.period() as i32;
    if problem.sectors > cfg.period() {
        return Err(anyhow!(
            "{} sectors exceed the {}-step phase wheel",
            problem.sectors,
            cfg.period()
        ));
    }
    engine.set_weights(&problem.embed(&cfg).to_f32())?;
    let noise_applied = engine.supports_noise();

    let b = engine.batch();
    if b == 0 {
        return Err(anyhow!("engine reports zero batch capacity"));
    }
    let chunk = engine.chunk_len().max(1);
    let chunks_per_wave = params.max_periods.div_ceil(chunk).max(1);
    let binary = problem.sectors == 2;
    // Exact objective for binary problems; phase-correlation proxy for
    // sector (Potts-like) problems.
    let eval = |phases: &[i32]| -> f64 {
        if binary {
            problem.energy(&problem.decode_spins(phases, p))
        } else {
            problem.phase_energy(&phases[..problem.n], p)
        }
    };

    let mut rng = Rng::new(params.seed);
    let mut best_energy = f64::INFINITY;
    let mut best_phases = vec![0i32; m];
    let mut initial_best = f64::INFINITY;
    let mut replica_phases: Vec<Vec<i32>> = Vec::with_capacity(params.replicas);
    let mut chunks_run = 0usize;
    let mut settled_replicas = 0usize;
    let mut early_exit = false;
    // Best polished replica (spins, energy) across all waves.
    let mut best_polished: Option<(Vec<i8>, f64)> = None;

    let mut phases = vec![0i32; b * m];
    let mut settled = vec![-1i32; b];
    let mut remaining = params.replicas;
    while remaining > 0 {
        let real = remaining.min(b);
        // Random init: binary problems start on the binary manifold
        // (the Hopfield submanifold of the phase dynamics), sector
        // problems anywhere on the phase wheel.  Padding slots repeat
        // replica 0 so the batch is well-formed.
        for slot in 0..b {
            let src = slot.min(real - 1);
            if slot < real {
                for i in 0..m {
                    phases[slot * m + i] = if binary {
                        spin_to_phase(rng.spin(), p)
                    } else {
                        rng.range_i64(0, p as i64) as i32
                    };
                }
            } else {
                let copy: Vec<i32> = phases[src * m..(src + 1) * m].to_vec();
                phases[slot * m..(slot + 1) * m].copy_from_slice(&copy);
            }
        }
        settled.iter_mut().for_each(|s| *s = -1);
        for slot in 0..real {
            let e = eval(&phases[slot * m..(slot + 1) * m]);
            initial_best = initial_best.min(e);
            if e < best_energy {
                best_energy = e;
                best_phases.copy_from_slice(&phases[slot * m..(slot + 1) * m]);
            }
        }

        let mut stall = 0usize;
        for k in 0..chunks_per_wave {
            // On engines without a noise hook no kicks ever happen, so
            // the dynamics are deterministic from chunk 0 and the
            // settle flags / early exits stay live for the whole run.
            let level = if noise_applied {
                params.schedule.level(k, chunks_per_wave)
            } else {
                0.0
            };
            if noise_applied {
                engine.set_noise(level, rng.next_u64())?;
            }
            engine.run_chunk(&mut phases, &mut settled, (k * chunk) as i32)?;
            chunks_run += 1;
            if level > 0.0 {
                // Settle flags are meaningless while kicks are active.
                settled.iter_mut().for_each(|s| *s = -1);
            }
            let mut improved = false;
            for slot in 0..real {
                let e = eval(&phases[slot * m..(slot + 1) * m]);
                if e < best_energy - 1e-12 {
                    best_energy = e;
                    best_phases.copy_from_slice(&phases[slot * m..(slot + 1) * m]);
                    improved = true;
                }
            }
            if level == 0.0 {
                let all_settled = (0..real).all(|slot| settled[slot] >= 0);
                if improved {
                    stall = 0;
                } else {
                    stall += 1;
                }
                if all_settled
                    || (params.plateau_chunks > 0 && stall >= params.plateau_chunks)
                {
                    early_exit = k + 1 < chunks_per_wave;
                    break;
                }
            }
        }

        settled_replicas += (0..real).filter(|&slot| settled[slot] >= 0).count();
        for slot in 0..real {
            let full = &phases[slot * m..(slot + 1) * m];
            replica_phases.push(full[..problem.n].to_vec());
            if params.polish && binary {
                // Polish every replica's final state while its true
                // ancilla phase is still attached (the gauge matters
                // for field problems); strict descent can only improve,
                // so the outcome dominates every unpolished replica.
                let mut spins = problem.decode_spins(full, p);
                greedy_descent(problem, &mut spins);
                let e = problem.energy(&spins);
                if best_polished.as_ref().map_or(true, |(_, be)| e < *be) {
                    best_polished = Some((spins, e));
                }
            }
        }
        remaining -= real;
    }

    let mut best_spins = problem.decode_spins(&best_phases, p);
    if params.polish && binary {
        // The best tracked state gets the same readout polish, then
        // competes with the best polished replica; best_energy always
        // describes best_spins.
        greedy_descent(problem, &mut best_spins);
        best_energy = problem.energy(&best_spins);
        if let Some((spins, e)) = best_polished {
            if e < best_energy {
                best_energy = e;
                best_spins = spins;
            }
        }
        best_phases = best_spins.iter().map(|&s| spin_to_phase(s, p)).collect();
    }

    Ok(SolveOutcome {
        best_spins,
        best_phases: best_phases[..problem.n].to_vec(),
        best_energy,
        initial_best_energy: initial_best,
        replica_phases,
        periods: chunks_run * chunk,
        chunks: chunks_run,
        replicas: params.replicas,
        settled_replicas,
        early_exit,
        noise_applied,
        engine: engine.kind(),
        sync_rounds: engine.sync_rounds(),
    })
}

/// Build the selected engine for the problem and run the portfolio on
/// it — the coordinator's solve path.  Batch and chunk geometry are
/// identical across selections, so the outcome is bit-identical whether
/// the fabric is one engine or a shard cluster.
pub fn solve_with(
    problem: &IsingProblem,
    params: &PortfolioParams,
    select: EngineSelect,
) -> Result<SolveOutcome> {
    let m = problem.embed_dim();
    let batch = params.replicas.clamp(1, 64);
    let mut engine = build_engine(m, batch, 8, select)?;
    solve_portfolio(engine.as_mut(), problem, params)
}

/// Convenience: run the portfolio on a single [`NativeEngine`] sized
/// for the problem.
pub fn solve_native(problem: &IsingProblem, params: &PortfolioParams) -> Result<SolveOutcome> {
    solve_with(problem, params, EngineSelect::Native)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::graph::Graph;
    use crate::solver::reductions::{self, max_cut};
    use crate::util::rng::Rng;

    fn params(replicas: usize, periods: usize, seed: u64) -> PortfolioParams {
        PortfolioParams {
            replicas,
            max_periods: periods,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn solves_odd_complete_bipartite_exactly() {
        // K_{3,3}: greedy polish alone guarantees the optimum from any
        // start, so this is deterministic regardless of dynamics.
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        let out = solve_native(&p, &params(8, 64, 11)).unwrap();
        assert_eq!(g.cut_value(&out.best_spins), 9);
        assert!((reductions::cut_from_energy(&g, out.best_energy) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn never_worse_than_best_initial_replica() {
        let mut rng = Rng::new(71);
        for trial in 0..5 {
            let g = Graph::random(20, 0.25, &mut rng);
            let p = max_cut(&g);
            let out = solve_native(&p, &params(8, 48, 100 + trial)).unwrap();
            assert!(
                out.best_energy <= out.initial_best_energy + 1e-9,
                "trial {trial}: {} vs initial {}",
                out.best_energy,
                out.initial_best_energy
            );
        }
    }

    #[test]
    fn polished_result_is_locally_optimal() {
        use crate::solver::sa::is_local_minimum;
        let mut rng = Rng::new(72);
        let g = Graph::random(18, 0.3, &mut rng);
        let p = max_cut(&g);
        let out = solve_native(&p, &params(6, 48, 5)).unwrap();
        assert!(is_local_minimum(&p, &out.best_spins));
    }

    #[test]
    fn multiwave_handles_replicas_beyond_batch() {
        let g = Graph::complete_bipartite(3, 3);
        let p = max_cut(&g);
        // batch caps at 64; 80 replicas forces two waves
        let out = solve_native(&p, &params(80, 16, 2)).unwrap();
        assert_eq!(out.replicas, 80);
        assert_eq!(out.replica_phases.len(), 80);
        assert_eq!(g.cut_value(&out.best_spins), 9);
    }

    #[test]
    fn rejects_mismatched_engine() {
        let g = Graph::complete_bipartite(2, 2);
        let p = max_cut(&g);
        let mut engine = NativeEngine::new(NetworkConfig::paper(7), 4, 8);
        assert!(solve_portfolio(&mut engine, &p, &params(4, 16, 1)).is_err());
    }

    #[test]
    fn rejects_degenerate_params() {
        let g = Graph::complete_bipartite(2, 2);
        let p = max_cut(&g);
        assert!(solve_native(&p, &params(0, 16, 1)).is_err());
        let mut bad = p.clone();
        bad.sectors = 99;
        assert!(solve_native(&bad, &params(4, 16, 1)).is_err());
    }

    #[test]
    fn plateau_exit_waits_for_the_noise_free_tail() {
        // Zero couplings: every state has energy 0, so no chunk ever
        // improves the best energy and a stall counter that ran during
        // noisy chunks would fire after chunk 0 with plateau_chunks = 1.
        // The regression contract: the plateau early exit must not fire
        // while the schedule's amplitude is still above the noise-free
        // tail threshold — only the deterministic tail, where settle
        // flags and plateaus mean something, may stop the run.
        use crate::solver::problem::IsingProblem;
        let problem = IsingProblem::new(5);
        let params = PortfolioParams {
            replicas: 4,
            max_periods: 64, // 8 chunks of 8
            schedule: Schedule::Constant { level: 0.8 },
            seed: 17,
            plateau_chunks: 1,
            polish: false,
        };
        let out = solve_native(&problem, &params).unwrap();
        let chunks_total = 64usize.div_ceil(8);
        let noisy = chunks_total - Schedule::noise_free_tail(chunks_total);
        assert!(out.early_exit, "the tail exit itself must still fire");
        assert!(
            out.chunks > noisy,
            "plateau exit fired during the noisy prefix: {} chunks run, {noisy} noisy",
            out.chunks
        );
        assert_eq!(out.best_energy, 0.0);
    }

    #[test]
    fn engine_selection_resolves_by_threshold() {
        let auto = EngineSelect::Auto { threshold: 100, max_shards: 4 };
        assert_eq!(auto.shards_for(99), 1);
        assert_eq!(auto.shards_for(100), 2);
        assert_eq!(auto.shards_for(250), 3);
        assert_eq!(auto.shards_for(4000), 4, "cap applies");
        let off = EngineSelect::Auto { threshold: 100, max_shards: 1 };
        assert_eq!(off.shards_for(4000), 1, "max_shards < 2 disables sharding");
        assert_eq!(EngineSelect::Native.shards_for(4000), 1);
        assert_eq!(EngineSelect::Sharded { shards: 5 }.shards_for(64), 5);
        assert_eq!(
            EngineSelect::Sharded { shards: 9 }.shards_for(3),
            3,
            "never more shards than rows"
        );
    }

    #[test]
    fn sharded_selection_solves_bit_identically_to_native() {
        let mut rng = Rng::new(74);
        let g = Graph::random(14, 0.3, &mut rng);
        let p = max_cut(&g);
        let prm = params(6, 48, 19);
        let native = solve_native(&p, &prm).unwrap();
        assert_eq!(native.engine, "native");
        assert_eq!(native.sync_rounds, 0);
        let sharded = solve_with(&p, &prm, EngineSelect::Sharded { shards: 3 }).unwrap();
        assert_eq!(sharded.engine, "sharded");
        assert!(sharded.sync_rounds > 0);
        assert_eq!(sharded.best_energy, native.best_energy);
        assert_eq!(sharded.best_spins, native.best_spins);
        assert_eq!(sharded.best_phases, native.best_phases);
        assert_eq!(sharded.periods, native.periods);
    }

    #[test]
    fn field_problems_run_through_ancilla() {
        // Vertex cover has fields; the whole pipeline must handle the
        // ancilla embed + gauge decode and return a valid cover after
        // repair.
        let mut rng = Rng::new(73);
        let g = Graph::random(10, 0.3, &mut rng);
        let p = reductions::min_vertex_cover(&g, 2.0);
        let out = solve_native(&p, &params(8, 64, 3)).unwrap();
        let cover = reductions::decode_cover(&g, &out.best_spins);
        assert!(reductions::is_cover(&g, &cover));
    }
}
