//! Generic Ising/QUBO optimization subsystem — the paper's named target
//! workload ("larger network sizes can be benchmarked using ...
//! especially combinatorial optimization problems") served through the
//! same batched chunk-engine runtime as pattern retrieval.
//!
//! Layout:
//!
//! * [`problem`] — the problem IR: [`problem::IsingProblem`] with an
//!   exact QUBO converter and a field-to-ancilla embedding into the
//!   quantized ONN coupling fabric.
//! * [`graph`] — the shared graph input type for the graph reductions.
//! * [`reductions`] — max-cut, k-coloring (multi-phase sectors), number
//!   partitioning and minimum vertex cover onto the IR, plus decoders
//!   with deterministic readout repair.
//! * [`anneal`] — phase-noise annealing schedules (geometric / linear /
//!   constant), all monotone non-increasing and ending noise-free.
//! * [`portfolio`] — the batched replica-portfolio driver over any
//!   [`crate::runtime::ChunkEngine`], with best-replica tracking,
//!   plateau early exit and greedy readout polish; plus the
//!   engine-selection layer ([`portfolio::EngineSelect`]) that places a
//!   solve on the single native engine or the row-sharded cluster
//!   (bit-exact either way, noise included).
//! * [`sa`] — the simulated-annealing baseline and the greedy-descent
//!   polish shared with the portfolio.
//!
//! The coordinator serves this subsystem over the JSON-lines protocol
//! as `SolveRequest`/`SolveResult` (see `coordinator::job` and
//! `DESIGN_SOLVER.md`).

pub mod anneal;
pub mod graph;
pub mod portfolio;
pub mod problem;
pub mod reductions;
pub mod sa;

pub use anneal::Schedule;
pub use graph::Graph;
pub use portfolio::{
    build_engine, solve_native, solve_portfolio, solve_with, EngineSelect, PortfolioParams,
    SolveOutcome,
};
pub use problem::{IsingProblem, Qubo};
