//! Undirected weighted graphs — the input shape shared by the graph
//! reductions (max-cut, coloring, vertex cover).  Moved here from
//! `apps::maxcut` so the solver subsystem has no dependency on the app
//! layer; `apps::maxcut` re-exports it for compatibility.

use crate::util::rng::Rng;

/// Undirected weighted graph.
#[derive(Debug, Clone)]
pub struct Graph {
    pub n: usize,
    pub edges: Vec<(usize, usize, i32)>,
}

impl Graph {
    /// Erdos-Renyi random graph with unit weights.
    pub fn random(n: usize, edge_prob: f64, rng: &mut Rng) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.f64() < edge_prob {
                    edges.push((i, j, 1));
                }
            }
        }
        Graph { n, edges }
    }

    /// Complete bipartite graph K_{a,b} with unit weights (vertices
    /// `0..a` on one side, `a..a+b` on the other).  Handy in tests: its
    /// max cut is exactly `a * b`.
    pub fn complete_bipartite(a: usize, b: usize) -> Graph {
        let edges = (0..a)
            .flat_map(|i| (a..a + b).map(move |j| (i, j, 1)))
            .collect();
        Graph { n: a + b, edges }
    }

    /// Cut value of a +-1 assignment.
    pub fn cut_value(&self, spins: &[i8]) -> i64 {
        assert_eq!(spins.len(), self.n);
        self.edges
            .iter()
            .filter(|(i, j, _)| spins[*i] != spins[*j])
            .map(|(_, _, w)| *w as i64)
            .sum()
    }

    pub fn total_weight(&self) -> i64 {
        self.edges.iter().map(|(_, _, w)| *w as i64).sum()
    }

    /// Adjacency lists (each undirected edge appears on both endpoints).
    pub fn adjacency(&self) -> Vec<Vec<(usize, i32)>> {
        let mut adj: Vec<Vec<(usize, i32)>> = vec![Vec::new(); self.n];
        for &(i, j, w) in &self.edges {
            adj[i].push((j, w));
            adj[j].push((i, w));
        }
        adj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_value_bipartite_complete() {
        // K_{2,2}: optimal cut = all 4 edges.
        let g = Graph::complete_bipartite(2, 2);
        assert_eq!(g.cut_value(&[1, 1, -1, -1]), 4);
        assert_eq!(g.cut_value(&[1, -1, 1, -1]), 2);
        assert_eq!(g.total_weight(), 4);
    }

    #[test]
    fn random_graph_edge_count_reasonable() {
        let mut rng = Rng::new(4);
        let g = Graph::random(30, 0.5, &mut rng);
        let max_edges = 30 * 29 / 2;
        assert!(g.edges.len() > max_edges / 4 && g.edges.len() < max_edges * 3 / 4);
    }

    #[test]
    fn adjacency_mirrors_edges() {
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 2), (1, 2, 1)],
        };
        let adj = g.adjacency();
        assert_eq!(adj[0], vec![(1, 2)]);
        assert_eq!(adj[1], vec![(0, 2), (2, 1)]);
        assert_eq!(adj[2], vec![(1, 1)]);
    }
}
