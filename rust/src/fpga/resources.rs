//! Structural resource estimates for the two architectures, composed
//! from `components` exactly along the paper's circuit descriptions.
//!
//! Calibration (DESIGN.md section 8): two free constants per architecture
//! (routing/congestion duplication and fixed infrastructure) are pinned so
//! the model hits the paper's Table 4 endpoints; the scaling *slopes*
//! (Figs. 9-10) and the capacity walls (max N) then emerge from the
//! structure.  A calibration unit test asserts the anchors.

use crate::fpga::components as c;
use crate::fpga::device::Device;
use crate::onn::config::NetworkConfig;

/// Resource usage of one synthesized design.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceEstimate {
    pub luts: usize,
    pub ffs: usize,
    pub dsps: usize,
    pub bram18: usize,
}

impl ResourceEstimate {
    pub fn bram36(&self) -> usize {
        self.bram18.div_ceil(2)
    }

    pub fn fits(&self, d: &Device) -> bool {
        self.luts <= d.luts && self.ffs <= d.ffs && self.dsps <= d.dsps && self.bram18 <= d.bram18
    }

    /// Mean of the four utilization percentages — the paper's "total
    /// area used" aggregate (section 4.2).
    pub fn area_percent(&self, d: &Device) -> f64 {
        let u = [
            self.luts as f64 / d.luts as f64,
            self.ffs as f64 / d.ffs as f64,
            self.dsps as f64 / d.dsps as f64,
            self.bram36() as f64 / d.bram36() as f64,
        ];
        100.0 * u.iter().sum::<f64>() / 4.0
    }
}

// ---- calibration constants -------------------------------------------------

/// Routing/congestion LUT duplication for the recurrent design, which
/// routes N^2 weight registers into N deep combinational cones.  Base
/// duplication at tiny N, plus growth with design size.  Pinned so that
/// RA at N=48 / 5wb / 4pb lands on the paper's 49 441 LUTs (93%).
fn ra_congestion(n: usize) -> f64 {
    1.15 + 0.45 * (n as f64 / 48.0)
}

/// Fixed AXI/control infrastructure of the RA bitstream.  Zero: the
/// paper's scaling sweep synthesizes the ONN core out of context (its
/// own small-N points would otherwise be dominated by AXI overhead and
/// could not fall on the power law it reports).
const RA_INFRA_LUTS: usize = 0;
const RA_INFRA_FFS: usize = 0;

/// Congestion factor for the hybrid design (shallower logic, but BRAM /
/// DSP column routing).  Pinned to HA at N=506 -> 41 547 LUTs.
fn ha_congestion(n: usize) -> f64 {
    1.10 + 0.15 * (n as f64 / 506.0)
}

/// Zero for the same reason as the RA infrastructure: the scaling sweep
/// synthesizes the ONN core out of context.
const HA_INFRA_LUTS: usize = 0;
const HA_INFRA_FFS: usize = 0;

/// BRAM36 place-and-route replication overhead (the paper reports 100%
/// BRAM where raw packing needs ~91%).
const HA_BRAM_PNR_FACTOR: f64 = 1.094;

/// DSP48E1 SIMD packing: up to two serial MACs share one DSP (TWO24
/// mode) once the plain one-MAC-per-DSP mapping exceeds the device.
pub const DSP_MACS_PACKED: usize = 2;

// ---- recurrent architecture -------------------------------------------------

/// Structural estimate for the recurrent architecture (Figs. 2-4):
/// N oscillators, each with an N-input combinational weighted-sum tree;
/// all N^2 weights in flip-flop registers (no BRAM, no DSP — Table 4).
pub fn recurrent(cfg: &NetworkConfig) -> ResourceEstimate {
    let n = cfg.n;
    let w = cfg.weight_bits as usize;
    let pb = cfg.phase_bits as usize;
    let p = cfg.period();

    // Per oscillator, LUTs:
    //   +-W sign-select per input, the adder tree, the output-tap mux of
    //   the shift register, phase-update adder, comparator/edge logic.
    let per_osc_luts = n * c::negate_mux_luts(w)
        + c::adder_tree_luts(n, w)
        + c::mux_luts(p, 1)
        + c::adder_luts(pb)
        + c::comparator_luts(c::sum_width(n, w))
        + 8; // edge detectors + FSM glue
    let struct_luts = n * per_osc_luts;

    // FFs: the N^2 weight registers dominate; plus shift registers,
    // phase/lag/edge state and a registered tree output.
    let weight_ffs = n * n * w;
    let per_osc_ffs = c::register_ffs(p) // circular shift register
        + c::register_ffs(pb) // phase (mux select)
        + c::counter_cost(pb).1 // lag counter
        + 2 // edge detector state
        + c::register_ffs(c::sum_width(n, w)); // registered sum
    let struct_ffs = weight_ffs + n * per_osc_ffs;

    ResourceEstimate {
        luts: (struct_luts as f64 * ra_congestion(n)).round() as usize + RA_INFRA_LUTS,
        ffs: struct_ffs + RA_INFRA_FFS,
        dsps: 0,
        bram18: 0,
    }
}

// ---- hybrid architecture -----------------------------------------------------

/// How the hybrid design's N serial MACs map onto DSP slices: plain
/// one-per-DSP while they fit, SIMD-packed (2 per DSP) once they don't,
/// and spilled into fabric when even packing exceeds the device.
pub fn hybrid_mac_mapping(n: usize, d: &Device) -> (usize, usize) {
    if n <= d.dsps {
        (n, 0) // (dsps used, fabric MACs)
    } else {
        let packed_capacity = d.dsps * DSP_MACS_PACKED;
        if n <= packed_capacity {
            (n.div_ceil(DSP_MACS_PACKED), 0)
        } else {
            (d.dsps, n - packed_capacity)
        }
    }
}

/// Structural estimate for the hybrid architecture (Fig. 5): per
/// oscillator one serial MAC (DSP), weights in BRAM18 (depth N x width w,
/// two oscillators per dual-ported BRAM18), an amplitude-snapshot
/// distributed RAM, address counter and the same phase-update logic.
pub fn hybrid(cfg: &NetworkConfig, d: &Device) -> ResourceEstimate {
    let n = cfg.n;
    let w = cfg.weight_bits as usize;
    let pb = cfg.phase_bits as usize;
    let p = cfg.period();
    let sw = c::sum_width(n, w);

    let (dsps, fabric_macs) = hybrid_mac_mapping(n, d);

    // LUTs per oscillator: amplitude snapshot RAM (1 bit x N deep),
    // address counter, zero-compare, tap mux, phase adder, edge logic,
    // CDC glue.
    let per_osc_luts = c::distributed_ram_luts(n, 1)
        + c::counter_cost(c::sum_width(n, 1) - 1).0 // addr counter ~ log2 N bits
        + c::comparator_luts(sw)
        + c::mux_luts(p, 1)
        + c::adder_luts(pb)
        + 34; // edge detectors, enable FSM, CDC glue, snapshot write,
              // BRAM readout register mux
    // Fabric MACs (negate-mux + accumulate adder) for the spill.
    let fabric_mac_luts = fabric_macs * (c::negate_mux_luts(w) + c::adder_luts(sw));
    let struct_luts = n * per_osc_luts + fabric_mac_luts;

    // FFs per oscillator: shift register, phase, lag counter, edge state,
    // accumulator + held sum, BRAM address register, clock-domain
    // synchronizers.
    let per_osc_ffs = c::register_ffs(p)
        + c::register_ffs(pb)
        + c::counter_cost(pb).1
        + 2
        + c::register_ffs(sw) * 2 // accumulator + held result
        + c::register_ffs(c::sum_width(n, 1) - 1) // BRAM address
        + 28; // CDC double-flops, enable FSM state, BRAM output pipeline
    let struct_ffs = n * per_osc_ffs;

    // BRAM18: one weight row (N x w) per port; dual-ported -> 2 rows per
    // BRAM18; plus 2 blocks of I/O buffering.
    let raw_bram18 = n.div_ceil(2) + 2;
    let bram36 = ((raw_bram18 as f64 / 2.0) * HA_BRAM_PNR_FACTOR).ceil() as usize;

    ResourceEstimate {
        luts: (struct_luts as f64 * ha_congestion(n)).round() as usize + HA_INFRA_LUTS,
        ffs: struct_ffs + HA_INFRA_FFS,
        dsps,
        bram18: bram36 * 2,
    }
}

/// Structural estimate for one device of an emulated multi-FPGA hybrid
/// cluster: the device hosts `rows` of the `cfg.n`-oscillator design's
/// row-split weight memory and the serial MACs for those rows only, but
/// every MAC still walks all `cfg.n` inputs — so datapath widths
/// (sum/comparator/address) stay pinned to the full network while the
/// per-oscillator replication count drops to `rows`.  The extra terms
/// over a scaled-down [`hybrid`] are the cluster link: a phase
/// all-gather buffer holding the whole network's phase words plus the
/// serial-link FSM and CDC glue.
pub fn hybrid_cluster_shard(cfg: &NetworkConfig, rows: usize, d: &Device) -> ResourceEstimate {
    let n = cfg.n;
    let rows = rows.max(1).min(n);
    let w = cfg.weight_bits as usize;
    let pb = cfg.phase_bits as usize;
    let p = cfg.period();
    let sw = c::sum_width(n, w);

    let (dsps, fabric_macs) = hybrid_mac_mapping(rows, d);

    let per_osc_luts = c::distributed_ram_luts(n, 1)
        + c::counter_cost(c::sum_width(n, 1) - 1).0
        + c::comparator_luts(sw)
        + c::mux_luts(p, 1)
        + c::adder_luts(pb)
        + 34;
    let fabric_mac_luts = fabric_macs * (c::negate_mux_luts(w) + c::adder_luts(sw));
    // Cluster link: an n x pb phase all-gather buffer (distributed RAM)
    // plus serial-link framing/arbitration FSM and CDC glue.
    let link_luts = c::distributed_ram_luts(n, pb) + 96;
    let struct_luts = rows * per_osc_luts + fabric_mac_luts + link_luts;

    let per_osc_ffs = c::register_ffs(p)
        + c::register_ffs(pb)
        + c::counter_cost(pb).1
        + 2
        + c::register_ffs(sw) * 2
        + c::register_ffs(c::sum_width(n, 1) - 1)
        + 28;
    let link_ffs = c::register_ffs(pb) * 2 + 64; // link shift register + FSM/CDC
    let struct_ffs = rows * per_osc_ffs + link_ffs;

    // Weight memory: one n x w row per BRAM18 port while a row fits the
    // 18Kb port, deeper row stacks otherwise; dual-ported -> 2 rows per
    // BRAM18; plus 2 blocks of link I/O buffering.
    let row_ports = (n * w).div_ceil(18 * 1024);
    let raw_bram18 = (rows * row_ports.max(1)).div_ceil(2) + 2;
    let bram36 = ((raw_bram18 as f64 / 2.0) * HA_BRAM_PNR_FACTOR).ceil() as usize;

    ResourceEstimate {
        luts: (struct_luts as f64 * ha_congestion(rows)).round() as usize + HA_INFRA_LUTS,
        ffs: struct_ffs + HA_INFRA_FFS,
        dsps,
        bram18: bram36 * 2,
    }
}

/// Largest fully connected `n` an emulated `devices`-FPGA hybrid
/// cluster fits at the given precision: every device must fit its own
/// row share (`ceil(n / devices)` rows — the widest shard of the
/// leader's split).  At `devices == 1` this matches
/// [`max_oscillators`]'s hybrid answer modulo the link overhead.
pub fn max_oscillators_hybrid_cluster(
    d: &Device,
    devices: usize,
    phase_bits: u32,
    weight_bits: u32,
) -> usize {
    let devices = devices.max(1);
    let mut best = 0;
    let mut n = 1;
    while n < 100_000 {
        let cfg = NetworkConfig {
            n,
            phase_bits,
            weight_bits,
        };
        let shard = hybrid_cluster_shard(&cfg, n.div_ceil(devices), d);
        if shard.fits(d) {
            best = n;
            n += 1;
        } else {
            break;
        }
    }
    best
}

/// Estimate for an architecture by name ("recurrent" / "hybrid").
pub fn estimate(arch: &str, cfg: &NetworkConfig, d: &Device) -> ResourceEstimate {
    match arch {
        "recurrent" => recurrent(cfg),
        "hybrid" => hybrid(cfg, d),
        other => panic!("unknown architecture '{other}'"),
    }
}

/// Largest N that fits the device at the given precision.
pub fn max_oscillators(arch: &str, d: &Device, phase_bits: u32, weight_bits: u32) -> usize {
    let mut best = 0;
    let mut n = 1;
    // Exponential probe + linear refine keeps this fast for any device.
    while n < 100_000 {
        let cfg = NetworkConfig {
            n,
            phase_bits,
            weight_bits,
        };
        if estimate(arch, &cfg, d).fits(d) {
            best = n;
            n += 1;
        } else {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::zynq7020;

    fn cfg(n: usize) -> NetworkConfig {
        NetworkConfig::paper(n)
    }

    /// DESIGN.md section 8 calibration anchors (paper Table 4).
    #[test]
    fn table4_recurrent_anchors() {
        let d = zynq7020();
        let r = recurrent(&cfg(48));
        let lut_pct = 100.0 * r.luts as f64 / d.luts as f64;
        assert!(
            (85.0..=97.0).contains(&lut_pct),
            "RA LUT% at N=48: {lut_pct:.1} (paper 92.9)"
        );
        // FF within 20% of the paper's 13 906.
        assert!(
            (r.ffs as f64 - 13_906.0).abs() / 13_906.0 < 0.20,
            "RA FFs at N=48: {}",
            r.ffs
        );
        assert_eq!(r.dsps, 0);
        assert_eq!(r.bram18, 0);
    }

    #[test]
    fn table4_hybrid_anchors() {
        let d = zynq7020();
        let r = hybrid(&cfg(506), &d);
        assert!(
            (r.luts as f64 - 41_547.0).abs() / 41_547.0 < 0.15,
            "HA LUTs at N=506: {}",
            r.luts
        );
        assert!(
            (r.ffs as f64 - 44_748.0).abs() / 44_748.0 < 0.15,
            "HA FFs at N=506: {}",
            r.ffs
        );
        assert_eq!(r.dsps, 220, "HA must saturate the DSP column");
        assert_eq!(r.bram36(), 140, "HA must saturate BRAM");
        assert!(r.fits(&d));
    }

    /// Paper headline: 48 vs 506 oscillators — a 10.5x increase.
    #[test]
    fn max_oscillator_capacity() {
        let d = zynq7020();
        let ra = max_oscillators("recurrent", &d, 4, 5);
        let ha = max_oscillators("hybrid", &d, 4, 5);
        assert!(
            (46..=50).contains(&ra),
            "RA max N = {ra} (paper 48)"
        );
        assert!(
            (500..=510).contains(&ha),
            "HA max N = {ha} (paper 506)"
        );
        let ratio = ha as f64 / ra as f64;
        assert!(
            (9.0..=11.5).contains(&ratio),
            "capacity ratio {ratio:.1} (paper 10.5)"
        );
    }

    #[test]
    fn recurrent_limited_by_luts() {
        let d = zynq7020();
        let ra = max_oscillators("recurrent", &d, 4, 5);
        let over = recurrent(&cfg(ra + 1));
        assert!(over.luts > d.luts, "RA wall must be the LUTs (paper 5.1)");
        assert!(over.ffs <= d.ffs);
    }

    #[test]
    fn hybrid_limited_by_bram_dsp() {
        let d = zynq7020();
        let ha = max_oscillators("hybrid", &d, 4, 5);
        let over = hybrid(&cfg(ha + 1), &d);
        assert!(
            over.bram18 > d.bram18 || over.dsps > d.dsps,
            "HA wall must be BRAM/DSP (paper 5.1): over={over:?}"
        );
        assert!(over.luts <= d.luts);
    }

    #[test]
    fn mac_mapping_regimes() {
        let d = zynq7020();
        assert_eq!(hybrid_mac_mapping(100, &d), (100, 0));
        assert_eq!(hybrid_mac_mapping(220, &d), (220, 0));
        assert_eq!(hybrid_mac_mapping(300, &d), (150, 0)); // packed
        assert_eq!(hybrid_mac_mapping(440, &d), (220, 0));
        assert_eq!(hybrid_mac_mapping(506, &d), (220, 66)); // spill
    }

    #[test]
    fn cluster_shards_scale_capacity_past_one_device() {
        let d = zynq7020();
        let single = max_oscillators("hybrid", &d, 4, 5);
        let two = max_oscillators_hybrid_cluster(&d, 2, 4, 5);
        let four = max_oscillators_hybrid_cluster(&d, 4, 4, 5);
        assert!(
            two > single,
            "two devices must fit more than one: {two} vs {single}"
        );
        assert!(four > two, "capacity keeps growing with devices: {four} vs {two}");
        // A row share past the single-device fit must itself not fit —
        // the per-shard wall is real, not a rubber stamp.
        let big = NetworkConfig::paper(4 * single);
        assert!(!hybrid_cluster_shard(&big, 4 * single, &d).fits(&d));
        // Paper-size network split two ways: each shard fits with room.
        let cfg506 = NetworkConfig::paper(506);
        let shard = hybrid_cluster_shard(&cfg506, 253, &d);
        assert!(shard.fits(&d));
        assert!(shard.dsps <= hybrid(&cfg506, &d).dsps);
    }

    #[test]
    fn estimates_monotone_in_n() {
        let d = zynq7020();
        for arch in ["recurrent", "hybrid"] {
            let mut prev = 0;
            for n in [4, 8, 16, 32, 64] {
                let r = estimate(arch, &cfg(n), &d);
                assert!(r.luts > prev, "{arch} LUTs not monotone at {n}");
                prev = r.luts;
            }
        }
    }

    #[test]
    fn area_percent_bounds() {
        let d = zynq7020();
        let r = hybrid(&cfg(506), &d);
        let a = r.area_percent(&d);
        assert!((50.0..=100.0).contains(&a), "area% = {a}");
    }
}
