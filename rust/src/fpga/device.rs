//! FPGA device capacity tables.

/// Programmable-logic capacities of a target device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub luts: usize,
    pub ffs: usize,
    pub dsps: usize,
    /// BRAM18 blocks (a BRAM36 is two of these).
    pub bram18: usize,
}

impl Device {
    pub fn bram36(&self) -> usize {
        self.bram18 / 2
    }
}

/// Zynq-7020 (PYNQ-Z2), the paper's test platform: 53 200 LUTs,
/// 106 400 flip-flops, 220 DSP48E1 slices, 140 BRAM36 (280 BRAM18).
pub fn zynq7020() -> Device {
    Device {
        name: "Zynq-7020",
        luts: 53_200,
        ffs: 106_400,
        dsps: 220,
        bram18: 280,
    }
}

/// Zynq-7010 — a smaller sibling, used by the what-if sweeps.
pub fn zynq7010() -> Device {
    Device {
        name: "Zynq-7010",
        luts: 17_600,
        ffs: 35_200,
        dsps: 80,
        bram18: 120,
    }
}

/// Kintex-7 K325T — a larger part, for the paper's "future work: larger
/// devices" extrapolation.
pub fn kintex7_325t() -> Device {
    Device {
        name: "Kintex-7 325T",
        luts: 203_800,
        ffs: 407_600,
        dsps: 840,
        bram18: 890,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq7020_capacities() {
        let d = zynq7020();
        assert_eq!(d.luts, 53_200);
        assert_eq!(d.ffs, 106_400);
        assert_eq!(d.dsps, 220);
        assert_eq!(d.bram36(), 140);
    }

    #[test]
    fn device_ordering_sane() {
        assert!(zynq7010().luts < zynq7020().luts);
        assert!(zynq7020().luts < kintex7_325t().luts);
    }
}
