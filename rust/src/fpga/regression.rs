//! Log-log linear regression — the analysis of paper section 4.2: "a
//! standard linear regression was fitted on the base-10 logarithm of the
//! data points ... the slope in the logarithmic scale equals the order
//! of scaling", with R^2 and 95% confidence intervals (Figs. 9-12).

/// Ordinary least squares fit y = a + b x with diagnostics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
    /// Half-width of the 95% confidence interval on the slope.
    pub slope_ci95: f64,
    pub n: usize,
}

/// Two-sided 97.5% Student-t quantiles for small dof (dof = n-2), then
/// the normal limit.
fn t_975(dof: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if dof == 0 {
        f64::INFINITY
    } else if dof <= 30 {
        TABLE[dof - 1]
    } else {
        1.96
    }
}

/// OLS in linear space.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Fit {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    assert!(n >= 2, "need at least 2 points");
    let nf = n as f64;
    let mx = xs.iter().sum::<f64>() / nf;
    let my = ys.iter().sum::<f64>() / nf;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (intercept + slope * x);
            e * e
        })
        .sum();
    let r2 = if syy > 0.0 { 1.0 - ss_res / syy } else { 1.0 };
    let slope_ci95 = if n > 2 {
        let se = (ss_res / (nf - 2.0) / sxx).sqrt();
        t_975(n - 2) * se
    } else {
        f64::INFINITY
    };
    Fit {
        slope,
        intercept,
        r2,
        slope_ci95,
        n,
    }
}

/// OLS on (log10 x, log10 y): `slope` is the scaling order.
pub fn loglog_fit(xs: &[f64], ys: &[f64]) -> Fit {
    let lx: Vec<f64> = xs.iter().map(|x| x.log10()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.log10()).collect();
    linear_fit(&lx, &ly)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(f.slope_ci95 < 1e-9);
    }

    #[test]
    fn loglog_recovers_power_law() {
        // y = 3 x^2.5
        let xs: Vec<f64> = (1..=12).map(|i| i as f64 * 4.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(2.5)).collect();
        let f = loglog_fit(&xs, &ys);
        assert!((f.slope - 2.5).abs() < 1e-9, "{}", f.slope);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_reasonable() {
        // y = x^2 with +-5% deterministic "noise".
        let xs: Vec<f64> = (2..=20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| x * x * if i % 2 == 0 { 1.05 } else { 0.95 })
            .collect();
        let f = loglog_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 0.1, "{}", f.slope);
        assert!(f.r2 > 0.99);
        assert!(f.slope_ci95 > 0.0 && f.slope_ci95 < 0.2);
    }

    #[test]
    fn negative_slope() {
        let xs = [1.0, 10.0, 100.0];
        let ys = [1000.0, 100.0, 10.0];
        let f = loglog_fit(&xs, &ys);
        assert!((f.slope + 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn rejects_single_point() {
        linear_fit(&[1.0], &[1.0]);
    }

    #[test]
    fn t_table_monotone() {
        assert!(t_975(1) > t_975(5));
        assert!(t_975(5) > t_975(30));
        assert_eq!(t_975(100), 1.96);
    }
}
