//! Critical-path timing model: logic frequency and the resulting
//! oscillation frequency for both architectures (paper Table 5, Fig. 11).
//!
//! Model: t_crit in ns = constant + logic-depth term + routing term.
//! The recurrent design's path crosses the N-input adder tree
//! (depth ~ log2 N) and its routing spreads with the quadratic design
//! area (~ sqrt(LUTs) ~ N); the hybrid design's path is the serial MAC
//! plus BRAM access, growing only through routing spread (~ sqrt N) and
//! the fabric-MAC spill penalty past the DSP capacity.  Constants are
//! pinned to the paper's Table 5 endpoints (RA 40 MHz @ 48, HA 50 MHz @
//! 506) and the fabric ceiling caps small designs.

use crate::fpga::device::Device;
use crate::fpga::resources::hybrid_mac_mapping;
use crate::onn::config::NetworkConfig;
use crate::rtl::hybrid::SYNC_OVERHEAD_CYCLES;

/// 7-series fabric practical Fmax ceiling for these designs (MHz).
pub const FABRIC_FMAX_MHZ: f64 = 110.0;

/// Phase-update FSM cycles per phase step in the recurrent design: the
/// measured oscillation frequency in the paper (625 kHz at 40 MHz logic,
/// 4 phase bits) implies a division of 64 = 16 * 4, i.e. a 4-state
/// update FSM per shift-register step.
pub const RA_FSM_CYCLES: usize = 4;

/// Recurrent-architecture logic frequency (MHz).
pub fn logic_frequency_recurrent(n: usize) -> f64 {
    // t = 1.0 + 0.8*log2(N) + 2.9*sqrt(N)   [ns]; anchor: 39 MHz @ 48.
    let nf = n.max(2) as f64;
    let t_ns = 1.0 + 0.8 * nf.log2() + 2.9 * nf.sqrt();
    (1000.0 / t_ns).min(FABRIC_FMAX_MHZ)
}

/// Hybrid-architecture logic frequency (MHz).
pub fn logic_frequency_hybrid(n: usize, d: &Device) -> f64 {
    let nf = n.max(2) as f64;
    let (_, fabric) = hybrid_mac_mapping(n, d);
    // Serial MAC + BRAM path, routing spread ~ sqrt(N); spilling MACs to
    // fabric adds a wide carry chain to the critical path.
    let spill_penalty = if fabric > 0 {
        2.0 + 0.01 * fabric as f64
    } else {
        0.0
    };
    let t_ns = 6.0 + 0.5 * nf.sqrt() + spill_penalty;
    (1000.0 / t_ns).min(FABRIC_FMAX_MHZ)
}

/// Per-device link handshake cost of one cluster all-gather, in fast
/// cycles: start-of-frame arbitration plus the CDC resync at the
/// receiver, paid once per participating device per exchange.
pub const CLUSTER_HANDSHAKE_CYCLES: u64 = 4;

/// Fast cycles one emulated multi-FPGA cluster spends synchronizing
/// per oscillation period: after every one of the `2^phase_bits` phase
/// steps each device broadcasts the phases of the rows it owns over
/// the shared serial link, so the whole network's `n` phase words
/// cross the wire once per step, plus a fixed per-device handshake
/// ([`CLUSTER_HANDSHAKE_CYCLES`]).  A single device never synchronizes
/// (0 cycles), which keeps the cluster cost model degenerate with the
/// single-fabric one at `devices == 1`.
pub fn cluster_sync_cycles(devices: usize, n: usize, phase_bits: u32) -> u64 {
    if devices <= 1 {
        return 0;
    }
    let steps = 1u64 << phase_bits;
    steps * (n as u64 + CLUSTER_HANDSHAKE_CYCLES * devices as u64)
}

/// Hybrid-architecture logic frequency (MHz) for one cluster device
/// carrying `rows` of an `n`-oscillator design: the serial-MAC path
/// still walks all `n` inputs (the `sqrt(n)` routing-spread term), but
/// the DSP spill penalty is set by the *rows the device hosts* — the
/// reason a row-split cluster avoids the fabric-MAC kink a single
/// device would pay past its packed-DSP capacity.
pub fn logic_frequency_hybrid_shard(n: usize, rows: usize, d: &Device) -> f64 {
    let nf = n.max(2) as f64;
    let (_, fabric) = hybrid_mac_mapping(rows.max(1), d);
    let spill_penalty = if fabric > 0 {
        2.0 + 0.01 * fabric as f64
    } else {
        0.0
    };
    let t_ns = 6.0 + 0.5 * nf.sqrt() + spill_penalty;
    (1000.0 / t_ns).min(FABRIC_FMAX_MHZ)
}

/// Oscillation frequency (kHz) for the recurrent design: logic clock
/// divided by the FSM cycles per phase step and the 2^pb steps/period.
pub fn oscillation_frequency_recurrent(cfg: &NetworkConfig) -> f64 {
    let f_logic_mhz = logic_frequency_recurrent(cfg.n);
    f_logic_mhz * 1e3 / (cfg.period() as f64 * RA_FSM_CYCLES as f64)
}

/// Oscillation frequency (kHz) for the hybrid design: each phase step
/// additionally waits for the serial sum (N + sync overhead fast
/// cycles) — the serialization trade-off of section 5.1.
pub fn oscillation_frequency_hybrid(cfg: &NetworkConfig, d: &Device) -> f64 {
    let f_logic_mhz = logic_frequency_hybrid(cfg.n, d);
    let fast_cycles = (cfg.n + SYNC_OVERHEAD_CYCLES) as f64;
    f_logic_mhz * 1e3 / (cfg.period() as f64 * fast_cycles)
}

/// Oscillation frequency (kHz) for the hybrid design driving a CSR
/// sparse coupling fabric: the serial MAC only walks the stored
/// nonzeros of each row, so the per-step wait shrinks from `n` to the
/// *average row nonzero count* (the rows are serviced round-robin, so
/// the mean — not the max — sets the sustained period).  At
/// `avg_row_nnz == n as f64` this degenerates to the dense model
/// exactly; the logic frequency is unchanged (same MAC, same routing
/// spread — only the iteration count drops).
pub fn oscillation_frequency_hybrid_sparse(
    cfg: &NetworkConfig,
    d: &Device,
    avg_row_nnz: f64,
) -> f64 {
    let f_logic_mhz = logic_frequency_hybrid(cfg.n, d);
    let fast_cycles = avg_row_nnz + SYNC_OVERHEAD_CYCLES as f64;
    f_logic_mhz * 1e3 / (cfg.period() as f64 * fast_cycles)
}

/// (f_logic MHz, f_osc kHz) for an architecture by name.
pub fn frequencies(arch: &str, cfg: &NetworkConfig, d: &Device) -> (f64, f64) {
    match arch {
        "recurrent" => (
            logic_frequency_recurrent(cfg.n),
            oscillation_frequency_recurrent(cfg),
        ),
        "hybrid" => (
            logic_frequency_hybrid(cfg.n, d),
            oscillation_frequency_hybrid(cfg, d),
        ),
        other => panic!("unknown architecture '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::zynq7020;

    fn cfg(n: usize) -> NetworkConfig {
        NetworkConfig::paper(n)
    }

    /// Paper Table 5 anchors.
    #[test]
    fn table5_recurrent_anchors() {
        let f_logic = logic_frequency_recurrent(48);
        assert!(
            (36.0..=44.0).contains(&f_logic),
            "RA f_logic @48 = {f_logic:.1} MHz (paper 40)"
        );
        let f_osc = oscillation_frequency_recurrent(&cfg(48));
        assert!(
            (560.0..=690.0).contains(&f_osc),
            "RA f_osc @48 = {f_osc:.1} kHz (paper 625)"
        );
    }

    #[test]
    fn table5_hybrid_anchors() {
        let d = zynq7020();
        let f_logic = logic_frequency_hybrid(506, &d);
        assert!(
            (45.0..=55.0).contains(&f_logic),
            "HA f_logic @506 = {f_logic:.1} MHz (paper 50)"
        );
        let f_osc = oscillation_frequency_hybrid(&cfg(506), &d);
        assert!(
            (5.5..=6.7).contains(&f_osc),
            "HA f_osc @506 = {f_osc:.2} kHz (paper 6.1)"
        );
    }

    #[test]
    fn hybrid_trades_frequency_for_size() {
        // Section 5.1: RA has lower f_logic but higher f_osc at its max.
        let d = zynq7020();
        let ra_osc = oscillation_frequency_recurrent(&cfg(48));
        let ha_osc = oscillation_frequency_hybrid(&cfg(506), &d);
        assert!(
            ra_osc > 50.0 * ha_osc,
            "RA {ra_osc:.1} kHz vs HA {ha_osc:.2} kHz"
        );
        assert!(logic_frequency_hybrid(506, &d) > logic_frequency_recurrent(48));
    }

    #[test]
    fn frequencies_decrease_with_n() {
        let d = zynq7020();
        let mut prev_ra = f64::INFINITY;
        let mut prev_ha = f64::INFINITY;
        for n in [8, 16, 32, 64, 128, 256, 506] {
            if n <= 48 {
                let f = oscillation_frequency_recurrent(&cfg(n));
                assert!(f < prev_ra);
                prev_ra = f;
            }
            let f = oscillation_frequency_hybrid(&cfg(n), &d);
            assert!(f < prev_ha);
            prev_ha = f;
        }
    }

    #[test]
    fn fmax_ceiling_applies() {
        let d = zynq7020();
        assert!(logic_frequency_hybrid(2, &d) <= FABRIC_FMAX_MHZ);
        assert!(logic_frequency_recurrent(2) <= FABRIC_FMAX_MHZ);
    }

    #[test]
    fn sparse_hybrid_prices_nonzeros_not_n() {
        let d = zynq7020();
        // Full rows degenerate to the dense model bit-for-bit.
        for n in [16, 128, 506] {
            let dense = oscillation_frequency_hybrid(&cfg(n), &d);
            let full = oscillation_frequency_hybrid_sparse(&cfg(n), &d, n as f64);
            assert_eq!(dense.to_bits(), full.to_bits(), "n={n}");
        }
        // Fewer nonzeros per row -> strictly faster oscillation, and
        // the speedup tracks the cycle-count ratio exactly (f_logic is
        // shared, so it cancels).
        let n = 512;
        let dense = oscillation_frequency_hybrid(&cfg(n), &d);
        let mut prev = 0.0;
        for nnz in [256.0, 64.0, 16.0, 4.0] {
            let f = oscillation_frequency_hybrid_sparse(&cfg(n), &d, nnz);
            assert!(f > prev, "monotone in sparsity: {nnz} -> {f}");
            prev = f;
            let want = dense * (n + SYNC_OVERHEAD_CYCLES) as f64
                / (nnz + SYNC_OVERHEAD_CYCLES as f64);
            assert!((f - want).abs() < 1e-9 * want, "nnz={nnz}: {f} vs {want}");
        }
    }

    #[test]
    fn cluster_sync_is_free_on_one_device_and_priced_past_it() {
        // Degenerate case: a single fabric never all-gathers.
        assert_eq!(cluster_sync_cycles(1, 506, 4), 0);
        assert_eq!(cluster_sync_cycles(0, 506, 4), 0);
        // Two devices at paper precision: 16 steps, each moving 506
        // phase words plus 2 handshakes of 4 cycles.
        assert_eq!(cluster_sync_cycles(2, 506, 4), 16 * (506 + 2 * 4));
        // Monotone in device count (handshakes) and network size (payload).
        assert!(cluster_sync_cycles(3, 506, 4) > cluster_sync_cycles(2, 506, 4));
        assert!(cluster_sync_cycles(2, 1000, 4) > cluster_sync_cycles(2, 506, 4));
        // Doubling the phase resolution doubles the exchanges per period.
        assert_eq!(
            cluster_sync_cycles(2, 506, 5),
            2 * cluster_sync_cycles(2, 506, 4)
        );
    }

    #[test]
    fn shard_frequency_avoids_the_spill_a_single_device_pays() {
        let d = zynq7020();
        // 600 oscillators spill fabric MACs on one device; 300 rows per
        // cluster device stay inside the packed-DSP capacity, so the
        // shard clock is strictly faster at the same network size.
        let single = logic_frequency_hybrid(600, &d);
        let shard = logic_frequency_hybrid_shard(600, 300, &d);
        assert!(shard > single, "{shard} vs {single}");
        // A shard carrying every row degenerates to the single-device
        // model bit-for-bit.
        for n in [48, 300, 506, 600] {
            assert_eq!(
                logic_frequency_hybrid_shard(n, n, &d).to_bits(),
                logic_frequency_hybrid(n, &d).to_bits(),
                "n={n}"
            );
        }
    }

    #[test]
    fn spill_penalty_kinks_the_curve() {
        let d = zynq7020();
        // Crossing the packed-DSP capacity (440) must cost extra delay.
        let before = logic_frequency_hybrid(440, &d);
        let after = logic_frequency_hybrid(441, &d);
        assert!(before - after > 3.0, "{before} -> {after}");
    }
}
