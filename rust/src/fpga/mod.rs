//! FPGA resource and timing models + the log-log regression used for the
//! paper's scaling analysis (Figures 9-12, Tables 4-5).
//!
//! The paper measured Vivado synthesis results on a Zynq-7020; this
//! module replaces the synthesizer with a *structural* cost model: each
//! architecture is decomposed into the circuit components the paper
//! describes (adder trees, +-W muxes, shift registers, serial MACs,
//! BRAM-held weight memories, counters), and per-component LUT/FF costs
//! follow standard Xilinx 7-series mapping rules.  Calibration anchors
//! (documented in DESIGN.md section 8) pin the few free constants to the
//! paper's reported endpoints; everything else — the scaling *slopes*,
//! the crossover shapes, the resource walls — is emergent.

pub mod components;
pub mod device;
pub mod regression;
pub mod resources;
pub mod timing;
