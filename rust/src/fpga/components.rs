//! Per-component LUT/FF cost primitives (Xilinx 7-series mapping rules).
//!
//! These follow standard synthesis results for the 7-series fabric:
//! a W-bit ripple-carry adder maps to ~W LUTs (carry chains are free),
//! a 2:1 mux of W bits to ~W LUTs, a 4:1 mux to one LUT6 per bit,
//! distributed RAM packs 64 bits per LUT (RAM64X1S), an SRL packs a
//! 16-deep shift register into one LUT.  The few *calibration constants*
//! (routing-congestion factor, infrastructure overhead) are pinned to the
//! paper's reported endpoints and documented at their definitions.

/// LUTs for a signed W-bit adder/subtractor.
pub fn adder_luts(width: usize) -> usize {
    width
}

/// LUTs for a +-W sign-select (the "multiplication" of Fig. 4/5: negate
/// the weight when the oscillator amplitude is low): XOR per bit plus
/// carry-in, ~width + 1.
pub fn negate_mux_luts(width: usize) -> usize {
    width + 1
}

/// LUTs for an M:1 mux of `width` bits (LUT6 = 4:1 mux per bit, tree'd).
pub fn mux_luts(inputs: usize, width: usize) -> usize {
    if inputs <= 1 {
        return 0;
    }
    // ceil(inputs/4) first level, then recurse; closed form ~ inputs/3.
    let mut total = 0;
    let mut m = inputs;
    while m > 1 {
        let level = m.div_ceil(4);
        total += level;
        m = level;
    }
    total * width
}

/// FFs for a register of `width` bits.
pub fn register_ffs(width: usize) -> usize {
    width
}

/// LUT+FF for a W-bit counter (increment logic + state).
pub fn counter_cost(width: usize) -> (usize, usize) {
    (width, width)
}

/// LUTs for a comparator against a constant (carry-chain assisted).
pub fn comparator_luts(width: usize) -> usize {
    width.div_ceil(2).max(1)
}

/// Distributed RAM (RAM64X1S): 64 bits per LUT, per bit-plane.
pub fn distributed_ram_luts(depth: usize, width: usize) -> usize {
    depth.div_ceil(64) * width
}

/// The parallel adder tree of the recurrent architecture (Fig. 4):
/// N inputs of `w` bits each; adder widths grow one bit per level.
/// Returns total LUTs for the N-1 adders.
pub fn adder_tree_luts(n_inputs: usize, w: usize) -> usize {
    if n_inputs <= 1 {
        return 0;
    }
    let mut total = 0;
    let mut m = n_inputs;
    let mut width = w + 1;
    while m > 1 {
        let adders = m / 2;
        total += adders * adder_luts(width);
        m = m - adders; // odd input carried to next level
        width += 1;
    }
    total
}

/// Depth (levels) of the adder tree — drives the critical path model.
pub fn adder_tree_depth(n_inputs: usize) -> usize {
    if n_inputs <= 1 {
        0
    } else {
        (usize::BITS - (n_inputs - 1).leading_zeros()) as usize
    }
}

/// Bit width of the weighted sum: w-bit weights accumulated N times.
pub fn sum_width(n: usize, w: usize) -> usize {
    w + (usize::BITS - n.max(1).leading_zeros()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adder_tree_counts_all_adders() {
        // 4 inputs -> 3 adders: widths 6, 6, 7 (w = 5).
        assert_eq!(adder_tree_luts(4, 5), 6 + 6 + 7);
        assert_eq!(adder_tree_luts(1, 5), 0);
        assert_eq!(adder_tree_luts(2, 5), 6);
    }

    #[test]
    fn adder_tree_handles_odd_inputs() {
        // 3 inputs: level 1 has 1 adder (2 remain), level 2 has 1.
        assert_eq!(adder_tree_luts(3, 5), 6 + 7);
    }

    #[test]
    fn adder_tree_depth_log2() {
        assert_eq!(adder_tree_depth(2), 1);
        assert_eq!(adder_tree_depth(4), 2);
        assert_eq!(adder_tree_depth(48), 6);
        assert_eq!(adder_tree_depth(506), 9);
        assert_eq!(adder_tree_depth(1), 0);
    }

    #[test]
    fn mux_tree() {
        assert_eq!(mux_luts(4, 1), 1);
        assert_eq!(mux_luts(16, 1), 4 + 1);
        assert_eq!(mux_luts(1, 8), 0);
        assert_eq!(mux_luts(4, 8), 8);
    }

    #[test]
    fn sum_width_grows_logarithmically() {
        assert_eq!(sum_width(1, 5), 6);
        assert_eq!(sum_width(48, 5), 11);
        assert_eq!(sum_width(506, 5), 14);
    }

    #[test]
    fn distributed_ram_packing() {
        assert_eq!(distributed_ram_luts(64, 1), 1);
        assert_eq!(distributed_ram_luts(65, 1), 2);
        assert_eq!(distributed_ram_luts(506, 1), 8);
    }
}
