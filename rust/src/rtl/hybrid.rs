//! Cycle-accurate simulator of the proposed **hybrid architecture**
//! (paper section 3): one serial multiply-accumulate per oscillator,
//! time-multiplexed over all N inputs on a fast clock domain, weights in
//! addressable memory (BRAM), MAC inferable to a DSP slice.
//!
//! Timing (paper Fig. 6): the slow-clock rising edge at tick `t`
//! triggers the serial accumulation whose result is consumed at tick
//! `t+1`, where the phase updates.  Because the oscillator shift
//! registers are clocked by the *slow* clock, the amplitudes sampled at
//! edge `t` are exactly the values the recurrent design's combinational
//! tree sees during cycle `t` — so a correctly synchronized hybrid
//! design computes the *same* phase updates as the recurrent design,
//! just one serial-latency later in wall-clock.  That is the paper's
//! Table 6 finding ("the oscillator dynamics of the hybrid architecture
//! are the same").
//!
//! The paper also observes run-to-run variance "because the signal that
//! enables computation is not synchronized with the oscillators", which
//! becomes visible only for small networks at high noise (3x3 / 50%).
//! [`HybridOnn::with_stale_enable`] models that mis-synchronization: the
//! enable fires one slow tick early, so sums lag the amplitudes by one
//! tick and the reference waveforms shift accordingly.
//!
//! Since the solver-engine refactor the simulator is a **resumable
//! chunked stepper with a batch-lane dimension**: one `HybridOnn` holds
//! any number of independent register-state lanes sharing the weight
//! memory (the way one synthesized core is re-run per anneal replica),
//! each steppable period by period with settle tracking that survives
//! chunk boundaries ([`HybridOnn::step_lane_period`]).  The classic
//! run-to-completion interface ([`RtlSim`], lane 0) is unchanged and
//! tick-for-tick identical — `rust/tests/prop_rtl.rs` holds that proof
//! obligation against the untouched recurrent simulator.

use crate::onn::config::NetworkConfig;
use crate::onn::phase::wrap;
use crate::onn::weights::WeightMatrix;
use crate::rtl::edge::{PhaseLagCounter, RisingEdge};
use crate::rtl::oscillator::ShiftRegOscillator;
use crate::rtl::{relative_phases, RtlSim};

/// Fast-clock cycles of pipeline/synchronization overhead per serial
/// sum, on top of the N accumulation cycles.  Chosen so the paper's
/// headline frequency division reproduces: N=506 gives 512 fast cycles
/// per slow cycle and f_osc = 50 MHz / (16 * 512) = 6.1 kHz (Table 5).
pub const SYNC_OVERHEAD_CYCLES: usize = 6;

/// The serial MAC datapath of Fig. 5: accumulator register + one
/// multiplier whose operands are the BRAM-read weight and the muxed
/// oscillator amplitude.  Modelled cycle-by-cycle for fidelity.
#[derive(Debug, Clone, Default)]
pub struct SerialMac {
    acc: i32,
    idx: usize,
    busy: bool,
    /// Total fast-clock cycles consumed over the simulation.
    pub fast_cycles: u64,
}

impl SerialMac {
    pub fn start(&mut self) {
        self.acc = 0;
        self.idx = 0;
        self.busy = true;
    }

    /// One fast-clock cycle: read weight `w[idx]` from BRAM, mux
    /// amplitude `amps[idx]`, accumulate. Returns the finished sum when
    /// the counter reaches the end of the row.
    pub fn cycle(&mut self, row: &[i8], amps: &[i32]) -> Option<i32> {
        debug_assert!(self.busy, "cycle() before start()");
        self.fast_cycles += 1;
        let j = self.idx;
        self.acc += if amps[j] > 0 {
            row[j] as i32
        } else {
            -(row[j] as i32)
        };
        self.idx += 1;
        if self.idx == row.len() {
            self.busy = false;
            self.fast_cycles += SYNC_OVERHEAD_CYCLES as u64;
            Some(self.acc)
        } else {
            None
        }
    }

    /// Run a complete serial accumulation (N + overhead fast cycles).
    pub fn run(&mut self, row: &[i8], amps: &[i32]) -> i32 {
        self.start();
        loop {
            if let Some(sum) = self.cycle(row, amps) {
                return sum;
            }
        }
    }
}

/// Register state of one lane: everything a synthesized hybrid core
/// holds besides the (shared) weight memory.  One lane per concurrent
/// anneal replica; lanes are fully independent.
#[derive(Debug, Clone)]
struct LaneState {
    osc: Vec<ShiftRegOscillator>,
    phases: Vec<i32>,
    ref_edge: Vec<RisingEdge>,
    own_edge: Vec<RisingEdge>,
    lag: Vec<PhaseLagCounter>,
    macs: Vec<SerialMac>,
    /// Result of the most recent completed serial accumulation.
    sums: Vec<i32>,
    sums_primed: bool,
    amps: Vec<i32>,
    pending: Vec<Option<i32>>,
    /// Whole periods stepped since the last phase (re)program — the
    /// resumable analog of the run-to-completion loop counter (period 0
    /// is edge-detector warm-up and never counts as settled).
    periods_done: usize,
    /// Relative phases after the previous period (settle comparand),
    /// carried across chunk boundaries.
    prev_rel: Vec<i32>,
}

impl LaneState {
    fn new(cfg: &NetworkConfig) -> Self {
        let n = cfg.n;
        let p = cfg.period();
        Self {
            osc: vec![ShiftRegOscillator::new(p); n],
            phases: vec![0; n],
            ref_edge: vec![RisingEdge::new(); n],
            own_edge: vec![RisingEdge::new(); n],
            lag: vec![PhaseLagCounter::new(p as i32); n],
            macs: vec![SerialMac::default(); n],
            sums: vec![0; n],
            sums_primed: false,
            amps: vec![0; n],
            pending: vec![None; n],
            periods_done: 0,
            prev_rel: vec![0; n],
        }
    }

    /// Load phases (mux selects) and reset every register to power-on
    /// state — a fresh run.  MAC cycle counters deliberately survive:
    /// they meter total emulated hardware work across runs.
    fn program(&mut self, cfg: &NetworkConfig, phases: &[i32]) {
        assert_eq!(phases.len(), cfg.n);
        let p = cfg.period();
        let pi = p as i32;
        self.phases.clear();
        self.phases.extend(phases.iter().map(|&x| wrap(x, pi)));
        for o in self.osc.iter_mut() {
            *o = ShiftRegOscillator::new(p);
        }
        for e in self.ref_edge.iter_mut() {
            *e = RisingEdge::new();
        }
        for e in self.own_edge.iter_mut() {
            *e = RisingEdge::new();
        }
        for l in self.lag.iter_mut() {
            *l = PhaseLagCounter::new(pi);
        }
        for pd in self.pending.iter_mut() {
            *pd = None;
        }
        self.sums_primed = false;
        self.periods_done = 0;
        self.prev_rel = relative_phases(&self.phases, pi);
    }

    fn serial_sums_from(&mut self, w: &WeightMatrix, amps_snapshot: &[i32]) {
        for (i, mac) in self.macs.iter_mut().enumerate() {
            self.sums[i] = mac.run(w.row(i), amps_snapshot);
        }
    }

    /// One phase-update clock tick (the old monolithic simulator's
    /// `tick`, verbatim, against this lane's registers).
    fn tick(&mut self, cfg: &NetworkConfig, w: &WeightMatrix, stale_enable: bool) {
        let n = cfg.n;

        for j in 0..n {
            self.amps[j] = self.osc[j].amplitude(self.phases[j]);
        }

        // Serial accumulation for this slow cycle (Fig. 6): triggered at
        // the slow edge, N + overhead fast cycles, result registered.
        // Correctly synchronized, the snapshot is this cycle's
        // amplitudes — the same values RA's combinational tree sees.
        // With the enable mis-synchronized (stale_enable) the result
        // still reflects the *previous* cycle when this one begins.
        if stale_enable {
            if !self.sums_primed {
                let snapshot = self.amps.clone();
                self.serial_sums_from(w, &snapshot);
                self.sums_primed = true;
            }
        } else {
            let snapshot = self.amps.clone();
            self.serial_sums_from(w, &snapshot);
            self.sums_primed = true;
        }

        for i in 0..n {
            let ref_level = if self.sums[i] > 0 {
                true
            } else if self.sums[i] < 0 {
                false
            } else {
                self.amps[i] > 0
            };
            let re = self.ref_edge[i].update(ref_level);
            self.lag[i].tick(re);
            let oe = self.own_edge[i].update(self.amps[i] > 0);
            self.pending[i] = match (oe, self.lag[i].lag()) {
                (true, Some(d)) => Some(d),
                _ => None,
            };
        }

        // Mis-synchronized enable: the computation kicked off now (from
        // this cycle's amplitudes) is only consumed next cycle.
        if stale_enable {
            let snapshot = self.amps.clone();
            self.serial_sums_from(w, &snapshot);
        }

        for o in self.osc.iter_mut() {
            o.tick();
        }
        let p = cfg.period() as i32;
        for i in 0..n {
            if let Some(d) = self.pending[i].take() {
                self.phases[i] = wrap(self.phases[i] + d, p);
            }
        }
    }

    /// Advance one whole oscillation period (P ticks) and update the
    /// chunk-spanning settle tracker.  Returns true when this period's
    /// relative phases reproduced the previous period's — the same
    /// criterion, warm-up rule included, as the run-to-completion
    /// `RtlSim::run_to_settle`.
    fn step_period(&mut self, cfg: &NetworkConfig, w: &WeightMatrix, stale_enable: bool) -> bool {
        for _ in 0..cfg.period() {
            self.tick(cfg, w, stale_enable);
        }
        let rel = relative_phases(&self.phases, cfg.period() as i32);
        let settled = self.periods_done >= 1 && rel == self.prev_rel;
        self.prev_rel = rel;
        self.periods_done += 1;
        settled
    }
}

/// One per-block weight bank: lanes `[lane0, lane0 + lanes)` read their
/// serial-MAC rows from `w` instead of the shared weight memory.  The
/// hardware already time-multiplexes one BRAM weight memory per period,
/// so a bank is a block-indexed read address — the lane-packing story
/// of DESIGN_SOLVER.md §12.
#[derive(Debug, Clone)]
struct LaneBank {
    lane0: usize,
    lanes: usize,
    w: WeightMatrix,
}

/// The multi-lane hybrid-architecture simulator.  [`RtlSim`] (the
/// classic single-trial interface) drives lane 0; the lane API carries
/// the batch dimension of the solver engine (`runtime::rtl`).
#[derive(Debug, Clone)]
pub struct HybridOnn {
    cfg: NetworkConfig,
    w: WeightMatrix,
    /// Mis-synchronized enable: sums lag the amplitudes by one tick.
    stale_enable: bool,
    lanes: Vec<LaneState>,
    /// Per-block weight banks (lane-packing); lanes outside every bank
    /// keep reading the shared weight memory `w`.
    banks: Vec<LaneBank>,
}

impl HybridOnn {
    pub fn new(cfg: NetworkConfig, w: WeightMatrix) -> Self {
        Self::with_lanes(cfg, w, 1)
    }

    /// A simulator with `lanes` independent register-state lanes sharing
    /// one weight memory — the batch dimension of the RTL solver engine.
    pub fn with_lanes(cfg: NetworkConfig, w: WeightMatrix, lanes: usize) -> Self {
        assert_eq!(cfg.n, w.n);
        assert!(lanes >= 1, "a simulator needs at least one lane");
        Self {
            cfg,
            w,
            stale_enable: false,
            lanes: (0..lanes).map(|_| LaneState::new(&cfg)).collect(),
            banks: Vec::new(),
        }
    }

    /// Variant with the computation-enable mis-synchronized by one slow
    /// tick (see module docs): reproduces the paper's small-network
    /// divergence and run-to-run variance.
    pub fn with_stale_enable(cfg: NetworkConfig, w: WeightMatrix) -> Self {
        let mut s = Self::new(cfg, w);
        s.stale_enable = true;
        s
    }

    pub fn weights(&self) -> &WeightMatrix {
        &self.w
    }

    /// Number of independent register-state lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Fast-clock cycles each phase update costs: the serialization
    /// factor of the slow clock domain (paper section 3).
    pub fn fast_cycles_per_update(&self) -> usize {
        self.cfg.n + SYNC_OVERHEAD_CYCLES
    }

    /// Total fast cycles burned so far across all MACs of all lanes.
    pub fn total_fast_cycles(&self) -> u64 {
        self.lanes
            .iter()
            .flat_map(|l| l.macs.iter())
            .map(|m| m.fast_cycles)
            .sum()
    }

    /// Emulated wall-clock fast cycles of one lane: its N MACs run in
    /// parallel in hardware (one per oscillator), so the lane's elapsed
    /// fast-clock time is any single MAC's cycle count.
    pub fn lane_fast_cycles(&self, lane: usize) -> u64 {
        self.lanes[lane].macs.first().map_or(0, |m| m.fast_cycles)
    }

    /// Total fast cycles burned by row `row`'s serial MAC, summed over
    /// all lanes — the meter a cluster device owning that row reads.
    /// Every row's MAC walks the same N inputs per update, so any row in
    /// a device's range is a faithful sample of that device's clock.
    pub fn row_fast_cycles(&self, row: usize) -> u64 {
        self.lanes.iter().map(|l| l.macs[row].fast_cycles).sum()
    }

    /// Install (or replace) the weight bank serving lanes
    /// `[lane0, lane0 + lanes)`.  Banks must stay inside the lane count
    /// and must not overlap each other; range/overlap policy is enforced
    /// by the engine layer, so violations here are programming errors.
    pub fn set_lane_bank(&mut self, lane0: usize, lanes: usize, w: WeightMatrix) {
        assert_eq!(self.cfg.n, w.n, "bank weights must match the network size");
        assert!(lanes >= 1 && lane0 + lanes <= self.lanes.len(), "bank out of range");
        assert!(
            !self
                .banks
                .iter()
                .any(|b| b.lane0 != lane0 && lane0 < b.lane0 + b.lanes && b.lane0 < lane0 + lanes),
            "bank overlaps an existing bank"
        );
        self.banks.retain(|b| b.lane0 != lane0);
        self.banks.push(LaneBank { lane0, lanes, w });
    }

    /// Remove the weight bank anchored at `lane0`; true when one was
    /// installed.  Its lanes fall back to the shared weight memory.
    pub fn clear_lane_bank(&mut self, lane0: usize) -> bool {
        let before = self.banks.len();
        self.banks.retain(|b| b.lane0 != lane0);
        self.banks.len() != before
    }

    /// The weight memory `lane` reads: its bank when one covers it, the
    /// shared matrix otherwise.
    fn bank_weights<'a>(banks: &'a [LaneBank], shared: &'a WeightMatrix, lane: usize) -> &'a WeightMatrix {
        banks
            .iter()
            .find(|b| lane >= b.lane0 && lane < b.lane0 + b.lanes)
            .map_or(shared, |b| &b.w)
    }

    /// Program a lane's phases and reset its registers — a fresh run on
    /// that lane.  Other lanes are untouched.
    pub fn set_lane_phases(&mut self, lane: usize, phases: &[i32]) {
        let cfg = self.cfg;
        self.lanes[lane].program(&cfg, phases);
    }

    pub fn lane_phases(&self, lane: usize) -> &[i32] {
        &self.lanes[lane].phases
    }

    /// Advance one phase-update clock tick on one lane.
    pub fn tick_lane(&mut self, lane: usize) {
        let cfg = self.cfg;
        let stale = self.stale_enable;
        // Split the borrow: the lane is mutated, the weights only read.
        let (banks, shared, lanes) = (&self.banks, &self.w, &mut self.lanes);
        let w = Self::bank_weights(banks, shared, lane);
        lanes[lane].tick(&cfg, w, stale);
    }

    /// Advance one lane by one whole period (P ticks); true when the
    /// lane's relative phases reproduced the previous period's (the
    /// resumable settle criterion — see `RtlSim::run_to_settle`).
    pub fn step_lane_period(&mut self, lane: usize) -> bool {
        let cfg = self.cfg;
        let stale = self.stale_enable;
        let (banks, shared, lanes) = (&self.banks, &self.w, &mut self.lanes);
        let w = Self::bank_weights(banks, shared, lane);
        lanes[lane].step_period(&cfg, w, stale)
    }

    /// Apply an in-place phase perturbation to one lane *without*
    /// resetting its registers — the injected annealing kick of the
    /// solver engine: the update circuit rewrites the mux selects while
    /// shift registers, edge detectors and counters keep running.  The
    /// settle comparand is rebased on the kicked state so the next
    /// period is judged against what the hardware actually holds.
    pub fn kick_lane_phases(&mut self, lane: usize, mut kick: impl FnMut(usize, i32) -> i32) {
        let p = self.cfg.period() as i32;
        let l = &mut self.lanes[lane];
        for (i, phi) in l.phases.iter_mut().enumerate() {
            *phi = wrap(kick(i, *phi), p);
        }
        l.prev_rel = relative_phases(&l.phases, p);
    }
}

impl RtlSim for HybridOnn {
    fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn set_phases(&mut self, phases: &[i32]) {
        self.set_lane_phases(0, phases);
    }

    fn phases(&self) -> &[i32] {
        self.lane_phases(0)
    }

    fn tick(&mut self) {
        self.tick_lane(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::learning::train_quantized;
    use crate::onn::patterns::dataset_3x3;
    use crate::onn::phase::{spin_to_phase, state_to_spins};
    use crate::rtl::recurrent::RecurrentOnn;
    use crate::util::rng::Rng;

    fn cfg(n: usize) -> NetworkConfig {
        NetworkConfig::paper(n)
    }

    #[test]
    fn serial_mac_equals_dot_product() {
        let mut rng = Rng::new(50);
        let n = 23;
        let row: Vec<i8> = (0..n).map(|_| rng.range_i64(-16, 16) as i8).collect();
        let amps: Vec<i32> = (0..n).map(|_| rng.spin() as i32).collect();
        let mut mac = SerialMac::default();
        let got = mac.run(&row, &amps);
        let want: i32 = row
            .iter()
            .zip(&amps)
            .map(|(&w, &a)| w as i32 * a)
            .sum();
        assert_eq!(got, want);
        assert_eq!(mac.fast_cycles, (n + SYNC_OVERHEAD_CYCLES) as u64);
    }

    #[test]
    fn frequency_division_matches_table5() {
        // N=506: 512 fast cycles per slow cycle; at 50 MHz fast clock the
        // oscillation frequency is 50e6 / (16 * 512) = 6.104 kHz.
        let sim = HybridOnn::new(cfg(506), WeightMatrix::zeros(506));
        assert_eq!(sim.fast_cycles_per_update(), 512);
        let f_osc = 50e6 / (16.0 * sim.fast_cycles_per_update() as f64);
        assert!((f_osc - 6.1e3).abs() < 50.0, "f_osc = {f_osc}");
    }

    #[test]
    fn zero_weights_hold_phases() {
        let n = 4;
        let mut sim = HybridOnn::new(cfg(n), WeightMatrix::zeros(n));
        sim.set_phases(&[1, 6, 9, 14]);
        let out = sim.run_to_settle(8);
        assert_eq!(out.phases, vec![1, 6, 9, 14]);
    }

    #[test]
    fn follower_aligns_to_pinned_leader() {
        let mut w = WeightMatrix::zeros(2);
        w.set(1, 0, 8);
        let mut sim = HybridOnn::new(cfg(2), w);
        sim.set_phases(&[4, 11]);
        let out = sim.run_to_settle(20);
        assert!(out.settled.is_some());
        assert_eq!(out.phases, vec![4, 4]);
    }

    #[test]
    fn stale_enable_follower_locks_one_tick_behind() {
        // With the computation enable mis-synchronized by one slow tick
        // (the paper's run-to-run variance source), a follower locks to
        // the leader's waveform as sampled one tick earlier: a constant
        // relative offset of -1 phase step.
        let mut w = WeightMatrix::zeros(2);
        w.set(1, 0, 8);
        let mut sim = HybridOnn::with_stale_enable(cfg(2), w);
        sim.set_phases(&[4, 11]);
        let out = sim.run_to_settle(20);
        assert!(out.settled.is_some());
        assert_eq!(out.phases[0], 4, "free-running leader must not move");
        assert_eq!(
            (out.phases[1] - out.phases[0]).rem_euclid(16),
            15,
            "follower one stale tick behind: {:?}",
            out.phases
        );
    }

    #[test]
    fn synchronized_hybrid_identical_to_recurrent() {
        // Correctly synchronized, the two architectures compute the same
        // phase updates (Table 6's finding) — bit-identical here.
        let mut rng = Rng::new(123);
        let n = 7;
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-8, 9) as i8);
            }
        }
        let mut ra = RecurrentOnn::new(cfg(n), w.clone());
        let mut ha = HybridOnn::new(cfg(n), w);
        for _ in 0..10 {
            let init: Vec<i32> =
                (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
            ra.set_phases(&init);
            ha.set_phases(&init);
            let (oa, ob) = (ra.run_to_settle(40), ha.run_to_settle(40));
            assert_eq!(oa.phases, ob.phases);
            assert_eq!(oa.settled, ob.settled);
        }
    }

    #[test]
    fn stored_pattern_is_stable() {
        let ds = dataset_3x3();
        let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
        let w = train_quantized(&pats, &cfg(9));
        let mut sim = HybridOnn::new(cfg(9), w);
        for pat in &pats {
            let phases: Vec<i32> = pat.iter().map(|&s| spin_to_phase(s, 16)).collect();
            sim.set_phases(&phases);
            let out = sim.run_to_settle(30);
            assert!(out.settled.is_some());
            let spins = state_to_spins(&out.phases, 16);
            let rel: Vec<i8> = pat.iter().map(|&s| s * pat[0]).collect();
            assert_eq!(spins, rel, "relative pattern moved");
        }
    }

    #[test]
    fn hybrid_close_to_recurrent_on_retrieval() {
        // Table 6's claim: the two architectures retrieve (nearly)
        // identically.  Run the same 3x3 corruption trials through both
        // RTL simulators and require closely matching accuracy.
        let ds = dataset_3x3();
        let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
        let w = train_quantized(&pats, &cfg(9));
        let mut ra = RecurrentOnn::new(cfg(9), w.clone());
        let mut ha = HybridOnn::new(cfg(9), w);
        let mut rng = Rng::new(99);
        let trials = 60;
        let (mut ok_ra, mut ok_ha) = (0i32, 0i32);
        for t in 0..trials {
            let target = &ds.patterns[t % 2];
            let corrupted = target.corrupt(2, &mut rng);
            let phases: Vec<i32> = corrupted
                .spins
                .iter()
                .map(|&s| spin_to_phase(s, 16))
                .collect();
            ra.set_phases(&phases);
            ha.set_phases(&phases);
            let (oa, ob) = (ra.run_to_settle(64), ha.run_to_settle(64));
            if oa.settled.is_some()
                && target.matches_up_to_inversion(&state_to_spins(&oa.phases, 16))
            {
                ok_ra += 1;
            }
            if ob.settled.is_some()
                && target.matches_up_to_inversion(&state_to_spins(&ob.phases, 16))
            {
                ok_ha += 1;
            }
        }
        assert!(
            (ok_ra - ok_ha).abs() <= trials as i32 / 5,
            "architectures diverged: RA {ok_ra} vs HA {ok_ha} of {trials}"
        );
    }

    #[test]
    fn lanes_are_independent_and_match_solo_runs() {
        // Every lane of a 3-lane simulator must reproduce the trajectory
        // of a dedicated single-lane simulator started from its init.
        let mut rng = Rng::new(321);
        let n = 5;
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-8, 9) as i8);
            }
        }
        let inits: Vec<Vec<i32>> = (0..3)
            .map(|_| (0..n).map(|_| rng.range_i64(0, 16) as i32).collect())
            .collect();
        let mut multi = HybridOnn::with_lanes(cfg(n), w.clone(), 3);
        for (lane, init) in inits.iter().enumerate() {
            multi.set_lane_phases(lane, init);
        }
        for period in 0..12 {
            // Interleave lane stepping to prove independence.
            for lane in [2usize, 0, 1] {
                multi.step_lane_period(lane);
            }
            for (lane, init) in inits.iter().enumerate() {
                let mut solo = HybridOnn::new(cfg(n), w.clone());
                solo.set_phases(init);
                for _ in 0..(period + 1) * 16 {
                    solo.tick();
                }
                assert_eq!(
                    multi.lane_phases(lane),
                    solo.phases(),
                    "lane {lane} diverged at period {period}"
                );
            }
        }
    }

    #[test]
    fn lane_banks_select_per_block_weight_memories() {
        // Lanes 0-1 read bank A, lane 2 reads bank B, lane 3 the shared
        // memory: every lane must reproduce a dedicated simulator built
        // on its own matrix, interleaved stepping included.
        let mut rng = Rng::new(654);
        let n = 4;
        let mut mk = |seed_off: i64| {
            let mut w = WeightMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    w.set(i, j, rng.range_i64(-8 + seed_off, 9) as i8);
                }
            }
            w
        };
        let (wa, wb, ws) = (mk(0), mk(1), mk(2));
        let inits: Vec<Vec<i32>> = (0..4)
            .map(|_| (0..n).map(|_| rng.range_i64(0, 16) as i32).collect())
            .collect();
        let mut multi = HybridOnn::with_lanes(cfg(n), ws.clone(), 4);
        multi.set_lane_bank(0, 2, wa.clone());
        multi.set_lane_bank(2, 1, wb.clone());
        for (lane, init) in inits.iter().enumerate() {
            multi.set_lane_phases(lane, init);
        }
        let lane_w = [&wa, &wa, &wb, &ws];
        for period in 0..10 {
            for lane in [3usize, 1, 2, 0] {
                multi.step_lane_period(lane);
            }
            for (lane, init) in inits.iter().enumerate() {
                let mut solo = HybridOnn::new(cfg(n), lane_w[lane].clone());
                solo.set_phases(init);
                for _ in 0..(period + 1) * 16 {
                    solo.tick();
                }
                assert_eq!(
                    multi.lane_phases(lane),
                    solo.phases(),
                    "lane {lane} diverged at period {period}"
                );
            }
        }
        // Replacing a bank re-points its lanes; clearing falls back to
        // the shared memory.
        multi.set_lane_bank(2, 1, ws.clone());
        assert!(multi.clear_lane_bank(0));
        assert!(!multi.clear_lane_bank(0), "already cleared");
        multi.set_lane_phases(0, &inits[0]);
        multi.step_lane_period(0);
        let mut solo = HybridOnn::new(cfg(n), ws.clone());
        solo.set_phases(&inits[0]);
        for _ in 0..16 {
            solo.tick();
        }
        assert_eq!(multi.lane_phases(0), solo.phases());
    }

    #[test]
    fn step_lane_period_settles_like_run_to_settle() {
        // The resumable per-period settle tracker must fire at exactly
        // the period index the monolithic run_to_settle reports.
        let mut w = WeightMatrix::zeros(2);
        w.set(1, 0, 8);
        let mut oracle = HybridOnn::new(cfg(2), w.clone());
        oracle.set_phases(&[4, 11]);
        let out = oracle.run_to_settle(20);
        let want = out.settled.expect("pinned leader settles");

        let mut sim = HybridOnn::new(cfg(2), w);
        sim.set_lane_phases(0, &[4, 11]);
        let mut got = None;
        for period in 0..20 {
            if sim.step_lane_period(0) {
                got = Some(period);
                break;
            }
        }
        assert_eq!(got, Some(want));
    }

    #[test]
    fn kick_preserves_register_state() {
        // A kick rewrites mux selects only: zero weights then hold the
        // kicked phases, and the MAC cycle meter keeps accumulating.
        let n = 3;
        let mut sim = HybridOnn::new(cfg(n), WeightMatrix::zeros(n));
        sim.set_lane_phases(0, &[1, 5, 9]);
        sim.step_lane_period(0);
        let before = sim.lane_fast_cycles(0);
        assert!(before > 0);
        sim.kick_lane_phases(0, |i, phi| phi + 1 + i as i32);
        assert_eq!(sim.lane_phases(0), &[2, 7, 12]);
        sim.step_lane_period(0);
        assert_eq!(sim.lane_phases(0), &[2, 7, 12], "zero weights must hold");
        assert!(sim.lane_fast_cycles(0) > before);
    }
}
