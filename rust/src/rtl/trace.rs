//! Waveform trace capture for the RTL simulators (a minimal VCD-style
//! recorder rendered as ASCII), used by tests and debugging sessions.

/// Records named digital/integer signals over simulation ticks.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    names: Vec<String>,
    samples: Vec<Vec<i32>>, // samples[tick][signal]
}

impl Trace {
    pub fn new(names: &[&str]) -> Self {
        Self {
            names: names.iter().map(|s| s.to_string()).collect(),
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, values: &[i32]) {
        assert_eq!(values.len(), self.names.len(), "trace width mismatch");
        self.samples.push(values.to_vec());
    }

    pub fn ticks(&self) -> usize {
        self.samples.len()
    }

    pub fn signal(&self, name: &str) -> Option<Vec<i32>> {
        let idx = self.names.iter().position(|n| n == name)?;
        Some(self.samples.iter().map(|row| row[idx]).collect())
    }

    /// ASCII waveform: 0/1 signals drawn as _ and #, wider integers as
    /// digit streams (mod 10).  One row per signal.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let width = self.names.iter().map(|n| n.len()).max().unwrap_or(0);
        for (i, name) in self.names.iter().enumerate() {
            out.push_str(&format!("{name:>width$} "));
            let vals: Vec<i32> = self.samples.iter().map(|r| r[i]).collect();
            let binary = vals.iter().all(|&v| v == 0 || v == 1);
            for v in vals {
                if binary {
                    out.push(if v == 1 { '#' } else { '_' });
                } else {
                    out.push(
                        char::from_digit((v.rem_euclid(10)) as u32, 10).unwrap_or('?'),
                    );
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reads_back() {
        let mut t = Trace::new(&["clk", "phase"]);
        t.record(&[0, 3]);
        t.record(&[1, 4]);
        assert_eq!(t.ticks(), 2);
        assert_eq!(t.signal("clk"), Some(vec![0, 1]));
        assert_eq!(t.signal("phase"), Some(vec![3, 4]));
        assert_eq!(t.signal("nope"), None);
    }

    #[test]
    fn renders_binary_as_waveform() {
        let mut t = Trace::new(&["s"]);
        for v in [0, 1, 1, 0] {
            t.record(&[v]);
        }
        let r = t.render();
        assert!(r.contains("_##_"), "{r}");
    }

    #[test]
    fn renders_integers_as_digits() {
        let mut t = Trace::new(&["p"]);
        for v in [3, 12, 5] {
            t.record(&[v]);
        }
        assert!(t.render().contains("325"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_wrong_width() {
        let mut t = Trace::new(&["a"]);
        t.record(&[1, 2]);
    }
}
