//! Cycle-accurate simulators of the paper's two digital architectures.
//!
//! These stand in for the paper's Verilog/FPGA implementation: every
//! register-transfer-level mechanism the paper describes is modelled at
//! clock-edge granularity — the circular-shift-register phase-controlled
//! oscillator (Fig. 3), the reference-signal generation from the sign of
//! the weighted sum, the edge detector + counter phase measurement, the
//! parallel adder tree of the recurrent design (Fig. 4) and the serial
//! MAC + two clock domains of the hybrid design (Figs. 5-6).
//!
//! The recurrent and hybrid simulators differ in exactly the way the
//! circuits differ: the recurrent design recomputes the weighted sum
//! combinationally *every* phase-update clock, while the hybrid design
//! serializes the sum over N fast-clock cycles during the previous
//! slow-clock period — so its reference signal is derived from
//! amplitudes that are one phase-update tick stale.

pub mod edge;
pub mod hybrid;
pub mod oscillator;
pub mod recurrent;
pub mod trace;

use crate::onn::config::NetworkConfig;

/// Phases relative to oscillator 0, wrapped into `[0, P)` — the
/// paper's readout ("measuring the final steady-state phases ... in
/// relation to each other") and the quantity settling is judged on.
/// One definition shared by the run-to-completion driver below and the
/// resumable lane stepper (`hybrid::HybridOnn`), so the two settle
/// paths — proven index-equal in `rust/tests/prop_rtl.rs` — can never
/// drift apart.
pub(crate) fn relative_phases(phases: &[i32], p: i32) -> Vec<i32> {
    let r = *phases.first().unwrap_or(&0);
    phases.iter().map(|&x| (x - r).rem_euclid(p)).collect()
}

/// Result of running an RTL simulation until the phases stop changing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RtlOutcome {
    pub phases: Vec<i32>,
    /// Oscillation periods elapsed until the first full period with no
    /// phase change, or None on timeout.
    pub settled: Option<usize>,
    /// Total phase-update clock ticks simulated.
    pub ticks: u64,
}

/// Common interface over the two architecture simulators.
pub trait RtlSim {
    fn config(&self) -> &NetworkConfig;
    /// Load phases (mux selects) as the initial condition.
    fn set_phases(&mut self, phases: &[i32]);
    fn phases(&self) -> &[i32];
    /// Advance one phase-update clock tick.
    fn tick(&mut self);
    /// Run whole periods until settled (no *relative* phase change
    /// across a full period) or `max_periods` elapsed.
    ///
    /// Two hardware realities shape this check:
    /// * Period 0 is warm-up — the edge detectors and lag counters only
    ///   become valid after the first reference rising edge, so an
    ///   unchanged period 0 does not count as settled.
    /// * Settling is judged on phases *relative to oscillator 0*, the
    ///   paper's own readout ("measuring the final steady-state phases
    ///   ... in relation to each other").  The hybrid design's
    ///   serialized sum is one tick stale, which manifests as a slow
    ///   uniform rotation of all phases — physically irrelevant, and
    ///   invisible to a relative-phase check.
    fn run_to_settle(&mut self, max_periods: usize) -> RtlOutcome {
        let p = self.config().period();
        let pi = p as i32;
        let relative = |phases: &[i32]| relative_phases(phases, pi);
        let mut ticks = 0u64;
        let mut prev_raw = self.phases().to_vec();
        let mut prev_rel = relative(&prev_raw);
        for period in 0..max_periods {
            for _ in 0..p {
                self.tick();
                ticks += 1;
            }
            let rel = relative(self.phases());
            if period >= 1 && rel == prev_rel {
                return RtlOutcome {
                    phases: prev_raw,
                    settled: Some(period),
                    ticks,
                };
            }
            prev_rel = rel;
            prev_raw.clear();
            prev_raw.extend_from_slice(self.phases());
        }
        RtlOutcome {
            phases: prev_raw,
            settled: None,
            ticks,
        }
    }
}
