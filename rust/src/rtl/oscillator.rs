//! Circular shift-register phase-controlled oscillator (paper Fig. 3).
//!
//! `2^phase_bits` registers rotate one position left per phase-update
//! clock; the first half initialize to 1 and the second half to 0, so
//! every tap carries the same square wave shifted by one extra clock.
//! Selecting tap `phi` through the mux realizes a phase shift of `phi`
//! steps — changing the mux select is how the phase update circuit
//! shifts the oscillator (Table 3 of the paper shows the state
//! evolution this module reproduces).

/// One phase-controlled oscillator.
#[derive(Debug, Clone)]
pub struct ShiftRegOscillator {
    regs: Vec<bool>,
}

impl ShiftRegOscillator {
    /// `p` registers (must be even); first half 1s, second half 0s.
    pub fn new(p: usize) -> Self {
        assert!(p >= 2 && p % 2 == 0, "period must be even, got {p}");
        let regs = (0..p).map(|i| i < p / 2).collect();
        Self { regs }
    }

    pub fn len(&self) -> usize {
        self.regs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regs.is_empty()
    }

    /// Shift one position left (register i takes register i+1's value,
    /// the last wraps around to the first's old value).
    pub fn tick(&mut self) {
        self.regs.rotate_left(1);
    }

    /// Mux output at tap `phi` as a logic level (true = high).
    pub fn output(&self, phi: i32) -> bool {
        self.regs[phi.rem_euclid(self.regs.len() as i32) as usize]
    }

    /// Output as a +1/-1 amplitude.
    pub fn amplitude(&self, phi: i32) -> i32 {
        if self.output(phi) {
            1
        } else {
            -1
        }
    }

    /// Raw register row (for Table-3-style traces).
    pub fn state(&self) -> Vec<u8> {
        self.regs.iter().map(|&b| b as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::phase::amplitude as wave_amplitude;

    #[test]
    fn table3_state_evolution() {
        // Paper Table 3 (n_phase_bits = 2): rows are time steps.
        let mut osc = ShiftRegOscillator::new(4);
        let expect = [
            [1, 1, 0, 0],
            [1, 0, 0, 1],
            [0, 0, 1, 1],
            [0, 1, 1, 0],
            [1, 1, 0, 0], // one full period
        ];
        for (t, row) in expect.iter().enumerate() {
            assert_eq!(osc.state(), row.to_vec(), "t={t}");
            osc.tick();
        }
    }

    #[test]
    fn tap_equals_shifted_wave() {
        // Column phi of Table 3 is the base square wave advanced by phi
        // clocks — the algebraic model in onn::phase.
        let p = 16;
        let mut osc = ShiftRegOscillator::new(p);
        for t in 0..(2 * p as i64) {
            for phi in 0..p as i32 {
                assert_eq!(
                    osc.amplitude(phi),
                    wave_amplitude(phi, t, p as i32),
                    "phi={phi} t={t}"
                );
            }
            osc.tick();
        }
    }

    #[test]
    fn period_matches_eq3() {
        // Eq. (3): the oscillator repeats after 2^phase_bits clocks.
        let mut osc = ShiftRegOscillator::new(8);
        let init = osc.state();
        for _ in 0..8 {
            osc.tick();
        }
        assert_eq!(osc.state(), init);
    }

    #[test]
    fn duty_cycle_half() {
        let osc = ShiftRegOscillator::new(16);
        let ones = osc.state().iter().filter(|&&x| x == 1).count();
        assert_eq!(ones, 8);
    }

    #[test]
    #[should_panic(expected = "period must be even")]
    fn odd_period_rejected() {
        ShiftRegOscillator::new(3);
    }
}
