//! Edge detection and phase-difference measurement.
//!
//! The paper measures the phase difference between the reference signal
//! (sign of the weighted sum) and the oscillator output with "an edge
//! detector and a counter": the counter restarts on each rising edge of
//! the reference; its value at the oscillator's own rising edge is the
//! lag, which the update circuit adds to the oscillator phase.

/// Rising-edge detector over a 1-bit signal.
#[derive(Debug, Clone, Default)]
pub struct RisingEdge {
    last: bool,
    primed: bool,
}

impl RisingEdge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed the current level; true exactly on a 0 -> 1 transition.
    /// The first sample only primes the detector.
    pub fn update(&mut self, level: bool) -> bool {
        let edge = self.primed && !self.last && level;
        self.last = level;
        self.primed = true;
        edge
    }
}

/// Counter of phase-update clocks since the last reference rising edge,
/// wrapping at the oscillation period.  Invalid until the first edge.
#[derive(Debug, Clone)]
pub struct PhaseLagCounter {
    p: i32,
    count: i32,
    valid: bool,
}

impl PhaseLagCounter {
    pub fn new(p: i32) -> Self {
        Self {
            p,
            count: 0,
            valid: false,
        }
    }

    /// Advance one clock; `ref_edge` marks a reference rising edge at
    /// this clock (which restarts the count at zero).
    pub fn tick(&mut self, ref_edge: bool) {
        if ref_edge {
            self.count = 0;
            self.valid = true;
        } else if self.valid {
            self.count = (self.count + 1) % self.p;
        }
    }

    /// Lag in clock ticks, if a reference edge has been seen.
    pub fn lag(&self) -> Option<i32> {
        self.valid.then_some(self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_rising_only() {
        let mut e = RisingEdge::new();
        assert!(!e.update(false)); // prime
        assert!(e.update(true)); // 0 -> 1
        assert!(!e.update(true)); // steady high
        assert!(!e.update(false)); // falling
        assert!(e.update(true)); // rising again
    }

    #[test]
    fn first_sample_never_edge() {
        let mut e = RisingEdge::new();
        assert!(!e.update(true), "power-on high is not an edge");
        assert!(!e.update(true));
    }

    #[test]
    fn lag_counts_from_ref_edge() {
        let mut c = PhaseLagCounter::new(16);
        assert_eq!(c.lag(), None);
        c.tick(true); // ref edge at t0
        assert_eq!(c.lag(), Some(0));
        for want in 1..=5 {
            c.tick(false);
            assert_eq!(c.lag(), Some(want));
        }
        c.tick(true); // new edge restarts
        assert_eq!(c.lag(), Some(0));
    }

    #[test]
    fn lag_wraps_at_period() {
        let mut c = PhaseLagCounter::new(4);
        c.tick(true);
        for _ in 0..4 {
            c.tick(false);
        }
        assert_eq!(c.lag(), Some(0)); // 4 mod 4
    }

    #[test]
    fn invalid_until_first_edge() {
        let mut c = PhaseLagCounter::new(8);
        c.tick(false);
        c.tick(false);
        assert_eq!(c.lag(), None);
    }
}
