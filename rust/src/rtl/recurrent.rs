//! Cycle-accurate simulator of the **recurrent architecture** (prior
//! art, paper section 2.3): every oscillator owns a fully combinational
//! arithmetic circuit (Fig. 4) that recomputes the weighted sum of all
//! oscillator outputs every phase-update clock.  Hardware cost of that
//! adder tree is what scales quadratically (Fig. 9/10).

use crate::onn::config::NetworkConfig;
use crate::onn::phase::wrap;
use crate::onn::weights::WeightMatrix;
use crate::rtl::edge::{PhaseLagCounter, RisingEdge};
use crate::rtl::oscillator::ShiftRegOscillator;
use crate::rtl::RtlSim;

#[derive(Debug, Clone)]
pub struct RecurrentOnn {
    cfg: NetworkConfig,
    w: WeightMatrix,
    osc: Vec<ShiftRegOscillator>,
    phases: Vec<i32>,
    ref_edge: Vec<RisingEdge>,
    own_edge: Vec<RisingEdge>,
    lag: Vec<PhaseLagCounter>,
    // scratch
    amps: Vec<i32>,
    sums: Vec<i32>,
    pending: Vec<Option<i32>>,
}

impl RecurrentOnn {
    pub fn new(cfg: NetworkConfig, w: WeightMatrix) -> Self {
        assert_eq!(cfg.n, w.n);
        let n = cfg.n;
        let p = cfg.period();
        Self {
            cfg,
            w,
            osc: vec![ShiftRegOscillator::new(p); n],
            phases: vec![0; n],
            ref_edge: vec![RisingEdge::new(); n],
            own_edge: vec![RisingEdge::new(); n],
            lag: vec![PhaseLagCounter::new(p as i32); n],
            amps: vec![0; n],
            sums: vec![0; n],
            pending: vec![None; n],
        }
    }

    pub fn weights(&self) -> &WeightMatrix {
        &self.w
    }

    /// The combinational weighted-sum block (adder tree of Fig. 4):
    /// sign-selected weights accumulated over all inputs.
    fn combinational_sums(&mut self) {
        let n = self.cfg.n;
        for i in 0..n {
            let row = self.w.row(i);
            let mut acc = 0i32;
            for j in 0..n {
                // "multiplication" is the +-W mux of the paper
                acc += if self.amps[j] > 0 {
                    row[j] as i32
                } else {
                    -(row[j] as i32)
                };
            }
            self.sums[i] = acc;
        }
    }

    fn reset_state(&mut self) {
        let p = self.cfg.period();
        for o in self.osc.iter_mut() {
            *o = ShiftRegOscillator::new(p);
        }
        for e in self.ref_edge.iter_mut() {
            *e = RisingEdge::new();
        }
        for e in self.own_edge.iter_mut() {
            *e = RisingEdge::new();
        }
        for l in self.lag.iter_mut() {
            *l = PhaseLagCounter::new(p as i32);
        }
    }
}

impl RtlSim for RecurrentOnn {
    fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn set_phases(&mut self, phases: &[i32]) {
        assert_eq!(phases.len(), self.cfg.n);
        let p = self.cfg.period() as i32;
        self.phases = phases.iter().map(|&x| wrap(x, p)).collect();
        self.reset_state();
    }

    fn phases(&self) -> &[i32] {
        &self.phases
    }

    fn tick(&mut self) {
        let n = self.cfg.n;

        // -- combinational stage (everything reads current state) --
        for j in 0..n {
            self.amps[j] = self.osc[j].amplitude(self.phases[j]);
        }
        self.combinational_sums();

        for i in 0..n {
            // Reference signal: sign of the weighted sum; exact zero
            // follows the oscillator's own amplitude (paper section 2.3).
            let ref_level = if self.sums[i] > 0 {
                true
            } else if self.sums[i] < 0 {
                false
            } else {
                self.amps[i] > 0
            };
            let re = self.ref_edge[i].update(ref_level);
            self.lag[i].tick(re);
            let oe = self.own_edge[i].update(self.amps[i] > 0);
            self.pending[i] = match (oe, self.lag[i].lag()) {
                (true, Some(d)) => Some(d),
                _ => None,
            };
        }

        // -- sequential stage (clock edge) --
        for o in self.osc.iter_mut() {
            o.tick();
        }
        let p = self.cfg.period() as i32;
        for i in 0..n {
            if let Some(d) = self.pending[i].take() {
                self.phases[i] = wrap(self.phases[i] + d, p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::learning::train_quantized;
    use crate::onn::patterns::dataset_3x3;
    use crate::onn::phase::{spin_to_phase, state_to_spins};
    use crate::util::rng::Rng;

    fn cfg(n: usize) -> NetworkConfig {
        NetworkConfig::paper(n)
    }

    #[test]
    fn zero_weights_hold_phases() {
        let n = 5;
        let mut sim = RecurrentOnn::new(cfg(n), WeightMatrix::zeros(n));
        sim.set_phases(&[0, 3, 8, 12, 15]);
        let out = sim.run_to_settle(8);
        assert_eq!(out.phases, vec![0, 3, 8, 12, 15]);
        assert_eq!(out.settled, Some(1), "period 0 is warm-up");
    }

    #[test]
    fn follower_aligns_to_pinned_leader() {
        // osc1 couples positively to osc0 only; osc0 sees nothing (zero
        // row) and free-runs.  osc1 must align to osc0's phase.
        let mut w = WeightMatrix::zeros(2);
        w.set(1, 0, 8);
        let mut sim = RecurrentOnn::new(cfg(2), w);
        sim.set_phases(&[4, 11]);
        let out = sim.run_to_settle(20);
        assert!(out.settled.is_some());
        assert_eq!(out.phases[0], 4, "free-running leader must not move");
        assert_eq!(out.phases[1], 4, "follower must lock to leader");
    }

    #[test]
    fn antiferro_follower_locks_antiphase() {
        let mut w = WeightMatrix::zeros(2);
        w.set(1, 0, -8);
        let mut sim = RecurrentOnn::new(cfg(2), w);
        sim.set_phases(&[2, 3]);
        let out = sim.run_to_settle(20);
        assert!(out.settled.is_some());
        assert_eq!(out.phases[0], 2);
        assert_eq!(
            (out.phases[1] - out.phases[0]).rem_euclid(16),
            8,
            "follower must be 180 degrees out of phase"
        );
    }

    #[test]
    fn stored_pattern_is_stable() {
        let ds = dataset_3x3();
        let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
        let w = train_quantized(&pats, &cfg(9));
        let mut sim = RecurrentOnn::new(cfg(9), w);
        for pat in &pats {
            let phases: Vec<i32> = pat.iter().map(|&s| spin_to_phase(s, 16)).collect();
            sim.set_phases(&phases);
            let out = sim.run_to_settle(30);
            assert!(out.settled.is_some(), "did not settle on stored pattern");
            let spins = state_to_spins(&out.phases, 16);
            let rel: Vec<i8> = pat.iter().map(|&s| s * pat[0]).collect();
            assert_eq!(spins, rel, "stored pattern moved");
        }
    }

    #[test]
    fn retrieves_corrupted_3x3_pattern() {
        let ds = dataset_3x3();
        let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
        let w = train_quantized(&pats, &cfg(9));
        let mut sim = RecurrentOnn::new(cfg(9), w);
        let mut rng = Rng::new(77);
        let mut correct = 0;
        let trials = 40;
        for t in 0..trials {
            let target = &ds.patterns[t % 2];
            let corrupted = target.corrupt(1, &mut rng);
            let phases: Vec<i32> = corrupted
                .spins
                .iter()
                .map(|&s| spin_to_phase(s, 16))
                .collect();
            sim.set_phases(&phases);
            let out = sim.run_to_settle(64);
            if out.settled.is_some() {
                let spins = state_to_spins(&out.phases, 16);
                if target.matches_up_to_inversion(&spins) {
                    correct += 1;
                }
            }
        }
        assert!(
            correct >= trials * 8 / 10,
            "retrieval too weak: {correct}/{trials}"
        );
    }

    #[test]
    fn set_phases_resets_detectors() {
        let n = 3;
        let mut w = WeightMatrix::zeros(n);
        w.set(1, 0, 5);
        let mut sim = RecurrentOnn::new(cfg(n), w);
        sim.set_phases(&[0, 4, 8]);
        let _ = sim.run_to_settle(10);
        // Re-arm with a fresh initial condition; behaviour must be
        // identical to a fresh simulator.
        sim.set_phases(&[0, 4, 8]);
        let a = sim.run_to_settle(10);
        let mut w2 = WeightMatrix::zeros(n);
        w2.set(1, 0, 5);
        let mut fresh = RecurrentOnn::new(cfg(n), w2);
        fresh.set_phases(&[0, 4, 8]);
        let b = fresh.run_to_settle(10);
        assert_eq!(a, b);
    }
}
