//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! Every stochastic experiment in the crate (pattern corruption, random
//! graphs, property tests) derives from this generator with an explicit
//! seed, so all tables and figures are exactly reproducible run-to-run.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and — most
/// importantly — trivially portable so experiment seeds stay meaningful.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream (for per-trial seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` via Lemire's rejection-free-ish method.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // 128-bit multiply keeps the modulo bias < 2^-64 — negligible and
        // deterministic, which is what we care about.
        (((self.next_u64() as u128) * (bound as u128)) >> 64) as u64
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// +1 / -1 spin.
    pub fn spin(&mut self) -> i8 {
        if self.bool() {
            1
        } else {
            -1
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices out of `[0, n)` (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_distinct({n}, {k})");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.usize_below(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let mut v = r.choose_distinct(20, 10);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 10);
        }
    }

    #[test]
    fn choose_distinct_full() {
        let mut r = Rng::new(12);
        let mut v = r.choose_distinct(5, 5);
        v.sort_unstable();
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent_prefix() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
