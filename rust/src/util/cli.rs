//! Tiny command-line parser (clap is unavailable offline).
//!
//! Grammar: `onn-scale <subcommand> [--flag] [--key value] ...`
//! Values parse on demand with typed getters; unknown flags are an error
//! so typos fail loudly.

use std::collections::BTreeMap;
use std::fmt;

/// CLI error type (implements Error so `?` works under anyhow).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl From<String> for CliError {
    fn from(s: String) -> Self {
        CliError(s)
    }
}

impl From<&str> for CliError {
    fn from(s: &str) -> Self {
        CliError(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

pub const FLAG_PRESENT: &str = "\u{1}"; // marker for value-less flags

impl Args {
    /// Parse `argv[1..]`. A leading non-`--` token is the subcommand.
    pub fn parse(argv: &[String]) -> Result<Args, CliError> {
        let mut it = argv.iter().peekable();
        let subcommand = match it.peek() {
            Some(s) if !s.starts_with("--") => Some(it.next().unwrap().clone()),
            _ => None,
        };
        let mut flags = BTreeMap::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| CliError(format!("expected --flag, got '{tok}'")))?;
            if key.is_empty() {
                return Err(CliError("empty flag name".into()));
            }
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => FLAG_PRESENT.to_string(),
            };
            if flags.insert(key.to_string(), val).is_some() {
                return Err(CliError(format!("duplicate flag --{key}")));
            }
        }
        Ok(Args {
            subcommand,
            flags,
            known: Vec::new(),
        })
    }

    pub fn from_env() -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&mut self, key: &str) {
        if !self.known.iter().any(|k| k == key) {
            self.known.push(key.to_string());
        }
    }

    /// Boolean flag: present (with or without a value of "true").
    pub fn has(&mut self, key: &str) -> bool {
        self.mark(key);
        match self.flags.get(key).map(|s| s.as_str()) {
            Some(FLAG_PRESENT) | Some("true") => true,
            Some("false") | None => false,
            Some(_) => true,
        }
    }

    pub fn get_str(&mut self, key: &str, default: &str) -> String {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) if v != FLAG_PRESENT => v.clone(),
            _ => default.to_string(),
        }
    }

    pub fn get_opt_str(&mut self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags
            .get(key)
            .filter(|v| v.as_str() != FLAG_PRESENT)
            .cloned()
    }

    pub fn get_usize(&mut self, key: &str, default: usize) -> Result<usize, CliError> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) if v != FLAG_PRESENT => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
            _ => Ok(default),
        }
    }

    pub fn get_u64(&mut self, key: &str, default: u64) -> Result<u64, CliError> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) if v != FLAG_PRESENT => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
            _ => Ok(default),
        }
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> Result<f64, CliError> {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) if v != FLAG_PRESENT => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
            _ => Ok(default),
        }
    }

    /// Call after all getters: errors on flags nobody asked about.
    pub fn finish(&self) -> Result<(), CliError> {
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !self.known.iter().any(|kk| kk == *k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError(format!(
                "unknown flag(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = Args::parse(&argv("table6 --trials 100 --engine native")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("table6"));
        assert_eq!(a.get_usize("trials", 1000).unwrap(), 100);
        assert_eq!(a.get_str("engine", "pjrt"), "native");
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let mut a = Args::parse(&argv("x")).unwrap();
        assert_eq!(a.get_usize("trials", 1000).unwrap(), 1000);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn boolean_flags() {
        let mut a = Args::parse(&argv("x --verbose --deep false")).unwrap();
        assert!(a.has("verbose"));
        assert!(!a.has("deep"));
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&argv("--help")).unwrap();
        assert_eq!(a.subcommand, None);
    }

    #[test]
    fn rejects_bad_tokens() {
        assert!(Args::parse(&argv("cmd stray")).is_err());
        assert!(Args::parse(&argv("cmd --a 1 --a 2")).is_err());
    }

    #[test]
    fn unknown_flags_detected() {
        let mut a = Args::parse(&argv("cmd --typo 3")).unwrap();
        let _ = a.get_usize("trials", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_reports_flag() {
        let mut a = Args::parse(&argv("cmd --trials abc")).unwrap();
        let e = a.get_usize("trials", 1).unwrap_err();
        assert!(e.0.contains("--trials"));
    }
}
