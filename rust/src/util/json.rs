//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! Supports the full JSON value model minus exotic number forms; used for
//! the artifact manifest, the coordinator's TCP protocol, and experiment
//! result dumps.  Parsing is recursive-descent over bytes with proper
//! string escapes; numbers are kept as f64 (all our payloads fit).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_i32(xs: &[i32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our payloads;
                            // map unpaired surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-sync to UTF-8 boundaries: push raw bytes.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize(), Some(1));
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\"b\\cA\t""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA\t"));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn parse_whitespace() {
        let v = Json::parse(" { \"k\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":"q\"w"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn display_integers_clean() {
        assert_eq!(Json::Num(506.0).to_string(), "506");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![("n", Json::num(9)), ("p", Json::arr_i32(&[1, -2]))]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(9));
        assert_eq!(v.to_string(), r#"{"n":9,"p":[1,-2]}"#);
    }
}
