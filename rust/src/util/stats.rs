//! Descriptive statistics used by the harness and the coordinator metrics.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0.0 when fewer than 2 points.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 1]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Min/max helpers tolerant of NaN-free data.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138).abs() < 0.01, "{s}");
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        assert_eq!(median(&[9.0, 1.0, 5.0]), 5.0);
    }

    #[test]
    fn min_max() {
        assert_eq!(min(&[3.0, -1.0, 2.0]), -1.0);
        assert_eq!(max(&[3.0, -1.0, 2.0]), 3.0);
    }
}
