//! In-tree infrastructure modules.
//!
//! This offline image only ships the `xla` crate's dependency closure, so
//! the usual ecosystem crates (rand, serde_json, clap, criterion,
//! proptest) are unavailable.  These modules are small, fully tested
//! replacements covering exactly what the rest of the crate needs.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
