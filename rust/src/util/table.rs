//! Plain-text table rendering for the paper-table reproductions.

/// A simple column-aligned table with a title, header row and rows of
/// string cells. Numeric formatting is the caller's concern.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Render an ASCII log-log scatter/line chart: one char per (x, y) bucket.
/// Series are labelled with single characters; used for the Figure
/// reproductions so the shape is visible directly in the terminal.
pub fn ascii_loglog_plot(
    title: &str,
    series: &[(&str, char, &[(f64, f64)])],
    width: usize,
    height: usize,
) -> String {
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, _, pts)| pts.iter().copied())
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &all {
        let (lx, ly) = (x.log10(), y.log10());
        x0 = x0.min(lx);
        x1 = x1.max(lx);
        y0 = y0.min(ly);
        y1 = y1.max(ly);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (_, ch, pts) in series {
        for (x, y) in pts.iter() {
            if *x <= 0.0 || *y <= 0.0 {
                continue;
            }
            let cx = ((x.log10() - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y.log10() - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = *ch;
        }
    }
    let mut out = format!("{title}\n");
    out.push_str(&format!("  y: 1e{:.1} .. 1e{:.1} (log)\n", y0, y1));
    for row in grid {
        out.push_str("  |");
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   x: 1e{:.1} .. 1e{:.1} (log)   ", x0, x1));
    for (name, ch, _) in series {
        out.push_str(&format!("[{ch}]={name} "));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row_strs(&["xx", "y"]);
        let s = t.render();
        assert!(s.contains("| a  | bbbb |"), "{s}");
        assert!(s.contains("| xx | y    |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row_strs(&["1", "2"]);
    }

    #[test]
    fn plot_contains_points() {
        let pts = [(1.0, 10.0), (10.0, 100.0), (100.0, 1000.0)];
        let s = ascii_loglog_plot("P", &[("lin", '*', &pts)], 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("[*]=lin"));
    }

    #[test]
    fn plot_handles_empty() {
        let s = ascii_loglog_plot("P", &[("e", '*', &[])], 10, 5);
        assert!(s.contains("no data"));
    }
}
