//! AOT artifact discovery: the manifest written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One lowered HLO-text artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub kind: String, // "chunk" | "step"
    pub file: PathBuf,
    pub n: usize,
    pub batch: usize,
    pub phase_bits: u32,
    pub weight_bits: u32,
    pub p: usize,
    pub chunk: usize,
    pub sha256: String,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

/// Default artifact directory: `$ONN_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("ONN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = v
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?;
        if format != "hlo-text" {
            return Err(anyhow!("unsupported artifact format '{format}'"));
        }
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let field = |k: &str| {
                a.get(k)
                    .ok_or_else(|| anyhow!("artifact[{i}] missing '{k}'"))
            };
            artifacts.push(ArtifactInfo {
                kind: field("kind")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact[{i}].kind not a string"))?
                    .to_string(),
                file: dir.join(
                    field("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("artifact[{i}].file not a string"))?,
                ),
                n: field("n")?.as_usize().ok_or_else(|| anyhow!("bad n"))?,
                batch: field("batch")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad batch"))?,
                phase_bits: field("phase_bits")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad phase_bits"))? as u32,
                weight_bits: field("weight_bits")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad weight_bits"))? as u32,
                p: field("p")?.as_usize().ok_or_else(|| anyhow!("bad p"))?,
                chunk: field("chunk")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad chunk"))?,
                sha256: field("sha256")?
                    .as_str()
                    .unwrap_or_default()
                    .to_string(),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Find the chunk artifact for a network size.
    pub fn chunk_for(&self, n: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "chunk" && a.n == n)
    }

    /// Network sizes with chunk artifacts, ascending.
    pub fn chunk_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "chunk")
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "version": 1,
      "artifacts": [
        {"kind": "chunk", "file": "onn_n9_b64_p16_c16_chunk.hlo.txt",
         "n": 9, "batch": 64, "phase_bits": 4, "weight_bits": 5,
         "p": 16, "chunk": 16, "sha256": "aa"},
        {"kind": "step", "file": "onn_n8_b4_p16_c16_step.hlo.txt",
         "n": 8, "batch": 4, "phase_bits": 4, "weight_bits": 5,
         "p": 16, "chunk": 1, "sha256": "bb"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let c = m.chunk_for(9).unwrap();
        assert_eq!(c.batch, 64);
        assert_eq!(c.chunk, 16);
        assert_eq!(c.file, PathBuf::from("/x/onn_n9_b64_p16_c16_chunk.hlo.txt"));
        assert!(m.chunk_for(99).is_none());
        assert_eq!(m.chunk_sizes(), vec![9]);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("/x"), &bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"format":"hlo-text","artifacts":[{"kind":"chunk"}]}"#;
        assert!(Manifest::parse(Path::new("/x"), bad).is_err());
    }
}
