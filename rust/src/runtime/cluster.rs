//! Emulated multi-FPGA cluster front end for the bit-true hardware
//! engine: `K` devices each hold a *row range* of the quantized weight
//! memory (the same partition `runtime::sharded` uses, via
//! `shard_row_ranges`) and exchange the full phase vector once per
//! oscillation period — the paper's Discussion names exactly this
//! multi-device synchronization as the path past a single Zynq-7020's
//! 506 oscillators.
//!
//! The cluster *dynamics* are served by one inner [`RtlEngine`]: row
//! partitioning a serial-MAC update does not change any oscillator's
//! phase sum (the integer adds commute), so splitting the rows across
//! devices is behaviorally invisible — every chunk is bit-exact with
//! the single-device engine by construction, which
//! `rust/tests/prop_rtl_packed.rs` verifies chunk by chunk.  What the
//! cluster changes is the *hardware model*:
//!
//! * **Compute.** Each device's elapsed fast-clock time is sampled from
//!   a genuine per-row `SerialMac` meter ([`RtlEngine::row_fast_cycles`]
//!   at the device's first row).  Every MAC still walks all `n` inputs
//!   per tick — the serial-MAC datapath is unchanged per oscillator — so
//!   the devices run in lockstep and the cluster's compute time is the
//!   *max* over devices, not the sum divided by `K`.  A cluster buys
//!   **capacity** (more oscillators than one device can host), not
//!   speed.
//! * **Sync.** Each emulated lane-period costs one phase all-gather,
//!   priced by [`timing::cluster_sync_cycles`] (phase words streamed
//!   per update step plus per-device handshakes) and reported as
//!   [`HardwareCost::sync_fast_cycles`].
//! * **Fit.** The design fits when *every* device's row shard fits the
//!   reference device ([`resources::hybrid_cluster_shard`]); the logic
//!   clock is the slowest shard's ([`timing::logic_frequency_hybrid_shard`])
//!   and the reported area the widest shard's.

use anyhow::{anyhow, Result};

use crate::fpga::device::{zynq7020, Device};
use crate::fpga::resources;
use crate::fpga::timing;
use crate::onn::config::NetworkConfig;
use crate::runtime::rtl::RtlEngine;
use crate::runtime::sharded::shard_row_ranges;
use crate::runtime::{ChunkEngine, HardwareCost};
use crate::telemetry::TraceSink;

pub struct RtlClusterEngine {
    inner: RtlEngine,
    cfg: NetworkConfig,
    /// Emulated device count; each owns one row range of the weight
    /// memory (`shard_row_ranges(cfg.n, shards)`).
    shards: usize,
    device: Device,
}

impl RtlClusterEngine {
    /// A `shards`-device cluster serving `cfg.n` oscillators with
    /// `batch` lanes and `chunk` periods per `run_chunk`, each device
    /// modeled on the paper's reference part (Zynq-7020).
    pub fn new(cfg: NetworkConfig, shards: usize, batch: usize, chunk: usize) -> Result<Self> {
        if shards == 0 || shards > cfg.n {
            return Err(anyhow!("bad cluster shard count {shards} for n={}", cfg.n));
        }
        Ok(Self {
            inner: RtlEngine::new(cfg, batch, chunk),
            cfg,
            shards,
            device: zynq7020(),
        })
    }
}

impl ChunkEngine for RtlClusterEngine {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn chunk_len(&self) -> usize {
        self.inner.chunk_len()
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        self.inner.set_weights(w_f32)
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        self.inner.run_chunk(phases, settled, period0)
    }

    fn kind(&self) -> &'static str {
        "rtl-cluster"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        self.inner.set_noise(amplitude, seed)
    }

    fn begin_wave(&mut self, active: usize) -> Result<()> {
        self.inner.begin_wave(active)
    }

    /// One all-gather per lane-period stepped — the cross-device cost
    /// metric the sharded float engine also reports.
    fn sync_rounds(&self) -> u64 {
        self.inner.lane_periods_stepped()
    }

    fn hardware_cost(&self) -> Option<HardwareCost> {
        if !self.inner.programmed() {
            return None;
        }
        let n = self.cfg.n;
        let d = &self.device;
        // Per-device compute: sample each device's row meter at its
        // first owned row; lockstep MACs make these equal, and the
        // cluster's elapsed compute is their max.
        let mut compute = 0u64;
        let mut fits = true;
        let mut f_logic_mhz = f64::INFINITY;
        let mut area_percent = 0.0f64;
        for (row0, rows) in shard_row_ranges(n, self.shards) {
            compute = compute.max(self.inner.row_fast_cycles(row0));
            let res = resources::hybrid_cluster_shard(&self.cfg, rows, d);
            fits &= res.fits(d);
            f_logic_mhz = f_logic_mhz.min(timing::logic_frequency_hybrid_shard(n, rows, d));
            area_percent = area_percent.max(res.area_percent(d));
        }
        let sync_fast_cycles = self.inner.lane_periods_stepped()
            * timing::cluster_sync_cycles(self.shards, n, self.cfg.phase_bits);
        let fast_cycles = compute + sync_fast_cycles;
        Some(HardwareCost {
            fast_cycles,
            f_logic_mhz,
            emulated_s: fast_cycles as f64 / (f_logic_mhz * 1e6),
            fits_device: fits,
            area_percent,
            sync_fast_cycles,
        })
    }

    fn set_trace_sink(&mut self, sink: Option<TraceSink>) {
        self.inner.set_trace_sink(sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect()
    }

    #[test]
    fn shard_count_is_validated() {
        let cfg = NetworkConfig::paper(4);
        assert!(RtlClusterEngine::new(cfg, 0, 2, 4).is_err());
        assert!(RtlClusterEngine::new(cfg, 5, 2, 4).is_err(), "shards > n");
        assert!(RtlClusterEngine::new(cfg, 4, 2, 4).is_ok());
    }

    #[test]
    fn cluster_is_bit_exact_with_the_single_device_engine() {
        // Row-splitting the weight memory is a hardware-model statement
        // only: every chunk's phases and settle flags must match the
        // solo engine bit for bit, noise on.
        let mut rng = Rng::new(52);
        let n = 6;
        let cfg = NetworkConfig::paper(n);
        let w = rand_w(&mut rng, n);
        let mut solo = RtlEngine::new(cfg, 3, 4);
        let mut cl = RtlClusterEngine::new(cfg, 3, 3, 4).unwrap();
        solo.set_weights(&w).unwrap();
        cl.set_weights(&w).unwrap();
        solo.set_noise(0.6, 9).unwrap();
        cl.set_noise(0.6, 9).unwrap();
        let init: Vec<i32> = (0..3 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let (mut pa, mut pb) = (init.clone(), init);
        let mut sa = vec![-1i32; 3];
        let mut sb = vec![-1i32; 3];
        for c in 0..3 {
            solo.run_chunk(&mut pa, &mut sa, c * 4).unwrap();
            cl.run_chunk(&mut pb, &mut sb, c * 4).unwrap();
            assert_eq!(pb, pa, "cluster diverged at chunk {c}");
            assert_eq!(sb, sa);
        }
        // One all-gather per lane-period stepped.
        assert_eq!(cl.sync_rounds(), (3 * 3 * 4) as u64);
        assert_eq!(solo.sync_rounds(), 0, "one device has no all-gather");
    }

    #[test]
    fn cluster_cost_prices_sync_and_extends_device_fit() {
        let n = 8;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut solo = RtlEngine::new(cfg, 2, 4);
        let mut cl = RtlClusterEngine::new(cfg, 2, 2, 4).unwrap();
        assert!(cl.hardware_cost().is_none(), "no cost before weights");
        solo.set_weights(&zeros).unwrap();
        cl.set_weights(&zeros).unwrap();
        let mut ph = vec![0i32; 2 * n];
        let mut st = vec![-1i32; 2];
        solo.run_chunk(&mut ph, &mut st, 0).unwrap();
        let mut ph2 = vec![0i32; 2 * n];
        let mut st2 = vec![-1i32; 2];
        cl.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        let hs = solo.hardware_cost().unwrap();
        let hc = cl.hardware_cost().unwrap();
        // Lockstep MACs: per-device compute equals the solo elapsed
        // time (a cluster buys capacity, not speed), and the all-gather
        // premium is exactly lane-periods x the per-period sync price.
        let sync = (2 * 4) as u64 * timing::cluster_sync_cycles(2, n, cfg.phase_bits);
        assert!(sync > 0);
        assert_eq!(hc.sync_fast_cycles, sync);
        assert_eq!(hc.fast_cycles, hs.fast_cycles + sync);
        assert_eq!(hs.sync_fast_cycles, 0);
        assert!(hc.f_logic_mhz > 0.0 && hc.emulated_s > 0.0);

        // Past the single-device ceiling (~506 oscillators on the
        // Zynq-7020) the solo design no longer fits; a two-device row
        // split does.  Static fit check only — no dynamics needed.
        let big = NetworkConfig::paper(560);
        let solo_fit = resources::hybrid(&big, &zynq7020());
        assert!(!solo_fit.fits(&zynq7020()), "n=560 must overflow one device");
        let mut big_cl = RtlClusterEngine::new(big, 2, 1, 1).unwrap();
        let big_zeros = vec![0.0f32; 560 * 560];
        big_cl.set_weights(&big_zeros).unwrap();
        let hw = big_cl.hardware_cost().unwrap();
        assert!(hw.fits_device, "two-device split of n=560 must fit");
        assert!(hw.area_percent > 0.0);
    }
}
