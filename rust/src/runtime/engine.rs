//! PJRT execution engine: loads an HLO-text artifact, compiles it once on
//! the PJRT CPU client, and runs batched chunks from the request path.
//!
//! This is the only place the `xla` crate is touched, and everything that
//! needs it sits behind the off-by-default `pjrt` cargo feature so the
//! default build works fully offline through [`crate::runtime::native::NativeEngine`].
//! Python is never on this path — the artifact was lowered once by
//! `python/compile/aot.py`.
//!
//! [`run_to_settle_batch`] is engine-agnostic and always available.

use anyhow::Result;

use crate::runtime::ChunkEngine;

#[cfg(feature = "pjrt")]
pub use self::pjrt_impl::{PjrtContext, PjrtEngine};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{anyhow, Result};

    use crate::runtime::artifact::ArtifactInfo;
    use crate::runtime::ChunkEngine;

    /// Shared PJRT client (one per process; engines share it).
    pub struct PjrtContext {
        client: xla::PjRtClient,
    }

    impl PjrtContext {
        pub fn cpu() -> Result<Arc<Self>> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(Arc::new(Self { client }))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }

    /// One compiled chunk executable bound to a (N, batch) artifact.
    pub struct PjrtEngine {
        ctx: Arc<PjrtContext>,
        info: ArtifactInfo,
        exe: xla::PjRtLoadedExecutable,
        weights: Vec<f32>,
    }

    impl PjrtEngine {
        /// Load + compile the artifact (HLO text — see aot.py for why text).
        pub fn load(ctx: Arc<PjrtContext>, info: &ArtifactInfo) -> Result<Self> {
            let path: &Path = &info.file;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = ctx
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            Ok(Self {
                ctx,
                info: info.clone(),
                exe,
                weights: vec![0f32; info.n * info.n],
            })
        }

        pub fn platform(&self) -> String {
            self.ctx.platform()
        }

        pub fn artifact(&self) -> &ArtifactInfo {
            &self.info
        }
    }

    impl ChunkEngine for PjrtEngine {
        fn n(&self) -> usize {
            self.info.n
        }

        fn batch(&self) -> usize {
            self.info.batch
        }

        fn chunk_len(&self) -> usize {
            self.info.chunk
        }

        fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
            if w_f32.len() != self.info.n * self.info.n {
                return Err(anyhow!(
                    "weights len {} != n^2 = {}",
                    w_f32.len(),
                    self.info.n * self.info.n
                ));
            }
            self.weights.copy_from_slice(w_f32);
            Ok(())
        }

        fn run_chunk(
            &mut self,
            phases: &mut [i32],
            settled: &mut [i32],
            period0: i32,
        ) -> Result<()> {
            let (n, b) = (self.info.n, self.info.batch);
            if phases.len() != n * b || settled.len() != b {
                return Err(anyhow!(
                    "shape mismatch: phases {} (want {}), settled {} (want {b})",
                    phases.len(),
                    n * b,
                    settled.len()
                ));
            }
            let w = xla::Literal::vec1(&self.weights[..])
                .reshape(&[n as i64, n as i64])
                .map_err(|e| anyhow!("reshape w: {e:?}"))?;
            let ph = xla::Literal::vec1(&phases[..])
                .reshape(&[b as i64, n as i64])
                .map_err(|e| anyhow!("reshape phases: {e:?}"))?;
            let st = xla::Literal::vec1(&settled[..]);
            let p0 = xla::Literal::scalar(period0);

            let result = self
                .exe
                .execute::<xla::Literal>(&[w, ph, st, p0])
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            let (ph_out, st_out) = result
                .to_tuple2()
                .map_err(|e| anyhow!("untuple: {e:?}"))?;
            let ph_vec = ph_out
                .to_vec::<i32>()
                .map_err(|e| anyhow!("phases out: {e:?}"))?;
            let st_vec = st_out
                .to_vec::<i32>()
                .map_err(|e| anyhow!("settled out: {e:?}"))?;
            if ph_vec.len() != phases.len() || st_vec.len() != settled.len() {
                return Err(anyhow!(
                    "artifact returned wrong shapes: {} / {}",
                    ph_vec.len(),
                    st_vec.len()
                ));
            }
            phases.copy_from_slice(&ph_vec);
            settled.copy_from_slice(&st_vec);
            Ok(())
        }

        fn kind(&self) -> &'static str {
            "pjrt"
        }
    }
}

/// Drive any ChunkEngine until every trial settles or `max_periods`
/// elapses.  Returns per-trial settle periods (None = timeout), leaving
/// the final phases in `phases`.
pub fn run_to_settle_batch(
    eng: &mut dyn ChunkEngine,
    phases: &mut [i32],
    max_periods: usize,
) -> Result<Vec<Option<usize>>> {
    let (b, n) = (eng.batch(), eng.n());
    let mut settled = vec![-1i32; b];
    let mut hopeless = vec![false; b];
    let mut period = 0usize;
    while period < max_periods {
        // Limit-cycle early exit (see coordinator::batcher): a trial
        // unchanged across a full chunk without settling never will.
        let snapshot = phases.to_vec();
        eng.run_chunk(phases, &mut settled, period as i32)?;
        period += eng.chunk_len();
        let mut active = false;
        for slot in 0..b {
            if settled[slot] >= 0 || hopeless[slot] {
                continue;
            }
            if phases[slot * n..(slot + 1) * n] == snapshot[slot * n..(slot + 1) * n] {
                hopeless[slot] = true;
            } else {
                active = true;
            }
        }
        if !active {
            break;
        }
    }
    Ok(settled
        .iter()
        .map(|&s| (s >= 0).then_some(s as usize))
        .collect())
}
