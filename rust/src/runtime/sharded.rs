//! Sharded execution of one logical ONN across several engine shards —
//! the paper's Discussion names multi-FPGA clustering ("synchronizing
//! multiple ONNs across multiple devices will pose a challenge") as the
//! path past a single device's 506 oscillators.  This module models
//! that topology: a leader broadcasts the phase state each oscillation
//! period, K shard workers each own a *row slice* of the weight matrix
//! and compute the reference/snap for their oscillators, and the leader
//! gathers the updated slices (an all-gather per period of every batch
//! trial — exactly the synchronization cost a multi-FPGA build would
//! pay per network update).
//!
//! The sharded engine is bit-exact with the single-engine dynamics:
//! row-partitioning the weighted sum does not change any oscillator's
//! reference waveform.  The same holds *with annealing noise on*: the
//! phase-kick stream (`onn::dynamics::PhaseNoise`) is counter-indexed by
//! `(seed, period tick, global oscillator index)`, so each shard replays
//! exactly the kicks the single engine would apply to its rows — the
//! leader broadcasts the tick, the shard derives its slice of the stream
//! from the seed plus its row offset.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::PhaseNoise;
use crate::onn::phase::{amplitude, wrap};
use crate::onn::sparse::SparseWeights;
use crate::onn::weights::WeightMatrix;
use crate::runtime::ChunkEngine;
use crate::telemetry::{TraceEvent, TraceSink};

/// A shard's view of the weight matrix: its dense row slice, or a
/// shared handle to the whole CSR fabric (sharding a CSR by row ranges
/// needs no copying — each shard walks the rows it owns).  Either way
/// the per-row arithmetic is the same order-independent integer sum, so
/// the sharded trajectory stays bit-exact with the single engine on
/// both fabrics.
enum ShardWeights {
    /// Row-slice of W, row-major `rows x n`.
    Dense(Vec<i8>),
    /// Whole symmetric CSR matrix; this shard reads only its global row
    /// range.
    Sparse(Arc<SparseWeights>),
}

/// One shard: rows `[row0, row0 + rows)` of the weight matrix.
struct ShardSpec {
    row0: usize,
    rows: usize,
    w: ShardWeights,
}

enum ShardMsg {
    /// Full phase vector + the leader's period tick for this period;
    /// the shard replies with its updated row slice.
    Step(Vec<i32>, u64),
    /// One period of the lane block keyed by its first lane: phase
    /// vector of one lane, block-local tick.
    StepBlock(Vec<i32>, u64, usize),
    /// Reprogram this shard's row slice of the weight matrix (also
    /// drops every lane block: whole-batch mode).
    SetWeights(Vec<i8>),
    /// Reprogram the cluster with a shared CSR fabric (also drops every
    /// lane block).  One Arc serves all shards; each reads its own row
    /// range.
    SetWeightsSparse(Arc<SparseWeights>),
    /// (Re)program this shard's row slice of one lane block's matrix;
    /// any noise the block carried is discarded (fresh stream).
    SetBlockWeights(usize, Vec<i8>),
    /// Install `(amplitude, seed)` phase noise; amplitude <= 0 clears it.
    SetNoise(f64, u64),
    /// Per-block noise stream; amplitude <= 0 clears it.
    SetBlockNoise(usize, f64, u64),
    /// Retire one lane block.
    ClearBlock(usize),
    Stop,
}

struct ShardHandle {
    tx: Sender<ShardMsg>,
    rx: Receiver<Vec<i32>>,
    join: Option<JoinHandle<()>>,
    row0: usize,
    rows: usize,
}

/// The canonical row partition of an `n`-oscillator network across
/// `num_shards` devices: `(row0, rows)` per shard, remainder rows going
/// to the leading shards.  Shared by this engine and the emulated
/// multi-FPGA cluster (`runtime::cluster`) so both fabrics split the
/// quantized weight memory identically.
pub(crate) fn shard_row_ranges(n: usize, num_shards: usize) -> Vec<(usize, usize)> {
    let base = n / num_shards;
    let extra = n % num_shards;
    let mut ranges = Vec::with_capacity(num_shards);
    let mut row0 = 0usize;
    for s in 0..num_shards {
        let rows = base + usize::from(s < extra);
        ranges.push((row0, rows));
        row0 += rows;
    }
    ranges
}

/// Leader-side record of one lane block (packed multi-problem mode):
/// which lanes it owns and where its block-local kick stream stands.
struct BlockInfo {
    lane0: usize,
    lanes: usize,
    /// Block-local kick-stream tick; reset by `set_lane_block` /
    /// `set_lane_block_noise`, advanced per period in batch-walk order
    /// within the block — exactly the walk a dedicated engine of
    /// `lanes` slots performs, which keeps packed lanes bit-exact with
    /// solo runs.
    tick: u64,
    /// Current amplitude (the tick only advances while noise is live,
    /// mirroring `PhaseNoise` on the single engine).
    amplitude: f64,
}

/// Leader + K shard workers executing the functional period dynamics.
pub struct ShardedEngine {
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    shards: Vec<ShardHandle>,
    /// All-gather rounds performed — one per period *per batch trial*,
    /// since the leader walks the batch sequentially (the multi-device
    /// sync cost metric).
    pub sync_rounds: u64,
    /// Active noise setting; `Some` only for amplitude > 0.
    noise: Option<(f64, u64)>,
    /// Period index into the kick stream since the last `set_noise` /
    /// `set_weights` (mirrors `PhaseNoise`'s tick on the single engine).
    tick: u64,
    /// Programmed lane blocks; non-empty switches `run_chunk` to
    /// block-dispatch mode (only block lanes advance).
    blocks: Vec<BlockInfo>,
    /// Set when lane-block mode has invalidated the whole-batch
    /// weights/kick stream: after the last block is cleared the engine
    /// demands a fresh `set_weights` instead of silently resuming a
    /// stale pre-packing problem mid-stream.
    whole_batch_stale: bool,
    /// Lifecycle trace sink; when set, `run_chunk` records one
    /// `engine_chunk` span carrying the chunk's all-gather round count
    /// and the microseconds spent inside those rounds.
    trace: Option<TraceSink>,
    /// Microseconds spent in broadcast+gather since the current
    /// `run_chunk` began; only accumulated while tracing.
    sync_us_acc: u64,
}

impl ShardedEngine {
    /// Partition `w` into `num_shards` row slices and spawn workers.
    pub fn new(
        cfg: NetworkConfig,
        w: &WeightMatrix,
        num_shards: usize,
        batch: usize,
        chunk: usize,
    ) -> Result<Self> {
        if num_shards == 0 || num_shards > cfg.n {
            return Err(anyhow!("bad shard count {num_shards} for n={}", cfg.n));
        }
        if cfg.period() > 64 {
            return Err(anyhow!("sharded engine supports phase_bits <= 6"));
        }
        assert_eq!(cfg.n, w.n);
        let n = cfg.n;
        let p = cfg.period();
        let mut shards = Vec::with_capacity(num_shards);
        for (row0, rows) in shard_row_ranges(n, num_shards) {
            let mut slice = Vec::with_capacity(rows * n);
            for r in row0..row0 + rows {
                slice.extend_from_slice(w.row(r));
            }
            let spec = ShardSpec {
                row0,
                rows,
                w: ShardWeights::Dense(slice),
            };
            let (tx, shard_rx) = channel::<ShardMsg>();
            let (reply_tx, rx) = channel::<Vec<i32>>();
            let join = std::thread::spawn(move || shard_loop(spec, n, p, shard_rx, reply_tx));
            shards.push(ShardHandle {
                tx,
                rx,
                join: Some(join),
                row0,
                rows,
            });
        }
        Ok(Self {
            cfg,
            batch,
            chunk,
            shards,
            sync_rounds: 0,
            noise: None,
            tick: 0,
            blocks: Vec::new(),
            whole_batch_stale: false,
            trace: None,
            sync_us_acc: 0,
        })
    }

    /// Build a cluster with all-zero couplings; callers program it later
    /// through [`ChunkEngine::set_weights`] (the solver path, where the
    /// problem arrives after the engine exists).
    pub fn unprogrammed(
        cfg: NetworkConfig,
        num_shards: usize,
        batch: usize,
        chunk: usize,
    ) -> Result<Self> {
        let w = WeightMatrix::zeros(cfg.n);
        Self::new(cfg, &w, num_shards, batch, chunk)
    }

    /// One synchronous period across all shards (broadcast + gather).
    fn period_step(&mut self, phases: &mut [i32]) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        // Broadcast the full state to every shard...
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::Step(phases.to_vec(), self.tick))
                .map_err(|_| anyhow!("shard died"))?;
        }
        // ...and gather the updated row slices.
        for sh in &self.shards {
            let slice = sh.rx.recv().map_err(|_| anyhow!("shard died"))?;
            debug_assert_eq!(slice.len(), sh.rows);
            phases[sh.row0..sh.row0 + sh.rows].copy_from_slice(&slice);
        }
        if let Some(t0) = t0 {
            self.sync_us_acc += t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        }
        self.sync_rounds += 1;
        if self.noise.is_some() {
            // Mirror PhaseNoise: the tick advances one slice per noisy
            // period, so the shards' kick streams track the single
            // engine's exactly.
            self.tick += 1;
        }
        Ok(())
    }

    /// One synchronous period of the lane block at `blocks[idx]` for a
    /// single lane's phase vector (broadcast + gather, same all-gather
    /// as the whole-batch path).
    fn period_step_block(&mut self, idx: usize, phases: &mut [i32]) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        let (lane0, tick) = (self.blocks[idx].lane0, self.blocks[idx].tick);
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::StepBlock(phases.to_vec(), tick, lane0))
                .map_err(|_| anyhow!("shard died"))?;
        }
        for sh in &self.shards {
            let slice = sh.rx.recv().map_err(|_| anyhow!("shard died"))?;
            if slice.len() != sh.rows {
                return Err(anyhow!("shard stepped an unprogrammed lane block"));
            }
            phases[sh.row0..sh.row0 + sh.rows].copy_from_slice(&slice);
        }
        if let Some(t0) = t0 {
            self.sync_us_acc += t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        }
        self.sync_rounds += 1;
        if self.blocks[idx].amplitude > 0.0 {
            self.blocks[idx].tick += 1;
        }
        Ok(())
    }

    fn block_position(&self, lane0: usize) -> Result<usize> {
        self.blocks
            .iter()
            .position(|b| b.lane0 == lane0)
            .ok_or_else(|| anyhow!("no lane block programmed at lane {lane0}"))
    }

    fn run_chunk_inner(
        &mut self,
        phases: &mut [i32],
        settled: &mut [i32],
        period0: i32,
    ) -> Result<()> {
        let n = self.cfg.n;
        let b = self.batch;
        if phases.len() != b * n || settled.len() != b {
            return Err(anyhow!("shape mismatch"));
        }
        let mut prev = vec![0i32; n];
        if !self.blocks.is_empty() {
            // Lane-block mode: each block's lanes advance with that
            // block's couplings + kick stream; other lanes stay put.
            let spans: Vec<(usize, usize)> =
                self.blocks.iter().map(|blk| (blk.lane0, blk.lanes)).collect();
            for (idx, (lane0, lanes)) in spans.into_iter().enumerate() {
                for slot in 0..lanes {
                    let bi = lane0 + slot;
                    let ph = &mut phases[bi * n..(bi + 1) * n];
                    for k in 0..self.chunk {
                        prev.copy_from_slice(ph);
                        self.period_step_block(idx, ph)?;
                        if settled[bi] < 0 && ph == &prev[..] {
                            settled[bi] = period0 + k as i32;
                        }
                    }
                }
            }
            return Ok(());
        }
        if self.whole_batch_stale {
            return Err(anyhow!(
                "whole-batch weights were invalidated by lane-block mode; \
                 call set_weights before running the full batch"
            ));
        }
        for bi in 0..b {
            let ph = &mut phases[bi * n..(bi + 1) * n];
            for k in 0..self.chunk {
                prev.copy_from_slice(ph);
                self.period_step(ph)?;
                if settled[bi] < 0 && ph == &prev[..] {
                    settled[bi] = period0 + k as i32;
                }
            }
        }
        Ok(())
    }

    /// Stop the shard workers and wait for them.  Dropping the engine
    /// does the same (see the `Drop` impl); this explicit form keeps
    /// call sites readable.
    pub fn shutdown(self) {}

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

/// Shard threads must not outlive the leader — a solve that errors
/// mid-anneal unwinds through here instead of leaking K workers per
/// failed request.
impl Drop for ShardedEngine {
    fn drop(&mut self) {
        for sh in &self.shards {
            // The shard may already be gone (its channel closed); that
            // is fine on this path.
            let _ = sh.tx.send(ShardMsg::Stop);
        }
        for sh in &mut self.shards {
            if let Some(join) = sh.join.take() {
                let _ = join.join();
            }
        }
    }
}

/// Reference-waveform sign rule shared by both fabrics: the sign of the
/// weighted sum, falling back to the oscillator's own amplitude on 0.
#[inline]
fn ref_sign(sum: i32, own: i8) -> i8 {
    if sum > 0 {
        1
    } else if sum < 0 {
        -1
    } else {
        own
    }
}

/// One shard's slice of a synchronous period: the reference waveform +
/// phase snap for `spec`'s rows from the broadcast state, plus the
/// annealing kick derived from `(seed, tick, global row index)` — the
/// same pure function the single engine evaluates, so the sharded
/// trajectory stays bit-exact under noise.
fn shard_step(
    spec: &ShardSpec,
    n: usize,
    p: usize,
    templates: &[i8],
    phases: &[i32],
    tick: u64,
    noise: Option<(f64, u64)>,
) -> Vec<i32> {
    let pi = p as i32;
    // amplitudes over the period for all oscillators
    let mut s = vec![0i8; n * p];
    for (j, &phi) in phases.iter().enumerate() {
        for t in 0..p {
            s[j * p + t] = amplitude(phi, t as i64, pi) as i8;
        }
    }
    let mut out = Vec::with_capacity(spec.rows);
    for r in 0..spec.rows {
        let gi = spec.row0 + r; // global oscillator index
        // reference waveform for oscillator gi
        let mut best_key = i32::MIN;
        let mut best_k = 0i32;
        let mut refsig = [0i8; 64];
        // Same order-independent integer sum on both fabrics; the CSR
        // walk just skips the entries that contribute 0.
        match &spec.w {
            ShardWeights::Dense(w) => {
                let row = &w[r * n..(r + 1) * n];
                for (t, rt) in refsig.iter_mut().enumerate().take(p) {
                    let mut sum = 0i32;
                    for j in 0..n {
                        sum += row[j] as i32 * s[j * p + t] as i32;
                    }
                    *rt = ref_sign(sum, s[gi * p + t]);
                }
            }
            ShardWeights::Sparse(sw) => {
                let (cols, vals) = sw.row(gi);
                for (t, rt) in refsig.iter_mut().enumerate().take(p) {
                    let mut sum = 0i32;
                    for (&j, &v) in cols.iter().zip(vals) {
                        sum += v as i32 * s[j as usize * p + t] as i32;
                    }
                    *rt = ref_sign(sum, s[gi * p + t]);
                }
            }
        }
        for k in 0..pi {
            let trow = &templates[k as usize * p..(k as usize + 1) * p];
            let mut score = 0i32;
            for t in 0..p {
                score += refsig[t] as i32 * trow[t] as i32;
            }
            let rel = wrap(k - phases[gi], pi);
            let key = score * 2 * pi + (pi - rel);
            if key > best_key {
                best_key = key;
                best_k = k;
            }
        }
        if let Some((a, seed)) = noise {
            best_k = PhaseNoise::kick_at(seed, tick, gi, a, best_k, pi);
        }
        out.push(best_k);
    }
    out
}

/// Worker: computes the reference waveform + phase snap for its rows
/// from the broadcast state (the per-device compute of a multi-FPGA
/// ONN, here the functional period semantics).  Besides the whole-batch
/// weights, the worker holds its row slice of every programmed lane
/// block — one small Ising problem per block in packed mode.
fn shard_loop(
    mut spec: ShardSpec,
    n: usize,
    p: usize,
    rx: Receiver<ShardMsg>,
    reply: Sender<Vec<i32>>,
) {
    let pi = p as i32;
    // templates[k * p + t]
    let mut templates = vec![0i8; p * p];
    for k in 0..p {
        for t in 0..p {
            templates[k * p + t] = amplitude(k as i32, t as i64, pi) as i8;
        }
    }
    // This shard's slice of the annealing kick stream; `Some` only for
    // amplitude > 0.
    let mut noise: Option<(f64, u64)> = None;
    // Lane blocks as this shard sees them: its row slice of each
    // block's matrix plus the block's slice of the kick stream.
    struct ShardBlock {
        lane0: usize,
        spec: ShardSpec,
        noise: Option<(f64, u64)>,
    }
    let mut blocks: Vec<ShardBlock> = Vec::new();
    loop {
        let out = match rx.recv() {
            Ok(ShardMsg::Step(phases, tick)) => {
                shard_step(&spec, n, p, &templates, &phases, tick, noise)
            }
            Ok(ShardMsg::StepBlock(phases, tick, lane0)) => {
                match blocks.iter().find(|b| b.lane0 == lane0) {
                    Some(blk) => {
                        shard_step(&blk.spec, n, p, &templates, &phases, tick, blk.noise)
                    }
                    // Protocol error: reply with an empty slice so the
                    // leader errors instead of deadlocking on recv.
                    None => Vec::new(),
                }
            }
            Ok(ShardMsg::SetWeights(w)) => {
                debug_assert_eq!(w.len(), spec.rows * n);
                spec.w = ShardWeights::Dense(w);
                blocks.clear();
                continue;
            }
            Ok(ShardMsg::SetWeightsSparse(sw)) => {
                debug_assert_eq!(sw.n(), n);
                spec.w = ShardWeights::Sparse(sw);
                blocks.clear();
                continue;
            }
            Ok(ShardMsg::SetBlockWeights(lane0, w)) => {
                debug_assert_eq!(w.len(), spec.rows * n);
                // Reprogramming drops any noise the block carried — a
                // backfilled block starts a fresh kick stream.
                blocks.retain(|b| b.lane0 != lane0);
                blocks.push(ShardBlock {
                    lane0,
                    spec: ShardSpec {
                        row0: spec.row0,
                        rows: spec.rows,
                        w: ShardWeights::Dense(w),
                    },
                    noise: None,
                });
                continue;
            }
            Ok(ShardMsg::SetNoise(a, seed)) => {
                noise = (a > 0.0).then_some((a, seed));
                continue;
            }
            Ok(ShardMsg::SetBlockNoise(lane0, a, seed)) => {
                if let Some(blk) = blocks.iter_mut().find(|b| b.lane0 == lane0) {
                    blk.noise = (a > 0.0).then_some((a, seed));
                }
                continue;
            }
            Ok(ShardMsg::ClearBlock(lane0)) => {
                blocks.retain(|b| b.lane0 != lane0);
                continue;
            }
            Ok(ShardMsg::Stop) | Err(_) => break,
        };
        if reply.send(out).is_err() {
            break;
        }
    }
}

impl ChunkEngine for ShardedEngine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn chunk_len(&self) -> usize {
        self.chunk
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        // Reprogramming the cluster reloads every device's row slice —
        // the shared validation gate guarantees both fabrics accept
        // exactly the same matrices (part of the bit-exact contract).
        let n = self.cfg.n;
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        for sh in &self.shards {
            let mut slice = Vec::with_capacity(sh.rows * n);
            for r in sh.row0..sh.row0 + sh.rows {
                slice.extend_from_slice(w.row(r));
            }
            sh.tx
                .send(ShardMsg::SetWeights(slice))
                .map_err(|_| anyhow!("shard died"))?;
        }
        // The native engine rebuilds its PhaseNoise on reload, which
        // restarts the kick stream; mirror that here.  Whole-batch
        // programming also retires every lane block (shards drop theirs
        // in the SetWeights handler).
        self.tick = 0;
        self.blocks.clear();
        self.whole_batch_stale = false;
        Ok(())
    }

    fn supports_sparse(&self) -> bool {
        true
    }

    fn set_weights_sparse(&mut self, w: &SparseWeights) -> Result<()> {
        // Same gate as the native fabric; the CSR is shared read-only
        // across shards (one Arc, each worker walking its own global
        // row range), so sharding needs no per-shard slicing at all.
        crate::runtime::checked_sparse_weights(&self.cfg, w)?;
        let shared = Arc::new(w.clone());
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::SetWeightsSparse(shared.clone()))
                .map_err(|_| anyhow!("shard died"))?;
        }
        // Identical reload lifecycle to the dense path: kick stream
        // restarts, lane blocks retire, whole-batch mode resumes.
        self.tick = 0;
        self.blocks.clear();
        self.whole_batch_stale = false;
        Ok(())
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        let rounds0 = self.sync_rounds;
        self.sync_us_acc = 0;
        self.run_chunk_inner(phases, settled, period0)?;
        if let (Some(t0), Some(sink)) = (t0, self.trace.as_ref()) {
            sink.borrow_mut().record(TraceEvent::EngineChunk {
                engine: "sharded",
                period0: period0 as i64,
                step_us: t0.elapsed().as_micros().min(u64::MAX as u128) as u64,
                sync_rounds: self.sync_rounds - rounds0,
                sync_us: self.sync_us_acc,
                fast_cycles: 0,
            });
        }
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "sharded"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        self.noise = (amplitude > 0.0).then_some((amplitude, seed));
        // A fresh setting restarts the kick stream, exactly like
        // installing a fresh PhaseNoise on the single engine.
        self.tick = 0;
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::SetNoise(amplitude, seed))
                .map_err(|_| anyhow!("shard died"))?;
        }
        Ok(())
    }

    fn sync_rounds(&self) -> u64 {
        self.sync_rounds
    }

    fn supports_lane_blocks(&self) -> bool {
        true
    }

    fn set_lane_block(&mut self, lane0: usize, lanes: usize, w_f32: &[f32]) -> Result<()> {
        if lanes == 0 || lane0 + lanes > self.batch {
            return Err(anyhow!(
                "lane block [{lane0}, {}) outside the {}-lane batch",
                lane0 + lanes,
                self.batch
            ));
        }
        if self
            .blocks
            .iter()
            .any(|b| b.lane0 != lane0 && lane0 < b.lane0 + b.lanes && b.lane0 < lane0 + lanes)
        {
            return Err(anyhow!("lane block at {lane0} overlaps a programmed block"));
        }
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        for sh in &self.shards {
            let mut slice = Vec::with_capacity(sh.rows * self.cfg.n);
            for r in sh.row0..sh.row0 + sh.rows {
                slice.extend_from_slice(w.row(r));
            }
            sh.tx
                .send(ShardMsg::SetBlockWeights(lane0, slice))
                .map_err(|_| anyhow!("shard died"))?;
        }
        self.blocks.retain(|b| b.lane0 != lane0);
        self.blocks.push(BlockInfo {
            lane0,
            lanes,
            tick: 0,
            amplitude: 0.0,
        });
        // Entering lane-block mode invalidates the whole-batch stream.
        self.whole_batch_stale = true;
        Ok(())
    }

    fn set_lane_block_noise(&mut self, lane0: usize, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        let idx = self.block_position(lane0)?;
        // A fresh setting restarts the block's kick stream, exactly like
        // installing a fresh PhaseNoise on a dedicated engine.
        self.blocks[idx].tick = 0;
        self.blocks[idx].amplitude = amplitude;
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::SetBlockNoise(lane0, amplitude, seed))
                .map_err(|_| anyhow!("shard died"))?;
        }
        Ok(())
    }

    fn clear_lane_block(&mut self, lane0: usize) -> Result<()> {
        let idx = self.block_position(lane0)?;
        self.blocks.remove(idx);
        for sh in &self.shards {
            sh.tx
                .send(ShardMsg::ClearBlock(lane0))
                .map_err(|_| anyhow!("shard died"))?;
        }
        Ok(())
    }

    fn set_trace_sink(&mut self, sink: Option<TraceSink>) {
        self.trace = sink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::dynamics::FunctionalEngine;
    use crate::util::rng::Rng;

    fn rand_net(rng: &mut Rng, n: usize) -> (WeightMatrix, Vec<i32>) {
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-16, 16) as i8);
            }
        }
        let ph = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
        (w, ph)
    }

    #[test]
    fn sharded_bit_exact_with_single_engine() {
        let mut rng = Rng::new(88);
        for shards in [1, 2, 3, 5] {
            let n = 17;
            let cfg = NetworkConfig::paper(n);
            let (w, ph0) = rand_net(&mut rng, n);
            let mut single = FunctionalEngine::new(cfg, w.clone());
            let mut sharded = ShardedEngine::new(cfg, &w, shards, 1, 4).unwrap();
            let mut a = ph0.clone();
            let mut b = ph0.clone();
            let mut sa = vec![-1i32; 1];
            let mut sb = vec![-1i32; 1];
            single.run_chunk(&mut a, &mut sa, 0, 4);
            sharded.run_chunk(&mut b, &mut sb, 0).unwrap();
            assert_eq!(a, b, "shards={shards}");
            assert_eq!(sa, sb, "shards={shards}");
            sharded.shutdown();
        }
    }

    #[test]
    fn sync_rounds_counted_per_period() {
        let mut rng = Rng::new(89);
        let n = 8;
        let cfg = NetworkConfig::paper(n);
        let (w, ph0) = rand_net(&mut rng, n);
        let mut sharded = ShardedEngine::new(cfg, &w, 2, 1, 6).unwrap();
        let mut ph = ph0;
        let mut st = vec![-1i32; 1];
        sharded.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_eq!(sharded.sync_rounds, 6, "one all-gather per period");
        sharded.shutdown();
    }

    #[test]
    fn uneven_partition_covers_all_rows() {
        // n=10 over 3 shards -> 4+3+3.
        let cfg = NetworkConfig::paper(10);
        let w = WeightMatrix::zeros(10);
        let eng = ShardedEngine::new(cfg, &w, 3, 1, 1).unwrap();
        let total: usize = eng.shards.iter().map(|s| s.rows).sum();
        assert_eq!(total, 10);
        assert_eq!(eng.shards[0].rows, 4);
        eng.shutdown();
    }

    #[test]
    fn rejects_bad_shard_counts() {
        let cfg = NetworkConfig::paper(4);
        let w = WeightMatrix::zeros(4);
        assert!(ShardedEngine::new(cfg, &w, 0, 1, 1).is_err());
        assert!(ShardedEngine::new(cfg, &w, 5, 1, 1).is_err());
    }

    #[test]
    fn set_weights_reprograms_all_shards() {
        let mut rng = Rng::new(90);
        let n = 11;
        let cfg = NetworkConfig::paper(n);
        let (w, ph0) = rand_net(&mut rng, n);
        // Build the cluster blank, then program it over the wire-style
        // reload path; it must match a single engine built directly.
        let mut sharded = ShardedEngine::unprogrammed(cfg, 3, 1, 5).unwrap();
        sharded.set_weights(&w.to_f32()).unwrap();
        let mut single = FunctionalEngine::new(cfg, w);
        let (mut a, mut b) = (ph0.clone(), ph0);
        let (mut sa, mut sb) = (vec![-1i32; 1], vec![-1i32; 1]);
        single.run_chunk(&mut a, &mut sa, 0, 5);
        sharded.run_chunk(&mut b, &mut sb, 0).unwrap();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        // Bad reloads are rejected with the same rules as the native
        // engine: wrong length, fractional, or out-of-range entries.
        assert!(sharded.set_weights(&[0.0; 4]).is_err());
        let mut bad = vec![0.0f32; n * n];
        bad[1] = 0.5;
        assert!(sharded.set_weights(&bad).is_err());
        bad[1] = 99.0;
        assert!(sharded.set_weights(&bad).is_err());
        sharded.shutdown();
    }

    #[test]
    fn noisy_dynamics_bit_exact_with_native_engine() {
        use crate::runtime::native::NativeEngine;
        let mut rng = Rng::new(91);
        let n = 13;
        let cfg = NetworkConfig::paper(n);
        let (w, _) = rand_net(&mut rng, n);
        let w_f32 = w.to_f32();
        let b = 2usize;
        for shards in [2usize, 4, 5] {
            let mut native = NativeEngine::new(cfg, b, 4);
            let mut sharded = ShardedEngine::unprogrammed(cfg, shards, b, 4).unwrap();
            native.set_weights(&w_f32).unwrap();
            sharded.set_weights(&w_f32).unwrap();
            native.set_noise(0.7, 42).unwrap();
            sharded.set_noise(0.7, 42).unwrap();
            let init: Vec<i32> = (0..b * n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let (mut pa, mut pb) = (init.clone(), init);
            let (mut sa, mut sb) = (vec![-1i32; b], vec![-1i32; b]);
            for chunk in 0..3 {
                native.run_chunk(&mut pa, &mut sa, chunk * 4).unwrap();
                sharded.run_chunk(&mut pb, &mut sb, chunk * 4).unwrap();
                assert_eq!(pa, pb, "shards={shards} chunk={chunk}");
                assert_eq!(sa, sb, "shards={shards} chunk={chunk}");
            }
            sharded.shutdown();
        }
    }

    #[test]
    fn sparse_fabric_bit_exact_with_native_sparse() {
        use crate::runtime::native::NativeEngine;
        let mut rng = Rng::new(93);
        let n = 19;
        let cfg = NetworkConfig::paper(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..i {
                if rng.f64() < 0.25 {
                    let v = rng.range_i64(-16, 16) as i8;
                    w.set(i, j, v);
                    w.set(j, i, v);
                }
            }
        }
        let sw = SparseWeights::from_dense(&w);
        let b = 2usize;
        // 4 does not divide 19: includes a non-dividing row split.
        for shards in [1usize, 3, 4] {
            let mut native = NativeEngine::new(cfg, b, 4);
            let mut sharded = ShardedEngine::unprogrammed(cfg, shards, b, 4).unwrap();
            assert!(sharded.supports_sparse());
            native.set_weights_sparse(&sw).unwrap();
            sharded.set_weights_sparse(&sw).unwrap();
            native.set_noise(0.6, 77).unwrap();
            sharded.set_noise(0.6, 77).unwrap();
            let init: Vec<i32> = (0..b * n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let (mut pa, mut pb) = (init.clone(), init);
            let (mut sa, mut sb) = (vec![-1i32; b], vec![-1i32; b]);
            for chunk in 0..3 {
                native.run_chunk(&mut pa, &mut sa, chunk * 4).unwrap();
                sharded.run_chunk(&mut pb, &mut sb, chunk * 4).unwrap();
                assert_eq!(pa, pb, "shards={shards} chunk={chunk}");
                assert_eq!(sa, sb, "shards={shards} chunk={chunk}");
            }
            sharded.shutdown();
        }
    }

    #[test]
    fn lane_blocks_bit_exact_with_native_lane_blocks() {
        use crate::runtime::native::NativeEngine;
        let mut rng = Rng::new(92);
        let n = 9;
        let cfg = NetworkConfig::paper(n);
        let (wa, _) = rand_net(&mut rng, n);
        let (wb, _) = rand_net(&mut rng, n);
        let init: Vec<i32> = (0..5 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let mut native = NativeEngine::new(cfg, 5, 3);
        let mut sharded = ShardedEngine::unprogrammed(cfg, 3, 5, 3).unwrap();
        for e in [
            &mut native as &mut dyn ChunkEngine,
            &mut sharded as &mut dyn ChunkEngine,
        ] {
            assert!(e.supports_lane_blocks());
            e.set_lane_block(0, 2, &wa.to_f32()).unwrap();
            e.set_lane_block(2, 3, &wb.to_f32()).unwrap();
            e.set_lane_block_noise(0, 0.7, 5).unwrap();
            e.set_lane_block_noise(2, 0.3, 6).unwrap();
        }
        let (mut pa, mut pb) = (init.clone(), init.clone());
        let (mut sa, mut sb) = (vec![-1i32; 5], vec![-1i32; 5]);
        for chunk in 0..3 {
            native.run_chunk(&mut pa, &mut sa, chunk * 3).unwrap();
            sharded.run_chunk(&mut pb, &mut sb, chunk * 3).unwrap();
            assert_eq!(pa, pb, "chunk {chunk}");
            assert_eq!(sa, sb, "chunk {chunk}");
        }
        // Retiring one block freezes its lanes on both fabrics.
        native.clear_lane_block(0).unwrap();
        sharded.clear_lane_block(0).unwrap();
        let frozen = pa[..2 * n].to_vec();
        native.run_chunk(&mut pa, &mut sa, 9).unwrap();
        sharded.run_chunk(&mut pb, &mut sb, 9).unwrap();
        assert_eq!(pa, pb);
        assert_eq!(&pa[..2 * n], &frozen[..], "retired lanes frozen");
        // Clearing the LAST block must not silently resume the stale
        // whole-batch stream on either fabric.
        native.clear_lane_block(2).unwrap();
        sharded.clear_lane_block(2).unwrap();
        assert!(native.run_chunk(&mut pa, &mut sa, 12).is_err());
        assert!(sharded.run_chunk(&mut pb, &mut sb, 12).is_err());
        sharded.shutdown();
    }

    #[test]
    fn drop_joins_shard_threads_without_explicit_shutdown() {
        // The error paths of a solve drop the engine without calling
        // shutdown(); the Drop impl must stop + join the workers (a
        // leak would hang nothing here, but the join proves the Stop
        // reached every shard).
        let cfg = NetworkConfig::paper(6);
        let w = WeightMatrix::zeros(6);
        let mut eng = ShardedEngine::new(cfg, &w, 3, 1, 2).unwrap();
        let mut ph = vec![0i32; 6];
        let mut st = vec![-1i32; 1];
        eng.run_chunk(&mut ph, &mut st, 0).unwrap();
        drop(eng);
    }
}
