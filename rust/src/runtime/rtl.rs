//! The bit-true emulated-hardware engine: the paper's serial-MAC hybrid
//! datapath (`rtl::hybrid::HybridOnn`) served through the same
//! [`ChunkEngine`] contract as the float fabrics, so the solver
//! portfolio and the coordinator can put solve traffic on the
//! cycle-accurate hardware model instead of `onn::dynamics`.
//!
//! Contract notes (DESIGN_SOLVER.md §8):
//!
//! * **Lanes.** The engine's batch dimension maps onto independent
//!   register-state lanes of one multi-lane `HybridOnn` sharing the
//!   weight memory — the way one synthesized core is re-run per anneal
//!   replica.  `run_chunk` detects externally (re)written lane phases
//!   (the portfolio's wave inits) and reprograms just those lanes,
//!   resetting their registers like a fresh hardware run.
//! * **Noise.** The annealing hook applies *quantized phase kicks* on
//!   the exact counter-indexed stream of `onn::dynamics::PhaseNoise`
//!   (`kick_at(seed, tick, oscillator)`): after each emulated period
//!   the update circuit rewrites the mux selects in place, registers
//!   keep running.  The tick walks in batch-lane order and restarts on
//!   `set_noise`/`set_weights`, mirroring the native engine — so an rtl
//!   solve is deterministic at equal seed (`rust/tests/prop_rtl.rs`).
//! * **Settling** is judged on phases relative to oscillator 0 across
//!   whole periods (the RTL semantics, warm-up period excluded), with
//!   the comparand carried across chunk boundaries.
//! * **Cost.** The lanes' `SerialMac` cycle counters meter emulated
//!   fast-clock work; [`ChunkEngine::hardware_cost`] converts it to an
//!   emulated time-to-solution via `fpga::timing` and reports device
//!   fit via `fpga::resources::hybrid`.
//!
//! Unsupported: lane blocks (one emulated device carries one problem)
//! and, by construction, the PJRT artifact path.

use anyhow::{anyhow, Result};

use crate::fpga::device::{zynq7020, Device};
use crate::fpga::resources;
use crate::fpga::timing;
use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::PhaseNoise;
use crate::rtl::hybrid::HybridOnn;
use crate::runtime::{ChunkEngine, HardwareCost};
use crate::telemetry::{TraceEvent, TraceSink};

pub struct RtlEngine {
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    device: Device,
    sim: Option<HybridOnn>,
    /// Pending (amplitude, seed) noise setting; amplitude 0 disables.
    noise: Option<(f64, u64)>,
    /// Periods consumed from the kick stream since the last
    /// `set_noise`/`set_weights` (the `tick` half of the kick index),
    /// advancing in batch-lane order like the native engine's.
    noise_tick: u64,
    /// Lanes `[0, active)` advance (and are cost-metered); the rest is
    /// caller-declared padding (`begin_wave`).  Whole batch by default.
    active: usize,
    /// A `begin_wave` arrived: the next `run_chunk` reprograms the
    /// active lanes unconditionally — a fresh init that happens to
    /// equal a lane's current phases must still reset its registers.
    pending_wave: Option<usize>,
    /// Lifecycle trace sink; when set, `run_chunk` records one
    /// `engine_chunk` span carrying the chunk's emulated fast-cycle
    /// delta next to the host step time.
    trace: Option<TraceSink>,
}

impl RtlEngine {
    /// An engine serving `cfg.n` oscillators with `batch` lanes and
    /// `chunk` periods per `run_chunk` call, modeled on the paper's
    /// reference device (Zynq-7020).
    pub fn new(cfg: NetworkConfig, batch: usize, chunk: usize) -> Self {
        Self {
            cfg,
            batch,
            chunk,
            device: zynq7020(),
            sim: None,
            noise: None,
            noise_tick: 0,
            active: batch,
            pending_wave: None,
            trace: None,
        }
    }

    /// Sum of every lane's fast-cycle counter (0 before `set_weights`).
    fn total_fast_cycles(&self) -> u64 {
        self.sim
            .as_ref()
            .map(|s| (0..s.lanes()).map(|l| s.lane_fast_cycles(l)).sum())
            .unwrap_or(0)
    }
}

impl ChunkEngine for RtlEngine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn chunk_len(&self) -> usize {
        self.chunk
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        self.sim = Some(HybridOnn::with_lanes(self.cfg, w, self.batch));
        // Reprogramming the weight memory restarts the kick stream,
        // exactly like the native engine rebuilding its PhaseNoise —
        // and returns the whole batch to active duty.
        self.noise_tick = 0;
        self.active = self.batch;
        self.pending_wave = None;
        Ok(())
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        let cycles0 = self.total_fast_cycles();
        let n = self.cfg.n;
        if phases.len() != self.batch * n || settled.len() != self.batch {
            return Err(anyhow!("shape mismatch"));
        }
        let wave = self.pending_wave.take();
        if let Some(active) = wave {
            self.active = active;
        }
        let sim = self
            .sim
            .as_mut()
            .ok_or_else(|| anyhow!("set_weights not called"))?;
        let p = self.cfg.period() as i32;
        // A declared wave reprograms every active lane unconditionally
        // (a fresh init may coincide with the lane's current phases —
        // its registers must reset anyway); otherwise externally
        // rewritten lanes are detected by value and reprogrammed, and
        // untouched lanes resume.  Lanes past `active` are padding:
        // never stepped, never metered.
        for lane in 0..self.active {
            let slice = &phases[lane * n..(lane + 1) * n];
            if wave.is_some() || sim.lane_phases(lane) != slice {
                sim.set_lane_phases(lane, slice);
            }
        }
        let noise = self.noise.filter(|&(a, _)| a > 0.0);
        for lane in 0..self.active {
            for k in 0..self.chunk {
                let settled_now = sim.step_lane_period(lane);
                if let Some((amp, seed)) = noise {
                    let tick = self.noise_tick;
                    sim.kick_lane_phases(lane, |i, phi| {
                        PhaseNoise::kick_at(seed, tick, i, amp, phi, p)
                    });
                    self.noise_tick += 1;
                }
                if settled_now && settled[lane] < 0 {
                    settled[lane] = period0 + k as i32;
                }
            }
            phases[lane * n..(lane + 1) * n].copy_from_slice(sim.lane_phases(lane));
        }
        if let (Some(t0), Some(sink)) = (t0, self.trace.as_ref()) {
            sink.borrow_mut().record(TraceEvent::EngineChunk {
                engine: "rtl",
                period0: period0 as i64,
                step_us: t0.elapsed().as_micros().min(u64::MAX as u128) as u64,
                sync_rounds: 0,
                sync_us: 0,
                fast_cycles: self.total_fast_cycles() - cycles0,
            });
        }
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "rtl"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        self.noise = Some((amplitude, seed));
        self.noise_tick = 0;
        Ok(())
    }

    fn begin_wave(&mut self, active: usize) -> Result<()> {
        if active == 0 || active > self.batch {
            return Err(anyhow!(
                "wave of {active} lanes outside the {}-lane batch",
                self.batch
            ));
        }
        self.pending_wave = Some(active);
        Ok(())
    }

    fn hardware_cost(&self) -> Option<HardwareCost> {
        let sim = self.sim.as_ref()?;
        // One device runs the lanes back to back: the emulated elapsed
        // fast-clock time is the sum of each lane's (parallel-MAC) wall
        // clock — N MACs per lane tick in lockstep, so any single MAC's
        // counter is its lane's elapsed cycles.
        let fast_cycles: u64 = (0..sim.lanes()).map(|l| sim.lane_fast_cycles(l)).sum();
        let f_logic_mhz = timing::logic_frequency_hybrid(self.cfg.n, &self.device);
        let res = resources::hybrid(&self.cfg, &self.device);
        Some(HardwareCost {
            fast_cycles,
            f_logic_mhz,
            emulated_s: fast_cycles as f64 / (f_logic_mhz * 1e6),
            fits_device: res.fits(&self.device),
            area_percent: res.area_percent(&self.device),
        })
    }

    fn set_trace_sink(&mut self, sink: Option<TraceSink>) {
        self.trace = sink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::hybrid::SYNC_OVERHEAD_CYCLES;
    use crate::rtl::RtlSim;
    use crate::util::rng::Rng;

    fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect()
    }

    #[test]
    fn shape_and_weight_validation() {
        let mut e = RtlEngine::new(NetworkConfig::paper(2), 1, 4);
        let mut ph = vec![0, 0];
        let mut st = vec![-1];
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_err(), "needs weights");
        assert!(e.set_weights(&[0.0, 99.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.5, 0.0, 0.0, 0.0]).is_err());
        e.set_weights(&[0.0, 15.0, -16.0, 0.0]).unwrap();
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_ok());
        let mut bad = vec![0, 0, 0];
        assert!(e.run_chunk(&mut bad, &mut st, 0).is_err(), "bad shape");
        assert!(e.set_noise(1.5, 1).is_err(), "amplitude range");
    }

    #[test]
    fn lanes_match_the_monolithic_simulator() {
        // Each engine lane must reproduce a solo HybridOnn trajectory,
        // across chunk boundaries, lane by lane.
        let mut rng = Rng::new(91);
        let n = 6;
        let cfg = NetworkConfig::paper(n);
        let w = rand_w(&mut rng, n);
        let mut e = RtlEngine::new(cfg, 3, 4);
        e.set_weights(&w).unwrap();
        let init: Vec<i32> = (0..3 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 3];
        for chunk_idx in 0..3 {
            e.run_chunk(&mut ph, &mut st, chunk_idx * 4).unwrap();
            for lane in 0..3 {
                let wm = crate::runtime::checked_weights(&cfg, &w).unwrap();
                let mut solo = HybridOnn::new(cfg, wm);
                solo.set_phases(&init[lane * n..(lane + 1) * n]);
                for _ in 0..(chunk_idx as usize + 1) * 4 * 16 {
                    solo.tick();
                }
                assert_eq!(
                    &ph[lane * n..(lane + 1) * n],
                    solo.phases(),
                    "lane {lane} chunk {chunk_idx}"
                );
            }
        }
    }

    #[test]
    fn settle_flags_resume_across_chunks() {
        // A pinned leader/follower pair settles after a few periods;
        // the flag must carry the absolute period index even when the
        // settling period falls in a later chunk.
        let n = 2;
        let cfg = NetworkConfig::paper(n);
        let mut w = vec![0.0f32; 4];
        w[2] = 8.0; // w[1][0]: follower 1 listens to leader 0
        let mut e = RtlEngine::new(cfg, 1, 2);
        e.set_weights(&w).unwrap();
        let mut ph = vec![4, 11];
        let mut st = vec![-1i32];
        let mut chunk_idx = 0;
        while st[0] < 0 && chunk_idx < 10 {
            e.run_chunk(&mut ph, &mut st, chunk_idx * 2).unwrap();
            chunk_idx += 1;
        }
        let wm = crate::runtime::checked_weights(&cfg, &w).unwrap();
        let mut oracle = HybridOnn::new(cfg, wm);
        oracle.set_phases(&[4, 11]);
        let want = oracle.run_to_settle(20).settled.unwrap() as i32;
        assert_eq!(st[0], want, "chunked settle index != run_to_settle");
    }

    #[test]
    fn noise_follows_the_counter_indexed_stream() {
        // Zero weights freeze the deterministic dynamics, so the engine
        // trajectory is exactly the kick stream: replaying kick_at by
        // hand (batch-lane tick order) must reproduce it.
        let n = 4;
        let cfg = NetworkConfig::paper(n);
        let (amp, seed) = (0.9, 77u64);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 2, 3);
        e.set_weights(&zeros).unwrap();
        e.set_noise(amp, seed).unwrap();
        let init: Vec<i32> = vec![1, 5, 9, 13, 2, 6, 10, 14];
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        let mut want = init.clone();
        let mut tick = 0u64;
        for lane in 0..2usize {
            for _ in 0..3 {
                for i in 0..n {
                    let phi = want[lane * n + i];
                    want[lane * n + i] = PhaseNoise::kick_at(seed, tick, i, amp, phi, 16);
                }
                tick += 1;
            }
        }
        assert_eq!(ph, want, "kick stream diverged from kick_at replay");
        // Reinstalling the noise restarts the stream: a fresh engine
        // from the same state reproduces the same chunk.
        e.set_noise(amp, seed).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 2];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert_eq!(ph2, ph, "set_noise must restart the stream");
    }

    #[test]
    fn begin_wave_reprograms_even_when_phases_coincide() {
        // A fresh wave whose init happens to equal the lane's settled
        // state must still get a fresh hardware run: registers reset,
        // warm-up period re-armed.  Value sniffing alone cannot see it
        // — that is exactly what the begin_wave hook exists for.
        let n = 2;
        let cfg = NetworkConfig::paper(n);
        let mut w = vec![0.0f32; 4];
        w[2] = 8.0; // follower 1 listens to leader 0
        let mut e = RtlEngine::new(cfg, 1, 4);
        e.set_weights(&w).unwrap();
        assert!(e.begin_wave(0).is_err(), "empty wave rejected");
        assert!(e.begin_wave(2).is_err(), "wave beyond the batch rejected");
        let mut ph = vec![4, 11];
        let mut st = vec![-1i32];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_eq!(ph, vec![4, 4], "pair must have locked");
        // Same buffer, new trial: without the wave hook the stale
        // settle tracker fires instantly at index 0...
        let mut st2 = vec![-1i32];
        e.run_chunk(&mut ph, &mut st2, 0).unwrap();
        assert_eq!(st2[0], 0, "sniff path resumes the old run");
        // ...with it, the lane restarts and the warm-up rule holds: a
        // fixed point is first *confirmed* at period 1.
        e.begin_wave(1).unwrap();
        let mut st3 = vec![-1i32];
        e.run_chunk(&mut ph, &mut st3, 0).unwrap();
        assert_eq!(st3[0], 1, "reprogrammed lane must re-arm warm-up");
    }

    #[test]
    fn padding_lanes_are_neither_stepped_nor_metered() {
        // begin_wave(3) on a 4-lane engine: the padding lane's buffer
        // slice stays untouched, its settle flag stays clear, and the
        // hardware meter prices exactly the three active lanes.
        let n = 3;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 4, 2);
        e.set_weights(&zeros).unwrap();
        e.begin_wave(3).unwrap();
        let init: Vec<i32> = (0..4 * n).map(|i| (i as i32 * 5) % 16).collect();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 4];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_eq!(&ph[3 * n..], &init[3 * n..], "padding lane moved");
        assert_eq!(st[3], -1, "padding lane reported a settle");
        assert!(st[..3].iter().all(|&s| s >= 0), "active lanes settle");
        let hw = e.hardware_cost().unwrap();
        assert_eq!(
            hw.fast_cycles,
            (3 * 2 * 16 * (n + SYNC_OVERHEAD_CYCLES)) as u64,
            "the meter must count the three active lanes only"
        );
        // A global set_weights returns the whole batch to active duty.
        e.set_weights(&zeros).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 4];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert!(st2.iter().all(|&s| s >= 0), "all four lanes advance again");
    }

    #[test]
    fn hardware_cost_meters_serialized_lanes() {
        let n = 5;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 2, 4);
        assert!(e.hardware_cost().is_none(), "no cost before weights");
        e.set_weights(&zeros).unwrap();
        let mut ph = vec![0i32; 2 * n];
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        let hw = e.hardware_cost().unwrap();
        // 2 lanes x 4 periods x 16 ticks, each tick one serial sum of
        // n + overhead fast cycles.
        let want = (2 * 4 * 16 * (n + SYNC_OVERHEAD_CYCLES)) as u64;
        assert_eq!(hw.fast_cycles, want);
        assert!(hw.f_logic_mhz > 0.0);
        assert!(hw.emulated_s > 0.0);
        assert!(hw.fits_device, "n=5 trivially fits the Zynq-7020");
        assert!(hw.area_percent > 0.0);
    }
}
