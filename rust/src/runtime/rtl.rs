//! The bit-true emulated-hardware engine: the paper's serial-MAC hybrid
//! datapath (`rtl::hybrid::HybridOnn`) served through the same
//! [`ChunkEngine`] contract as the float fabrics, so the solver
//! portfolio and the coordinator can put solve traffic on the
//! cycle-accurate hardware model instead of `onn::dynamics`.
//!
//! Contract notes (DESIGN_SOLVER.md §8):
//!
//! * **Lanes.** The engine's batch dimension maps onto independent
//!   register-state lanes of one multi-lane `HybridOnn` sharing the
//!   weight memory — the way one synthesized core is re-run per anneal
//!   replica.  `run_chunk` detects externally (re)written lane phases
//!   (the portfolio's wave inits) and reprograms just those lanes,
//!   resetting their registers like a fresh hardware run.
//! * **Noise.** The annealing hook applies *quantized phase kicks* on
//!   the exact counter-indexed stream of `onn::dynamics::PhaseNoise`
//!   (`kick_at(seed, tick, oscillator)`): after each emulated period
//!   the update circuit rewrites the mux selects in place, registers
//!   keep running.  The tick walks in batch-lane order and restarts on
//!   `set_noise`/`set_weights`, mirroring the native engine — so an rtl
//!   solve is deterministic at equal seed (`rust/tests/prop_rtl.rs`).
//! * **Settling** is judged on phases relative to oscillator 0 across
//!   whole periods (the RTL semantics, warm-up period excluded), with
//!   the comparand carried across chunk boundaries.
//! * **Cost.** The lanes' `SerialMac` cycle counters meter emulated
//!   fast-clock work; [`ChunkEngine::hardware_cost`] converts it to an
//!   emulated time-to-solution via `fpga::timing` and reports device
//!   fit via `fpga::resources::hybrid`.
//! * **Lane blocks.** The hardware time-multiplexes one weight memory
//!   per period anyway, so a block is a bank-select away:
//!   `set_lane_block` installs a per-block quantized weight bank on the
//!   simulator (`HybridOnn::set_lane_bank`) and gives the block its own
//!   *block-local* counter-indexed kick stream — within the block, tick
//!   order is exactly a dedicated engine's batch-lane walk, so a packed
//!   rtl solve is bit-exact lane by lane with the same problem run solo
//!   (`rust/tests/prop_rtl_packed.rs`).  Per-block `SerialMac` baselines
//!   let `lane_block_hardware_cost` price each problem's share of the
//!   emulated fabric.
//!
//! Unsupported, by construction: the PJRT artifact path.

use anyhow::{anyhow, Result};

use crate::fpga::device::{zynq7020, Device};
use crate::fpga::resources;
use crate::fpga::timing;
use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::PhaseNoise;
use crate::onn::weights::WeightMatrix;
use crate::rtl::hybrid::HybridOnn;
use crate::runtime::{ChunkEngine, HardwareCost};
use crate::telemetry::{TraceEvent, TraceSink};

/// Bookkeeping of one programmed lane block: its lane range, its
/// block-local kick stream, and the per-lane cycle baseline taken when
/// it was programmed (so its hardware cost excludes whatever a retired
/// predecessor burned on the same lanes).
struct RtlBlock {
    lane0: usize,
    lanes: usize,
    /// Pending (amplitude, seed); amplitude 0 disables kicks.
    noise: Option<(f64, u64)>,
    /// Periods consumed from the block's kick stream since the last
    /// `set_lane_block_noise`, advancing in block-lane order — the
    /// block-local twin of the whole-batch `noise_tick`.
    tick: u64,
    /// Sum of the block lanes' fast-cycle meters at program time.
    base_cycles: u64,
    /// The next `run_chunk` reprograms the block's lanes
    /// unconditionally: a freshly placed block must never resume a
    /// retired problem's registers even if the init phases coincide.
    fresh: bool,
}

pub struct RtlEngine {
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    device: Device,
    sim: Option<HybridOnn>,
    /// Pending (amplitude, seed) noise setting; amplitude 0 disables.
    noise: Option<(f64, u64)>,
    /// Periods consumed from the kick stream since the last
    /// `set_noise`/`set_weights` (the `tick` half of the kick index),
    /// advancing in batch-lane order like the native engine's.
    noise_tick: u64,
    /// Lanes `[0, active)` advance (and are cost-metered); the rest is
    /// caller-declared padding (`begin_wave`).  Whole batch by default.
    active: usize,
    /// A `begin_wave` arrived: the next `run_chunk` reprograms the
    /// active lanes unconditionally — a fresh init that happens to
    /// equal a lane's current phases must still reset its registers.
    pending_wave: Option<usize>,
    /// True when the simulator's shared weight memory holds a valid
    /// whole-batch problem.  Programming any lane block turns this off
    /// (one-way: clearing the last block leaves the engine demanding a
    /// fresh `set_weights` rather than resuming a stale problem).
    whole: bool,
    /// Programmed lane blocks (the packed solve path); empty in
    /// whole-batch mode.
    blocks: Vec<RtlBlock>,
    /// Lane-periods stepped since construction, whole-batch and block
    /// paths alike — the per-period all-gather count the emulated
    /// cluster front end prices (`runtime::cluster`).
    lane_periods: u64,
    /// Lifecycle trace sink; when set, `run_chunk` records one
    /// `engine_chunk` span carrying the chunk's emulated fast-cycle
    /// delta next to the host step time.
    trace: Option<TraceSink>,
}

impl RtlEngine {
    /// An engine serving `cfg.n` oscillators with `batch` lanes and
    /// `chunk` periods per `run_chunk` call, modeled on the paper's
    /// reference device (Zynq-7020).
    pub fn new(cfg: NetworkConfig, batch: usize, chunk: usize) -> Self {
        Self {
            cfg,
            batch,
            chunk,
            device: zynq7020(),
            sim: None,
            noise: None,
            noise_tick: 0,
            active: batch,
            pending_wave: None,
            whole: false,
            blocks: Vec::new(),
            lane_periods: 0,
            trace: None,
        }
    }

    /// Sum of every lane's fast-cycle counter (0 before `set_weights`).
    fn total_fast_cycles(&self) -> u64 {
        self.sim
            .as_ref()
            .map(|s| (0..s.lanes()).map(|l| s.lane_fast_cycles(l)).sum())
            .unwrap_or(0)
    }

    /// Lane-periods stepped since construction (each is one per-period
    /// phase all-gather on a multi-device composition of this fabric).
    pub(crate) fn lane_periods_stepped(&self) -> u64 {
        self.lane_periods
    }

    /// True once a simulator exists (whole-batch weights or a lane
    /// block have been programmed).
    pub(crate) fn programmed(&self) -> bool {
        self.sim.is_some()
    }

    /// Fast-cycle meter of weight row `row`'s MAC summed across lanes —
    /// the elapsed work of an emulated cluster device owning that row
    /// (`runtime::cluster` samples each device at its first row).
    pub(crate) fn row_fast_cycles(&self, row: usize) -> u64 {
        self.sim.as_ref().map_or(0, |s| s.row_fast_cycles(row))
    }

    /// Price `fast_cycles` of emulated work on this engine's device at
    /// its network size — the shared tail of `hardware_cost` and
    /// `lane_block_hardware_cost`.
    fn price(&self, fast_cycles: u64) -> HardwareCost {
        let f_logic_mhz = timing::logic_frequency_hybrid(self.cfg.n, &self.device);
        let res = resources::hybrid(&self.cfg, &self.device);
        HardwareCost {
            fast_cycles,
            f_logic_mhz,
            emulated_s: fast_cycles as f64 / (f_logic_mhz * 1e6),
            fits_device: res.fits(&self.device),
            area_percent: res.area_percent(&self.device),
            sync_fast_cycles: 0,
        }
    }
}

impl ChunkEngine for RtlEngine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn chunk_len(&self) -> usize {
        self.chunk
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        self.sim = Some(HybridOnn::with_lanes(self.cfg, w, self.batch));
        // Reprogramming the weight memory restarts the kick stream,
        // exactly like the native engine rebuilding its PhaseNoise —
        // clears every lane block, and returns the whole batch to
        // active duty.
        self.noise_tick = 0;
        self.active = self.batch;
        self.pending_wave = None;
        self.whole = true;
        self.blocks.clear();
        Ok(())
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        let cycles0 = self.total_fast_cycles();
        let n = self.cfg.n;
        if phases.len() != self.batch * n || settled.len() != self.batch {
            return Err(anyhow!("shape mismatch"));
        }
        let p = self.cfg.period() as i32;
        let chunk = self.chunk;
        if !self.blocks.is_empty() {
            // Packed mode: each programmed block advances its own lanes
            // against its own weight bank and block-local kick stream;
            // lanes outside every block are neither stepped nor metered.
            let sim = self
                .sim
                .as_mut()
                .expect("block mode always has a simulator");
            for b in self.blocks.iter_mut() {
                for off in 0..b.lanes {
                    let lane = b.lane0 + off;
                    let slice = &phases[lane * n..(lane + 1) * n];
                    if b.fresh || sim.lane_phases(lane) != slice {
                        sim.set_lane_phases(lane, slice);
                    }
                }
                b.fresh = false;
                let noise = b.noise.filter(|&(a, _)| a > 0.0);
                for off in 0..b.lanes {
                    let lane = b.lane0 + off;
                    for k in 0..chunk {
                        let settled_now = sim.step_lane_period(lane);
                        self.lane_periods += 1;
                        if let Some((amp, seed)) = noise {
                            let tick = b.tick;
                            sim.kick_lane_phases(lane, |i, phi| {
                                PhaseNoise::kick_at(seed, tick, i, amp, phi, p)
                            });
                            b.tick += 1;
                        }
                        if settled_now && settled[lane] < 0 {
                            settled[lane] = period0 + k as i32;
                        }
                    }
                    phases[lane * n..(lane + 1) * n].copy_from_slice(sim.lane_phases(lane));
                }
            }
        } else {
            if !self.whole {
                return Err(anyhow!("set_weights not called"));
            }
            let wave = self.pending_wave.take();
            if let Some(active) = wave {
                self.active = active;
            }
            let sim = self
                .sim
                .as_mut()
                .ok_or_else(|| anyhow!("set_weights not called"))?;
            // A declared wave reprograms every active lane
            // unconditionally (a fresh init may coincide with the lane's
            // current phases — its registers must reset anyway);
            // otherwise externally rewritten lanes are detected by value
            // and reprogrammed, and untouched lanes resume.  Lanes past
            // `active` are padding: never stepped, never metered.
            for lane in 0..self.active {
                let slice = &phases[lane * n..(lane + 1) * n];
                if wave.is_some() || sim.lane_phases(lane) != slice {
                    sim.set_lane_phases(lane, slice);
                }
            }
            let noise = self.noise.filter(|&(a, _)| a > 0.0);
            for lane in 0..self.active {
                for k in 0..chunk {
                    let settled_now = sim.step_lane_period(lane);
                    self.lane_periods += 1;
                    if let Some((amp, seed)) = noise {
                        let tick = self.noise_tick;
                        sim.kick_lane_phases(lane, |i, phi| {
                            PhaseNoise::kick_at(seed, tick, i, amp, phi, p)
                        });
                        self.noise_tick += 1;
                    }
                    if settled_now && settled[lane] < 0 {
                        settled[lane] = period0 + k as i32;
                    }
                }
                phases[lane * n..(lane + 1) * n].copy_from_slice(sim.lane_phases(lane));
            }
        }
        if let (Some(t0), Some(sink)) = (t0, self.trace.as_ref()) {
            sink.borrow_mut().record(TraceEvent::EngineChunk {
                engine: "rtl",
                period0: period0 as i64,
                step_us: t0.elapsed().as_micros().min(u64::MAX as u128) as u64,
                sync_rounds: 0,
                sync_us: 0,
                fast_cycles: self.total_fast_cycles() - cycles0,
            });
        }
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "rtl"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        self.noise = Some((amplitude, seed));
        self.noise_tick = 0;
        Ok(())
    }

    fn begin_wave(&mut self, active: usize) -> Result<()> {
        if active == 0 || active > self.batch {
            return Err(anyhow!(
                "wave of {active} lanes outside the {}-lane batch",
                self.batch
            ));
        }
        self.pending_wave = Some(active);
        Ok(())
    }

    fn supports_lane_blocks(&self) -> bool {
        true
    }

    fn set_lane_block(&mut self, lane0: usize, lanes: usize, w_f32: &[f32]) -> Result<()> {
        if lanes == 0 || lane0 + lanes > self.batch {
            return Err(anyhow!(
                "lane block [{lane0}, {}) outside the {}-lane batch",
                lane0 + lanes,
                self.batch
            ));
        }
        if self.blocks.iter().any(|b| {
            b.lane0 != lane0 && lane0 < b.lane0 + b.lanes && b.lane0 < lane0 + lanes
        }) {
            return Err(anyhow!(
                "lane block [{lane0}, {}) overlaps a programmed block",
                lane0 + lanes
            ));
        }
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        // Entering block mode invalidates whole-batch weights one-way;
        // a cold engine gets a simulator whose shared memory is zeros
        // (no lane outside a block ever steps against it).
        self.whole = false;
        let sim = self.sim.get_or_insert_with(|| {
            HybridOnn::with_lanes(self.cfg, WeightMatrix::zeros(self.cfg.n), self.batch)
        });
        sim.set_lane_bank(lane0, lanes, w);
        let base_cycles = (lane0..lane0 + lanes).map(|l| sim.lane_fast_cycles(l)).sum();
        // Re-programming the same range replaces the weights AND
        // discards the retired block's kick stream and cycle baseline.
        self.blocks.retain(|b| b.lane0 != lane0);
        self.blocks.push(RtlBlock {
            lane0,
            lanes,
            noise: None,
            tick: 0,
            base_cycles,
            fresh: true,
        });
        Ok(())
    }

    fn set_lane_block_noise(&mut self, lane0: usize, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        let b = self
            .blocks
            .iter_mut()
            .find(|b| b.lane0 == lane0)
            .ok_or_else(|| anyhow!("no lane block at lane {lane0}"))?;
        b.noise = Some((amplitude, seed));
        b.tick = 0;
        Ok(())
    }

    fn clear_lane_block(&mut self, lane0: usize) -> Result<()> {
        let before = self.blocks.len();
        self.blocks.retain(|b| b.lane0 != lane0);
        if self.blocks.len() == before {
            return Err(anyhow!("no lane block at lane {lane0}"));
        }
        if let Some(sim) = self.sim.as_mut() {
            sim.clear_lane_bank(lane0);
        }
        Ok(())
    }

    fn hardware_cost(&self) -> Option<HardwareCost> {
        // One device runs the lanes back to back: the emulated elapsed
        // fast-clock time is the sum of each lane's (parallel-MAC) wall
        // clock — N MACs per lane tick in lockstep, so any single MAC's
        // counter is its lane's elapsed cycles.
        self.sim.as_ref()?;
        Some(self.price(self.total_fast_cycles()))
    }

    fn lane_block_hardware_cost(&self, lane0: usize) -> Option<HardwareCost> {
        let sim = self.sim.as_ref()?;
        let b = self.blocks.iter().find(|b| b.lane0 == lane0)?;
        let cycles: u64 = (b.lane0..b.lane0 + b.lanes)
            .map(|l| sim.lane_fast_cycles(l))
            .sum();
        Some(self.price(cycles - b.base_cycles))
    }

    fn set_trace_sink(&mut self, sink: Option<TraceSink>) {
        self.trace = sink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::hybrid::SYNC_OVERHEAD_CYCLES;
    use crate::rtl::RtlSim;
    use crate::util::rng::Rng;

    fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect()
    }

    #[test]
    fn shape_and_weight_validation() {
        let mut e = RtlEngine::new(NetworkConfig::paper(2), 1, 4);
        let mut ph = vec![0, 0];
        let mut st = vec![-1];
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_err(), "needs weights");
        assert!(e.set_weights(&[0.0, 99.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.5, 0.0, 0.0, 0.0]).is_err());
        e.set_weights(&[0.0, 15.0, -16.0, 0.0]).unwrap();
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_ok());
        let mut bad = vec![0, 0, 0];
        assert!(e.run_chunk(&mut bad, &mut st, 0).is_err(), "bad shape");
        assert!(e.set_noise(1.5, 1).is_err(), "amplitude range");
    }

    #[test]
    fn lanes_match_the_monolithic_simulator() {
        // Each engine lane must reproduce a solo HybridOnn trajectory,
        // across chunk boundaries, lane by lane.
        let mut rng = Rng::new(91);
        let n = 6;
        let cfg = NetworkConfig::paper(n);
        let w = rand_w(&mut rng, n);
        let mut e = RtlEngine::new(cfg, 3, 4);
        e.set_weights(&w).unwrap();
        let init: Vec<i32> = (0..3 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 3];
        for chunk_idx in 0..3 {
            e.run_chunk(&mut ph, &mut st, chunk_idx * 4).unwrap();
            for lane in 0..3 {
                let wm = crate::runtime::checked_weights(&cfg, &w).unwrap();
                let mut solo = HybridOnn::new(cfg, wm);
                solo.set_phases(&init[lane * n..(lane + 1) * n]);
                for _ in 0..(chunk_idx as usize + 1) * 4 * 16 {
                    solo.tick();
                }
                assert_eq!(
                    &ph[lane * n..(lane + 1) * n],
                    solo.phases(),
                    "lane {lane} chunk {chunk_idx}"
                );
            }
        }
    }

    #[test]
    fn settle_flags_resume_across_chunks() {
        // A pinned leader/follower pair settles after a few periods;
        // the flag must carry the absolute period index even when the
        // settling period falls in a later chunk.
        let n = 2;
        let cfg = NetworkConfig::paper(n);
        let mut w = vec![0.0f32; 4];
        w[2] = 8.0; // w[1][0]: follower 1 listens to leader 0
        let mut e = RtlEngine::new(cfg, 1, 2);
        e.set_weights(&w).unwrap();
        let mut ph = vec![4, 11];
        let mut st = vec![-1i32];
        let mut chunk_idx = 0;
        while st[0] < 0 && chunk_idx < 10 {
            e.run_chunk(&mut ph, &mut st, chunk_idx * 2).unwrap();
            chunk_idx += 1;
        }
        let wm = crate::runtime::checked_weights(&cfg, &w).unwrap();
        let mut oracle = HybridOnn::new(cfg, wm);
        oracle.set_phases(&[4, 11]);
        let want = oracle.run_to_settle(20).settled.unwrap() as i32;
        assert_eq!(st[0], want, "chunked settle index != run_to_settle");
    }

    #[test]
    fn noise_follows_the_counter_indexed_stream() {
        // Zero weights freeze the deterministic dynamics, so the engine
        // trajectory is exactly the kick stream: replaying kick_at by
        // hand (batch-lane tick order) must reproduce it.
        let n = 4;
        let cfg = NetworkConfig::paper(n);
        let (amp, seed) = (0.9, 77u64);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 2, 3);
        e.set_weights(&zeros).unwrap();
        e.set_noise(amp, seed).unwrap();
        let init: Vec<i32> = vec![1, 5, 9, 13, 2, 6, 10, 14];
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        let mut want = init.clone();
        let mut tick = 0u64;
        for lane in 0..2usize {
            for _ in 0..3 {
                for i in 0..n {
                    let phi = want[lane * n + i];
                    want[lane * n + i] = PhaseNoise::kick_at(seed, tick, i, amp, phi, 16);
                }
                tick += 1;
            }
        }
        assert_eq!(ph, want, "kick stream diverged from kick_at replay");
        // Reinstalling the noise restarts the stream: a fresh engine
        // from the same state reproduces the same chunk.
        e.set_noise(amp, seed).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 2];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert_eq!(ph2, ph, "set_noise must restart the stream");
    }

    #[test]
    fn begin_wave_reprograms_even_when_phases_coincide() {
        // A fresh wave whose init happens to equal the lane's settled
        // state must still get a fresh hardware run: registers reset,
        // warm-up period re-armed.  Value sniffing alone cannot see it
        // — that is exactly what the begin_wave hook exists for.
        let n = 2;
        let cfg = NetworkConfig::paper(n);
        let mut w = vec![0.0f32; 4];
        w[2] = 8.0; // follower 1 listens to leader 0
        let mut e = RtlEngine::new(cfg, 1, 4);
        e.set_weights(&w).unwrap();
        assert!(e.begin_wave(0).is_err(), "empty wave rejected");
        assert!(e.begin_wave(2).is_err(), "wave beyond the batch rejected");
        let mut ph = vec![4, 11];
        let mut st = vec![-1i32];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_eq!(ph, vec![4, 4], "pair must have locked");
        // Same buffer, new trial: without the wave hook the stale
        // settle tracker fires instantly at index 0...
        let mut st2 = vec![-1i32];
        e.run_chunk(&mut ph, &mut st2, 0).unwrap();
        assert_eq!(st2[0], 0, "sniff path resumes the old run");
        // ...with it, the lane restarts and the warm-up rule holds: a
        // fixed point is first *confirmed* at period 1.
        e.begin_wave(1).unwrap();
        let mut st3 = vec![-1i32];
        e.run_chunk(&mut ph, &mut st3, 0).unwrap();
        assert_eq!(st3[0], 1, "reprogrammed lane must re-arm warm-up");
    }

    #[test]
    fn padding_lanes_are_neither_stepped_nor_metered() {
        // begin_wave(3) on a 4-lane engine: the padding lane's buffer
        // slice stays untouched, its settle flag stays clear, and the
        // hardware meter prices exactly the three active lanes.
        let n = 3;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 4, 2);
        e.set_weights(&zeros).unwrap();
        e.begin_wave(3).unwrap();
        let init: Vec<i32> = (0..4 * n).map(|i| (i as i32 * 5) % 16).collect();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 4];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_eq!(&ph[3 * n..], &init[3 * n..], "padding lane moved");
        assert_eq!(st[3], -1, "padding lane reported a settle");
        assert!(st[..3].iter().all(|&s| s >= 0), "active lanes settle");
        let hw = e.hardware_cost().unwrap();
        assert_eq!(
            hw.fast_cycles,
            (3 * 2 * 16 * (n + SYNC_OVERHEAD_CYCLES)) as u64,
            "the meter must count the three active lanes only"
        );
        // A global set_weights returns the whole batch to active duty.
        e.set_weights(&zeros).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 4];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert!(st2.iter().all(|&s| s >= 0), "all four lanes advance again");
    }

    #[test]
    fn lane_blocks_match_dedicated_engines() {
        // Two blocks (different weights, different noise) on one 5-lane
        // engine: each must reproduce a dedicated engine of its own
        // geometry bit for bit, chunk after chunk; the unblocked lane 4
        // never moves.
        let mut rng = Rng::new(93);
        let n = 4;
        let cfg = NetworkConfig::paper(n);
        let wa = rand_w(&mut rng, n);
        let wb = rand_w(&mut rng, n);
        let mut packed = RtlEngine::new(cfg, 5, 3);
        packed.set_lane_block(0, 2, &wa).unwrap();
        packed.set_lane_block(2, 2, &wb).unwrap();
        let init: Vec<i32> = (0..5 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 5];
        let mut solo_a = RtlEngine::new(cfg, 2, 3);
        solo_a.set_weights(&wa).unwrap();
        let mut pa = init[..2 * n].to_vec();
        let mut sa = vec![-1i32; 2];
        let mut solo_b = RtlEngine::new(cfg, 2, 3);
        solo_b.set_weights(&wb).unwrap();
        let mut pb = init[2 * n..4 * n].to_vec();
        let mut sb = vec![-1i32; 2];
        for chunk_idx in 0..3 {
            packed.set_lane_block_noise(0, 0.8, 11 + chunk_idx).unwrap();
            packed.set_lane_block_noise(2, 0.4, 22 + chunk_idx).unwrap();
            solo_a.set_noise(0.8, 11 + chunk_idx).unwrap();
            solo_b.set_noise(0.4, 22 + chunk_idx).unwrap();
            let p0 = chunk_idx as i32 * 3;
            packed.run_chunk(&mut ph, &mut st, p0).unwrap();
            solo_a.run_chunk(&mut pa, &mut sa, p0).unwrap();
            solo_b.run_chunk(&mut pb, &mut sb, p0).unwrap();
            assert_eq!(&ph[..2 * n], &pa[..], "block A diverged at {chunk_idx}");
            assert_eq!(&ph[2 * n..4 * n], &pb[..], "block B diverged at {chunk_idx}");
            assert_eq!(&ph[4 * n..], &init[4 * n..], "unblocked lane moved");
            assert_eq!(st[4], -1);
        }
        // Per-block hardware shares: each block burned exactly its solo
        // twin's cycles, and the whole-fabric meter is their sum.
        let ha = packed.lane_block_hardware_cost(0).unwrap();
        let hb = packed.lane_block_hardware_cost(2).unwrap();
        assert_eq!(ha.fast_cycles, solo_a.hardware_cost().unwrap().fast_cycles);
        assert_eq!(hb.fast_cycles, solo_b.hardware_cost().unwrap().fast_cycles);
        assert_eq!(
            packed.hardware_cost().unwrap().fast_cycles,
            ha.fast_cycles + hb.fast_cycles
        );
        assert!(packed.lane_block_hardware_cost(1).is_none(), "not a block anchor");
    }

    #[test]
    fn lane_block_lifecycle_validation() {
        let n = 3;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 4, 2);
        assert!(e.supports_lane_blocks());
        assert!(e.set_lane_block(0, 0, &zeros).is_err(), "empty block");
        assert!(e.set_lane_block(3, 2, &zeros).is_err(), "past the batch");
        e.set_lane_block(0, 2, &zeros).unwrap();
        assert!(e.set_lane_block(1, 2, &zeros).is_err(), "overlap");
        assert!(e.set_lane_block_noise(1, 0.5, 1).is_err(), "no block there");
        assert!(e.set_lane_block_noise(0, 1.5, 1).is_err(), "amplitude range");
        assert!(e.clear_lane_block(1).is_err());
        // Re-programming the same range restarts its kick stream: two
        // fresh programs of the same block replay identical kicks.
        let init = vec![1, 5, 9, 2, 6, 10, 0, 0, 0, 0, 0, 0];
        e.set_lane_block_noise(0, 0.9, 7).unwrap();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 4];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        e.set_lane_block(0, 2, &zeros).unwrap();
        e.set_lane_block_noise(0, 0.9, 7).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 4];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert_eq!(ph2, ph, "reprogram must restart the block kick stream");
        // Clearing the last block is one-way: the engine demands a
        // fresh set_weights before any whole-batch run.
        e.clear_lane_block(0).unwrap();
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_err(), "unprogrammed");
        e.set_weights(&zeros).unwrap();
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_ok());
    }

    #[test]
    fn hardware_cost_meters_serialized_lanes() {
        let n = 5;
        let cfg = NetworkConfig::paper(n);
        let zeros = vec![0.0f32; n * n];
        let mut e = RtlEngine::new(cfg, 2, 4);
        assert!(e.hardware_cost().is_none(), "no cost before weights");
        e.set_weights(&zeros).unwrap();
        let mut ph = vec![0i32; 2 * n];
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        let hw = e.hardware_cost().unwrap();
        // 2 lanes x 4 periods x 16 ticks, each tick one serial sum of
        // n + overhead fast cycles.
        let want = (2 * 4 * 16 * (n + SYNC_OVERHEAD_CYCLES)) as u64;
        assert_eq!(hw.fast_cycles, want);
        assert!(hw.f_logic_mhz > 0.0);
        assert!(hw.emulated_s > 0.0);
        assert!(hw.fits_device, "n=5 trivially fits the Zynq-7020");
        assert!(hw.area_percent > 0.0);
    }
}
