//! Native fallback engine: the same [`ChunkEngine`] contract implemented
//! by the in-process functional dynamics (`onn::dynamics`).  Bit-exact
//! with the PJRT artifacts (integer math everywhere) — the integration
//! tests cross-validate the two engines trial-for-trial.
//!
//! Besides the whole-batch mode, the engine supports *lane blocks*
//! (DESIGN_SOLVER.md §7): contiguous batch-lane ranges each backed by
//! their own [`FunctionalEngine`], so one engine carries several small
//! Ising problems at once.  A block behaves exactly like a dedicated
//! engine of its own size — same weights gate, same noise tick walk —
//! which is what makes the packed solve path bit-exact with solo runs.

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::{FunctionalEngine, PhaseNoise};
use crate::runtime::ChunkEngine;
use crate::telemetry::{TraceEvent, TraceSink};

/// One programmed lane block: lanes `[lane0, lane0 + lanes)` running
/// their own problem on a private functional engine.
struct LaneBlock {
    lane0: usize,
    lanes: usize,
    engine: FunctionalEngine,
}

pub struct NativeEngine {
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    inner: Option<FunctionalEngine>,
    /// Pending (amplitude, seed) noise setting; re-applied when weights
    /// (and thus the inner engine) are replaced.
    noise: Option<(f64, u64)>,
    /// Programmed lane blocks; non-empty switches `run_chunk` to
    /// block-dispatch mode (only block lanes advance).
    blocks: Vec<LaneBlock>,
    /// Lifecycle trace sink; when set, `run_chunk` records one
    /// `engine_chunk` span (host step time; this fabric has no sync or
    /// cycle meters).
    trace: Option<TraceSink>,
}

impl NativeEngine {
    pub fn new(cfg: NetworkConfig, batch: usize, chunk: usize) -> Self {
        Self {
            cfg,
            batch,
            chunk,
            inner: None,
            noise: None,
            blocks: Vec::new(),
            trace: None,
        }
    }

    fn apply_noise(&mut self) {
        if let Some(inner) = self.inner.as_mut() {
            inner.set_noise(match self.noise {
                Some((a, seed)) if a > 0.0 => Some(PhaseNoise::new(a, seed)),
                _ => None,
            });
        }
    }

    fn block_mut(&mut self, lane0: usize) -> Result<&mut LaneBlock> {
        self.blocks
            .iter_mut()
            .find(|b| b.lane0 == lane0)
            .ok_or_else(|| anyhow!("no lane block programmed at lane {lane0}"))
    }

    fn run_chunk_inner(
        &mut self,
        phases: &mut [i32],
        settled: &mut [i32],
        period0: i32,
    ) -> Result<()> {
        let n = self.cfg.n;
        if phases.len() != self.batch * n || settled.len() != self.batch {
            return Err(anyhow!("shape mismatch"));
        }
        if !self.blocks.is_empty() {
            // Lane-block mode: each block advances through its own
            // engine; lanes outside every block stay untouched.
            for blk in self.blocks.iter_mut() {
                blk.engine.run_chunk(
                    &mut phases[blk.lane0 * n..(blk.lane0 + blk.lanes) * n],
                    &mut settled[blk.lane0..blk.lane0 + blk.lanes],
                    period0,
                    self.chunk,
                );
            }
            return Ok(());
        }
        let eng = self
            .inner
            .as_mut()
            .ok_or_else(|| anyhow!("set_weights not called"))?;
        eng.run_chunk(phases, settled, period0, self.chunk);
        Ok(())
    }
}

impl ChunkEngine for NativeEngine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn chunk_len(&self) -> usize {
        self.chunk
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        // Whole-batch programming retires every lane block.
        self.blocks.clear();
        self.inner = Some(FunctionalEngine::new(self.cfg, w));
        self.apply_noise();
        Ok(())
    }

    fn supports_sparse(&self) -> bool {
        true
    }

    fn set_weights_sparse(&mut self, w: &crate::onn::sparse::SparseWeights) -> Result<()> {
        crate::runtime::checked_sparse_weights(&self.cfg, w)?;
        // Same lifecycle as the dense gate: whole-batch programming
        // retires every lane block and restarts the noise stream.
        self.blocks.clear();
        self.inner = Some(FunctionalEngine::new_sparse(self.cfg, w.clone()));
        self.apply_noise();
        Ok(())
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        let t0 = self.trace.as_ref().map(|_| std::time::Instant::now());
        self.run_chunk_inner(phases, settled, period0)?;
        if let (Some(sink), Some(t0)) = (self.trace.as_ref(), t0) {
            sink.borrow_mut().record(TraceEvent::EngineChunk {
                engine: "native",
                period0: period0 as i64,
                step_us: t0.elapsed().as_micros().min(u64::MAX as u128) as u64,
                sync_rounds: 0,
                sync_us: 0,
                fast_cycles: 0,
            });
        }
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "native"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        self.noise = Some((amplitude, seed));
        self.apply_noise();
        Ok(())
    }

    fn supports_lane_blocks(&self) -> bool {
        true
    }

    fn set_lane_block(&mut self, lane0: usize, lanes: usize, w_f32: &[f32]) -> Result<()> {
        if lanes == 0 || lane0 + lanes > self.batch {
            return Err(anyhow!(
                "lane block [{lane0}, {}) outside the {}-lane batch",
                lane0 + lanes,
                self.batch
            ));
        }
        if self
            .blocks
            .iter()
            .any(|b| b.lane0 != lane0 && lane0 < b.lane0 + b.lanes && b.lane0 < lane0 + lanes)
        {
            return Err(anyhow!("lane block at {lane0} overlaps a programmed block"));
        }
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        // Entering lane-block mode invalidates any whole-batch
        // programming: once the last block is cleared the engine
        // demands a fresh set_weights instead of silently resuming a
        // stale pre-packing problem.
        self.inner = None;
        // Replacing a block rebuilds its engine, which also discards the
        // previous problem's kick stream (fresh noise is installed via
        // set_lane_block_noise).
        self.blocks.retain(|b| b.lane0 != lane0);
        self.blocks.push(LaneBlock {
            lane0,
            lanes,
            engine: FunctionalEngine::new(self.cfg, w),
        });
        Ok(())
    }

    fn set_lane_block_noise(&mut self, lane0: usize, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        let blk = self.block_mut(lane0)?;
        blk.engine
            .set_noise((amplitude > 0.0).then(|| PhaseNoise::new(amplitude, seed)));
        Ok(())
    }

    fn clear_lane_block(&mut self, lane0: usize) -> Result<()> {
        let before = self.blocks.len();
        self.blocks.retain(|b| b.lane0 != lane0);
        if self.blocks.len() == before {
            return Err(anyhow!("no lane block programmed at lane {lane0}"));
        }
        Ok(())
    }

    fn set_trace_sink(&mut self, sink: Option<TraceSink>) {
        self.trace = sink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::run_to_settle_batch;
    use crate::util::rng::Rng;

    #[test]
    fn rejects_out_of_range_weights() {
        let mut e = NativeEngine::new(NetworkConfig::paper(2), 1, 4);
        assert!(e.set_weights(&[0.0, 99.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.5, 0.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.0, 15.0, -16.0, 0.0]).is_ok());
    }

    #[test]
    fn run_requires_weights() {
        let mut e = NativeEngine::new(NetworkConfig::paper(2), 1, 4);
        let mut ph = vec![0, 0];
        let mut st = vec![-1];
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_err());
    }

    #[test]
    fn noise_hook_survives_weight_reload() {
        let n = 3;
        let mut e = NativeEngine::new(NetworkConfig::paper(n), 2, 4);
        assert!(e.supports_noise());
        assert!(e.set_noise(1.5, 1).is_err());
        e.set_noise(0.8, 7).unwrap();
        let w = vec![0.0f32; n * n];
        e.set_weights(&w).unwrap();
        // Zero weights normally freeze every state; with noise the
        // phases must move.
        let init = vec![1i32, 5, 9, 2, 6, 10];
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_ne!(ph, init, "noise did not perturb frozen dynamics");
        // Turning noise off restores determinism.
        e.set_noise(0.0, 7).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 2];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert_eq!(ph2, init);
    }

    #[test]
    fn sparse_install_matches_dense_install() {
        use crate::onn::sparse::SparseWeights;
        let n = 6;
        let cfg = NetworkConfig::paper(n);
        let mut rng = Rng::new(44);
        let mut w = crate::onn::weights::WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..i {
                if rng.f64() < 0.4 {
                    let v = rng.range_i64(-8, 9) as i8;
                    w.set(i, j, v);
                    w.set(j, i, v);
                }
            }
        }
        let sw = SparseWeights::from_dense(&w);
        let init: Vec<i32> = (0..3 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let seed = rng.next_u64();

        let mut dense = NativeEngine::new(cfg, 3, 4);
        dense.set_weights(&w.to_f32()).unwrap();
        dense.set_noise(0.6, seed).unwrap();
        let mut dp = init.clone();
        let mut ds = vec![-1i32; 3];
        dense.run_chunk(&mut dp, &mut ds, 0).unwrap();

        let mut sparse = NativeEngine::new(cfg, 3, 4);
        assert!(sparse.supports_sparse());
        sparse.set_weights_sparse(&sw).unwrap();
        sparse.set_noise(0.6, seed).unwrap();
        let mut sp = init.clone();
        let mut ss = vec![-1i32; 3];
        sparse.run_chunk(&mut sp, &mut ss, 0).unwrap();

        assert_eq!(dp, sp, "sparse fabric diverged from dense");
        assert_eq!(ds, ss);
    }

    #[test]
    fn sparse_install_gate_rejects_bad_fabrics() {
        use crate::onn::sparse::SparseWeights;
        let mut e = NativeEngine::new(NetworkConfig::paper(3), 1, 4);
        // Wrong size.
        let sw = SparseWeights::from_triplets(4, &[(0, 1, 1), (1, 0, 1)]).unwrap();
        assert!(e.set_weights_sparse(&sw).is_err());
        // Asymmetric.
        let sw = SparseWeights::from_triplets(3, &[(0, 1, 1)]).unwrap();
        assert!(e.set_weights_sparse(&sw).is_err());
        // In-range symmetric installs fine.
        let sw = SparseWeights::from_triplets(3, &[(0, 1, -16), (1, 0, -16)]).unwrap();
        assert!(e.set_weights_sparse(&sw).is_ok());
    }

    #[test]
    fn lane_blocks_match_dedicated_engines() {
        // Two blocks with different couplings + different noise streams
        // must each reproduce a dedicated engine of their own size.
        let n = 4;
        let cfg = NetworkConfig::paper(n);
        let mut rng = Rng::new(31);
        let wa: Vec<f32> = (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect();
        let wb: Vec<f32> = (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect();
        let init: Vec<i32> = (0..5 * n).map(|_| rng.range_i64(0, 16) as i32).collect();

        let mut packed = NativeEngine::new(cfg, 5, 4);
        assert!(packed.supports_lane_blocks());
        packed.set_lane_block(0, 2, &wa).unwrap();
        packed.set_lane_block(2, 2, &wb).unwrap();
        packed.set_lane_block_noise(0, 0.8, 11).unwrap();
        packed.set_lane_block_noise(2, 0.4, 22).unwrap();
        let mut pp = init.clone();
        let mut ps = vec![-1i32; 5];
        packed.run_chunk(&mut pp, &mut ps, 0).unwrap();

        for (lane0, w, amp, seed) in [(0usize, &wa, 0.8, 11u64), (2, &wb, 0.4, 22)] {
            let mut solo = NativeEngine::new(cfg, 2, 4);
            solo.set_weights(w).unwrap();
            solo.set_noise(amp, seed).unwrap();
            let mut sp = init[lane0 * n..(lane0 + 2) * n].to_vec();
            let mut ss = vec![-1i32; 2];
            solo.run_chunk(&mut sp, &mut ss, 0).unwrap();
            assert_eq!(&pp[lane0 * n..(lane0 + 2) * n], &sp[..], "block at {lane0}");
            assert_eq!(&ps[lane0..lane0 + 2], &ss[..], "block at {lane0}");
        }
        // The unprogrammed lane (index 4) never advances.
        assert_eq!(&pp[4 * n..], &init[4 * n..]);
        assert_eq!(ps[4], -1);
    }

    #[test]
    fn lane_block_validation() {
        let cfg = NetworkConfig::paper(3);
        let w = vec![0.0f32; 9];
        let mut e = NativeEngine::new(cfg, 4, 4);
        assert!(e.set_lane_block(3, 2, &w).is_err(), "out of range");
        assert!(e.set_lane_block(0, 0, &w).is_err(), "empty block");
        assert!(e.set_lane_block(0, 2, &[0.5; 9]).is_err(), "bad weights");
        e.set_lane_block(0, 2, &w).unwrap();
        assert!(e.set_lane_block(1, 2, &w).is_err(), "overlap");
        assert!(e.set_lane_block_noise(2, 0.5, 1).is_err(), "no block there");
        assert!(e.set_lane_block_noise(0, 1.5, 1).is_err(), "amplitude range");
        e.set_lane_block(2, 2, &w).unwrap();
        e.clear_lane_block(0).unwrap();
        assert!(e.clear_lane_block(0).is_err(), "already cleared");
        // Clearing the LAST block must not fall back to any stale
        // whole-batch programming — the engine demands set_weights.
        e.clear_lane_block(2).unwrap();
        let mut ph = vec![0i32; 12];
        let mut st = vec![-1i32; 4];
        assert!(
            e.run_chunk(&mut ph, &mut st, 0).is_err(),
            "stale whole-batch weights must not resume after packing"
        );
        // Global programming restores whole-batch mode: every lane
        // advances again.
        e.set_weights(&w).unwrap();
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert!(st.iter().all(|&s| s >= 0), "zero weights settle instantly");
    }

    #[test]
    fn settle_batch_drives_chunks() {
        // Ferro 3-net: everything snaps to consensus quickly.
        let n = 3;
        let mut e = NativeEngine::new(NetworkConfig::paper(n), 4, 4);
        let w = [0., 8., 8., 8., 0., 8., 8., 8., 0.];
        e.set_weights(&w).unwrap();
        let mut rng = Rng::new(5);
        let mut phases: Vec<i32> = (0..4 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let settled = run_to_settle_batch(&mut e, &mut phases, 64).unwrap();
        for (b, s) in settled.iter().enumerate() {
            assert!(s.is_some(), "trial {b} did not settle");
            let ph = &phases[b * n..(b + 1) * n];
            assert!(
                ph.iter().all(|&x| x == ph[0]),
                "trial {b} no consensus: {ph:?}"
            );
        }
    }
}
