//! Native fallback engine: the same [`ChunkEngine`] contract implemented
//! by the in-process functional dynamics (`onn::dynamics`).  Bit-exact
//! with the PJRT artifacts (integer math everywhere) — the integration
//! tests cross-validate the two engines trial-for-trial.

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::{FunctionalEngine, PhaseNoise};
use crate::runtime::ChunkEngine;

pub struct NativeEngine {
    cfg: NetworkConfig,
    batch: usize,
    chunk: usize,
    inner: Option<FunctionalEngine>,
    /// Pending (amplitude, seed) noise setting; re-applied when weights
    /// (and thus the inner engine) are replaced.
    noise: Option<(f64, u64)>,
}

impl NativeEngine {
    pub fn new(cfg: NetworkConfig, batch: usize, chunk: usize) -> Self {
        Self {
            cfg,
            batch,
            chunk,
            inner: None,
            noise: None,
        }
    }

    fn apply_noise(&mut self) {
        if let Some(inner) = self.inner.as_mut() {
            inner.set_noise(match self.noise {
                Some((a, seed)) if a > 0.0 => Some(PhaseNoise::new(a, seed)),
                _ => None,
            });
        }
    }
}

impl ChunkEngine for NativeEngine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn chunk_len(&self) -> usize {
        self.chunk
    }

    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()> {
        let w = crate::runtime::checked_weights(&self.cfg, w_f32)?;
        self.inner = Some(FunctionalEngine::new(self.cfg, w));
        self.apply_noise();
        Ok(())
    }

    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()> {
        let eng = self
            .inner
            .as_mut()
            .ok_or_else(|| anyhow!("set_weights not called"))?;
        if phases.len() != self.batch * self.cfg.n || settled.len() != self.batch {
            return Err(anyhow!("shape mismatch"));
        }
        eng.run_chunk(phases, settled, period0, self.chunk);
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "native"
    }

    fn supports_noise(&self) -> bool {
        true
    }

    fn set_noise(&mut self, amplitude: f64, seed: u64) -> Result<()> {
        if !(0.0..=1.0).contains(&amplitude) {
            return Err(anyhow!("noise amplitude {amplitude} outside [0, 1]"));
        }
        self.noise = Some((amplitude, seed));
        self.apply_noise();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::run_to_settle_batch;
    use crate::util::rng::Rng;

    #[test]
    fn rejects_out_of_range_weights() {
        let mut e = NativeEngine::new(NetworkConfig::paper(2), 1, 4);
        assert!(e.set_weights(&[0.0, 99.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.5, 0.0, 0.0, 0.0]).is_err());
        assert!(e.set_weights(&[0.0, 15.0, -16.0, 0.0]).is_ok());
    }

    #[test]
    fn run_requires_weights() {
        let mut e = NativeEngine::new(NetworkConfig::paper(2), 1, 4);
        let mut ph = vec![0, 0];
        let mut st = vec![-1];
        assert!(e.run_chunk(&mut ph, &mut st, 0).is_err());
    }

    #[test]
    fn noise_hook_survives_weight_reload() {
        let n = 3;
        let mut e = NativeEngine::new(NetworkConfig::paper(n), 2, 4);
        assert!(e.supports_noise());
        assert!(e.set_noise(1.5, 1).is_err());
        e.set_noise(0.8, 7).unwrap();
        let w = vec![0.0f32; n * n];
        e.set_weights(&w).unwrap();
        // Zero weights normally freeze every state; with noise the
        // phases must move.
        let init = vec![1i32, 5, 9, 2, 6, 10];
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        assert_ne!(ph, init, "noise did not perturb frozen dynamics");
        // Turning noise off restores determinism.
        e.set_noise(0.0, 7).unwrap();
        let mut ph2 = init.clone();
        let mut st2 = vec![-1i32; 2];
        e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
        assert_eq!(ph2, init);
    }

    #[test]
    fn settle_batch_drives_chunks() {
        // Ferro 3-net: everything snaps to consensus quickly.
        let n = 3;
        let mut e = NativeEngine::new(NetworkConfig::paper(n), 4, 4);
        let w = [0., 8., 8., 8., 0., 8., 8., 8., 0.];
        e.set_weights(&w).unwrap();
        let mut rng = Rng::new(5);
        let mut phases: Vec<i32> = (0..4 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let settled = run_to_settle_batch(&mut e, &mut phases, 64).unwrap();
        for (b, s) in settled.iter().enumerate() {
            assert!(s.is_some(), "trial {b} did not settle");
            let ph = &phases[b * n..(b + 1) * n];
            assert!(
                ph.iter().all(|&x| x == ph[0]),
                "trial {b} no consensus: {ph:?}"
            );
        }
    }
}
