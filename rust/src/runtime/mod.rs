//! Execution engines for the batched functional dynamics.
//!
//! The production path loads the HLO-text artifacts that
//! `python/compile/aot.py` lowered from the JAX/Pallas model and runs
//! them on the PJRT CPU client ([`engine::PjrtEngine`]).  The native
//! engine ([`native::NativeEngine`]) implements the same [`ChunkEngine`]
//! trait on top of `onn::dynamics` — bit-exact with the artifacts — and
//! serves as the fallback when artifacts are absent plus as the
//! cross-validation oracle in the integration tests.  Two more fabrics
//! implement the trait: the row-sharded multi-device cluster
//! ([`sharded::ShardedEngine`], bit-exact with native) and the bit-true
//! emulated-hardware engine ([`rtl::RtlEngine`]) that runs the paper's
//! serial-MAC hybrid datapath cycle by cycle.

pub mod artifact;
pub mod cluster;
pub mod engine;
pub mod native;
pub mod rtl;
pub mod sharded;

use anyhow::{anyhow, Result};

use crate::onn::config::NetworkConfig;
use crate::onn::sparse::SparseWeights;
use crate::onn::weights::WeightMatrix;

/// Validate an f32 weight payload (length n^2, integer-valued entries
/// inside the config's signed range) and build the quantized matrix.
/// The native, sharded, and rtl engines all install weights through
/// this one gate, so every fabric accepts exactly the same matrices —
/// part of the native/sharded bit-exactness contract, and what puts
/// the rtl engine on the same quantized couplings a programmed FPGA
/// would hold.
pub(crate) fn checked_weights(cfg: &NetworkConfig, w_f32: &[f32]) -> Result<WeightMatrix> {
    let n = cfg.n;
    if w_f32.len() != n * n {
        return Err(anyhow!("weights len {} != {}", w_f32.len(), n * n));
    }
    let (lo, hi) = cfg.weight_range();
    let mut w = WeightMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            let v = w_f32[i * n + j];
            if v.fract() != 0.0 || v < lo as f32 || v > hi as f32 {
                return Err(anyhow!("weight [{i}][{j}] = {v} outside {lo}..={hi}"));
            }
            w.set(i, j, v as i8);
        }
    }
    Ok(w)
}

/// Validate a quantized CSR payload against the engine geometry: size
/// match, every stored value inside the config's signed weight range,
/// and symmetry (structure + values — the sparse kernels read rows as
/// columns).  The native and sharded fabrics both install sparse
/// weights through this one gate, mirroring [`checked_weights`] so the
/// two fabrics accept exactly the same matrices.
pub(crate) fn checked_sparse_weights(cfg: &NetworkConfig, w: &SparseWeights) -> Result<()> {
    if w.n() != cfg.n {
        return Err(anyhow!(
            "sparse weights are {0}x{0}, engine wants {1}x{1}",
            w.n(),
            cfg.n
        ));
    }
    let (lo, hi) = cfg.weight_range();
    for (i, j, v) in w.iter() {
        let v = v as i32;
        if v < lo || v > hi {
            return Err(anyhow!("weight [{i}][{j}] = {v} outside {lo}..={hi}"));
        }
    }
    if !w.is_symmetric() {
        return Err(anyhow!("sparse weights must be symmetric"));
    }
    Ok(())
}

/// Emulated hardware cost of a solve, as reported by an engine that
/// models the synthesized design cycle by cycle ([`rtl::RtlEngine`]).
/// Float fabrics report `None` from [`ChunkEngine::hardware_cost`] —
/// they have no hardware to meter.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareCost {
    /// Fast-clock cycles the run consumed, batch lanes serialized onto
    /// one device (each phase update costs N + sync-overhead cycles —
    /// the serial-MAC trade-off of paper section 3).
    pub fast_cycles: u64,
    /// Modeled logic frequency of the synthesized design in MHz
    /// (`fpga::timing::logic_frequency_hybrid`).
    pub f_logic_mhz: f64,
    /// Emulated wall-clock seconds: `fast_cycles / (f_logic_mhz * 1e6)`
    /// — the hardware time-to-solution the benchmarks compare against
    /// host-simulation time.
    pub emulated_s: f64,
    /// Whether the design fits the reference device (Zynq-7020) at this
    /// network size (`fpga::resources::hybrid`) — for a cluster fabric,
    /// whether *every device's shard* fits
    /// (`fpga::resources::hybrid_cluster_shard`).
    pub fits_device: bool,
    /// Mean utilization percent on the reference device (the paper's
    /// "total area used" aggregate); the widest shard's, on a cluster.
    pub area_percent: f64,
    /// Fast cycles of `fast_cycles` spent on cross-device phase
    /// all-gathers (`fpga::timing::cluster_sync_cycles`) — the sync-cost
    /// breakdown of an emulated multi-FPGA cluster.  0 on one device.
    pub sync_fast_cycles: u64,
}

/// A batched chunk executor: the contract of one AOT artifact call.
///
/// `phases` is `[batch * n]` row-major, `settled[b]` is the absolute
/// period index of trial b's first fixed point or -1, `period0` the
/// absolute period index at the chunk start.  Implementations advance
/// every trial by exactly `chunk_len()` periods.
///
/// Deliberately NOT `Send`: the PJRT handles are thread-affine, so the
/// coordinator constructs each engine *inside* its worker thread via an
/// [`EngineFactory`].
pub trait ChunkEngine {
    fn n(&self) -> usize;
    fn batch(&self) -> usize;
    fn chunk_len(&self) -> usize;
    /// Install the weight matrix used by subsequent `run_chunk` calls.
    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()>;
    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()>;
    /// Human-readable engine kind ("pjrt" / "native" / "sharded" /
    /// "rtl" / "rtl-cluster").
    fn kind(&self) -> &'static str;

    /// True when the engine implements the optional phase-noise hook
    /// used by the annealed solver (`solver::portfolio`).
    fn supports_noise(&self) -> bool {
        false
    }

    /// True when the engine can run a CSR sparse coupling fabric
    /// ([`onn::sparse::SparseWeights`]) — per-period work and weight
    /// memory scale with the nonzeros instead of n^2, bit-identical to
    /// the dense fabric on the same matrix (DESIGN_SOLVER.md §11).
    fn supports_sparse(&self) -> bool {
        false
    }

    /// Install a sparse (CSR) weight fabric used by subsequent
    /// `run_chunk` calls.  Like `set_weights` this replaces the whole
    /// fabric: lane blocks are cleared and any installed noise stream
    /// restarts on reinstall.  Engines without a sparse kernel (pjrt,
    /// rtl) refuse; callers fall back to the dense path.
    fn set_weights_sparse(&mut self, _w: &SparseWeights) -> Result<()> {
        Err(anyhow!("{} engine has no sparse fabric", self.kind()))
    }

    /// Set the phase-noise amplitude in `[0, 1]` for subsequent
    /// `run_chunk` calls (`0` restores deterministic dynamics); `seed`
    /// derives the kick stream so runs stay reproducible.  Engines whose
    /// dynamics are baked into an artifact (PJRT) do not support this.
    fn set_noise(&mut self, _amplitude: f64, _seed: u64) -> Result<()> {
        Err(anyhow!("{} engine has no phase-noise hook", self.kind()))
    }

    /// Cross-device synchronization rounds this engine has performed —
    /// the all-gather cost a multi-device fabric pays, one round per
    /// period per batch trial it has driven.  Single-device engines
    /// report 0.
    fn sync_rounds(&self) -> u64 {
        0
    }

    /// True when the engine can carve its batch dimension into *lane
    /// blocks* — contiguous lane ranges each programmed with their own
    /// coupling matrix and annealing kick stream, so one engine serves
    /// several small problems at once (the packed solve path of
    /// `solver::portfolio::solve_packed`; DESIGN_SOLVER.md §7).
    fn supports_lane_blocks(&self) -> bool {
        false
    }

    /// Program lanes `[lane0, lane0 + lanes)` as one block carrying its
    /// own full `n x n` coupling matrix (callers zero-pad problems
    /// smaller than the engine).  Re-programming a lane range (same
    /// `lane0`) replaces the weights AND discards any installed noise
    /// stream — a backfilled block must never inherit the retired
    /// problem's kick-stream tick.  While any block is programmed,
    /// `run_chunk` advances block lanes only; a global `set_weights`
    /// clears every block and returns the engine to whole-batch mode.
    /// The transition is one-way without it: programming any block
    /// invalidates prior whole-batch weights, so clearing the last
    /// block leaves the engine demanding a fresh `set_weights` rather
    /// than silently resuming a stale pre-packing problem.
    fn set_lane_block(&mut self, _lane0: usize, _lanes: usize, _w_f32: &[f32]) -> Result<()> {
        Err(anyhow!("{} engine has no lane-block support", self.kind()))
    }

    /// Install (or, with amplitude 0, clear) the annealing noise of the
    /// block starting at `lane0`, restarting its kick stream.  The
    /// stream is *block-local*: within the block the tick advances
    /// exactly as it would on a dedicated engine of `lanes` batch slots,
    /// so a lane block's trajectory is bit-exact with the same problem
    /// run solo at the same seed.
    fn set_lane_block_noise(&mut self, _lane0: usize, _amplitude: f64, _seed: u64) -> Result<()> {
        Err(anyhow!("{} engine has no lane-block support", self.kind()))
    }

    /// Retire the block starting at `lane0`: its lanes stop advancing
    /// and become free for a new block.
    fn clear_lane_block(&mut self, _lane0: usize) -> Result<()> {
        Err(anyhow!("{} engine has no lane-block support", self.kind()))
    }

    /// Optional hook: the caller has just (re)written lanes
    /// `[0, active)` of the phase buffer as fresh trials for a new wave,
    /// and any lanes at or beyond `active` are padding it will never
    /// read.  Engines with per-lane *hidden* state (the rtl engine's
    /// register files) need this: value-sniffing cannot tell a fresh
    /// init that happens to equal a lane's current phases from an
    /// untouched lane, so they reset the active lanes unconditionally —
    /// and stop advancing (and cost-metering) the padding.  Stateless
    /// fabrics ignore it: their dynamics are a pure function of the
    /// buffer, and padding lanes advancing is harmless.
    fn begin_wave(&mut self, _active: usize) -> Result<()> {
        Ok(())
    }

    /// Emulated hardware cost accumulated since the last `set_weights`,
    /// for engines that model the synthesized design cycle by cycle
    /// (the rtl engine).  Float fabrics return `None`.
    fn hardware_cost(&self) -> Option<HardwareCost> {
        None
    }

    /// Emulated hardware cost of the lane block anchored at `lane0`
    /// alone — the share of the fabric's metered work the block burned
    /// since it was programmed, so a packed solve's outcome can report
    /// per-problem hardware the way a solo run does.  `None` on float
    /// fabrics and on engines without such a block.
    fn lane_block_hardware_cost(&self, _lane0: usize) -> Option<HardwareCost> {
        None
    }

    /// Install (or, with `None`, remove) a solve-lifecycle trace sink
    /// (DESIGN_SOLVER.md §9).  Instrumented engines record one
    /// `engine_chunk` span per `run_chunk` call — host step time plus
    /// their own meters (sync-round latency on the sharded cluster,
    /// fast-cycle deltas on the rtl engine).  Recording only observes
    /// values the engine already computed; a traced run is bit-identical
    /// to an untraced one.  Engines without instrumentation ignore the
    /// sink.
    fn set_trace_sink(&mut self, _sink: Option<crate::telemetry::TraceSink>) {}
}

/// Constructs an engine inside a worker thread (PJRT handles are
/// thread-affine, so they cannot cross threads after construction).
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn ChunkEngine>> + Send>;
