//! Execution engines for the batched functional dynamics.
//!
//! The production path loads the HLO-text artifacts that
//! `python/compile/aot.py` lowered from the JAX/Pallas model and runs
//! them on the PJRT CPU client ([`engine::PjrtEngine`]).  The native
//! engine ([`native::NativeEngine`]) implements the same [`ChunkEngine`]
//! trait on top of `onn::dynamics` — bit-exact with the artifacts — and
//! serves as the fallback when artifacts are absent plus as the
//! cross-validation oracle in the integration tests.

pub mod artifact;
pub mod engine;
pub mod native;
pub mod sharded;

use anyhow::{anyhow, Result};

/// A batched chunk executor: the contract of one AOT artifact call.
///
/// `phases` is `[batch * n]` row-major, `settled[b]` is the absolute
/// period index of trial b's first fixed point or -1, `period0` the
/// absolute period index at the chunk start.  Implementations advance
/// every trial by exactly `chunk_len()` periods.
///
/// Deliberately NOT `Send`: the PJRT handles are thread-affine, so the
/// coordinator constructs each engine *inside* its worker thread via an
/// [`EngineFactory`].
pub trait ChunkEngine {
    fn n(&self) -> usize;
    fn batch(&self) -> usize;
    fn chunk_len(&self) -> usize;
    /// Install the weight matrix used by subsequent `run_chunk` calls.
    fn set_weights(&mut self, w_f32: &[f32]) -> Result<()>;
    fn run_chunk(&mut self, phases: &mut [i32], settled: &mut [i32], period0: i32) -> Result<()>;
    /// Human-readable engine kind ("pjrt" / "native").
    fn kind(&self) -> &'static str;

    /// True when the engine implements the optional phase-noise hook
    /// used by the annealed solver (`solver::portfolio`).
    fn supports_noise(&self) -> bool {
        false
    }

    /// Set the phase-noise amplitude in `[0, 1]` for subsequent
    /// `run_chunk` calls (`0` restores deterministic dynamics); `seed`
    /// derives the kick stream so runs stay reproducible.  Engines whose
    /// dynamics are baked into an artifact (PJRT) do not support this.
    fn set_noise(&mut self, _amplitude: f64, _seed: u64) -> Result<()> {
        Err(anyhow!("{} engine has no phase-noise hook", self.kind()))
    }
}

/// Constructs an engine inside a worker thread (PJRT handles are
/// thread-affine, so they cannot cross threads after construction).
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn ChunkEngine>> + Send>;
