//! Experiment harness: drivers that regenerate every table and figure of
//! the paper's evaluation section, plus the micro-benchmark timer used by
//! the `cargo bench` targets (criterion is unavailable offline).

pub mod ablation;
pub mod bench;
pub mod datasets;
pub mod report;
pub mod retrieval;
pub mod scaling;
pub mod solverbench;
