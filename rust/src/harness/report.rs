//! Paper-table/figure renderers: each function prints the same rows the
//! paper reports, from our measured data — plus the solver-path
//! trajectory renderer that puts `BENCH_solver.json` (replica-periods/
//! sec, packed serving, float-vs-rtl quality) next to the paper tables.

use crate::fpga::device::zynq7020;
use crate::fpga::resources::{estimate, max_oscillators};
use crate::harness::retrieval::CellStats;
use crate::harness::scaling::{
    fig12_balance, fig12_crossover, hybrid_sweep, recurrent_sweep, table5_rows, Sweep,
};
use crate::onn::config::NetworkConfig;
use crate::util::json::Json;
use crate::util::table::{ascii_loglog_plot, Table};

fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Table 1: element-count scaling orders (structural, from the config).
pub fn table1() -> String {
    let mut t = Table::new(
        "Table 1: Order of number of network elements for N oscillators",
        &["Element", "Order of scaling"],
    );
    t.row_strs(&["Oscillators", "N"]);
    t.row_strs(&["Coupling elements", "N^2"]);
    t.row_strs(&["Memory cells for weights", "N^2"]);
    t.render()
}

/// Table 2: state-of-the-art comparison — literature rows are cited
/// values; "This work" rows are measured from our models.
pub fn table2() -> String {
    let d = zynq7020();
    let ra_n = max_oscillators("recurrent", &d, 4, 5);
    let ha_n = max_oscillators("hybrid", &d, 4, 5);
    let mut t = Table::new(
        "Table 2: Comparison of oscillator-based architectures",
        &["Reference", "Oscillator", "Nodes", "Connection", "Connections", "Topology"],
    );
    t.row_strs(&["Abernot et al. [2-4,18]", "Digital", "35", "Digital", "1190", "All-to-all"]);
    t.row_strs(&["Jackson et al. [16]", "Digital*", "100", "Analog (res.)", "10000", "All-to-all"]);
    t.row_strs(&["Nikhar et al. [21]", "Digital P-bit", "1008", "Digital", "~9072", "Neighbor+cfg"]);
    t.row_strs(&["Bashar et al. [5]", "Digital SDE", "10000", "Digital", "80 (streamed)", "All-to-all str."]);
    t.row_strs(&["Liu et al. [17]", "Ring osc.", "1024", "Analog (cap.)", "~3716", "King's graph"]);
    t.row_strs(&["Moy et al. [20]", "Ring osc.", "1968", "Transm. gates", "~7342", "King's graph"]);
    t.row_strs(&["Wang et al. [30,31]", "Analog (LC)", "240", "Analog (res.)", "1200", "Chimera"]);
    t.row_strs(&["Vaidya et al. [29]", "Analog (Schmitt)", "4", "Analog (cap.)", "6", "All-to-all"]);
    t.row(&[
        "This work (recurrent)".to_string(),
        "Digital".to_string(),
        ra_n.to_string(),
        "Digital".to_string(),
        (ra_n * ra_n).to_string(),
        "All-to-all".to_string(),
    ]);
    t.row(&[
        "This work (hybrid)".to_string(),
        "Digital".to_string(),
        ha_n.to_string(),
        "Digital".to_string(),
        (ha_n * ha_n).to_string(),
        "All-to-all serialized".to_string(),
    ]);
    t.render()
}

/// Table 4: resource usage at the maximum feasible size per design.
pub fn table4() -> String {
    let d = zynq7020();
    let mut t = Table::new(
        "Table 4: Resource usage on Zynq-7020 at max oscillators (5 wb / 4 pb)",
        &["Design", "N", "Resource", "Usage [-]", "Usage [%]"],
    );
    for (name, arch) in [("Hybrid", "hybrid"), ("Recurrent", "recurrent")] {
        let n = max_oscillators(arch, &d, 4, 5);
        let r = estimate(arch, &NetworkConfig::paper(n), &d);
        let rows: [(&str, usize, usize); 4] = [
            ("LUT", r.luts, d.luts),
            ("FF", r.ffs, d.ffs),
            ("DSP Slices", r.dsps, d.dsps),
            ("Block RAM (36Kb)", r.bram36(), d.bram36()),
        ];
        for (res, used, cap) in rows {
            t.row(&[
                name.to_string(),
                n.to_string(),
                res.to_string(),
                used.to_string(),
                fmt_f(100.0 * used as f64 / cap as f64, 1),
            ]);
        }
    }
    t.render()
}

/// Table 5: max frequencies and max oscillator counts.
pub fn table5() -> String {
    let mut t = Table::new(
        "Table 5: Performance on Zynq-7020 at max oscillators (5 wb / 4 pb)",
        &["Design", "Statistic", "Value"],
    );
    for r in table5_rows() {
        t.row(&[
            r.arch.to_string(),
            "Max logic frequency".to_string(),
            format!("{:.0} MHz", r.f_logic_mhz),
        ]);
        t.row(&[
            r.arch.to_string(),
            "Oscillation frequency".to_string(),
            if r.f_osc_khz < 100.0 {
                format!("{:.1} kHz", r.f_osc_khz)
            } else {
                format!("{:.0} kHz", r.f_osc_khz)
            },
        ]);
        t.row(&[
            r.arch.to_string(),
            "Max #oscillators".to_string(),
            r.max_n.to_string(),
        ]);
    }
    t.render()
}

/// Tables 6 & 7 from collected cells: rows are (size, corruption) pairs;
/// RA cells are None where "patterns too large to implement" (paper).
pub struct RetrievalReport {
    /// (dataset name, corruption pct, RA stats, HA stats)
    pub cells: Vec<(String, f64, Option<CellStats>, CellStats)>,
}

impl RetrievalReport {
    pub fn table6(&self) -> String {
        let mut t = Table::new(
            "Table 6: Pattern retrieval accuracy (5 wb / 4 pb)",
            &["Pattern size", "Corrupted [%]", "Correct RA [%]", "Correct HA [%]"],
        );
        for (name, pct, ra, ha) in &self.cells {
            t.row(&[
                name.clone(),
                fmt_f(*pct, 0),
                ra.map(|s| fmt_f(s.accuracy_pct(), 1))
                    .unwrap_or_else(|| "too large for RA".to_string()),
                fmt_f(ha.accuracy_pct(), 1),
            ]);
        }
        t.render()
    }

    pub fn table7(&self) -> String {
        let mut t = Table::new(
            "Table 7: Mean time to settle [cycles], timeouts excluded",
            &["Pattern size", "Corrupted [%]", "Settle RA", "Settle HA"],
        );
        for (name, pct, ra, ha) in &self.cells {
            t.row(&[
                name.clone(),
                fmt_f(*pct, 0),
                ra.map(|s| fmt_f(s.mean_settle, 1))
                    .unwrap_or_else(|| "too large for RA".to_string()),
                fmt_f(ha.mean_settle, 1),
            ]);
        }
        t.render()
    }
}

/// Figure 9/10/11 rendering: data rows, fits, ASCII log-log plot.
pub fn figure_scaling(
    title: &str,
    ra: &Sweep,
    ha: &Sweep,
    metric: impl Fn(&crate::harness::scaling::DesignPoint) -> f64,
    ra_fit: crate::fpga::regression::Fit,
    ha_fit: crate::fpga::regression::Fit,
    paper_slopes: (f64, f64),
) -> String {
    let mut out = String::new();
    let ra_pts: Vec<(f64, f64)> = ra.points.iter().map(|p| (p.n as f64, metric(p))).collect();
    let ha_pts: Vec<(f64, f64)> = ha.points.iter().map(|p| (p.n as f64, metric(p))).collect();
    out.push_str(&ascii_loglog_plot(
        title,
        &[("recurrent", 'R', &ra_pts), ("hybrid", 'H', &ha_pts)],
        60,
        16,
    ));
    out.push_str(&format!(
        "  RA: slope {:.4} +- {:.4} (95% CI), R2 {:.4}   [paper: {:.2}]\n",
        ra_fit.slope, ra_fit.slope_ci95, ra_fit.r2, paper_slopes.0
    ));
    out.push_str(&format!(
        "  HA: slope {:.4} +- {:.4} (95% CI), R2 {:.4}   [paper: {:.2}]\n",
        ha_fit.slope, ha_fit.slope_ci95, ha_fit.r2, paper_slopes.1
    ));
    out
}

pub fn fig9() -> String {
    let (ra, ha) = (recurrent_sweep(), hybrid_sweep());
    let (fa, fb) = (ra.lut_fit(), ha.lut_fit());
    figure_scaling(
        "Figure 9: LUT usage vs network size (log-log)",
        &ra,
        &ha,
        |p| p.res.luts as f64,
        fa,
        fb,
        (2.08, 1.22),
    )
}

pub fn fig10() -> String {
    let (ra, ha) = (recurrent_sweep(), hybrid_sweep());
    let (fa, fb) = (ra.ff_fit(), ha.ff_fit());
    figure_scaling(
        "Figure 10: Flip-flop usage vs network size (log-log)",
        &ra,
        &ha,
        |p| p.res.ffs as f64,
        fa,
        fb,
        (2.39, 1.11),
    )
}

pub fn fig11() -> String {
    let (ra, ha) = (recurrent_sweep(), hybrid_sweep());
    let (fa, fb) = (ra.freq_fit(), ha.freq_fit());
    figure_scaling(
        "Figure 11: Oscillation frequency vs network size (log-log)",
        &ra,
        &ha,
        |p| p.f_osc_khz,
        fa,
        fb,
        (-0.46, -1.35),
    )
}

pub fn fig12() -> String {
    let sweep = hybrid_sweep();
    let bal = fig12_balance(&sweep);
    let mut t = Table::new(
        "Figure 12: Hybrid area utilization vs % of max oscillation frequency",
        &["N", "Area [%]", "Freq [% of max]"],
    );
    for b in &bal {
        t.row(&[b.n.to_string(), fmt_f(b.area_pct, 1), fmt_f(b.freq_pct, 1)]);
    }
    let mut out = t.render();
    match fig12_crossover(&bal) {
        Some((n, pct)) => out.push_str(&format!(
            "  Balance point: N ~ {n:.0} at ~{pct:.1}% (paper: N ~ 65 at ~15%)\n"
        )),
        None => out.push_str("  No crossover found in sweep range\n"),
    }
    out
}

/// Render a `BENCH_solver.json` document (written by `solve-bench`)
/// in the same table style as the paper reproduction: the solver
/// throughput trajectory (replica-periods/sec vs N per engine), the
/// packed-serving comparison, the float-native vs bit-true-RTL
/// quality/time-to-solution rows, the per-fabric latency percentiles,
/// the online-learning associative-memory rows (delta-reprogram vs
/// full-rebuild recalls/sec plus accuracy vs stored load), and the
/// per-chunk convergence trajectories.  Missing sections render as
/// absent — older trajectory files stay readable.
pub fn solver_bench_report(doc: &Json) -> String {
    let num = |row: &Json, key: &str| row.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let mut out = String::new();
    if let Some(stamp) = doc.get("recorded_unix_s").and_then(Json::as_f64) {
        out.push_str(&format!(
            "BENCH_solver.json (recorded at unix {stamp:.0})\n"
        ));
    }
    if let Some(points) = doc.get("points").and_then(Json::as_arr) {
        let mut t = Table::new(
            "Solver throughput: replica-periods/sec vs N per engine fabric",
            &["N", "Engine", "Shards", "Replicas", "Periods", "RP/s", "Sync rounds"],
        );
        for p in points {
            t.row(&[
                fmt_f(num(p, "n"), 0),
                p.get("engine").and_then(Json::as_str).unwrap_or("?").to_string(),
                fmt_f(num(p, "shards"), 0),
                fmt_f(num(p, "replicas"), 0),
                fmt_f(num(p, "periods"), 0),
                fmt_f(num(p, "replica_periods_per_sec"), 0),
                fmt_f(num(p, "sync_rounds"), 0),
            ]);
        }
        out.push_str(&t.render());
    }
    if let Some(packed) = doc.get("packed").and_then(Json::as_arr) {
        if !packed.is_empty() {
            let mut t = Table::new(
                "Packed serving: shared lane-block engine vs one-engine-per-request",
                &["Bucket N", "Problems", "Lanes", "Packed RP/s", "Unpacked RP/s", "Speedup"],
            );
            for p in packed {
                let (pr, ur) = (
                    num(p, "packed_replica_periods_per_sec"),
                    num(p, "unpacked_replica_periods_per_sec"),
                );
                t.row(&[
                    fmt_f(num(p, "bucket_n"), 0),
                    fmt_f(num(p, "problems"), 0),
                    fmt_f(num(p, "lanes"), 0),
                    fmt_f(pr, 0),
                    fmt_f(ur, 0),
                    fmt_f(if ur > 0.0 { pr / ur } else { 0.0 }, 2),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(rtl) = doc.get("rtl").and_then(Json::as_arr) {
        if !rtl.is_empty() {
            let mut t = Table::new(
                "Float-native vs bit-true RTL: quality and emulated time-to-solution",
                &[
                    "N",
                    "Native cut",
                    "RTL cut",
                    "Quant err",
                    "Periods",
                    "Fast cycles",
                    "f_logic [MHz]",
                    "Emulated [s]",
                    "Host sim [s]",
                ],
            );
            for p in rtl {
                t.row(&[
                    fmt_f(num(p, "n"), 0),
                    fmt_f(num(p, "native_cut"), 0),
                    fmt_f(num(p, "rtl_cut"), 0),
                    fmt_f(num(p, "quantization_error"), 4),
                    fmt_f(num(p, "periods"), 0),
                    fmt_f(num(p, "fast_cycles"), 0),
                    fmt_f(num(p, "f_logic_mhz"), 1),
                    format!("{:.3e}", num(p, "emulated_s")),
                    fmt_f(num(p, "host_s"), 3),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(rp) = doc.get("rtl_packed").and_then(Json::as_arr) {
        if !rp.is_empty() {
            let mut t = Table::new(
                "RTL lane-bank packing: shared emulated fabric vs one device per \
                 request (bit-exact, cycle parity asserted)",
                &[
                    "Bucket N",
                    "Problems",
                    "Lanes",
                    "Packed cycles",
                    "Solo cycles",
                    "Packed solves/s (emu)",
                    "Solo solves/s (emu)",
                    "Packed host [s]",
                    "Solo host [s]",
                ],
            );
            for p in rp {
                t.row(&[
                    fmt_f(num(p, "bucket_n"), 0),
                    fmt_f(num(p, "problems"), 0),
                    fmt_f(num(p, "lanes"), 0),
                    fmt_f(num(p, "packed_fast_cycles"), 0),
                    fmt_f(num(p, "solo_fast_cycles"), 0),
                    fmt_f(num(p, "packed_emulated_solves_per_sec"), 0),
                    fmt_f(num(p, "solo_emulated_solves_per_sec"), 0),
                    fmt_f(num(p, "packed_host_median_s"), 3),
                    fmt_f(num(p, "solo_host_median_s"), 3),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(rc) = doc.get("rtl_cluster").and_then(Json::as_arr) {
        if !rc.is_empty() {
            let mut t = Table::new(
                "Emulated multi-FPGA cluster: time-to-solution past the \
                 single-device fit (Table 5 anchor: max #oscillators per Zynq-7020)",
                &[
                    "N",
                    "Devices",
                    "1-dev fit",
                    "Fits/shard",
                    "Periods",
                    "Compute cycles",
                    "Sync cycles",
                    "f_logic [MHz]",
                    "Emulated [s]",
                    "Host sim [s]",
                ],
            );
            for p in rc {
                let fits = p.get("fits_device").and_then(Json::as_bool).unwrap_or(false);
                t.row(&[
                    fmt_f(num(p, "n"), 0),
                    fmt_f(num(p, "shards"), 0),
                    fmt_f(num(p, "single_device_fit"), 0),
                    (if fits { "yes" } else { "NO" }).to_string(),
                    fmt_f(num(p, "periods"), 0),
                    fmt_f(num(p, "compute_fast_cycles"), 0),
                    fmt_f(num(p, "sync_fast_cycles"), 0),
                    fmt_f(num(p, "f_logic_mhz"), 1),
                    format!("{:.3e}", num(p, "emulated_s")),
                    fmt_f(num(p, "host_s"), 3),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(lat) = doc.get("latency").and_then(Json::as_arr) {
        if !lat.is_empty() {
            let mut t = Table::new(
                "Solve latency percentiles per engine fabric (log-bucketed, \
                 upper-bound estimates)",
                &["Engine", "N", "Samples", "Mean [ms]", "p50 [ms]", "p90 [ms]", "p99 [ms]"],
            );
            for p in lat {
                t.row(&[
                    p.get("engine").and_then(Json::as_str).unwrap_or("?").to_string(),
                    fmt_f(num(p, "n"), 0),
                    fmt_f(num(p, "samples"), 0),
                    fmt_f(num(p, "mean_ms"), 3),
                    fmt_f(num(p, "p50_ms"), 3),
                    fmt_f(num(p, "p90_ms"), 3),
                    fmt_f(num(p, "p99_ms"), 3),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(sparse) = doc.get("sparse").and_then(Json::as_arr) {
        if !sparse.is_empty() {
            let mut t = Table::new(
                "Dense vs CSR coupling fabric (bit-exact work per row)",
                &[
                    "N",
                    "Density",
                    "nnz/row",
                    "Dense RP/s",
                    "CSR RP/s",
                    "Speedup",
                    "Dense B",
                    "CSR B",
                    "HW dense kHz",
                    "HW CSR kHz",
                ],
            );
            for p in sparse {
                t.row(&[
                    fmt_f(num(p, "n"), 0),
                    fmt_f(num(p, "density"), 3),
                    fmt_f(num(p, "avg_row_nnz"), 1),
                    fmt_f(num(p, "dense_replica_periods_per_sec"), 0),
                    fmt_f(num(p, "sparse_replica_periods_per_sec"), 0),
                    fmt_f(num(p, "sparse_speedup"), 2),
                    fmt_f(num(p, "dense_weight_bytes"), 0),
                    fmt_f(num(p, "sparse_weight_bytes"), 0),
                    fmt_f(num(p, "hw_dense_khz"), 2),
                    fmt_f(num(p, "hw_sparse_khz"), 2),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if let Some(assoc) = doc.get("associative").and_then(Json::as_arr) {
        if !assoc.is_empty() {
            let mut t = Table::new(
                "Online-learning associative memory: delta-reprogrammed warm \
                 recalls vs cold retrain+rebuild (bit-identity asserted)",
                &[
                    "N",
                    "Capacity",
                    "Engine",
                    "Shards",
                    "Recalls",
                    "Delta rec/s",
                    "Rebuild rec/s",
                    "Speedup",
                ],
            );
            for p in assoc {
                t.row(&[
                    fmt_f(num(p, "n"), 0),
                    fmt_f(num(p, "capacity"), 0),
                    p.get("engine").and_then(Json::as_str).unwrap_or("?").to_string(),
                    fmt_f(num(p, "shards"), 0),
                    fmt_f(num(p, "recalls"), 0),
                    fmt_f(num(p, "delta_recalls_per_sec"), 1),
                    fmt_f(num(p, "rebuild_recalls_per_sec"), 1),
                    fmt_f(num(p, "speedup"), 2),
                ]);
            }
            out.push_str(&t.render());
            let mut lt = Table::new(
                "Associative recall accuracy vs stored load (corrupted \
                 probes, match up to inversion)",
                &["N", "Stores", "Stored", "Trials", "Matched", "Accuracy"],
            );
            for p in assoc {
                let n = num(p, "n");
                for l in p.get("load").and_then(Json::as_arr).unwrap_or(&[]) {
                    lt.row(&[
                        fmt_f(n, 0),
                        fmt_f(num(l, "stores"), 0),
                        fmt_f(num(l, "patterns"), 0),
                        fmt_f(num(l, "trials"), 0),
                        fmt_f(num(l, "matched"), 0),
                        fmt_f(num(l, "accuracy"), 2),
                    ]);
                }
            }
            out.push_str(&lt.render());
        }
    }
    if let Some(conv) = doc.get("convergence").and_then(Json::as_arr) {
        if !conv.is_empty() {
            let mut t = Table::new(
                "Convergence traces: running best energy per anneal chunk",
                &["N", "Engine", "Waves", "Chunks", "First E", "Last E", "Final E", "Monotone"],
            );
            for p in conv {
                let traj = p.get("best_energy").and_then(Json::as_arr).unwrap_or(&[]);
                let first = traj.first().and_then(Json::as_f64).unwrap_or(0.0);
                let last = traj.last().and_then(Json::as_f64).unwrap_or(0.0);
                let mono = p.get("monotone").and_then(Json::as_bool).unwrap_or(false);
                let flag = if mono { "yes" } else { "NO" };
                t.row(&[
                    fmt_f(num(p, "n"), 0),
                    p.get("engine").and_then(Json::as_str).unwrap_or("?").to_string(),
                    fmt_f(num(p, "waves"), 0),
                    fmt_f(num(p, "chunks"), 0),
                    fmt_f(first, 2),
                    fmt_f(last, 2),
                    fmt_f(num(p, "final_energy"), 2),
                    flag.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
    }
    if out.is_empty() {
        out.push_str("BENCH_solver.json carries no recognizable sections\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        for s in [table1(), table2(), table4(), table5()] {
            assert!(s.lines().count() > 5, "{s}");
        }
    }

    #[test]
    fn figures_render_with_fits() {
        for s in [fig9(), fig10(), fig11()] {
            assert!(s.contains("slope"), "{s}");
            assert!(s.contains("paper"), "{s}");
        }
        assert!(fig12().contains("Balance point"));
    }

    #[test]
    fn table2_contains_this_work() {
        let s = table2();
        assert!(s.contains("This work (hybrid)"));
        assert!(s.contains("506") || s.contains("50"), "{s}");
    }

    #[test]
    fn solver_bench_report_renders_all_sections() {
        use crate::harness::solverbench::{
            bench_json, AssocLoadPoint, AssociativePoint, ConvergencePoint, LatencyPoint,
            PackedPoint, RtlClusterPoint, RtlPackedPoint, RtlPoint, SolverBench, SparsePoint,
            ThroughputPoint,
        };
        use crate::telemetry::LatencySummary;
        let pts = vec![ThroughputPoint {
            n: 8,
            replicas: 4,
            periods: 16,
            median_s: 0.5,
            replica_periods_per_sec: 128.0,
            engine: "native",
            shards: 1,
            sync_rounds: 0,
        }];
        let packed = vec![PackedPoint {
            bucket_n: 16,
            problems: 3,
            lanes: 12,
            packed_median_s: 0.2,
            unpacked_median_s: 0.3,
            packed_rps: 300.0,
            unpacked_rps: 200.0,
        }];
        let rtl = vec![RtlPoint {
            n: 8,
            engine: "rtl",
            native_cut: 11,
            rtl_cut: 10,
            native_energy: -7.0,
            rtl_energy: -6.0,
            quantization_error: 0.0,
            periods: 16,
            fast_cycles: 7_168,
            f_logic_mhz: 99.0,
            emulated_s: 7.2e-5,
            host_s: 0.01,
        }];
        let rtl_packed = vec![RtlPackedPoint {
            bucket_n: 16,
            problems: 4,
            lanes: 8,
            replicas: 2,
            total_periods: 128,
            packed_fast_cycles: 45_056,
            solo_fast_cycles: 45_056,
            packed_emulated_s: 4.5e-4,
            solo_emulated_s: 4.5e-4,
            packed_emulated_solves_per_sec: 8888.0,
            solo_emulated_solves_per_sec: 8888.0,
            packed_host_median_s: 0.04,
            solo_host_median_s: 0.11,
        }];
        let rtl_cluster = vec![RtlClusterPoint {
            n: 556,
            shards: 2,
            replicas: 2,
            periods: 8,
            single_device_fit: 506,
            fits_device: true,
            cut: 1234,
            fast_cycles: 300_000,
            sync_fast_cycles: 75_000,
            compute_fast_cycles: 225_000,
            f_logic_mhz: 100.0,
            emulated_s: 3.0e-3,
            host_s: 0.5,
        }];
        let bench = SolverBench {
            points: pts,
            packed,
            rtl,
            rtl_packed,
            rtl_cluster,
            latency: vec![LatencyPoint {
                engine: "native",
                n: 8,
                samples: 9,
                summary: LatencySummary {
                    count: 9,
                    mean_ms: 1.2,
                    p50_ms: 1.024,
                    p90_ms: 2.048,
                    p99_ms: 2.048,
                },
            }],
            convergence: vec![ConvergencePoint {
                n: 8,
                engine: "native",
                waves: 1,
                best_energy: vec![-3.0, -6.0],
                monotone: true,
                final_energy: -6.0,
            }],
            sparse: vec![SparsePoint {
                n: 512,
                edge_prob: 0.05,
                density: 0.05,
                avg_row_nnz: 25.6,
                replicas: 4,
                periods: 32,
                dense_median_s: 0.8,
                sparse_median_s: 0.1,
                dense_replica_periods_per_sec: 160.0,
                sparse_replica_periods_per_sec: 1280.0,
                sparse_speedup: 8.0,
                dense_weight_bytes: 1_310_720,
                sparse_weight_bytes: 30_000,
                hw_dense_khz: 6.0,
                hw_sparse_khz: 98.0,
            }],
            associative: vec![AssociativePoint {
                n: 32,
                capacity: 4,
                engine: "sharded",
                shards: 2,
                recalls: 4,
                delta_median_s: 0.01,
                rebuild_median_s: 0.05,
                delta_recalls_per_sec: 400.0,
                rebuild_recalls_per_sec: 80.0,
                speedup: 5.0,
                load: vec![AssocLoadPoint {
                    patterns: 4,
                    stores: 6,
                    trials: 4,
                    matched: 3,
                    accuracy: 0.75,
                }],
            }],
            ..Default::default()
        };
        let doc = bench_json(&bench, 42);
        let s = solver_bench_report(&doc);
        assert!(s.contains("Solver throughput"), "{s}");
        assert!(s.contains("Packed serving"), "{s}");
        assert!(s.contains("bit-true RTL"), "{s}");
        assert!(s.contains("RTL lane-bank packing"), "{s}");
        assert!(s.contains("Emulated multi-FPGA cluster"), "{s}");
        assert!(s.contains("Table 5 anchor"), "{s}");
        assert!(s.contains("506"), "single-device fit anchor renders: {s}");
        assert!(s.contains("75000"), "sync-cycle breakdown renders: {s}");
        assert!(s.contains("native"), "{s}");
        assert!(s.contains("latency percentiles"), "{s}");
        assert!(s.contains("p99 [ms]"), "{s}");
        assert!(s.contains("Convergence traces"), "{s}");
        assert!(s.contains("Dense vs CSR"), "{s}");
        assert!(s.contains("8.00"), "sparse speedup column renders: {s}");
        assert!(s.contains("Online-learning associative memory"), "{s}");
        assert!(s.contains("400.0"), "delta recalls/sec column renders: {s}");
        assert!(
            s.contains("accuracy vs stored load"),
            "load-sweep table renders: {s}"
        );
        assert!(s.contains("0.75"), "accuracy column renders: {s}");
        assert!(s.contains("yes"), "monotone flag renders: {s}");
        // Unrelated documents degrade gracefully instead of panicking.
        let s = solver_bench_report(&Json::obj(vec![("x", Json::num(1.0))]));
        assert!(s.contains("no recognizable sections"), "{s}");
    }

    #[test]
    fn retrieval_report_renders_ra_gaps() {
        let cell = CellStats {
            trials: 10,
            correct: 9,
            timeouts: 0,
            mean_settle: 12.0,
        };
        let rep = RetrievalReport {
            cells: vec![
                ("3x3".into(), 10.0, Some(cell), cell),
                ("22x22".into(), 10.0, None, cell),
            ],
        };
        let t6 = rep.table6();
        assert!(t6.contains("90.0"));
        assert!(t6.contains("too large for RA"));
        assert!(rep.table7().contains("12.0"));
    }
}
