//! Minimal benchmark timer (criterion substitute): warmup, repeated
//! timed runs, robust summary statistics, and a one-line report format
//! shared by all `cargo bench` targets.

use std::time::{Duration, Instant};

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10.3} ms   mean {:>10.3} ms   sd {:>8.3} ms   min {:>10.3} ms   ({} iters)",
            self.name,
            self.median.as_secs_f64() * 1e3,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats::mean(&samples)),
        median: Duration::from_secs_f64(stats::median(&samples)),
        stddev: Duration::from_secs_f64(stats::stddev(&samples)),
        min: Duration::from_secs_f64(stats::min(&samples)),
    }
}

/// Run-and-print convenience for bench mains.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    println!("{}", r.report());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0;
        let r = bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 10);
    }

    #[test]
    fn bench_measures_sleep() {
        let r = bench("sleep", 0, 3, || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(r.median >= Duration::from_millis(2));
        assert!(r.median < Duration::from_millis(60));
    }
}
