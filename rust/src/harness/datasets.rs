//! Benchmark dataset construction: the paper's five pattern datasets,
//! DO-I trained and quantized to the paper precision (section 4.3).

use crate::onn::config::NetworkConfig;
use crate::onn::learning::{diederich_opper_i, is_fixed_point};
use crate::onn::patterns::{paper_datasets, Dataset};
use crate::onn::weights::WeightMatrix;

/// A ready-to-run benchmark network: dataset + trained quantized weights.
#[derive(Debug, Clone)]
pub struct BenchmarkSet {
    pub dataset: Dataset,
    pub cfg: NetworkConfig,
    pub weights: WeightMatrix,
    pub doi_epochs: usize,
}

/// Train one dataset with DO-I (margin 0.5) and quantize to 5wb.
pub fn build(dataset: Dataset) -> BenchmarkSet {
    let cfg = NetworkConfig::paper(dataset.n());
    let pats: Vec<Vec<i8>> = dataset.patterns.iter().map(|p| p.spins.clone()).collect();
    let res = diederich_opper_i(&pats, 0.5, 1000);
    assert!(
        res.converged,
        "DO-I failed to converge on dataset {}",
        dataset.name
    );
    let weights = WeightMatrix::quantize(&res.weights, cfg.n, &cfg);
    BenchmarkSet {
        dataset,
        cfg,
        weights,
        doi_epochs: res.epochs,
    }
}

/// All five paper datasets, trained.
pub fn paper_benchmarks() -> Vec<BenchmarkSet> {
    paper_datasets().into_iter().map(build).collect()
}

/// One dataset by name ("3x3", "5x4", "7x6", "10x10", "22x22").
pub fn benchmark_by_name(name: &str) -> Option<BenchmarkSet> {
    paper_datasets()
        .into_iter()
        .find(|d| d.name == name)
        .map(build)
}

/// Diagnostic: how many stored patterns survive quantization as fixed
/// points (should be all of them).
pub fn stable_pattern_count(set: &BenchmarkSet) -> usize {
    set.dataset
        .patterns
        .iter()
        .filter(|p| is_fixed_point(&set.weights, &p.spins))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_benchmarks_train_and_stabilize() {
        for name in ["3x3", "5x4", "7x6"] {
            let set = benchmark_by_name(name).unwrap();
            assert_eq!(
                stable_pattern_count(&set),
                set.dataset.patterns.len(),
                "dataset {name}: stored patterns unstable after quantization"
            );
            assert!(set.weights.max_abs() <= 15);
        }
    }

    #[test]
    fn large_benchmark_trains() {
        let set = benchmark_by_name("22x22").unwrap();
        assert_eq!(set.cfg.n, 484);
        assert_eq!(stable_pattern_count(&set), 5);
    }
}
