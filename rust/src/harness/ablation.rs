//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! * **Precision** — the paper fixes 5 weight bits / 4 phase bits
//!   ("determined to be sufficient" by prior work); this sweep measures
//!   both sides of that choice: device capacity (max N) and retrieval
//!   accuracy as precision varies.
//! * **Storage capacity** — DO-I vs plain Hebbian learning: how many
//!   patterns a fixed-size network can store before retrieval collapses
//!   (the reason the paper trains with DO-I at all).

use crate::fpga::device::zynq7020;
use crate::fpga::resources::max_oscillators;
use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::FunctionalEngine;
use crate::onn::learning::{diederich_opper_i, hebbian};
use crate::onn::patterns::dataset_by_name;
use crate::onn::phase::{spin_to_phase, state_to_spins};
use crate::onn::weights::WeightMatrix;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// One precision design point.
#[derive(Debug, Clone, Copy)]
pub struct PrecisionPoint {
    pub weight_bits: u32,
    pub phase_bits: u32,
    /// Hybrid-architecture capacity on the Zynq-7020.
    pub max_n_hybrid: usize,
    /// Recurrent-architecture capacity.
    pub max_n_recurrent: usize,
    /// Retrieval accuracy (%) on the 7x6 dataset at 25% corruption.
    pub accuracy_pct: f64,
}

/// Sweep precision: capacity from the resource model, accuracy from the
/// functional engine on the 7x6 dataset (25% corruption).
pub fn precision_sweep(trials: usize, seed: u64) -> Vec<PrecisionPoint> {
    let d = zynq7020();
    let mut out = Vec::new();
    for (wb, pb) in [(3u32, 4u32), (4, 4), (5, 4), (6, 4), (5, 3), (5, 5), (8, 4)] {
        let max_h = max_oscillators("hybrid", &d, pb, wb);
        let max_r = max_oscillators("recurrent", &d, pb, wb);
        let accuracy_pct = precision_accuracy(wb, pb, trials, seed);
        out.push(PrecisionPoint {
            weight_bits: wb,
            phase_bits: pb,
            max_n_hybrid: max_h,
            max_n_recurrent: max_r,
            accuracy_pct,
        });
    }
    out
}

fn precision_accuracy(wb: u32, pb: u32, trials: usize, seed: u64) -> f64 {
    let ds = dataset_by_name("7x6").expect("dataset");
    let cfg = NetworkConfig {
        n: ds.n(),
        phase_bits: pb,
        weight_bits: wb,
    };
    let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
    let res = diederich_opper_i(&pats, 0.5, 1000);
    let w = WeightMatrix::quantize(&res.weights, cfg.n, &cfg);
    let mut eng = FunctionalEngine::new(cfg, w);
    let p = cfg.period() as i32;
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    let mut total = 0usize;
    for (pi, target) in ds.patterns.iter().enumerate() {
        for t in 0..trials {
            let mut trng = rng.fork((pi * 1000 + t) as u64);
            let corrupted = target.corrupt(target.corruption_count(25.0), &mut trng);
            let init: Vec<i32> = corrupted
                .spins
                .iter()
                .map(|&s| spin_to_phase(s, p))
                .collect();
            let out = eng.run_to_settle(&init, 256);
            if out.settled.is_some()
                && target.matches_up_to_inversion(&state_to_spins(&out.phases, p))
            {
                correct += 1;
            }
            total += 1;
        }
    }
    100.0 * correct as f64 / total as f64
}

pub fn precision_table(points: &[PrecisionPoint]) -> String {
    let mut t = Table::new(
        "Ablation: numerical precision vs capacity and accuracy (7x6 @ 25%)",
        &["wb", "pb", "max N hybrid", "max N recurrent", "accuracy [%]"],
    );
    for p in points {
        t.row(&[
            p.weight_bits.to_string(),
            p.phase_bits.to_string(),
            p.max_n_hybrid.to_string(),
            p.max_n_recurrent.to_string(),
            format!("{:.1}", p.accuracy_pct),
        ]);
    }
    t.render()
}

/// Storage-capacity curve: accuracy retrieving one stored pattern (10%
/// corruption) as the number of stored random patterns grows.
#[derive(Debug, Clone, Copy)]
pub struct CapacityPoint {
    pub patterns: usize,
    pub accuracy_doi: f64,
    pub accuracy_hebbian: f64,
}

pub fn capacity_sweep(n: usize, trials: usize, seed: u64) -> Vec<CapacityPoint> {
    let cfg = NetworkConfig::paper(n);
    let loads: Vec<usize> = [1, 2, 3, 5, 8, 12, 16]
        .iter()
        .copied()
        .filter(|&m| m < n)
        .collect();
    let mut rng = Rng::new(seed);
    loads
        .into_iter()
        .map(|m| {
            let pats: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..n).map(|_| rng.spin()).collect())
                .collect();
            let doi = diederich_opper_i(&pats, 0.5, 500).weights;
            let heb = hebbian(&pats);
            let acc = |master: &[f32], rng: &mut Rng| {
                let w = WeightMatrix::quantize(master, n, &cfg);
                let mut eng = FunctionalEngine::new(cfg, w);
                let mut ok = 0usize;
                for t in 0..trials {
                    let pat = &pats[t % m];
                    let flips = (n as f64 * 0.10 + 0.5) as usize;
                    let mut spins = pat.clone();
                    for idx in rng.choose_distinct(n, flips) {
                        spins[idx] = -spins[idx];
                    }
                    let init: Vec<i32> =
                        spins.iter().map(|&s| spin_to_phase(s, 16)).collect();
                    let out = eng.run_to_settle(&init, 128);
                    if out.settled.is_some() {
                        let got = state_to_spins(&out.phases, 16);
                        let rel: Vec<i8> = pat.iter().map(|&s| s * pat[0]).collect();
                        if got == rel {
                            ok += 1;
                        }
                    }
                }
                100.0 * ok as f64 / trials as f64
            };
            CapacityPoint {
                patterns: m,
                accuracy_doi: acc(&doi, &mut rng),
                accuracy_hebbian: acc(&heb, &mut rng),
            }
        })
        .collect()
}

pub fn capacity_table(n: usize, points: &[CapacityPoint]) -> String {
    let mut t = Table::new(
        &format!("Ablation: storage capacity at N={n} (10% corruption)"),
        &["stored patterns", "DO-I accuracy [%]", "Hebbian accuracy [%]"],
    );
    for p in points {
        t.row(&[
            p.patterns.to_string(),
            format!("{:.1}", p.accuracy_doi),
            format!("{:.1}", p.accuracy_hebbian),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_doi_beats_hebbian_at_high_load() {
        let pts = capacity_sweep(20, 20, 3);
        // At light load both work...
        let light = &pts[0];
        assert!(light.accuracy_doi >= 80.0);
        // ...at heavy load DO-I must hold up markedly better (its whole
        // reason for existing here).
        let heavy = pts.iter().find(|p| p.patterns >= 8).unwrap();
        assert!(
            heavy.accuracy_doi >= heavy.accuracy_hebbian,
            "DO-I {:.1} vs Hebbian {:.1} at {} patterns",
            heavy.accuracy_doi,
            heavy.accuracy_hebbian,
            heavy.patterns
        );
    }

    #[test]
    fn precision_capacity_monotone_in_weight_bits() {
        // More weight bits -> more memory/logic per connection -> fewer
        // oscillators fit.
        let d = zynq7020();
        let n3 = max_oscillators("hybrid", &d, 4, 3);
        let n5 = max_oscillators("hybrid", &d, 4, 5);
        let n8 = max_oscillators("hybrid", &d, 4, 8);
        assert!(n3 >= n5 && n5 >= n8, "{n3} {n5} {n8}");
    }

    #[test]
    fn precision_tables_render() {
        let pts = vec![PrecisionPoint {
            weight_bits: 5,
            phase_bits: 4,
            max_n_hybrid: 506,
            max_n_recurrent: 49,
            accuracy_pct: 77.0,
        }];
        let s = precision_table(&pts);
        assert!(s.contains("506"));
        let c = capacity_table(20, &capacity_sweep(12, 5, 1));
        assert!(c.contains("DO-I"));
    }
}
