//! Pattern-retrieval sweep driver: regenerates Tables 6 and 7.
//!
//! For each (dataset, corruption level): corrupt each stored pattern
//! `trials` times with distinct seeds, run every trial to a fixed point
//! on the selected engine, and score retrieval accuracy (exact match up
//! to global inversion) plus mean time-to-settle excluding timeouts —
//! exactly the paper's methodology (section 4.3).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::job::RetrievalRequest;
use crate::coordinator::server::{Coordinator, EngineKind, PoolSpec};
use crate::harness::datasets::BenchmarkSet;
use crate::onn::phase::{spin_to_phase, state_to_spins};
use crate::rtl::hybrid::HybridOnn;
use crate::rtl::recurrent::RecurrentOnn;
use crate::rtl::RtlSim;
use crate::util::rng::Rng;

/// Which implementation executes the trials.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Functional engine through the coordinator (native worker).
    Native,
    /// AOT artifact through the coordinator (PJRT worker).
    Pjrt,
    /// Cycle-accurate recurrent-architecture simulator.
    RtlRecurrent,
    /// Cycle-accurate hybrid-architecture simulator.
    RtlHybrid,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "native" => Some(Engine::Native),
            "pjrt" => Some(Engine::Pjrt),
            "rtl-recurrent" => Some(Engine::RtlRecurrent),
            "rtl-hybrid" => Some(Engine::RtlHybrid),
            _ => None,
        }
    }
}

/// Statistics of one (dataset, corruption) table cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellStats {
    pub trials: usize,
    pub correct: usize,
    pub timeouts: usize,
    /// Mean periods to settle, timeouts excluded (paper Table 7).
    pub mean_settle: f64,
}

impl CellStats {
    pub fn accuracy_pct(&self) -> f64 {
        100.0 * self.correct as f64 / self.trials as f64
    }
}

pub const MAX_PERIODS: usize = 256;

/// Run one table cell on an RTL simulator (parallel over trials).
fn run_cell_rtl(
    set: &BenchmarkSet,
    corruption_pct: f64,
    trials: usize,
    seed: u64,
    recurrent: bool,
) -> CellStats {
    let p = set.cfg.period() as i32;
    let n_threads = std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(4)
        .min(trials.max(1));
    let jobs: Vec<(usize, usize)> = {
        // (pattern index, trial index) pairs, round-robin over patterns
        let mut v = Vec::new();
        for pi in 0..set.dataset.patterns.len() {
            for t in 0..trials {
                v.push((pi, t));
            }
        }
        v
    };
    let chunk = jobs.len().div_ceil(n_threads);
    let results: Vec<(bool, Option<usize>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for part in jobs.chunks(chunk) {
            let set = &set;
            handles.push(scope.spawn(move || {
                let mut out = Vec::with_capacity(part.len());
                let mut ra = recurrent
                    .then(|| RecurrentOnn::new(set.cfg, set.weights.clone()));
                let mut ha = (!recurrent)
                    .then(|| HybridOnn::new(set.cfg, set.weights.clone()));
                for &(pi, t) in part {
                    let target = &set.dataset.patterns[pi];
                    let mut rng =
                        Rng::new(seed ^ (pi as u64) << 32 ^ t as u64);
                    let flips = target.corruption_count(corruption_pct);
                    let corrupted = target.corrupt(flips, &mut rng);
                    let phases: Vec<i32> = corrupted
                        .spins
                        .iter()
                        .map(|&s| spin_to_phase(s, p))
                        .collect();
                    let outcome = if let Some(sim) = ra.as_mut() {
                        sim.set_phases(&phases);
                        sim.run_to_settle(MAX_PERIODS)
                    } else {
                        let sim = ha.as_mut().unwrap();
                        sim.set_phases(&phases);
                        sim.run_to_settle(MAX_PERIODS)
                    };
                    let ok = outcome.settled.is_some()
                        && target
                            .matches_up_to_inversion(&state_to_spins(&outcome.phases, p));
                    out.push((ok, outcome.settled));
                }
                out
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("rtl worker panicked"))
            .collect()
    });
    summarize(&results)
}

/// Run one table cell through the coordinator (native or PJRT workers).
fn run_cell_service(
    set: &BenchmarkSet,
    corruption_pct: f64,
    trials: usize,
    seed: u64,
    kind: EngineKind,
) -> Result<CellStats> {
    let p = set.cfg.period() as i32;
    // Sweep cells are throughput-bound: run several engine workers per
    // pool (native engines are cheap; PJRT workers each own a client).
    let workers = std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(4)
        .clamp(1, 8);
    let spec = PoolSpec::new(set.cfg, set.weights.clone(), kind).with_workers(workers);
    let coord = Arc::new(Coordinator::start(
        vec![spec],
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_periods_cap: MAX_PERIODS,
        },
    )?);

    // Submit everything, then collect: keeps the batcher's windows full
    // (this is what the dynamic batching is for).
    let mut pending = Vec::new();
    let mut targets = Vec::new();
    for (pi, target) in set.dataset.patterns.iter().enumerate() {
        for t in 0..trials {
            let mut rng = Rng::new(seed ^ (pi as u64) << 32 ^ t as u64);
            let flips = target.corruption_count(corruption_pct);
            let corrupted = target.corrupt(flips, &mut rng);
            let req = RetrievalRequest::from_pattern(
                coord.next_id(),
                &corrupted,
                p,
                MAX_PERIODS,
            );
            pending.push(coord.router.submit(req)?);
            targets.push(pi);
        }
    }
    let mut results = Vec::with_capacity(pending.len());
    for (rx, pi) in pending.into_iter().zip(targets) {
        let res = rx.recv()?;
        let target = &set.dataset.patterns[pi];
        let ok = res.settled.is_some()
            && target.matches_up_to_inversion(&state_to_spins(&res.phases, p));
        results.push((ok, res.settled));
    }
    Arc::try_unwrap(coord)
        .map_err(|_| anyhow::anyhow!("coordinator still referenced"))?
        .shutdown()?;
    Ok(summarize(&results))
}

fn summarize(results: &[(bool, Option<usize>)]) -> CellStats {
    let trials = results.len();
    let correct = results.iter().filter(|(ok, _)| *ok).count();
    let settles: Vec<f64> = results
        .iter()
        .filter_map(|(_, s)| s.map(|x| x as f64))
        .collect();
    CellStats {
        trials,
        correct,
        timeouts: trials - settles.len(),
        mean_settle: crate::util::stats::mean(&settles),
    }
}

/// Run one (dataset, corruption) cell on the chosen engine.
pub fn run_cell(
    set: &BenchmarkSet,
    corruption_pct: f64,
    trials: usize,
    seed: u64,
    engine: Engine,
) -> Result<CellStats> {
    match engine {
        Engine::RtlRecurrent => Ok(run_cell_rtl(set, corruption_pct, trials, seed, true)),
        Engine::RtlHybrid => Ok(run_cell_rtl(set, corruption_pct, trials, seed, false)),
        Engine::Native => run_cell_service(set, corruption_pct, trials, seed, EngineKind::Native),
        Engine::Pjrt => run_cell_service(set, corruption_pct, trials, seed, EngineKind::Pjrt),
    }
}

/// The paper's three corruption levels.
pub const CORRUPTION_LEVELS: [f64; 3] = [10.0, 25.0, 50.0];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::datasets::benchmark_by_name;

    #[test]
    fn native_cell_retrieves_3x3() {
        let set = benchmark_by_name("3x3").unwrap();
        let stats = run_cell(&set, 10.0, 20, 42, Engine::Native).unwrap();
        assert_eq!(stats.trials, 40); // 2 patterns x 20
        assert!(
            stats.accuracy_pct() >= 90.0,
            "accuracy {:.1}",
            stats.accuracy_pct()
        );
        assert!(stats.mean_settle < 64.0);
    }

    #[test]
    fn rtl_cells_agree_with_native_on_easy_case() {
        let set = benchmark_by_name("3x3").unwrap();
        let a = run_cell(&set, 10.0, 15, 7, Engine::Native).unwrap();
        let b = run_cell(&set, 10.0, 15, 7, Engine::RtlRecurrent).unwrap();
        let c = run_cell(&set, 10.0, 15, 7, Engine::RtlHybrid).unwrap();
        for (name, s) in [("native", &a), ("rtl-ra", &b), ("rtl-ha", &c)] {
            assert!(
                s.accuracy_pct() >= 85.0,
                "{name} accuracy {:.1}",
                s.accuracy_pct()
            );
        }
    }

    #[test]
    fn accuracy_degrades_with_corruption() {
        let set = benchmark_by_name("5x4").unwrap();
        let lo = run_cell(&set, 10.0, 20, 3, Engine::Native).unwrap();
        let hi = run_cell(&set, 50.0, 20, 3, Engine::Native).unwrap();
        assert!(
            lo.accuracy_pct() >= hi.accuracy_pct(),
            "{} vs {}",
            lo.accuracy_pct(),
            hi.accuracy_pct()
        );
    }

    #[test]
    fn engine_parse() {
        assert_eq!(Engine::parse("pjrt"), Some(Engine::Pjrt));
        assert_eq!(Engine::parse("rtl-hybrid"), Some(Engine::RtlHybrid));
        assert_eq!(Engine::parse("bogus"), None);
    }
}
