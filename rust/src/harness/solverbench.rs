//! Solver-path harness: ONN annealed portfolio vs simulated annealing at
//! matched effort on G(n, p) random graphs, plus the solver throughput
//! sweep recorded to `BENCH_solver.json` so future PRs have a perf
//! trajectory for this path.
//!
//! Effort accounting: one ONN period updates all `n` oscillators of
//! every replica, one SA sweep updates `n` spins once — so equal
//! elementary spin updates means `sa_sweeps = replicas * max_periods`.
//! That is the *hardware-hostile* accounting (the batched engine does
//! replicas in parallel, SA gets the same updates sequentially); the
//! portfolio has to win on search quality, not on bookkeeping.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::assoc::{capacity_for, LearningRule, MemorySpace};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::server::{serve_tcp, Coordinator, SolverPoolConfig};
use crate::coordinator::stream::serve_evented;
use crate::fpga::device::zynq7020;
use crate::fpga::resources::max_oscillators;
use crate::fpga::timing::{oscillation_frequency_hybrid, oscillation_frequency_hybrid_sparse};
use crate::harness::bench;
use crate::onn::config::NetworkConfig;
use crate::onn::learning::hebbian;
use crate::onn::patterns::spins_match_up_to_inversion;
use crate::onn::phase::{spin_to_phase, state_to_spins};
use crate::onn::weights::WeightMatrix;
use crate::runtime::rtl::RtlEngine;
use crate::runtime::ChunkEngine;
use crate::solver::anneal::Schedule;
use crate::solver::graph::Graph;
use crate::solver::portfolio::{
    build_engine_cfg, drive_retrieval, solve_native, solve_packed, solve_packed_native,
    solve_with, solve_with_trace, wants_sparse, EngineSelect, PortfolioParams, DEFAULT_CHUNK,
    MAX_WAVE_REPLICAS,
};
use crate::solver::problem::IsingProblem;
use crate::solver::reductions::{coloring, max_cut, max_cut_sparse};
use crate::solver::sa;
use crate::telemetry::{sink, LatencyHistogram, LatencySummary, TraceEvent, DEFAULT_TRACE_CAP};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;

/// One instance's head-to-head outcome.
#[derive(Debug, Clone)]
pub struct QualityRow {
    pub instance: usize,
    pub edges: usize,
    pub onn_cut: i64,
    pub sa_cut: i64,
}

/// The quality comparison over a batch of random instances.
#[derive(Debug, Clone)]
pub struct QualityReport {
    pub n: usize,
    pub edge_prob: f64,
    pub replicas: usize,
    pub max_periods: usize,
    pub sa_sweeps: usize,
    pub rows: Vec<QualityRow>,
}

impl QualityReport {
    pub fn onn_mean(&self) -> f64 {
        stats::mean(&self.rows.iter().map(|r| r.onn_cut as f64).collect::<Vec<_>>())
    }

    pub fn sa_mean(&self) -> f64 {
        stats::mean(&self.rows.iter().map(|r| r.sa_cut as f64).collect::<Vec<_>>())
    }

    /// ONN mean / SA mean (1.0 = parity).
    pub fn ratio(&self) -> f64 {
        let sa = self.sa_mean();
        if sa == 0.0 {
            1.0
        } else {
            self.onn_mean() / sa
        }
    }

    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "max-cut on G(n={}, p={}) — ONN portfolio ({} replicas x {} periods) \
             vs SA ({} sweeps, equal spin updates)\n",
            self.n, self.edge_prob, self.replicas, self.max_periods, self.sa_sweeps
        ));
        out.push_str(&format!(
            "  {:>8} {:>7} {:>9} {:>9} {:>8}\n",
            "instance", "edges", "ONN cut", "SA cut", "ratio"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "  {:>8} {:>7} {:>9} {:>9} {:>8.3}\n",
                r.instance,
                r.edges,
                r.onn_cut,
                r.sa_cut,
                r.onn_cut as f64 / (r.sa_cut.max(1)) as f64
            ));
        }
        out.push_str(&format!(
            "  mean: ONN {:.2} vs SA {:.2}  ratio {:.4}  -> {}\n",
            self.onn_mean(),
            self.sa_mean(),
            self.ratio(),
            if self.ratio() >= 0.98 {
                "MATCHES-OR-BEATS"
            } else {
                "BEHIND"
            }
        ));
        out
    }
}

/// Head-to-head quality on `instances` random graphs.
pub fn quality_vs_sa(
    n: usize,
    edge_prob: f64,
    instances: usize,
    replicas: usize,
    max_periods: usize,
    seed: u64,
) -> QualityReport {
    let sa_sweeps = replicas * max_periods;
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(instances);
    for inst in 0..instances {
        let g = Graph::random(n, edge_prob, &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas,
            max_periods,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed: seed.wrapping_add(1 + inst as u64),
            ..Default::default()
        };
        let onn = solve_native(&problem, &params).expect("portfolio on valid reduction");
        let sa = sa::anneal(&problem, sa_sweeps, seed.wrapping_add(1000 + inst as u64));
        rows.push(QualityRow {
            instance: inst,
            edges: g.edges.len(),
            onn_cut: g.cut_value(&onn.best_spins),
            sa_cut: g.cut_value(&sa.spins),
        });
    }
    QualityReport {
        n,
        edge_prob,
        replicas,
        max_periods,
        sa_sweeps,
        rows,
    }
}

/// One throughput measurement: replicas x periods of annealed portfolio
/// work per second on one engine fabric at size `n`.
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    pub n: usize,
    pub replicas: usize,
    pub periods: usize,
    pub median_s: f64,
    pub replica_periods_per_sec: f64,
    /// Engine kind that ran this row ("native" / "sharded").
    pub engine: &'static str,
    /// Shard workers (1 on the native rows).
    pub shards: usize,
    /// All-gather sync rounds of the probe run (0 on native rows) — the
    /// distributed-coordination cost the row's rate already pays for.
    pub sync_rounds: u64,
}

/// Measure solver throughput across network sizes with the shared bench
/// timer (`harness::bench`); `shards <= 1` rates the native engine, a
/// larger count rates the row-sharded cluster on identical work (the
/// trajectories are bit-exact, so rows differ only in where time goes:
/// compute vs per-period all-gather synchronization).
pub fn throughput_sweep(
    sizes: &[usize],
    replicas: usize,
    periods: usize,
    seed: u64,
    shards: usize,
) -> Vec<ThroughputPoint> {
    let select = if shards <= 1 {
        EngineSelect::Native
    } else {
        EngineSelect::Sharded { shards }
    };
    let mut points = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let mut rng = Rng::new(seed.wrapping_add(n as u64));
        let g = Graph::random(n, (8.0 / n as f64).min(0.5), &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed,
            plateau_chunks: 0, // disable the stall exit for steadier work
            ..Default::default()
        };
        // The run is deterministic per (params, seed), so one probe run
        // reports the periods every timed iteration will actually drive
        // (the all-settled early exit may stop short of the nominal
        // budget; rating nominal work would inflate the throughput).
        let probe = solve_with(&problem, &params, select).expect("portfolio probe");
        let actual_periods = probe.periods;
        let r = bench::bench(&format!("solver/portfolio_{}_n{n}", probe.engine), 1, 3, || {
            let out = solve_with(&problem, &params, select).expect("portfolio");
            assert_eq!(out.replicas, replicas);
        });
        let median_s = r.median.as_secs_f64();
        points.push(ThroughputPoint {
            n,
            replicas,
            periods: actual_periods,
            median_s,
            replica_periods_per_sec: (replicas * actual_periods) as f64
                / median_s.max(1e-12),
            engine: probe.engine,
            shards: select.shards_for(problem.embed_dim()),
            sync_rounds: probe.sync_rounds,
        });
    }
    points
}

/// One float-native vs bit-true-RTL head-to-head row: the same max-cut
/// instance solved on both fabrics at equal params/seed.  The fabrics
/// run *different dynamics* (the rtl engine is the cycle-accurate
/// serial-MAC hardware model), so the row compares solution quality —
/// and prices the hardware run in emulated fast-clock time-to-solution
/// next to the host-simulation wall time.
#[derive(Debug, Clone)]
pub struct RtlPoint {
    pub n: usize,
    /// Always "rtl" — the row's engine tag (and the CI gate's key).
    pub engine: &'static str,
    pub native_cut: i64,
    pub rtl_cut: i64,
    pub native_energy: f64,
    pub rtl_energy: f64,
    /// RMS coupling rounding loss of the quantized embedding.
    pub quantization_error: f64,
    /// Periods the rtl portfolio drove (early exits included).
    pub periods: usize,
    /// Emulated fast-clock cycles of the rtl solve (lanes serialized).
    pub fast_cycles: u64,
    /// Modeled logic frequency of the synthesized design (MHz).
    pub f_logic_mhz: f64,
    /// Emulated hardware time-to-solution in seconds.
    pub emulated_s: f64,
    /// Host wall-clock seconds the cycle-accurate simulation took.
    pub host_s: f64,
}

/// Solve one max-cut instance per size on the float-native engine and
/// on the bit-true rtl engine at identical params/seed, and price the
/// hardware run (`solve-bench --rtl`).
pub fn rtl_comparison(
    sizes: &[usize],
    replicas: usize,
    periods: usize,
    seed: u64,
) -> Vec<RtlPoint> {
    let mut points = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let mut rng = Rng::new(seed.wrapping_add(n as u64));
        let g = Graph::random(n, (8.0 / n as f64).min(0.5), &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed,
            ..Default::default()
        };
        let native = solve_with(&problem, &params, EngineSelect::Native).expect("native solve");
        let t0 = Instant::now();
        let rtl = solve_with(&problem, &params, EngineSelect::Rtl).expect("rtl solve");
        let host_s = t0.elapsed().as_secs_f64();
        let hw = rtl
            .hardware
            .clone()
            .expect("rtl outcomes report hardware cost");
        points.push(RtlPoint {
            n,
            engine: "rtl",
            native_cut: g.cut_value(&native.best_spins),
            rtl_cut: g.cut_value(&rtl.best_spins),
            native_energy: native.best_energy,
            rtl_energy: rtl.best_energy,
            quantization_error: rtl.quantization_error,
            periods: rtl.periods,
            fast_cycles: hw.fast_cycles,
            f_logic_mhz: hw.f_logic_mhz,
            emulated_s: hw.emulated_s,
            host_s,
        });
    }
    points
}

/// One dense-vs-CSR fabric measurement: the same max-cut instance
/// solved through the dense matrix kernel and the sparse (CSR) kernel
/// at identical params/seed.  The trajectories are bit-exact (asserted
/// by a probe before any timing), so the rows differ only in per-period
/// work — `n` multiplies per row dense vs `avg_row_nnz` sparse — and in
/// weight-fabric memory.
#[derive(Debug, Clone)]
pub struct SparsePoint {
    pub n: usize,
    /// Edge probability the G(n, p) instance was drawn with.
    pub edge_prob: f64,
    /// Realized nonzero density of the coupling matrix.
    pub density: f64,
    /// Mean stored nonzeros per CSR row.
    pub avg_row_nnz: f64,
    pub replicas: usize,
    /// Periods the probe actually drove (identical on both fabrics).
    pub periods: usize,
    pub dense_median_s: f64,
    pub sparse_median_s: f64,
    pub dense_replica_periods_per_sec: f64,
    pub sparse_replica_periods_per_sec: f64,
    /// sparse rate / dense rate — the kernel speedup CSR buys.
    pub sparse_speedup: f64,
    /// Dense weight fabric bytes: n^2 i8 weights + the n^2 i32
    /// column-major copy the kernel walks.
    pub dense_weight_bytes: usize,
    /// CSR fabric bytes ([`crate::onn::sparse::SparseWeights::memory_bytes`]).
    pub sparse_weight_bytes: usize,
    /// Modeled hybrid-architecture oscillation frequency (kHz) when the
    /// serial MAC walks all n columns per row.
    pub hw_dense_khz: f64,
    /// Same design with the MAC walking stored nonzeros only
    /// ([`oscillation_frequency_hybrid_sparse`]).
    pub hw_sparse_khz: f64,
}

/// Rate the dense kernel against the CSR kernel on one G(n, p) max-cut
/// instance per `(n, edge_prob)` spec, asserting bit-exact outcomes
/// before timing anything (`solve-bench --sparse`).
pub fn sparse_comparison(
    specs: &[(usize, f64)],
    replicas: usize,
    periods: usize,
    seed: u64,
) -> Vec<SparsePoint> {
    let d = zynq7020();
    let mut rows = Vec::with_capacity(specs.len());
    for &(n, edge_prob) in specs {
        let mut rng = Rng::new(seed.wrapping_add(n as u64));
        let g = Graph::random(n, edge_prob, &mut rng);
        let dense_problem = max_cut(&g);
        let sparse_problem = max_cut_sparse(&g);
        assert!(
            wants_sparse(&sparse_problem),
            "sparse bench spec (n={n}, p={edge_prob}) lands above the density threshold"
        );
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed,
            plateau_chunks: 0, // steady work: rate the full budget
            ..Default::default()
        };
        // The two forms must be the same computation: bit-equal best
        // state and equal period count, or the speedup is meaningless.
        let probe_dense =
            solve_with(&dense_problem, &params, EngineSelect::Native).expect("dense probe");
        let probe_sparse =
            solve_with(&sparse_problem, &params, EngineSelect::Native).expect("sparse probe");
        assert_eq!(
            probe_dense.best_energy.to_bits(),
            probe_sparse.best_energy.to_bits(),
            "sparse kernel diverged from dense at n={n}"
        );
        assert_eq!(probe_dense.best_spins, probe_sparse.best_spins);
        assert_eq!(probe_dense.periods, probe_sparse.periods);
        assert!(probe_sparse.sparse && !probe_dense.sparse);
        let actual_periods = probe_sparse.periods;
        let rd = bench::bench(&format!("solver/sparse_dense_n{n}"), 1, 3, || {
            solve_with(&dense_problem, &params, EngineSelect::Native).expect("dense");
        });
        let rs = bench::bench(&format!("solver/sparse_csr_n{n}"), 1, 3, || {
            solve_with(&sparse_problem, &params, EngineSelect::Native).expect("sparse");
        });
        let (dense_median_s, sparse_median_s) = (rd.median.as_secs_f64(), rs.median.as_secs_f64());
        let rp = (replicas * actual_periods) as f64;
        let dense_rps = rp / dense_median_s.max(1e-12);
        let sparse_rps = rp / sparse_median_s.max(1e-12);
        // Memory + modeled-hardware columns come from the quantized
        // fabric the engine actually installed.
        let cfg = NetworkConfig::paper(n);
        let (sw, _) = sparse_problem.embed_sparse_with_error(&cfg);
        rows.push(SparsePoint {
            n,
            edge_prob,
            density: sparse_problem.coupling_density(),
            avg_row_nnz: sw.avg_row_nnz(),
            replicas,
            periods: actual_periods,
            dense_median_s,
            sparse_median_s,
            dense_replica_periods_per_sec: dense_rps,
            sparse_replica_periods_per_sec: sparse_rps,
            sparse_speedup: if dense_rps > 0.0 { sparse_rps / dense_rps } else { 0.0 },
            dense_weight_bytes: n * n * (1 + std::mem::size_of::<i32>()),
            sparse_weight_bytes: sw.memory_bytes(),
            hw_dense_khz: oscillation_frequency_hybrid(&cfg, &d),
            hw_sparse_khz: oscillation_frequency_hybrid_sparse(&cfg, &d, sw.avg_row_nnz()),
        });
    }
    rows
}

/// One packed-vs-unpacked serving measurement: a mix of small
/// max-cut/coloring instances solved once through a shared lane-block
/// engine (`solve_packed`) and once one-engine-per-request — identical
/// answers (the packed path is bit-exact lane by lane), so the rows
/// differ only in where the serving time goes.
#[derive(Debug, Clone)]
pub struct PackedPoint {
    /// Oscillator bucket of the shared engine.
    pub bucket_n: usize,
    /// Problems in the mix (all sharing the one engine).
    pub problems: usize,
    /// Lane capacity of the packed engine (problems beyond it backfill
    /// retired lanes mid-run).  `problems` > 1 sharing these lanes IS
    /// the batch occupancy the row demonstrates.
    pub lanes: usize,
    pub packed_median_s: f64,
    pub unpacked_median_s: f64,
    /// Aggregate replica-periods/sec through the shared engine.
    pub packed_rps: f64,
    /// The same work, one engine per request.
    pub unpacked_rps: f64,
}

/// Measure the packed solve path against the one-engine-per-request
/// baseline on a mix of `problems` small instances (alternating max-cut
/// and 3-coloring, sizes cycling inside one bucket).
pub fn packed_throughput(
    problems: usize,
    replicas: usize,
    periods: usize,
    seed: u64,
) -> PackedPoint {
    assert!(problems >= 1);
    // A packed lane block carries at most one solo wave of replicas;
    // clamp instead of panicking when the CLI asks for more.
    let replicas = replicas.clamp(1, MAX_WAVE_REPLICAS);
    let sizes = [10usize, 12, 14, 16];
    let mut rng = Rng::new(seed);
    let mut entries: Vec<(IsingProblem, PortfolioParams)> = Vec::with_capacity(problems);
    for i in 0..problems {
        let n = sizes[i % sizes.len()];
        let g = Graph::random(n, 0.3, &mut rng);
        let problem = if i % 2 == 0 { max_cut(&g) } else { coloring(&g, 3) };
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            seed: seed.wrapping_add(1 + i as u64),
            plateau_chunks: 0, // steady work: rate the full budget
            ..Default::default()
        };
        entries.push((problem, params));
    }
    let bucket_n = entries
        .iter()
        .map(|(p, _)| p.embed_dim())
        .max()
        .expect("problems >= 1")
        .next_power_of_two();
    let lanes = (problems * replicas).min(MAX_WAVE_REPLICAS).max(replicas);
    // One probe run pins the aggregate work actually driven (identical
    // on both paths — they are bit-exact) and sanity-checks exactly
    // that before rating anything.
    let probe =
        solve_packed_native(bucket_n, lanes, DEFAULT_CHUNK, &entries).expect("packed probe");
    let total_rp: usize = probe.iter().map(|o| o.replicas * o.periods).sum();
    for ((problem, params), out) in entries.iter().zip(&probe) {
        let solo = solve_with(problem, params, EngineSelect::Native).expect("solo probe");
        assert_eq!(
            (out.best_energy, out.periods),
            (solo.best_energy, solo.periods),
            "packed probe diverged from solo"
        );
    }
    let rp = bench::bench(&format!("solver/packed_x{problems}_b{bucket_n}"), 1, 3, || {
        solve_packed_native(bucket_n, lanes, DEFAULT_CHUNK, &entries).expect("packed");
    });
    let ru = bench::bench(&format!("solver/unpacked_x{problems}_b{bucket_n}"), 1, 3, || {
        for (problem, params) in &entries {
            solve_with(problem, params, EngineSelect::Native).expect("unpacked");
        }
    });
    let (packed_median_s, unpacked_median_s) =
        (rp.median.as_secs_f64(), ru.median.as_secs_f64());
    PackedPoint {
        bucket_n,
        problems,
        lanes,
        packed_median_s,
        unpacked_median_s,
        packed_rps: total_rp as f64 / packed_median_s.max(1e-12),
        unpacked_rps: total_rp as f64 / unpacked_median_s.max(1e-12),
    }
}

/// One packed-vs-solo measurement on the *emulated hardware* fabric: a
/// mix of equal-size max-cut instances solved once through a shared
/// rtl lane-bank engine ([`solve_packed`] on [`RtlEngine`]) and once
/// one-engine-per-request at identical seeds.  Equal sizes make the
/// bucket exactly each instance's embedding (no padding rows), so the
/// packed run must burn *exactly* the solo runs' fast cycles — asserted
/// before anything is recorded — and the row shows lane-bank packing
/// costs nothing in emulated time while the host serving rate improves.
#[derive(Debug, Clone)]
pub struct RtlPackedPoint {
    /// Oscillator bucket of the shared rtl engine (== every instance's
    /// embedding dimension, so cycle parity with solo runs is exact).
    pub bucket_n: usize,
    pub problems: usize,
    /// Lane capacity of the packed engine (problems beyond it backfill
    /// retired lane blocks mid-run).
    pub lanes: usize,
    pub replicas: usize,
    /// Aggregate periods driven across the mix (identical packed vs
    /// solo — the two paths are bit-exact).
    pub total_periods: usize,
    /// Emulated fast-clock cycles of the packed run, summed over the
    /// per-block `SerialMac` meters.
    pub packed_fast_cycles: u64,
    /// The same mix one-engine-per-request; equals
    /// `packed_fast_cycles` exactly (asserted).
    pub solo_fast_cycles: u64,
    pub packed_emulated_s: f64,
    pub solo_emulated_s: f64,
    /// Emulated solves/sec through the shared fabric — the CI gate: it
    /// must be >= the solo rate.
    pub packed_emulated_solves_per_sec: f64,
    pub solo_emulated_solves_per_sec: f64,
    /// Host wall medians: one engine program + one packed run vs
    /// `problems` engine programs — the serving-path win.
    pub packed_host_median_s: f64,
    pub solo_host_median_s: f64,
}

/// Measure rtl lane-bank packing against one-engine-per-request on a
/// mix of `problems` equal-size max-cut instances
/// (`solve-bench --rtl-packed`).  Gates asserted before recording:
/// bit-exact outcomes per entry, exact fast-cycle parity, and packed
/// emulated solves/sec no worse than solo.
pub fn rtl_packed_throughput(
    problems: usize,
    replicas: usize,
    periods: usize,
    seed: u64,
) -> RtlPackedPoint {
    assert!(problems >= 2, "a packed rtl row needs a mix sharing the fabric");
    let replicas = replicas.clamp(1, MAX_WAVE_REPLICAS);
    // Equal sizes, and max-cut embeds 1:1, so the bucket equals every
    // entry's embedding: the packed engine carries no padding rows and
    // per-block cycles must equal a dedicated engine's run exactly.
    let n = 16usize;
    let mut rng = Rng::new(seed);
    let mut entries: Vec<(IsingProblem, PortfolioParams)> = Vec::with_capacity(problems);
    for i in 0..problems {
        let g = Graph::random(n, 0.3, &mut rng);
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            seed: seed.wrapping_add(1 + i as u64),
            plateau_chunks: 0, // steady work: rate the full budget
            ..Default::default()
        };
        entries.push((max_cut(&g), params));
    }
    let lanes = (problems * replicas).min(MAX_WAVE_REPLICAS).max(replicas);
    let cfg = NetworkConfig::paper(n);
    // One probe run holds the bit-exactness and cycle-parity gates and
    // pins the emulated costs every timed iteration will reproduce
    // (the rtl fabric is deterministic per seed).
    let mut probe_engine = RtlEngine::new(cfg, lanes, DEFAULT_CHUNK);
    let packed_probe = solve_packed(&mut probe_engine, &entries).expect("rtl packed probe");
    let mut solo_hw = Vec::with_capacity(problems);
    let mut total_periods = 0usize;
    for ((problem, params), out) in entries.iter().zip(&packed_probe) {
        let solo = solve_with(problem, params, EngineSelect::Rtl).expect("rtl solo probe");
        assert_eq!(
            (out.best_energy.to_bits(), &out.best_spins, out.periods),
            (solo.best_energy.to_bits(), &solo.best_spins, solo.periods),
            "rtl packed probe diverged from solo"
        );
        total_periods += out.periods;
        solo_hw.push(solo.hardware.clone().expect("rtl solo outcomes report hardware"));
    }
    let block_hw = |o: &crate::solver::portfolio::SolveOutcome| {
        o.hardware.clone().expect("rtl packed outcomes report hardware")
    };
    let packed_fast_cycles: u64 = packed_probe.iter().map(|o| block_hw(o).fast_cycles).sum();
    let solo_fast_cycles: u64 = solo_hw.iter().map(|h| h.fast_cycles).sum();
    assert_eq!(
        packed_fast_cycles, solo_fast_cycles,
        "lane-bank packing must burn exactly the solo runs' emulated cycles"
    );
    let packed_emulated_s: f64 = packed_probe.iter().map(|o| block_hw(o).emulated_s).sum();
    let solo_emulated_s: f64 = solo_hw.iter().map(|h| h.emulated_s).sum();
    let packed_esps = problems as f64 / packed_emulated_s.max(1e-12);
    let solo_esps = problems as f64 / solo_emulated_s.max(1e-12);
    assert!(
        packed_esps >= solo_esps * (1.0 - 1e-9),
        "packed emulated solves/sec regressed vs solo: {packed_esps} < {solo_esps}"
    );
    let rp = bench::bench(&format!("solver/rtl_packed_x{problems}_n{n}"), 1, 3, || {
        let mut engine = RtlEngine::new(cfg, lanes, DEFAULT_CHUNK);
        solve_packed(&mut engine, &entries).expect("rtl packed");
    });
    let rs = bench::bench(&format!("solver/rtl_solo_x{problems}_n{n}"), 1, 3, || {
        for (problem, params) in &entries {
            solve_with(problem, params, EngineSelect::Rtl).expect("rtl solo");
        }
    });
    RtlPackedPoint {
        bucket_n: n,
        problems,
        lanes,
        replicas,
        total_periods,
        packed_fast_cycles,
        solo_fast_cycles,
        packed_emulated_s,
        solo_emulated_s,
        packed_emulated_solves_per_sec: packed_esps,
        solo_emulated_solves_per_sec: solo_esps,
        packed_host_median_s: rp.median.as_secs_f64(),
        solo_host_median_s: rs.median.as_secs_f64(),
    }
}

/// One emulated multi-FPGA cluster measurement: a max-cut instance
/// *larger than the single-device fit* solved on the rtl cluster
/// engine — row ranges of the quantized weight memory sharded over
/// `shards` emulated Zynq-7020s with the per-period phase all-gather
/// priced by `fpga::timing::cluster_sync_cycles`.  A small-n probe
/// asserts the cluster is bit-exact with the single-device engine
/// before the big instance runs.
#[derive(Debug, Clone)]
pub struct RtlClusterPoint {
    pub n: usize,
    /// Emulated devices the rows are sharded over.
    pub shards: usize,
    pub replicas: usize,
    /// Periods the cluster portfolio drove.
    pub periods: usize,
    /// Largest hybrid design that fits one Zynq-7020 at paper
    /// precision (paper Table 5) — the row's `n` must exceed it.
    pub single_device_fit: usize,
    /// Every shard fits its device (asserted).
    pub fits_device: bool,
    pub cut: i64,
    /// Emulated fast cycles: max-over-devices compute + all-gather
    /// sync.
    pub fast_cycles: u64,
    /// The all-gather share of `fast_cycles` — the sync-cost breakdown
    /// a cluster pays that one device never does.
    pub sync_fast_cycles: u64,
    /// `fast_cycles - sync_fast_cycles`.
    pub compute_fast_cycles: u64,
    pub f_logic_mhz: f64,
    /// Emulated cluster time-to-solution in seconds.
    pub emulated_s: f64,
    /// Host wall seconds the cycle-accurate cluster simulation took.
    pub host_s: f64,
}

/// Solve one max-cut instance ~10% past the single-device oscillator
/// fit on a `shards`-device emulated cluster and price the run
/// (`solve-bench --rtl-cluster`).  Gates asserted before recording:
/// small-n bit-exactness with the single-device engine, every shard
/// fits its device, and the all-gather cycles are a nonzero minority
/// of the meter.
pub fn rtl_cluster_scale(
    shards: usize,
    replicas: usize,
    periods: usize,
    seed: u64,
) -> RtlClusterPoint {
    let shards = shards.max(2);
    // The row demonstrates capacity, not search effort, and the host
    // pays cycle-accurate n^2 work per period — clamp the budget so
    // the point stays CI-cheap.
    let replicas = replicas.clamp(1, 2);
    let periods = periods.clamp(1, 8);
    let d = zynq7020();
    let pcfg = NetworkConfig::paper(1);
    let single_fit = max_oscillators("hybrid", &d, pcfg.phase_bits, pcfg.weight_bits);
    let n = single_fit + single_fit / 10;
    // Bit-exactness first, at a size where the single-device engine
    // still exists: the cluster must be the same computation.
    {
        let mut rng = Rng::new(seed);
        let g = Graph::random(12, 0.4, &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            seed,
            ..Default::default()
        };
        let solo = solve_with(&problem, &params, EngineSelect::Rtl).expect("rtl probe");
        let cl = solve_with(&problem, &params, EngineSelect::RtlCluster { shards })
            .expect("rtl cluster probe");
        assert_eq!(
            (solo.best_energy.to_bits(), &solo.best_spins, &solo.best_phases, solo.periods),
            (cl.best_energy.to_bits(), &cl.best_spins, &cl.best_phases, cl.periods),
            "cluster probe diverged from the single-device engine"
        );
    }
    let mut rng = Rng::new(seed.wrapping_add(n as u64));
    let g = Graph::random(n, (8.0 / n as f64).min(0.5), &mut rng);
    let problem = max_cut(&g);
    let params = PortfolioParams {
        replicas,
        max_periods: periods,
        schedule: Schedule::Geometric {
            start: 0.5,
            factor: 0.8,
        },
        seed,
        ..Default::default()
    };
    let t0 = Instant::now();
    let out = solve_with(&problem, &params, EngineSelect::RtlCluster { shards })
        .expect("rtl cluster solve");
    let host_s = t0.elapsed().as_secs_f64();
    let hw = out.hardware.clone().expect("cluster outcomes report hardware cost");
    assert!(n > single_fit, "the row must solve beyond the single-device fit");
    assert!(hw.fits_device, "every shard of the cluster design must fit its device");
    assert!(hw.sync_fast_cycles > 0, "a cluster run must price its all-gathers");
    assert!(
        hw.fast_cycles > hw.sync_fast_cycles,
        "compute must dominate the emulated meter"
    );
    RtlClusterPoint {
        n,
        shards,
        replicas,
        periods: out.periods,
        single_device_fit: single_fit,
        fits_device: hw.fits_device,
        cut: g.cut_value(&out.best_spins),
        fast_cycles: hw.fast_cycles,
        sync_fast_cycles: hw.sync_fast_cycles,
        compute_fast_cycles: hw.fast_cycles - hw.sync_fast_cycles,
        f_logic_mhz: hw.f_logic_mhz,
        emulated_s: hw.emulated_s,
        host_s,
    }
}

/// Latency percentiles of repeated solves on one engine fabric,
/// measured through the same log-bucketed histogram the serving
/// metrics use ([`crate::telemetry::LatencyHistogram`]), so the bench
/// file and a live pool's `metrics` snapshot report comparable
/// quantile estimates (bucket upper bounds, never under-estimates).
#[derive(Debug, Clone)]
pub struct LatencyPoint {
    /// Engine kind that served the samples ("native"/"sharded"/"rtl").
    pub engine: &'static str,
    pub n: usize,
    /// Repeated solves of one fixed instance (identical work, so the
    /// spread is pure serving jitter).
    pub samples: usize,
    pub summary: LatencySummary,
}

/// Solve one small max-cut instance `samples` times per engine fabric
/// (native always; sharded when `shards >= 2`; rtl when `rtl`) and
/// report log-bucketed latency percentiles per fabric.
pub fn latency_percentiles(
    n: usize,
    replicas: usize,
    periods: usize,
    seed: u64,
    samples: usize,
    shards: usize,
    rtl: bool,
) -> Vec<LatencyPoint> {
    let samples = samples.max(1);
    let mut fabrics: Vec<(&'static str, EngineSelect)> = vec![("native", EngineSelect::Native)];
    if shards >= 2 {
        fabrics.push(("sharded", EngineSelect::Sharded { shards }));
    }
    if rtl {
        fabrics.push(("rtl", EngineSelect::Rtl));
    }
    let mut rng = Rng::new(seed.wrapping_add(n as u64));
    let g = Graph::random(n, (8.0 / n as f64).min(0.5), &mut rng);
    let problem = max_cut(&g);
    let params = PortfolioParams {
        replicas,
        max_periods: periods,
        schedule: Schedule::Geometric {
            start: 0.5,
            factor: 0.8,
        },
        seed,
        ..Default::default()
    };
    let mut rows = Vec::with_capacity(fabrics.len());
    for (engine, select) in fabrics {
        let hist = LatencyHistogram::new();
        for _ in 0..samples {
            let t0 = Instant::now();
            solve_with(&problem, &params, select).expect("latency probe");
            hist.record(t0.elapsed());
        }
        rows.push(LatencyPoint {
            engine,
            n,
            samples,
            summary: hist.summary(),
        });
    }
    rows
}

/// The per-chunk best-energy trajectory of one traced solve: the
/// `chunk` events of the telemetry contract (DESIGN_SOLVER.md §9),
/// persisted so the bench file carries convergence shape, not just
/// end-to-end rates.
#[derive(Debug, Clone)]
pub struct ConvergencePoint {
    pub n: usize,
    pub engine: &'static str,
    /// Replica waves the portfolio drove.
    pub waves: usize,
    /// Running best energy after each anneal chunk, in chunk order.
    pub best_energy: Vec<f64>,
    /// Whether the trajectory is monotone non-increasing (it must be —
    /// the solver keeps a running best; persisted so a regression is
    /// visible in the artifact itself).
    pub monotone: bool,
    /// The outcome's best energy (<= the last chunk entry: greedy
    /// polish may still improve on the raw readout).
    pub final_energy: f64,
}

/// Run one traced native solve per size and extract the per-chunk
/// best-energy trajectory from the trace (tracing never perturbs the
/// solve, so these rows price nothing — they show convergence shape).
pub fn convergence_traces(
    sizes: &[usize],
    replicas: usize,
    periods: usize,
    seed: u64,
) -> Vec<ConvergencePoint> {
    let mut rows = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let mut rng = Rng::new(seed.wrapping_add(n as u64));
        let g = Graph::random(n, (8.0 / n as f64).min(0.5), &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas,
            max_periods: periods,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed,
            ..Default::default()
        };
        let trace = sink(DEFAULT_TRACE_CAP);
        let out = solve_with_trace(&problem, &params, EngineSelect::Native, Some(&trace))
            .expect("traced convergence probe");
        let rec = trace.borrow();
        let mut best = Vec::new();
        let mut waves = 0usize;
        for r in rec.records() {
            match &r.event {
                TraceEvent::Chunk { best_energy, .. } => best.push(*best_energy),
                TraceEvent::WaveEnd { .. } => waves += 1,
                _ => {}
            }
        }
        let monotone = best.windows(2).all(|w| w[1] <= w[0] + 1e-12);
        rows.push(ConvergencePoint {
            n,
            engine: out.engine,
            waves,
            best_energy: best,
            monotone,
            final_energy: out.best_energy,
        });
    }
    rows
}

/// One connection-scale serving measurement: the same solve traffic
/// driven by `clients` concurrent streaming connections against both
/// front ends — the thread-per-connection baseline (`serve_tcp`, cold
/// engine per request: arena disabled) and the evented readiness loop
/// (`serve_evented`, warm engine arena) — on otherwise identical pools.
#[derive(Debug, Clone)]
pub struct ConnectionScalePoint {
    /// Concurrent client connections driving each front end.
    pub clients: usize,
    /// Wall seconds each front end was driven.
    pub measure_s: f64,
    /// Solves completed inside the window, per front end.
    pub baseline_solves: u64,
    pub evented_solves: u64,
    pub baseline_solves_per_sec: f64,
    pub evented_solves_per_sec: f64,
    /// evented rate / baseline rate — the serving-path speedup the
    /// evented front end + warm arena buy at this connection count.
    pub speedup: f64,
    /// Warm-arena hit rate of the evented run (the baseline runs with
    /// the arena disabled, so its rate is definitionally 0).
    pub arena_hit_rate: f64,
}

/// Drive one front end with `clients` concurrent connections for
/// `measure` wall time; returns (solves completed, elapsed seconds,
/// arena hit rate).  Every client loops a small streaming max-cut
/// request and waits for its result line before sending the next, so
/// the count is *sustained served solves*, not submissions.
fn drive_front_end(
    evented: bool,
    clients: usize,
    seed: u64,
    measure: Duration,
) -> (u64, f64, f64) {
    let solver = SolverPoolConfig {
        // The baseline is the pre-arena serving shape: every request
        // builds a cold engine.  The evented run keeps the default
        // warm-arena capacity.
        arena_capacity: if evented {
            SolverPoolConfig::default().arena_capacity
        } else {
            0
        },
        ..Default::default()
    };
    let coord = Coordinator::start_with_solver(Vec::new(), BatchPolicy::default(), solver)
        .expect("coordinator for connection-scale bench");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind bench listener");
    let addr = listener.local_addr().expect("bench listener addr");
    let router = Arc::clone(&coord.router);
    let serve = std::thread::spawn(move || {
        if evented {
            serve_evented(router, listener)
        } else {
            serve_tcp(router, listener)
        }
    });

    // One small ring instance; identical request bytes hit both front
    // ends ("stream" is parsed by both, honored only by the evented
    // loop), so the rows differ in serving shape, never in work.
    let n = 12usize;
    let edges = (0..n)
        .map(|i| format!("[{},{},1]", i, (i + 1) % n))
        .collect::<Vec<_>>()
        .join(",");
    let solved = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let deadline = t0 + measure;
    let mut drivers = Vec::with_capacity(clients);
    for c in 0..clients {
        let solved = Arc::clone(&solved);
        let edges = edges.clone();
        drivers.push(std::thread::spawn(move || {
            let stream = match TcpStream::connect(addr) {
                Ok(s) => s,
                Err(_) => return,
            };
            let mut writer = match stream.try_clone() {
                Ok(s) => s,
                Err(_) => return,
            };
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            let mut iter = 0u64;
            while Instant::now() < deadline {
                let req = format!(
                    "{{\"type\":\"solve\",\"id\":{iter},\"n\":{n},\
                     \"edges\":[{edges}],\"replicas\":2,\"max_periods\":8,\
                     \"stream\":true,\"seed\":{}}}\n",
                    seed.wrapping_add(1 + c as u64).wrapping_add(iter)
                );
                if writer.write_all(req.as_bytes()).is_err() {
                    return;
                }
                // Progress lines arrive interleaved; only the result
                // line (it alone carries "spins") completes the solve.
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => return,
                        Ok(_) => {}
                    }
                    if line.contains("\"spins\"") {
                        solved.fetch_add(1, Ordering::Relaxed);
                        break;
                    }
                    if line.contains("\"error\"") {
                        break;
                    }
                }
                iter += 1;
            }
        }));
    }
    for d in drivers {
        let _ = d.join();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let hit_rate = coord.snapshot().arena_hit_rate();
    coord.shutdown().expect("bench pool shutdown");
    serve
        .join()
        .expect("serve thread")
        .expect("serve loop exits on shutdown");
    (solved.load(Ordering::Relaxed), elapsed, hit_rate)
}

/// Measure sustained solves/sec at `clients` concurrent streaming
/// connections on the thread-per-connection baseline vs the evented
/// front end (`solve-bench --connections N`).
pub fn connection_scale(clients: usize, seed: u64, measure: Duration) -> ConnectionScalePoint {
    let clients = clients.max(1);
    let (baseline_solves, baseline_s, _) = drive_front_end(false, clients, seed, measure);
    let (evented_solves, evented_s, arena_hit_rate) =
        drive_front_end(true, clients, seed, measure);
    let baseline_solves_per_sec = baseline_solves as f64 / baseline_s.max(1e-9);
    let evented_solves_per_sec = evented_solves as f64 / evented_s.max(1e-9);
    ConnectionScalePoint {
        clients,
        measure_s: measure.as_secs_f64(),
        baseline_solves,
        evented_solves,
        baseline_solves_per_sec,
        evented_solves_per_sec,
        speedup: if baseline_solves_per_sec > 0.0 {
            evented_solves_per_sec / baseline_solves_per_sec
        } else {
            0.0
        },
        arena_hit_rate,
    }
}

/// One accuracy-vs-load row of the associative bench: recall accuracy
/// of 10%-corrupted probes on the native fabric after `stores` store
/// operations hit a fresh space.
#[derive(Debug, Clone)]
pub struct AssocLoadPoint {
    /// Patterns live when the probes ran (the LRU policy holds this at
    /// capacity even as stores keep coming).
    pub patterns: usize,
    /// Store operations issued to reach this load (> `patterns` once
    /// the capacity policy starts evicting).
    pub stores: usize,
    /// Corrupted probes driven (one per surviving pattern).
    pub trials: usize,
    pub matched: usize,
    /// matched / trials — the paper-style retrieval-accuracy column.
    pub accuracy: f64,
}

/// The online-learning associative-memory measurement: recalls served
/// by delta-reprogramming a warm engine vs cold retrain+rebuild per
/// recall, on one live memory space with real store/evict/forget
/// history — bit-identical outcomes asserted before timing — plus a
/// native accuracy-vs-load sweep past the capacity bound.
#[derive(Debug, Clone)]
pub struct AssociativePoint {
    pub n: usize,
    /// Pattern capacity of the measured space ([`capacity_for`]).
    pub capacity: usize,
    /// Headline fabric ("sharded": the rebuild path pays the shard
    /// worker spawn/join on every recall, the warm path never does).
    pub engine: &'static str,
    pub shards: usize,
    /// Recalls per timed pass (one exact-pattern probe per survivor).
    pub recalls: usize,
    pub delta_median_s: f64,
    pub rebuild_median_s: f64,
    /// Recalls/sec with the warm engine delta-reprogrammed per recall.
    pub delta_recalls_per_sec: f64,
    /// Recalls/sec retraining the master and building a fresh engine
    /// per recall (the pre-tentpole serving shape).
    pub rebuild_recalls_per_sec: f64,
    /// delta rate / rebuild rate — the CI-gated reprogram win.
    pub speedup: f64,
    /// Accuracy vs load on the native fabric (1..=capacity+2 stores).
    pub load: Vec<AssocLoadPoint>,
}

/// The native accuracy-vs-load sweep: for every store count in
/// `1..=capacity + 2`, fill a fresh Hebbian space with random patterns
/// and probe each survivor with a copy corrupted in 10% of its spins.
fn assoc_accuracy_sweep(
    n: usize,
    capacity: usize,
    max_periods: usize,
    seed: u64,
) -> Vec<AssocLoadPoint> {
    let cfg = NetworkConfig::paper(n);
    let period = cfg.period() as i32;
    let flips = (n / 10).max(1);
    let mut rows = Vec::with_capacity(capacity + 2);
    for stores in 1..=capacity + 2 {
        let mut rng = Rng::new(seed.wrapping_add(stores as u64));
        let mut ms = MemorySpace::new(n, capacity, LearningRule::Hebbian);
        for _ in 0..stores {
            let p: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
            ms.store(p).expect("sweep store");
        }
        let survivors = ms.stored_patterns();
        let mut engine = build_engine_cfg(cfg, 1, DEFAULT_CHUNK, EngineSelect::Native)
            .expect("sweep engine");
        engine.set_weights(&ms.weights().to_f32()).expect("sweep program");
        let mut matched = 0usize;
        for p in &survivors {
            let mut corrupted = p.clone();
            for i in rng.choose_distinct(n, flips) {
                corrupted[i] = -corrupted[i];
            }
            let init: Vec<i32> =
                corrupted.iter().map(|&s| spin_to_phase(s, period)).collect();
            let (phases, _) =
                drive_retrieval(engine.as_mut(), &init, max_periods).expect("sweep recall");
            if spins_match_up_to_inversion(&state_to_spins(&phases, period), p) {
                matched += 1;
            }
        }
        let trials = survivors.len();
        rows.push(AssocLoadPoint {
            patterns: trials,
            stores,
            trials,
            matched,
            accuracy: matched as f64 / trials.max(1) as f64,
        });
    }
    rows
}

/// Rate delta-reprogrammed warm-engine recalls against cold
/// retrain+rebuild recalls on one live memory space
/// (`solve-bench --associative`).  Gates asserted before any timing:
/// the space's delta-maintained quantized matrix equals quantizing
/// `hebbian(survivors)` cold, and every warm recall settles to exactly
/// the spins the rebuilt path settles to.  The headline runs on the
/// sharded fabric, where a rebuild per recall also pays the shard
/// worker spawn/join the warm path amortizes away.
pub fn associative_throughput(periods: usize, seed: u64) -> AssociativePoint {
    let n = 32usize;
    let shards = 2usize;
    let select = EngineSelect::Sharded { shards };
    let cfg = NetworkConfig::paper(n);
    let max_periods = periods.clamp(8, 64);
    let capacity = capacity_for(n);
    let mut rng = Rng::new(seed);
    let mut ms = MemorySpace::new(n, capacity, LearningRule::Hebbian);
    // A real online history: one store past capacity (the LRU policy
    // evicts) and one explicit forget, so the timed master is a
    // survivor set, not a pristine batch.
    for _ in 0..capacity + 1 {
        let p: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        ms.store(p).expect("bench store");
    }
    let first = ms.stored_patterns()[0].clone();
    ms.forget(&first).expect("bench forget");
    let survivors = ms.stored_patterns();
    assert!(!survivors.is_empty());
    // Gate 1: the tentpole identity on this exact workload.
    let cold = WeightMatrix::quantize(&hebbian(&survivors), n, &cfg);
    assert_eq!(
        ms.weights(),
        &cold,
        "delta-maintained quantized matrix diverged from cold retrain"
    );
    let weights_f32 = ms.weights().to_f32();
    let period = cfg.period() as i32;
    let probes: Vec<Vec<i32>> = survivors
        .iter()
        .map(|p| p.iter().map(|&s| spin_to_phase(s, period)).collect())
        .collect();
    // Gate 2: warm reprogrammed recalls == cold rebuilt recalls, spin
    // for spin, on the headline fabric.
    let mut warm = build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select).expect("warm engine");
    for probe in &probes {
        warm.set_weights(&weights_f32).expect("warm reprogram");
        let (wp, _) =
            drive_retrieval(warm.as_mut(), probe, max_periods).expect("warm settle");
        let rebuilt = WeightMatrix::quantize(&hebbian(&survivors), n, &cfg);
        let mut fresh = build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select).expect("cold engine");
        fresh.set_weights(&rebuilt.to_f32()).expect("cold program");
        let (cp, _) =
            drive_retrieval(fresh.as_mut(), probe, max_periods).expect("cold settle");
        assert_eq!(wp, cp, "warm delta recall diverged from cold rebuild recall");
    }
    let recalls = probes.len();
    let rd = bench::bench(&format!("solver/assoc_delta_n{n}"), 1, 3, || {
        for probe in &probes {
            warm.set_weights(&weights_f32).expect("delta reprogram");
            drive_retrieval(warm.as_mut(), probe, max_periods).expect("delta recall");
        }
    });
    let rr = bench::bench(&format!("solver/assoc_rebuild_n{n}"), 1, 3, || {
        for probe in &probes {
            let rebuilt = WeightMatrix::quantize(&hebbian(&survivors), n, &cfg);
            let mut engine =
                build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select).expect("rebuild engine");
            engine.set_weights(&rebuilt.to_f32()).expect("rebuild program");
            drive_retrieval(engine.as_mut(), probe, max_periods).expect("rebuild recall");
        }
    });
    let (delta_median_s, rebuild_median_s) = (rd.median.as_secs_f64(), rr.median.as_secs_f64());
    let delta_rps = recalls as f64 / delta_median_s.max(1e-12);
    let rebuild_rps = recalls as f64 / rebuild_median_s.max(1e-12);
    AssociativePoint {
        n,
        capacity,
        engine: "sharded",
        shards,
        recalls,
        delta_median_s,
        rebuild_median_s,
        delta_recalls_per_sec: delta_rps,
        rebuild_recalls_per_sec: rebuild_rps,
        speedup: if rebuild_rps > 0.0 { delta_rps / rebuild_rps } else { 0.0 },
        load: assoc_accuracy_sweep(n, capacity, max_periods, seed.wrapping_add(77)),
    }
}

/// Everything one `record_throughput` run measured — the in-memory
/// mirror of the `BENCH_solver.json` document it writes.
#[derive(Debug, Clone, Default)]
pub struct SolverBench {
    pub points: Vec<ThroughputPoint>,
    pub packed: Vec<PackedPoint>,
    pub rtl: Vec<RtlPoint>,
    pub rtl_packed: Vec<RtlPackedPoint>,
    pub rtl_cluster: Vec<RtlClusterPoint>,
    pub latency: Vec<LatencyPoint>,
    pub convergence: Vec<ConvergencePoint>,
    pub connection_scale: Vec<ConnectionScalePoint>,
    pub sparse: Vec<SparsePoint>,
    pub associative: Vec<AssociativePoint>,
}

/// One `"associative"` row of the bench document.
fn assoc_row_json(p: &AssociativePoint) -> Json {
    let load = p
        .load
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("patterns", Json::num(l.patterns as f64)),
                ("stores", Json::num(l.stores as f64)),
                ("trials", Json::num(l.trials as f64)),
                ("matched", Json::num(l.matched as f64)),
                ("accuracy", Json::num(l.accuracy)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n", Json::num(p.n as f64)),
        ("capacity", Json::num(p.capacity as f64)),
        ("engine", Json::str(p.engine)),
        ("shards", Json::num(p.shards as f64)),
        ("recalls", Json::num(p.recalls as f64)),
        ("delta_median_s", Json::num(p.delta_median_s)),
        ("rebuild_median_s", Json::num(p.rebuild_median_s)),
        ("delta_recalls_per_sec", Json::num(p.delta_recalls_per_sec)),
        (
            "rebuild_recalls_per_sec",
            Json::num(p.rebuild_recalls_per_sec),
        ),
        ("speedup", Json::num(p.speedup)),
        ("load", Json::Arr(load)),
    ])
}

/// Serialize a throughput sweep as the `BENCH_solver.json` document.
/// Each point carries its engine label, so native and sharded rows for
/// the same sizes live side by side in one trajectory file; packed
/// rows (one per measured mix) sit alongside under `"packed"`,
/// float-vs-bit-true hardware rows under `"rtl"`, lane-bank packed
/// hardware rows under `"rtl_packed"`, emulated multi-FPGA cluster
/// rows under `"rtl_cluster"`, latency percentiles
/// per fabric under `"latency"`, per-chunk best-energy trajectories
/// under `"convergence"`, dense-vs-CSR fabric rows under `"sparse"`,
/// connection-scale serving rows (evented front end vs
/// thread-per-connection baseline) under `"connection_scale"`, and the
/// online-learning associative row (delta-reprogram vs full-rebuild
/// recalls/sec + accuracy vs load) under `"associative"`.
pub fn bench_json(bench: &SolverBench, recorded_unix_s: u64) -> Json {
    let points = &bench.points;
    let packed = &bench.packed;
    let rtl = &bench.rtl;
    let mut engines: Vec<&'static str> = Vec::new();
    for p in points {
        if !engines.contains(&p.engine) {
            engines.push(p.engine);
        }
    }
    Json::obj(vec![
        ("bench", Json::str("solver_portfolio_throughput")),
        ("engines", Json::Arr(engines.into_iter().map(Json::str).collect())),
        ("unit", Json::str("replica_periods_per_sec")),
        ("recorded_unix_s", Json::num(recorded_unix_s as f64)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("n", Json::num(p.n as f64)),
                            ("engine", Json::str(p.engine)),
                            ("shards", Json::num(p.shards as f64)),
                            ("sync_rounds", Json::num(p.sync_rounds as f64)),
                            ("replicas", Json::num(p.replicas as f64)),
                            ("periods", Json::num(p.periods as f64)),
                            ("median_s", Json::num(p.median_s)),
                            (
                                "replica_periods_per_sec",
                                Json::num(p.replica_periods_per_sec),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "packed",
            Json::Arr(
                packed
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("bucket_n", Json::num(p.bucket_n as f64)),
                            ("problems", Json::num(p.problems as f64)),
                            ("lanes", Json::num(p.lanes as f64)),
                            ("packed_median_s", Json::num(p.packed_median_s)),
                            ("unpacked_median_s", Json::num(p.unpacked_median_s)),
                            (
                                "packed_replica_periods_per_sec",
                                Json::num(p.packed_rps),
                            ),
                            (
                                "unpacked_replica_periods_per_sec",
                                Json::num(p.unpacked_rps),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "rtl",
            Json::Arr(
                rtl.iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("engine", Json::str(p.engine)),
                            ("n", Json::num(p.n as f64)),
                            ("native_cut", Json::num(p.native_cut as f64)),
                            ("rtl_cut", Json::num(p.rtl_cut as f64)),
                            ("native_energy", Json::num(p.native_energy)),
                            ("rtl_energy", Json::num(p.rtl_energy)),
                            ("quantization_error", Json::num(p.quantization_error)),
                            ("periods", Json::num(p.periods as f64)),
                            ("fast_cycles", Json::num(p.fast_cycles as f64)),
                            ("f_logic_mhz", Json::num(p.f_logic_mhz)),
                            ("emulated_s", Json::num(p.emulated_s)),
                            ("host_s", Json::num(p.host_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "rtl_packed",
            Json::Arr(
                bench
                    .rtl_packed
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("bucket_n", Json::num(p.bucket_n as f64)),
                            ("problems", Json::num(p.problems as f64)),
                            ("lanes", Json::num(p.lanes as f64)),
                            ("replicas", Json::num(p.replicas as f64)),
                            ("total_periods", Json::num(p.total_periods as f64)),
                            ("packed_fast_cycles", Json::num(p.packed_fast_cycles as f64)),
                            ("solo_fast_cycles", Json::num(p.solo_fast_cycles as f64)),
                            ("packed_emulated_s", Json::num(p.packed_emulated_s)),
                            ("solo_emulated_s", Json::num(p.solo_emulated_s)),
                            (
                                "packed_emulated_solves_per_sec",
                                Json::num(p.packed_emulated_solves_per_sec),
                            ),
                            (
                                "solo_emulated_solves_per_sec",
                                Json::num(p.solo_emulated_solves_per_sec),
                            ),
                            ("packed_host_median_s", Json::num(p.packed_host_median_s)),
                            ("solo_host_median_s", Json::num(p.solo_host_median_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "rtl_cluster",
            Json::Arr(
                bench
                    .rtl_cluster
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("n", Json::num(p.n as f64)),
                            ("shards", Json::num(p.shards as f64)),
                            ("replicas", Json::num(p.replicas as f64)),
                            ("periods", Json::num(p.periods as f64)),
                            ("single_device_fit", Json::num(p.single_device_fit as f64)),
                            ("fits_device", Json::Bool(p.fits_device)),
                            ("cut", Json::num(p.cut as f64)),
                            ("fast_cycles", Json::num(p.fast_cycles as f64)),
                            ("sync_fast_cycles", Json::num(p.sync_fast_cycles as f64)),
                            (
                                "compute_fast_cycles",
                                Json::num(p.compute_fast_cycles as f64),
                            ),
                            ("f_logic_mhz", Json::num(p.f_logic_mhz)),
                            ("emulated_s", Json::num(p.emulated_s)),
                            ("host_s", Json::num(p.host_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "latency",
            Json::Arr(
                bench
                    .latency
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("engine", Json::str(p.engine)),
                            ("n", Json::num(p.n as f64)),
                            ("samples", Json::num(p.samples as f64)),
                            ("count", Json::num(p.summary.count as f64)),
                            ("mean_ms", Json::num(p.summary.mean_ms)),
                            ("p50_ms", Json::num(p.summary.p50_ms)),
                            ("p90_ms", Json::num(p.summary.p90_ms)),
                            ("p99_ms", Json::num(p.summary.p99_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "convergence",
            Json::Arr(
                bench
                    .convergence
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("n", Json::num(p.n as f64)),
                            ("engine", Json::str(p.engine)),
                            ("waves", Json::num(p.waves as f64)),
                            ("chunks", Json::num(p.best_energy.len() as f64)),
                            ("monotone", Json::Bool(p.monotone)),
                            (
                                "best_energy",
                                Json::Arr(p.best_energy.iter().map(|&e| Json::num(e)).collect()),
                            ),
                            ("final_energy", Json::num(p.final_energy)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "sparse",
            Json::Arr(
                bench
                    .sparse
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("n", Json::num(p.n as f64)),
                            ("edge_prob", Json::num(p.edge_prob)),
                            ("density", Json::num(p.density)),
                            ("avg_row_nnz", Json::num(p.avg_row_nnz)),
                            ("replicas", Json::num(p.replicas as f64)),
                            ("periods", Json::num(p.periods as f64)),
                            ("dense_median_s", Json::num(p.dense_median_s)),
                            ("sparse_median_s", Json::num(p.sparse_median_s)),
                            (
                                "dense_replica_periods_per_sec",
                                Json::num(p.dense_replica_periods_per_sec),
                            ),
                            (
                                "sparse_replica_periods_per_sec",
                                Json::num(p.sparse_replica_periods_per_sec),
                            ),
                            ("sparse_speedup", Json::num(p.sparse_speedup)),
                            ("dense_weight_bytes", Json::num(p.dense_weight_bytes as f64)),
                            ("sparse_weight_bytes", Json::num(p.sparse_weight_bytes as f64)),
                            ("hw_dense_khz", Json::num(p.hw_dense_khz)),
                            ("hw_sparse_khz", Json::num(p.hw_sparse_khz)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "connection_scale",
            Json::Arr(
                bench
                    .connection_scale
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("clients", Json::num(p.clients as f64)),
                            ("measure_s", Json::num(p.measure_s)),
                            ("baseline_solves", Json::num(p.baseline_solves as f64)),
                            ("evented_solves", Json::num(p.evented_solves as f64)),
                            (
                                "baseline_solves_per_sec",
                                Json::num(p.baseline_solves_per_sec),
                            ),
                            (
                                "evented_solves_per_sec",
                                Json::num(p.evented_solves_per_sec),
                            ),
                            ("speedup", Json::num(p.speedup)),
                            ("arena_hit_rate", Json::num(p.arena_hit_rate)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "associative",
            Json::Arr(bench.associative.iter().map(assoc_row_json).collect()),
        ),
    ])
}

/// Run the sweep(s) and write `BENCH_solver.json`-style output to
/// `path`: always the native rows, plus — when `shards >= 2` — the
/// sharded rows on the exact same instances (native-vs-sharded
/// replica-periods/sec vs N), plus — when `packed_problems >= 2` — one
/// packed row comparing a `packed_problems`-instance mix through a
/// shared lane-block engine against the one-engine-per-request
/// baseline, plus — when `rtl` — one float-vs-bit-true row per size
/// (solution quality + emulated hardware time-to-solution), plus —
/// when `rtl_packed` — one lane-bank packed hardware row (a mix of
/// equal-size instances through one shared rtl engine vs
/// one-engine-per-request, with exact fast-cycle parity asserted),
/// plus — when `rtl_cluster` — one emulated multi-FPGA cluster row
/// (an instance past the single-device fit, with the per-period
/// all-gather priced), plus —
/// when `connections >= 1` — one connection-scale serving row
/// (sustained solves/sec at `connections` concurrent streaming clients,
/// evented front end vs thread-per-connection baseline), plus — when
/// `sparse` — the dense-vs-CSR fabric rows (fixed density 0.05 at the
/// sizes the scaling argument bites, and a constant-degree G(n, 4/n)
/// sweep), plus — when `associative` — the online-learning associative
/// row (delta-reprogrammed warm recalls vs cold retrain+rebuild,
/// bit-identity asserted, with a native accuracy-vs-load sweep).
/// Every run
/// also records latency percentiles per engine fabric (repeated solves
/// of the smallest size through a log-bucketed histogram) and one
/// traced convergence trajectory per size.
#[allow(clippy::too_many_arguments)]
pub fn record_throughput(
    path: &std::path::Path,
    sizes: &[usize],
    replicas: usize,
    periods: usize,
    seed: u64,
    shards: usize,
    packed_problems: usize,
    rtl: bool,
    rtl_packed: bool,
    rtl_cluster: bool,
    connections: usize,
    sparse: bool,
    associative: bool,
) -> std::io::Result<SolverBench> {
    // Repeated solves per fabric for the percentile rows: enough to
    // make p90 land off the extremes, few enough to stay cheap.
    const LATENCY_SAMPLES: usize = 9;
    // Wall time each front end is driven for the connection-scale row:
    // long enough to amortize accept/warmup, short enough for CI.
    const CONNECTION_MEASURE: Duration = Duration::from_millis(1200);
    let t0 = Instant::now();
    let mut points = throughput_sweep(sizes, replicas, periods, seed, 1);
    if shards >= 2 {
        points.extend(throughput_sweep(sizes, replicas, periods, seed, shards));
    }
    let mut packed = Vec::new();
    if packed_problems >= 2 {
        packed.push(packed_throughput(packed_problems, replicas, periods, seed));
    }
    let rtl_points = if rtl {
        rtl_comparison(sizes, replicas, periods, seed)
    } else {
        Vec::new()
    };
    let rtl_packed_points = if rtl_packed {
        // Reuse the packed-mix size when the CLI asked for one;
        // otherwise a 4-instance mix demonstrates the sharing.
        let problems = if packed_problems >= 2 { packed_problems } else { 4 };
        vec![rtl_packed_throughput(problems, replicas, periods, seed)]
    } else {
        Vec::new()
    };
    let rtl_cluster_points = if rtl_cluster {
        let devices = if shards >= 2 { shards } else { 2 };
        vec![rtl_cluster_scale(devices, replicas, periods, seed)]
    } else {
        Vec::new()
    };
    let latency_n = sizes.iter().copied().min().unwrap_or(16);
    let latency =
        latency_percentiles(latency_n, replicas, periods, seed, LATENCY_SAMPLES, shards, rtl);
    let convergence = convergence_traces(sizes, replicas, periods, seed);
    let connection_points = if connections >= 1 {
        vec![connection_scale(connections, seed, CONNECTION_MEASURE)]
    } else {
        Vec::new()
    };
    let sparse_points = if sparse {
        // The fixed-density rows carry the acceptance argument (CSR
        // must beat dense at density 0.05 by the time n reaches 512);
        // the G(n, 4/n) rows show constant-degree scaling — per-row
        // work flat while the dense kernel grows linearly.
        let specs = [
            (256, 0.05),
            (512, 0.05),
            (128, 4.0 / 128.0),
            (256, 4.0 / 256.0),
            (512, 4.0 / 512.0),
        ];
        sparse_comparison(&specs, replicas, periods, seed)
    } else {
        Vec::new()
    };
    let associative_points = if associative {
        vec![associative_throughput(periods, seed)]
    } else {
        Vec::new()
    };
    let bench = SolverBench {
        points,
        packed,
        rtl: rtl_points,
        rtl_packed: rtl_packed_points,
        rtl_cluster: rtl_cluster_points,
        latency,
        convergence,
        connection_scale: connection_points,
        sparse: sparse_points,
        associative: associative_points,
    };
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let doc = bench_json(&bench, stamp);
    std::fs::write(path, format!("{doc}\n"))?;
    eprintln!(
        "wrote {} ({} rows + {} packed + {} rtl + {} rtl-packed + {} rtl-cluster \
         + {} latency + {} convergence + {} connection-scale + {} sparse \
         + {} associative in {:.1}s)",
        path.display(),
        bench.points.len(),
        bench.packed.len(),
        bench.rtl.len(),
        bench.rtl_packed.len(),
        bench.rtl_cluster.len(),
        bench.latency.len(),
        bench.convergence.len(),
        bench.connection_scale.len(),
        bench.sparse.len(),
        bench.associative.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(bench)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quality_report_aggregates() {
        // Tiny sizes keep this test fast; the full comparison runs in
        // the integration suite and the `solve-bench` CLI.
        let rep = quality_vs_sa(12, 0.3, 2, 4, 32, 7);
        assert_eq!(rep.rows.len(), 2);
        assert_eq!(rep.sa_sweeps, 4 * 32);
        assert!(rep.onn_mean() > 0.0);
        assert!(rep.ratio() > 0.5, "ratio {}", rep.ratio());
        let t = rep.table();
        assert!(t.contains("ONN"), "{t}");
    }

    #[test]
    fn throughput_points_have_positive_rates() {
        let pts = throughput_sweep(&[8, 12], 4, 16, 3, 1);
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.replica_periods_per_sec > 0.0);
            assert_eq!(p.engine, "native");
            assert_eq!(p.sync_rounds, 0);
        }
    }

    #[test]
    fn sharded_sweep_rows_carry_sync_cost() {
        let pts = throughput_sweep(&[10], 2, 8, 3, 2);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].engine, "sharded");
        assert_eq!(pts[0].shards, 2);
        assert!(pts[0].sync_rounds > 0, "sharded rows must pay sync rounds");
        assert!(pts[0].replica_periods_per_sec > 0.0);
    }

    #[test]
    fn bench_json_shape() {
        let pts = vec![
            ThroughputPoint {
                n: 8,
                replicas: 4,
                periods: 16,
                median_s: 0.5,
                replica_periods_per_sec: 128.0,
                engine: "native",
                shards: 1,
                sync_rounds: 0,
            },
            ThroughputPoint {
                n: 8,
                replicas: 4,
                periods: 16,
                median_s: 0.7,
                replica_periods_per_sec: 91.0,
                engine: "sharded",
                shards: 2,
                sync_rounds: 64,
            },
        ];
        let packed = vec![PackedPoint {
            bucket_n: 16,
            problems: 4,
            lanes: 16,
            packed_median_s: 0.2,
            unpacked_median_s: 0.3,
            packed_rps: 320.0,
            unpacked_rps: 213.0,
        }];
        let rtl = vec![RtlPoint {
            n: 8,
            engine: "rtl",
            native_cut: 11,
            rtl_cut: 11,
            native_energy: -7.0,
            rtl_energy: -7.0,
            quantization_error: 0.01,
            periods: 64,
            fast_cycles: 14_336,
            f_logic_mhz: 100.0,
            emulated_s: 1.4e-4,
            host_s: 0.02,
        }];
        let rtl_packed = vec![RtlPackedPoint {
            bucket_n: 16,
            problems: 4,
            lanes: 8,
            replicas: 2,
            total_periods: 128,
            packed_fast_cycles: 45_056,
            solo_fast_cycles: 45_056,
            packed_emulated_s: 4.5e-4,
            solo_emulated_s: 4.5e-4,
            packed_emulated_solves_per_sec: 8888.0,
            solo_emulated_solves_per_sec: 8888.0,
            packed_host_median_s: 0.04,
            solo_host_median_s: 0.11,
        }];
        let rtl_cluster = vec![RtlClusterPoint {
            n: 556,
            shards: 2,
            replicas: 2,
            periods: 8,
            single_device_fit: 506,
            fits_device: true,
            cut: 1234,
            fast_cycles: 300_000,
            sync_fast_cycles: 75_000,
            compute_fast_cycles: 225_000,
            f_logic_mhz: 100.0,
            emulated_s: 3.0e-3,
            host_s: 0.5,
        }];
        let bench = SolverBench {
            points: pts,
            packed,
            rtl,
            rtl_packed,
            rtl_cluster,
            latency: vec![LatencyPoint {
                engine: "native",
                n: 8,
                samples: 9,
                summary: LatencySummary {
                    count: 9,
                    mean_ms: 1.5,
                    p50_ms: 1.024,
                    p90_ms: 2.048,
                    p99_ms: 2.048,
                },
            }],
            convergence: vec![ConvergencePoint {
                n: 8,
                engine: "native",
                waves: 1,
                best_energy: vec![-3.0, -5.0, -5.0],
                monotone: true,
                final_energy: -5.5,
            }],
            connection_scale: vec![ConnectionScalePoint {
                clients: 64,
                measure_s: 1.2,
                baseline_solves: 600,
                evented_solves: 1500,
                baseline_solves_per_sec: 500.0,
                evented_solves_per_sec: 1250.0,
                speedup: 2.5,
                arena_hit_rate: 0.9,
            }],
            sparse: vec![SparsePoint {
                n: 512,
                edge_prob: 0.05,
                density: 0.0499,
                avg_row_nnz: 25.6,
                replicas: 4,
                periods: 32,
                dense_median_s: 0.8,
                sparse_median_s: 0.1,
                dense_replica_periods_per_sec: 160.0,
                sparse_replica_periods_per_sec: 1280.0,
                sparse_speedup: 8.0,
                dense_weight_bytes: 512 * 512 * 5,
                sparse_weight_bytes: 30_000,
                hw_dense_khz: 6.0,
                hw_sparse_khz: 98.0,
            }],
            associative: vec![AssociativePoint {
                n: 32,
                capacity: 4,
                engine: "sharded",
                shards: 2,
                recalls: 4,
                delta_median_s: 0.01,
                rebuild_median_s: 0.05,
                delta_recalls_per_sec: 400.0,
                rebuild_recalls_per_sec: 80.0,
                speedup: 5.0,
                load: vec![AssocLoadPoint {
                    patterns: 4,
                    stores: 6,
                    trials: 4,
                    matched: 3,
                    accuracy: 0.75,
                }],
            }],
        };
        let doc = bench_json(&bench, 123);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(Json::as_str),
            Some("solver_portfolio_throughput")
        );
        let engines = parsed.get("engines").and_then(Json::as_arr).unwrap();
        assert_eq!(engines.len(), 2);
        let points = parsed.get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[1].get("engine").and_then(Json::as_str), Some("sharded"));
        assert_eq!(points[1].get("sync_rounds").and_then(Json::as_usize), Some(64));
        let prow = &parsed.get("packed").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(prow.get("problems").and_then(Json::as_usize), Some(4));
        assert_eq!(
            prow.get("packed_replica_periods_per_sec").and_then(Json::as_f64),
            Some(320.0)
        );
        assert_eq!(
            prow.get("unpacked_replica_periods_per_sec").and_then(Json::as_f64),
            Some(213.0)
        );
        let rrow = &parsed.get("rtl").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(rrow.get("engine").and_then(Json::as_str), Some("rtl"));
        assert_eq!(rrow.get("rtl_cut").and_then(Json::as_usize), Some(11));
        assert_eq!(rrow.get("fast_cycles").and_then(Json::as_usize), Some(14_336));
        let rp = &parsed.get("rtl_packed").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(rp.get("problems").and_then(Json::as_usize), Some(4));
        assert_eq!(
            rp.get("packed_fast_cycles").and_then(Json::as_usize),
            rp.get("solo_fast_cycles").and_then(Json::as_usize),
        );
        assert_eq!(
            rp.get("packed_emulated_solves_per_sec").and_then(Json::as_f64),
            Some(8888.0)
        );
        let rc = &parsed.get("rtl_cluster").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(rc.get("shards").and_then(Json::as_usize), Some(2));
        assert_eq!(rc.get("single_device_fit").and_then(Json::as_usize), Some(506));
        assert_eq!(rc.get("fits_device").and_then(Json::as_bool), Some(true));
        assert_eq!(rc.get("sync_fast_cycles").and_then(Json::as_usize), Some(75_000));
        assert_eq!(
            rc.get("compute_fast_cycles").and_then(Json::as_usize),
            Some(225_000)
        );
        let lrow = &parsed.get("latency").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(lrow.get("engine").and_then(Json::as_str), Some("native"));
        assert_eq!(lrow.get("p50_ms").and_then(Json::as_f64), Some(1.024));
        assert_eq!(lrow.get("p99_ms").and_then(Json::as_f64), Some(2.048));
        let crow = &parsed.get("convergence").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(crow.get("chunks").and_then(Json::as_usize), Some(3));
        assert_eq!(crow.get("monotone").and_then(Json::as_bool), Some(true));
        assert_eq!(crow.get("best_energy").and_then(Json::as_arr).map(|a| a.len()), Some(3));
        let srow = &parsed.get("connection_scale").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(srow.get("clients").and_then(Json::as_usize), Some(64));
        assert_eq!(srow.get("speedup").and_then(Json::as_f64), Some(2.5));
        assert_eq!(srow.get("arena_hit_rate").and_then(Json::as_f64), Some(0.9));
        let arow = &parsed.get("associative").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(arow.get("engine").and_then(Json::as_str), Some("sharded"));
        assert_eq!(arow.get("capacity").and_then(Json::as_usize), Some(4));
        assert_eq!(
            arow.get("delta_recalls_per_sec").and_then(Json::as_f64),
            Some(400.0)
        );
        assert_eq!(
            arow.get("rebuild_recalls_per_sec").and_then(Json::as_f64),
            Some(80.0)
        );
        let aload = &arow.get("load").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(aload.get("stores").and_then(Json::as_usize), Some(6));
        assert_eq!(aload.get("accuracy").and_then(Json::as_f64), Some(0.75));
        let sprow = &parsed.get("sparse").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(sprow.get("n").and_then(Json::as_usize), Some(512));
        assert_eq!(sprow.get("avg_row_nnz").and_then(Json::as_f64), Some(25.6));
        assert_eq!(sprow.get("sparse_speedup").and_then(Json::as_f64), Some(8.0));
        assert_eq!(
            sprow.get("sparse_replica_periods_per_sec").and_then(Json::as_f64),
            Some(1280.0)
        );
        assert_eq!(
            sprow.get("dense_weight_bytes").and_then(Json::as_usize),
            Some(512 * 512 * 5)
        );
        assert!(
            doc.to_string().contains("\"engine\":\"rtl\""),
            "the CI gate greps for this literal"
        );
        for key in [
            "\"p50_ms\"",
            "\"convergence\"",
            "\"connection_scale\"",
            "\"speedup\"",
            "\"sparse\"",
            "\"sparse_replica_periods_per_sec\"",
            "\"sparse_speedup\"",
            "\"avg_row_nnz\"",
            "\"rtl_packed\"",
            "\"rtl_cluster\"",
            "\"packed_emulated_solves_per_sec\"",
            "\"solo_emulated_solves_per_sec\"",
            "\"sync_fast_cycles\"",
            "\"compute_fast_cycles\"",
            "\"single_device_fit\"",
            "\"associative\"",
            "\"delta_recalls_per_sec\"",
            "\"rebuild_recalls_per_sec\"",
        ] {
            assert!(doc.to_string().contains(key), "the CI gate greps for {key}");
        }
    }

    #[test]
    fn rtl_rows_price_the_hardware_run() {
        let pts = rtl_comparison(&[8], 2, 16, 5);
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert_eq!(p.engine, "rtl");
        assert!(p.periods > 0 && p.periods <= 16);
        // 2 replica lanes serialized, 16 ticks per period, n + 6 fast
        // cycles per tick.
        assert_eq!(
            p.fast_cycles,
            (2 * p.periods * 16 * (8 + 6)) as u64,
            "fast-cycle meter disagrees with the serialization model"
        );
        assert!(p.emulated_s > 0.0 && p.f_logic_mhz > 0.0);
        assert!(p.native_cut > 0 && p.rtl_cut > 0);
        assert_eq!(p.quantization_error, 0.0, "±1 max-cut couplings are exact");
    }

    #[test]
    fn latency_rows_cover_each_engine_fabric() {
        let rows = latency_percentiles(8, 2, 8, 3, 3, 2, true);
        let engines: Vec<_> = rows.iter().map(|r| r.engine).collect();
        assert_eq!(engines, vec!["native", "sharded", "rtl"]);
        for r in &rows {
            assert_eq!(r.samples, 3);
            assert_eq!(r.summary.count, 3, "every sample lands in a bucket");
            assert!(
                r.summary.p50_ms <= r.summary.p90_ms && r.summary.p90_ms <= r.summary.p99_ms,
                "percentiles ordered on {}",
                r.engine
            );
            assert!(r.summary.mean_ms.is_finite() && r.summary.p99_ms > 0.0);
        }
    }

    #[test]
    fn convergence_traces_are_monotone_per_chunk() {
        let rows = convergence_traces(&[8, 10], 2, 16, 5);
        assert_eq!(rows.len(), 2);
        for c in &rows {
            assert_eq!(c.engine, "native");
            assert!(!c.best_energy.is_empty(), "a solve always runs chunks");
            assert!(c.waves >= 1);
            assert!(c.monotone, "running best energy can only improve");
            let last = *c.best_energy.last().unwrap();
            assert!(
                c.final_energy <= last + 1e-9,
                "polish may improve on the last chunk ({last}), never regress \
                 ({})",
                c.final_energy
            );
        }
    }

    #[test]
    fn connection_scale_rates_both_front_ends() {
        // Tiny scale keeps the test fast; `solve-bench --connections`
        // runs the real 64-client row.  Both front ends must serve real
        // solves inside the window and the evented run must exercise
        // the warm arena (rate in [0, 1]; > 0 once any geometry
        // repeats, which two looping clients guarantee).
        let p = connection_scale(2, 11, Duration::from_millis(300));
        assert_eq!(p.clients, 2);
        assert!(p.baseline_solves > 0, "baseline served no solves");
        assert!(p.evented_solves > 0, "evented front end served no solves");
        assert!(p.baseline_solves_per_sec > 0.0);
        assert!(p.evented_solves_per_sec > 0.0);
        assert!(p.speedup > 0.0);
        assert!((0.0..=1.0).contains(&p.arena_hit_rate));
    }

    #[test]
    fn sparse_rows_rate_both_fabrics_on_identical_work() {
        // Tiny instance keeps this fast; `solve-bench --sparse` runs
        // the real n=512 rows.  The probe inside asserts bit-exact
        // dense==sparse outcomes before any timing happens.
        let rows = sparse_comparison(&[(24, 0.15)], 2, 8, 7);
        assert_eq!(rows.len(), 1);
        let p = &rows[0];
        assert_eq!(p.n, 24);
        assert!(p.density > 0.0 && p.density < 0.25);
        assert!(p.avg_row_nnz > 0.0);
        assert!(p.dense_replica_periods_per_sec > 0.0);
        assert!(p.sparse_replica_periods_per_sec > 0.0);
        assert!(p.sparse_speedup > 0.0);
        assert!(
            p.sparse_weight_bytes < p.dense_weight_bytes,
            "CSR must store less than the dense fabric at this density: {} vs {}",
            p.sparse_weight_bytes,
            p.dense_weight_bytes
        );
        assert!(
            p.hw_sparse_khz > p.hw_dense_khz,
            "the nnz-priced serial MAC must oscillate faster than the n-cycle one"
        );
    }

    #[test]
    fn rtl_packed_row_holds_exact_cycle_parity() {
        // The gates live *inside* the bench fn (bit-exact outcomes,
        // exact fast-cycle parity, emulated rate no worse than solo) —
        // this run exercises them at tiny effort and checks the row.
        let p = rtl_packed_throughput(3, 2, 16, 9);
        assert_eq!(p.problems, 3);
        assert_eq!(p.bucket_n, 16);
        assert_eq!(p.lanes, 6);
        assert_eq!(p.packed_fast_cycles, p.solo_fast_cycles);
        assert!(p.packed_fast_cycles > 0);
        assert!(p.total_periods > 0);
        assert!(p.packed_emulated_solves_per_sec >= p.solo_emulated_solves_per_sec);
        assert!(p.packed_host_median_s > 0.0 && p.solo_host_median_s > 0.0);
    }

    #[test]
    fn rtl_cluster_row_solves_past_the_single_device_fit() {
        // One replica and a short budget keep the cycle-accurate n^2
        // simulation fast; the fn itself asserts the small-n
        // bit-exactness probe, per-shard fit, and nonzero sync share.
        let p = rtl_cluster_scale(2, 1, 8, 5);
        assert_eq!(p.shards, 2);
        assert!(
            p.n > p.single_device_fit,
            "cluster row must exceed the one-device fit ({} vs {})",
            p.n,
            p.single_device_fit
        );
        assert!(p.fits_device);
        assert!(p.sync_fast_cycles > 0);
        assert_eq!(p.fast_cycles, p.compute_fast_cycles + p.sync_fast_cycles);
        assert!(p.emulated_s > 0.0 && p.f_logic_mhz > 0.0);
        assert!(p.periods > 0 && p.periods <= 8);
    }

    #[test]
    fn associative_row_gates_bit_identity() {
        // The gates live *inside* the bench fn (delta-maintained
        // quantized matrix == cold retrain, warm recall spins ==
        // rebuilt recall spins); this run exercises them at a tiny
        // settle budget and checks the row + its load sweep.
        let p = associative_throughput(8, 21);
        assert_eq!(p.n, 32);
        assert_eq!(p.engine, "sharded");
        assert_eq!(p.shards, 2);
        assert!(p.recalls > 0 && p.recalls <= p.capacity);
        assert!(p.delta_recalls_per_sec > 0.0);
        assert!(p.rebuild_recalls_per_sec > 0.0);
        assert!(p.speedup > 0.0);
        assert_eq!(p.load.len(), p.capacity + 2);
        for l in &p.load {
            assert!(l.patterns <= p.capacity, "eviction caps the load");
            assert_eq!(l.trials, l.patterns);
            assert!(l.matched <= l.trials);
            assert!((0.0..=1.0).contains(&l.accuracy));
        }
        // Past-capacity rows kept storing but the space stayed full.
        let last = p.load.last().unwrap();
        assert_eq!(last.stores, p.capacity + 2);
        assert_eq!(last.patterns, p.capacity);
    }

    #[test]
    fn packed_point_rates_a_real_mix() {
        // Small mix, tiny effort: the row must show several problems
        // sharing one engine and positive rates for both serving modes
        // (the probe inside asserts packed == solo answers before any
        // timing happens).
        let p = packed_throughput(3, 2, 16, 9);
        assert!(p.problems > 1, "the mix must actually share an engine");
        assert_eq!(p.problems, 3);
        assert!(p.bucket_n >= 14 && p.bucket_n.is_power_of_two());
        assert!(p.packed_rps > 0.0);
        assert!(p.unpacked_rps > 0.0);
    }
}
