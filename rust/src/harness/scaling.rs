//! Hardware scaling sweep driver: regenerates Tables 4-5 and Figures
//! 9-12 from the FPGA resource/timing models (paper section 4.2).

use crate::fpga::device::{zynq7020, Device};
use crate::fpga::regression::{loglog_fit, Fit};
use crate::fpga::resources::{estimate, max_oscillators, ResourceEstimate};
use crate::fpga::timing::frequencies;
use crate::onn::config::NetworkConfig;

/// One synthesized design point.
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    pub n: usize,
    pub res: ResourceEstimate,
    pub f_logic_mhz: f64,
    pub f_osc_khz: f64,
}

/// A full sweep over network sizes for one architecture.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub arch: &'static str,
    pub device: Device,
    pub points: Vec<DesignPoint>,
}

/// Sweep sizes used for the paper figures: the recurrent sweep stops at
/// its resource wall (48), the hybrid sweep reaches its own (506).
pub fn recurrent_sweep_sizes() -> Vec<usize> {
    vec![4, 8, 12, 16, 20, 24, 32, 40, 48]
}

pub fn hybrid_sweep_sizes() -> Vec<usize> {
    vec![16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 506]
}

pub fn sweep(arch: &'static str, sizes: &[usize]) -> Sweep {
    let device = zynq7020();
    let points = sizes
        .iter()
        .map(|&n| {
            let cfg = NetworkConfig::paper(n);
            let res = estimate(arch, &cfg, &device);
            let (f_logic, f_osc) = frequencies(arch, &cfg, &device);
            DesignPoint {
                n,
                res,
                f_logic_mhz: f_logic,
                f_osc_khz: f_osc,
            }
        })
        .collect();
    Sweep {
        arch,
        device,
        points,
    }
}

pub fn recurrent_sweep() -> Sweep {
    sweep("recurrent", &recurrent_sweep_sizes())
}

pub fn hybrid_sweep() -> Sweep {
    sweep("hybrid", &hybrid_sweep_sizes())
}

impl Sweep {
    fn xs(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.n as f64).collect()
    }

    /// Figure 9: log-log fit of LUT usage vs N.
    pub fn lut_fit(&self) -> Fit {
        let ys: Vec<f64> = self.points.iter().map(|p| p.res.luts as f64).collect();
        loglog_fit(&self.xs(), &ys)
    }

    /// Figure 10: log-log fit of FF usage vs N.
    pub fn ff_fit(&self) -> Fit {
        let ys: Vec<f64> = self.points.iter().map(|p| p.res.ffs as f64).collect();
        loglog_fit(&self.xs(), &ys)
    }

    /// Figure 11: log-log fit of oscillation frequency vs N.
    pub fn freq_fit(&self) -> Fit {
        let ys: Vec<f64> = self.points.iter().map(|p| p.f_osc_khz).collect();
        loglog_fit(&self.xs(), &ys)
    }
}

/// Figure 12 data: hybrid area%% and %% of max oscillation frequency.
#[derive(Debug, Clone)]
pub struct BalancePoint {
    pub n: usize,
    pub area_pct: f64,
    pub freq_pct: f64,
}

pub fn fig12_balance(sweep: &Sweep) -> Vec<BalancePoint> {
    let fmax = sweep
        .points
        .iter()
        .map(|p| p.f_osc_khz)
        .fold(f64::NEG_INFINITY, f64::max);
    sweep
        .points
        .iter()
        .map(|p| BalancePoint {
            n: p.n,
            area_pct: p.res.area_percent(&sweep.device),
            freq_pct: 100.0 * p.f_osc_khz / fmax,
        })
        .collect()
}

/// The crossover of the two Fig.-12 curves (linear interpolation between
/// sweep points): the paper finds N ~ 65 at ~15%.
pub fn fig12_crossover(balance: &[BalancePoint]) -> Option<(f64, f64)> {
    for w in balance.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        let fa = a.freq_pct - a.area_pct;
        let fb = b.freq_pct - b.area_pct;
        if fa >= 0.0 && fb < 0.0 {
            let t = fa / (fa - fb);
            let n = a.n as f64 + t * (b.n - a.n) as f64;
            let pct = a.area_pct + t * (b.area_pct - a.area_pct);
            return Some((n, pct));
        }
    }
    None
}

/// Table 5 summary for one architecture at its maximum size.
#[derive(Debug, Clone, Copy)]
pub struct Table5Row {
    pub arch: &'static str,
    pub max_n: usize,
    pub f_logic_mhz: f64,
    pub f_osc_khz: f64,
}

pub fn table5_rows() -> Vec<Table5Row> {
    let d = zynq7020();
    ["hybrid", "recurrent"]
        .into_iter()
        .map(|arch| {
            let max_n = max_oscillators(arch, &d, 4, 5);
            let cfg = NetworkConfig::paper(max_n);
            let (f_logic, f_osc) = frequencies(arch, &cfg, &d);
            Table5Row {
                arch: if arch == "hybrid" { "Hybrid" } else { "Recurrent" },
                max_n,
                f_logic_mhz: f_logic,
                f_osc_khz: f_osc,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 9 shape: RA slightly above quadratic, HA slightly above
    /// linear (paper: 2.08 and 1.22).
    #[test]
    fn fig9_lut_slopes() {
        let ra = recurrent_sweep().lut_fit();
        let ha = hybrid_sweep().lut_fit();
        assert!(
            (1.9..=2.3).contains(&ra.slope),
            "RA LUT slope {:.3} (paper 2.08)",
            ra.slope
        );
        assert!(
            (1.05..=1.40).contains(&ha.slope),
            "HA LUT slope {:.3} (paper 1.22)",
            ha.slope
        );
        assert!(ra.r2 > 0.97, "RA r2 {:.4}", ra.r2);
        assert!(ha.r2 > 0.97, "HA r2 {:.4}", ha.r2);
    }

    /// Figure 10 shape: RA well above linear approaching quadratic
    /// (paper 2.39 with R2 0.906 and an admitted outlier), HA near
    /// linear (paper 1.11).
    #[test]
    fn fig10_ff_slopes() {
        let ra = recurrent_sweep().ff_fit();
        let ha = hybrid_sweep().ff_fit();
        assert!(
            (1.45..=2.5).contains(&ra.slope),
            "RA FF slope {:.3} (paper 2.39, noisy)",
            ra.slope
        );
        assert!(
            (1.0..=1.25).contains(&ha.slope),
            "HA FF slope {:.3} (paper 1.11)",
            ha.slope
        );
    }

    /// Figure 11 shape: RA ~ -0.46, HA steeper than -1 (paper -1.35).
    #[test]
    fn fig11_freq_slopes() {
        let ra = recurrent_sweep().freq_fit();
        let ha = hybrid_sweep().freq_fit();
        assert!(
            (-0.65..=-0.30).contains(&ra.slope),
            "RA f_osc slope {:.3} (paper -0.46)",
            ra.slope
        );
        assert!(
            (-1.5..=-0.95).contains(&ha.slope),
            "HA f_osc slope {:.3} (paper -1.35)",
            ha.slope
        );
    }

    /// Figure 12 shape: crossover in the N ~ 50-120 band at 10-20% area.
    #[test]
    fn fig12_crossover_band() {
        let sweep = hybrid_sweep();
        let bal = fig12_balance(&sweep);
        let (n, pct) = fig12_crossover(&bal).expect("no crossover found");
        assert!(
            (40.0..=130.0).contains(&n),
            "crossover N = {n:.0} (paper ~65)"
        );
        assert!(
            (8.0..=25.0).contains(&pct),
            "crossover area = {pct:.1}% (paper ~15%)"
        );
    }

    #[test]
    fn table5_matches_paper_shape() {
        let rows = table5_rows();
        let hy = rows.iter().find(|r| r.arch == "Hybrid").unwrap();
        let ra = rows.iter().find(|r| r.arch == "Recurrent").unwrap();
        let ratio = hy.max_n as f64 / ra.max_n as f64;
        assert!((9.0..=11.5).contains(&ratio), "ratio {ratio:.2} (paper 10.5)");
        assert!(hy.f_logic_mhz > ra.f_logic_mhz, "paper: 50 vs 40 MHz");
        assert!(ra.f_osc_khz > hy.f_osc_khz, "paper: 625 vs 6.1 kHz");
    }

    #[test]
    fn balance_percentages_bounded() {
        let bal = fig12_balance(&hybrid_sweep());
        for b in &bal {
            assert!((0.0..=100.0 + 1e-9).contains(&b.freq_pct));
            assert!((0.0..=100.0).contains(&b.area_pct), "{b:?}");
        }
    }
}
