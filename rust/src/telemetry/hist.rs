//! Log-bucketed latency histograms (DESIGN_SOLVER.md §9).
//!
//! The coordinator's hot path records durations with one atomic add per
//! sample — no locks, no allocation — into power-of-two microsecond
//! buckets: bucket 0 holds sub-microsecond samples, bucket `i` (i >= 1)
//! holds `[2^(i-1), 2^i)` µs.  Forty buckets cover everything from
//! sub-µs up to ~6 days, which is more than any solve or retrieval
//! latency this stack can produce.  Percentiles are estimated at
//! snapshot time from the bucket counts and reported as each bucket's
//! upper bound (a conservative over-estimate, never an under-estimate).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of power-of-two buckets; bucket `BUCKETS - 1` absorbs
/// everything at or above `2^(BUCKETS - 2)` µs.
pub const BUCKETS: usize = 40;

/// Bucket index for a sample of `us` microseconds.
fn bucket_index(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i`, in milliseconds.
pub fn bucket_upper_ms(i: usize) -> f64 {
    // Bucket i covers [2^(i-1), 2^i) µs, so its upper bound is 2^i µs.
    (1u64 << i) as f64 / 1e3
}

/// Percentile snapshot of one histogram.  All fields are finite for
/// every histogram state, including empty (zeros, never NaN).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
}

/// Lock-free log-bucketed histogram: one atomic add per sample.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// One consistent read of every bucket counter.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    pub fn count(&self) -> u64 {
        self.bucket_counts().iter().sum()
    }

    /// Percentiles from the bucket counts.  The count and the
    /// percentiles come from one bucket snapshot, so the summary is
    /// internally consistent even under concurrent recording.
    pub fn summary(&self) -> LatencySummary {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return LatencySummary::default();
        }
        let sum_us = self.sum_us.load(Ordering::Relaxed);
        LatencySummary {
            count: total,
            mean_ms: sum_us as f64 / total as f64 / 1e3,
            p50_ms: percentile(&counts, total, 0.50),
            p90_ms: percentile(&counts, total, 0.90),
            p99_ms: percentile(&counts, total, 0.99),
        }
    }
}

/// Upper bound (ms) of the bucket holding the q-th quantile sample.
fn percentile(counts: &[u64; BUCKETS], total: u64, q: f64) -> f64 {
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return bucket_upper_ms(i);
        }
    }
    bucket_upper_ms(BUCKETS - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_power_of_two_microseconds() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper_ms(0), 0.001);
        assert_eq!(bucket_upper_ms(11), 2.048);
    }

    #[test]
    fn empty_summary_is_all_zero_and_finite() {
        let h = LatencyHistogram::new();
        let s = h.summary();
        assert_eq!(s, LatencySummary::default());
        for v in [s.mean_ms, s.p50_ms, s.p90_ms, s.p99_ms] {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn percentiles_bound_the_samples_and_stay_ordered() {
        let h = LatencyHistogram::new();
        // 100 samples: 1..=100 ms.
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50_ms <= s.p90_ms && s.p90_ms <= s.p99_ms);
        // Upper-bound estimates never under-report...
        assert!(s.p50_ms >= 50.0, "p50 {} under the true median", s.p50_ms);
        // ...and stay within one power of two of the true value.
        assert!(s.p50_ms <= 128.0, "p50 {} too coarse", s.p50_ms);
        assert!(s.p99_ms <= 256.0, "p99 {} too coarse", s.p99_ms);
        assert!((s.mean_ms - 50.5).abs() < 0.5, "mean {}", s.mean_ms);
    }

    #[test]
    fn bucket_counts_sum_to_sample_count() {
        let h = LatencyHistogram::new();
        for us in [0u64, 1, 7, 900, 1024, 1_000_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 6);
    }
}
