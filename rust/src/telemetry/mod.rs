//! Observability for the solver stack (DESIGN_SOLVER.md §9): the
//! solve-lifecycle trace recorder threaded through the portfolio and
//! the engines, and the log-bucketed latency histograms behind the
//! coordinator's `Metrics` percentiles and the `"type": "metrics"`
//! wire command.

pub mod hist;
pub mod trace;

pub use hist::{bucket_upper_ms, LatencyHistogram, LatencySummary, BUCKETS};
pub use trace::{
    sink, validate_trace_jsonl, TraceEvent, TraceRecord, TraceRecorder, TraceSink,
    DEFAULT_TRACE_CAP,
};
