//! Solve-lifecycle tracing (DESIGN_SOLVER.md §9): a ring-buffered
//! span/event recorder with monotonic timestamps, cheap enough to leave
//! compiled into the hot path (recording is a `RefCell` borrow plus a
//! `VecDeque` push; disabled tracing costs one `Option` test).
//!
//! The recorder observes the solve — it never participates in it.  The
//! portfolio and the engines record values they already computed, and
//! draw nothing from any RNG, so a traced solve is bit-identical to an
//! untraced one (`rust/tests/integration_telemetry.rs` proves it).
//!
//! Export formats: JSONL (one record per line, `solve --trace <path>`)
//! and the compact wire attachment (`"trace": true` on a solve
//! request).  Both flatten every record to the same schema, validated
//! by [`validate_trace_jsonl`] (the `trace-check` CLI gate).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;

use crate::util::json::Json;

/// Default ring capacity: enough for every chunk of a 64-replica,
/// 256-period solve with engine spans, small enough to ship on the wire.
pub const DEFAULT_TRACE_CAP: usize = 4096;

/// One lifecycle event.  Field meanings are part of the telemetry
/// contract (DESIGN_SOLVER.md §9); energies are the solver's objective
/// values, timestamps live on the enclosing [`TraceRecord`].
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Portfolio accepted the problem and programmed the engine.
    SolveStart {
        n: usize,
        engine: &'static str,
        replicas: usize,
    },
    /// A wave of `lanes` fresh replicas started annealing.
    WaveStart { wave: usize, lanes: usize },
    /// One anneal chunk finished: the running best energy across all
    /// waves so far (monotone non-increasing) and this wave's settled
    /// lane count after the chunk.
    Chunk {
        wave: usize,
        chunk: usize,
        noise: f64,
        best_energy: f64,
        settled_lanes: usize,
    },
    /// The wave retired.  `exit` is "completed" (ran every chunk),
    /// "all_settled", or "plateau" (early exits).
    WaveEnd {
        wave: usize,
        lanes: usize,
        settled_lanes: usize,
        chunks: usize,
        exit: &'static str,
    },
    /// Greedy single-flip polish on one replica's readout.
    Polish {
        replica: usize,
        pre_energy: f64,
        post_energy: f64,
    },
    /// One engine `run_chunk` span: host step time plus the engine's
    /// own meters over the chunk (all deltas, zero where a fabric has
    /// no such meter — sync for sharded, fast cycles for rtl).
    EngineChunk {
        engine: &'static str,
        period0: i64,
        step_us: u64,
        sync_rounds: u64,
        sync_us: u64,
        fast_cycles: u64,
    },
    /// Portfolio readout done.
    SolveEnd {
        best_energy: f64,
        periods: usize,
        settled_replicas: usize,
    },
}

impl TraceEvent {
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::SolveStart { .. } => "solve_start",
            TraceEvent::WaveStart { .. } => "wave_start",
            TraceEvent::Chunk { .. } => "chunk",
            TraceEvent::WaveEnd { .. } => "wave_end",
            TraceEvent::Polish { .. } => "polish",
            TraceEvent::EngineChunk { .. } => "engine_chunk",
            TraceEvent::SolveEnd { .. } => "solve_end",
        }
    }
}

/// One recorded event with its sequence number and microseconds since
/// the recorder's origin (monotonic: `t_us` never decreases, `seq`
/// strictly increases even across ring-buffer drops).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub seq: u64,
    pub t_us: u64,
    pub event: TraceEvent,
}

impl TraceRecord {
    /// Flatten to the documented JSONL/wire schema: `seq`, `t_us`,
    /// `event`, plus the event's own fields at the top level.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("seq", Json::num(self.seq as f64)),
            ("t_us", Json::num(self.t_us as f64)),
            ("event", Json::str(self.event.name())),
        ];
        match &self.event {
            TraceEvent::SolveStart {
                n,
                engine,
                replicas,
            } => {
                fields.push(("n", Json::num(*n as f64)));
                fields.push(("engine", Json::str(engine)));
                fields.push(("replicas", Json::num(*replicas as f64)));
            }
            TraceEvent::WaveStart { wave, lanes } => {
                fields.push(("wave", Json::num(*wave as f64)));
                fields.push(("lanes", Json::num(*lanes as f64)));
            }
            TraceEvent::Chunk {
                wave,
                chunk,
                noise,
                best_energy,
                settled_lanes,
            } => {
                fields.push(("wave", Json::num(*wave as f64)));
                fields.push(("chunk", Json::num(*chunk as f64)));
                fields.push(("noise", Json::num(*noise)));
                fields.push(("best_energy", Json::num(*best_energy)));
                fields.push(("settled_lanes", Json::num(*settled_lanes as f64)));
            }
            TraceEvent::WaveEnd {
                wave,
                lanes,
                settled_lanes,
                chunks,
                exit,
            } => {
                fields.push(("wave", Json::num(*wave as f64)));
                fields.push(("lanes", Json::num(*lanes as f64)));
                fields.push(("settled_lanes", Json::num(*settled_lanes as f64)));
                fields.push(("chunks", Json::num(*chunks as f64)));
                fields.push(("exit", Json::str(exit)));
            }
            TraceEvent::Polish {
                replica,
                pre_energy,
                post_energy,
            } => {
                fields.push(("replica", Json::num(*replica as f64)));
                fields.push(("pre_energy", Json::num(*pre_energy)));
                fields.push(("post_energy", Json::num(*post_energy)));
            }
            TraceEvent::EngineChunk {
                engine,
                period0,
                step_us,
                sync_rounds,
                sync_us,
                fast_cycles,
            } => {
                fields.push(("engine", Json::str(engine)));
                fields.push(("period0", Json::num(*period0 as f64)));
                fields.push(("step_us", Json::num(*step_us as f64)));
                fields.push(("sync_rounds", Json::num(*sync_rounds as f64)));
                fields.push(("sync_us", Json::num(*sync_us as f64)));
                fields.push(("fast_cycles", Json::num(*fast_cycles as f64)));
            }
            TraceEvent::SolveEnd {
                best_energy,
                periods,
                settled_replicas,
            } => {
                fields.push(("best_energy", Json::num(*best_energy)));
                fields.push(("periods", Json::num(*periods as f64)));
                fields.push(("settled_replicas", Json::num(*settled_replicas as f64)));
            }
        }
        Json::obj(fields)
    }
}

/// Ring-buffered recorder.  When the ring is full the oldest record is
/// dropped (and counted) — the tail of a solve is always retained.
#[derive(Debug)]
pub struct TraceRecorder {
    origin: Instant,
    cap: usize,
    next_seq: u64,
    dropped: u64,
    records: VecDeque<TraceRecord>,
}

impl TraceRecorder {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            origin: Instant::now(),
            cap,
            next_seq: 0,
            dropped: 0,
            records: VecDeque::with_capacity(cap.min(1024)),
        }
    }

    pub fn record(&mut self, event: TraceEvent) {
        if self.records.len() == self.cap {
            self.records.pop_front();
            self.dropped += 1;
        }
        let t_us = self.origin.elapsed().as_micros().min(u64::MAX as u128) as u64;
        self.records.push_back(TraceRecord {
            seq: self.next_seq,
            t_us,
            event,
        });
        self.next_seq += 1;
    }

    pub fn records(&self) -> &VecDeque<TraceRecord> {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records dropped to the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Move the retained records out (e.g. into a `SolveResult`).
    pub fn take(&mut self) -> Vec<TraceRecord> {
        self.records.drain(..).collect()
    }

    /// One JSON object per line, newline-terminated.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

/// Shared handle threaded through the (single-threaded, `!Send`) solve
/// path: the portfolio and the engine both hold one.
pub type TraceSink = Rc<RefCell<TraceRecorder>>;

/// A fresh sink with the given ring capacity.
pub fn sink(cap: usize) -> TraceSink {
    Rc::new(RefCell::new(TraceRecorder::new(cap)))
}

/// Required per-event fields: `(numeric fields, string fields)`.
fn schema(event: &str) -> Option<(&'static [&'static str], &'static [&'static str])> {
    Some(match event {
        "solve_start" => (&["n", "replicas"][..], &["engine"][..]),
        "wave_start" => (&["wave", "lanes"][..], &[][..]),
        "chunk" => (
            &["wave", "chunk", "noise", "best_energy", "settled_lanes"][..],
            &[][..],
        ),
        "wave_end" => (
            &["wave", "lanes", "settled_lanes", "chunks"][..],
            &["exit"][..],
        ),
        "polish" => (&["replica", "pre_energy", "post_energy"][..], &[][..]),
        "engine_chunk" => (
            &["period0", "step_us", "sync_rounds", "sync_us", "fast_cycles"][..],
            &["engine"][..],
        ),
        "solve_end" => (
            &["best_energy", "periods", "settled_replicas"][..],
            &[][..],
        ),
        _ => return None,
    })
}

/// Validate a JSONL trace export against the documented schema: every
/// line parses, carries `seq`/`t_us`/`event`, `seq` strictly increases,
/// `t_us` never decreases, the event name is known, and the event's
/// required fields are present with the right types.  Returns the
/// record count.
pub fn validate_trace_jsonl(text: &str) -> Result<usize, String> {
    let mut count = 0usize;
    let mut prev_seq: Option<u64> = None;
    let mut prev_t: Option<u64> = None;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ln = i + 1;
        let v = Json::parse(line).map_err(|e| format!("line {ln}: bad JSON: {e}"))?;
        let seq = v
            .get("seq")
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("line {ln}: missing numeric 'seq'"))? as u64;
        let t_us = v
            .get("t_us")
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("line {ln}: missing numeric 't_us'"))? as u64;
        let event = v
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("line {ln}: missing string 'event'"))?
            .to_string();
        if let Some(p) = prev_seq {
            if seq <= p {
                return Err(format!("line {ln}: seq {seq} not above previous {p}"));
            }
        }
        if let Some(p) = prev_t {
            if t_us < p {
                return Err(format!("line {ln}: t_us {t_us} below previous {p}"));
            }
        }
        let (nums, strs) =
            schema(&event).ok_or_else(|| format!("line {ln}: unknown event '{event}'"))?;
        for k in nums {
            if v.get(k).and_then(Json::as_f64).is_none() {
                return Err(format!("line {ln}: event '{event}' missing numeric '{k}'"));
            }
        }
        for k in strs {
            if v.get(k).and_then(Json::as_str).is_none() {
                return Err(format!("line {ln}: event '{event}' missing string '{k}'"));
            }
        }
        prev_seq = Some(seq);
        prev_t = Some(t_us);
        count += 1;
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: usize) -> TraceEvent {
        TraceEvent::Chunk {
            wave: 0,
            chunk: i,
            noise: 0.5,
            best_energy: -(i as f64),
            settled_lanes: i,
        }
    }

    #[test]
    fn ring_drops_oldest_and_keeps_seq_monotone() {
        let mut rec = TraceRecorder::new(3);
        for i in 0..5 {
            rec.record(ev(i));
        }
        assert_eq!(rec.len(), 3);
        assert_eq!(rec.dropped(), 2);
        let seqs: Vec<u64> = rec.records().iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4], "oldest records dropped");
        let ts: Vec<u64> = rec.records().iter().map(|r| r.t_us).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "timestamps monotone");
    }

    #[test]
    fn jsonl_round_trips_through_the_validator() {
        let mut rec = TraceRecorder::new(64);
        rec.record(TraceEvent::SolveStart {
            n: 8,
            engine: "native",
            replicas: 4,
        });
        rec.record(TraceEvent::WaveStart { wave: 0, lanes: 4 });
        rec.record(ev(0));
        rec.record(TraceEvent::EngineChunk {
            engine: "sharded",
            period0: 0,
            step_us: 12,
            sync_rounds: 8,
            sync_us: 3,
            fast_cycles: 0,
        });
        rec.record(TraceEvent::WaveEnd {
            wave: 0,
            lanes: 4,
            settled_lanes: 4,
            chunks: 1,
            exit: "all_settled",
        });
        rec.record(TraceEvent::Polish {
            replica: 0,
            pre_energy: -3.0,
            post_energy: -4.0,
        });
        rec.record(TraceEvent::SolveEnd {
            best_energy: -4.0,
            periods: 8,
            settled_replicas: 4,
        });
        let jsonl = rec.to_jsonl();
        assert_eq!(validate_trace_jsonl(&jsonl).unwrap(), 7);
    }

    #[test]
    fn validator_rejects_schema_violations() {
        let ok = r#"{"seq":0,"t_us":1,"event":"wave_start","wave":0,"lanes":2}"#;
        assert_eq!(validate_trace_jsonl(ok).unwrap(), 1);
        for (bad, why) in [
            (r#"{"t_us":1,"event":"wave_start","wave":0,"lanes":2}"#, "no seq"),
            (r#"{"seq":0,"t_us":1,"event":"nope"}"#, "unknown event"),
            (r#"{"seq":0,"t_us":1,"event":"wave_start","wave":0}"#, "missing field"),
            (
                r#"{"seq":0,"t_us":1,"event":"wave_end","wave":0,"lanes":1,"settled_lanes":0,"chunks":1,"exit":3}"#,
                "exit must be a string",
            ),
            ("not json", "parse error"),
        ] {
            assert!(validate_trace_jsonl(bad).is_err(), "{why}");
        }
        // Ordering violations across lines.
        let unordered_seq = format!("{ok}\n{ok}");
        assert!(validate_trace_jsonl(&unordered_seq).is_err(), "seq must rise");
        let t_back = r#"{"seq":0,"t_us":9,"event":"wave_start","wave":0,"lanes":2}
{"seq":1,"t_us":3,"event":"wave_start","wave":1,"lanes":2}"#;
        assert!(validate_trace_jsonl(t_back).is_err(), "t_us must not rewind");
    }
}
