//! Online-learning associative memory: the coordinator's third traffic
//! class (`store` / `recall` / `forget`), serving the paper's original
//! retrieval workload with *live* pattern programming.
//!
//! Every named **memory space** keeps the float master matrix of its
//! stored patterns as exact integer Hebbian co-occurrence counts
//! (`onn::learning::accumulate_outer`): integer adds commute and invert
//! exactly, so the incremental master after any store/forget sequence
//! is bit-identical to retraining from the surviving pattern set — and
//! therefore the quantized matrix a delta reprogram installs
//! (`WeightMatrix::apply_delta`) is bit-identical to a cold
//! retrain+rebuild.  Recalls snapshot the quantized weights under the
//! registry lock and settle on a warm arena engine reprogrammed via
//! `set_weights` — the reprogram-as-hot-path serving model the paper's
//! hardware targets, proven bit-identical to cold builds on the native,
//! sharded, and rtl fabrics (`rust/tests/prop_assoc.rs`).
//!
//! Capacity follows the classical Hopfield retrieval bound the paper's
//! tables trace (~0.138 n): storing past it evicts the least-recently
//! used pattern (recency = last store or last matched recall).
//! Duplicate stores — exact *or inverted*, since an inverted pattern's
//! outer product is identical — are idempotent recency refreshes, never
//! a second Hebbian contribution (DESIGN_SOLVER.md §13).

use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::arena::{ArenaKey, EngineArena};
use crate::coordinator::job::{RecallRequest, RecallResult};
use crate::coordinator::metrics::Metrics;
use crate::onn::config::NetworkConfig;
use crate::onn::learning::{accumulate_outer, counts_to_master, diederich_opper_i};
use crate::onn::patterns::spins_match_up_to_inversion;
use crate::onn::phase::{spin_to_phase, state_to_spins};
use crate::onn::weights::WeightMatrix;
use crate::runtime::ChunkEngine;
use crate::solver::portfolio::{build_engine_cfg, drive_retrieval, EngineSelect, DEFAULT_CHUNK};

/// DO-I refinement parameters (the paper's training pipeline).
const DOI_MARGIN: f32 = 0.5;
const DOI_MAX_EPOCHS: usize = 1000;

/// Default pattern capacity of an n-oscillator space: the classical
/// Hopfield retrieval bound `0.138 n` the paper's tables trace, floored
/// at 2 so even the 3x3 toy space holds a pair.
pub fn capacity_for(n: usize) -> usize {
    (n * 138 / 1000).max(2)
}

/// Which learning rule maintains a space's float master matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearningRule {
    /// Plain Hebbian outer products — O(n^2) incremental updates via
    /// the integer count master.
    Hebbian,
    /// Hebbian counts refined by a full Diederich-Opper-I retrain over
    /// the stored patterns (in storage order, so the retrain is
    /// deterministic) on every mutation.
    Doi,
}

impl LearningRule {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "hebbian" => Ok(Self::Hebbian),
            "doi" => Ok(Self::Doi),
            other => Err(anyhow!(
                "unknown learning rule '{other}' (want 'hebbian' or 'doi')"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Hebbian => "hebbian",
            Self::Doi => "doi",
        }
    }
}

/// One stored pattern with its LRU stamp.
#[derive(Debug, Clone)]
struct StoredPattern {
    spins: Vec<i8>,
    last_used: u64,
}

/// Outcome of a `store` mutation.
#[derive(Debug, Clone)]
pub struct StoreOutcome {
    /// The pattern (or its inverse) was already stored: recency was
    /// refreshed, nothing else changed.
    pub duplicate: bool,
    /// Patterns evicted by the capacity policy (0 or 1).
    pub evicted: usize,
    /// Stored patterns after the mutation.
    pub patterns: usize,
    pub capacity: usize,
    /// Quantized entries the delta reprogram actually rewrote.
    pub delta_entries: usize,
    /// RMS quantization loss of the new master.
    pub quantization_error: f64,
    /// Master-update + requantize wall time.
    pub delta_latency: Duration,
}

/// Outcome of a `forget` mutation.
#[derive(Debug, Clone)]
pub struct ForgetOutcome {
    /// Stored patterns after the removal.
    pub patterns: usize,
    pub delta_entries: usize,
    pub quantization_error: f64,
    pub delta_latency: Duration,
}

/// Everything a recall needs, captured under the registry lock at
/// submit time so the settle runs against one consistent master even
/// while stores keep mutating the space.
#[derive(Debug, Clone)]
pub struct RecallSnapshot {
    pub n: usize,
    /// Quantized weights as the integer-valued f32 view every engine's
    /// `set_weights` installs.
    pub weights_f32: Vec<f32>,
    /// Stored patterns at snapshot time (the match targets).
    pub patterns: Vec<Vec<i8>>,
    /// Master version the snapshot was taken at.
    pub version: u64,
}

/// Internal envelope for recall traffic: request + consistent snapshot
/// + reply channel.  Errors (engine failures) travel back as `Err` so
/// the front ends can answer a structured error line.
#[derive(Debug)]
pub struct RecallJob {
    pub req: RecallRequest,
    pub snapshot: RecallSnapshot,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<Result<RecallResult>>,
}

/// One named memory space: the live pattern set, its exact integer
/// Hebbian count master, and the quantized matrix currently programmed
/// into recall engines.
#[derive(Debug)]
pub struct MemorySpace {
    pub n: usize,
    capacity: usize,
    rule: LearningRule,
    /// Exact integer Hebbian co-occurrence counts (the incremental
    /// master; see module docs for the bit-identity argument).
    counts: Vec<i32>,
    /// Stored patterns in storage order (DO-I retrains iterate this
    /// order, so the refined master is deterministic too).
    patterns: Vec<StoredPattern>,
    /// LRU clock: bumped by every store and every matched recall.
    clock: u64,
    /// The quantized matrix recalls are served from, maintained by
    /// [`WeightMatrix::apply_delta`] — bit-identical to quantizing the
    /// master cold.
    quantized: WeightMatrix,
    quantization_error: f64,
    /// Bumped by every successful mutation; recalls carry the version
    /// they were served against so stale LRU touches are dropped.
    version: u64,
    cfg: NetworkConfig,
}

impl MemorySpace {
    pub fn new(n: usize, capacity: usize, rule: LearningRule) -> Self {
        assert!(n > 0, "empty memory space");
        assert!(capacity > 0, "zero-capacity memory space");
        Self {
            n,
            capacity,
            rule,
            counts: vec![0; n * n],
            patterns: Vec::new(),
            clock: 0,
            quantized: WeightMatrix::zeros(n),
            quantization_error: 0.0,
            version: 0,
            cfg: NetworkConfig::paper(n),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn rule(&self) -> LearningRule {
        self.rule
    }

    pub fn pattern_count(&self) -> usize {
        self.patterns.len()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// The quantized matrix recalls are currently served from.
    pub fn weights(&self) -> &WeightMatrix {
        &self.quantized
    }

    pub fn quantization_error(&self) -> f64 {
        self.quantization_error
    }

    /// Stored patterns in storage order (the cold-retrain input).
    pub fn stored_patterns(&self) -> Vec<Vec<i8>> {
        self.patterns.iter().map(|p| p.spins.clone()).collect()
    }

    /// The float master matrix of the current pattern set.  Hebbian
    /// reads the integer counts (one divide per entry — bit-identical
    /// to `learning::hebbian` over the survivors); DO-I retrains over
    /// the stored patterns in storage order.
    pub fn master(&self) -> Vec<f32> {
        match self.rule {
            LearningRule::Hebbian => counts_to_master(&self.counts, self.n),
            LearningRule::Doi => {
                if self.patterns.is_empty() {
                    vec![0.0; self.n * self.n]
                } else {
                    let pats = self.stored_patterns();
                    diederich_opper_i(&pats, DOI_MARGIN, DOI_MAX_EPOCHS).weights
                }
            }
        }
    }

    /// Store one ±1 pattern.  Duplicates (exact or inverted) are
    /// idempotent recency refreshes; at capacity the LRU pattern is
    /// evicted first; otherwise the master is updated incrementally and
    /// the quantized matrix delta-reprogrammed.
    pub fn store(&mut self, spins: Vec<i8>) -> Result<StoreOutcome> {
        self.check_pattern(&spins)?;
        if let Some(idx) = self.position_of(&spins) {
            self.clock += 1;
            self.patterns[idx].last_used = self.clock;
            return Ok(StoreOutcome {
                duplicate: true,
                evicted: 0,
                patterns: self.patterns.len(),
                capacity: self.capacity,
                delta_entries: 0,
                quantization_error: self.quantization_error,
                delta_latency: Duration::ZERO,
            });
        }
        let mut evicted = 0usize;
        if self.patterns.len() >= self.capacity {
            let lru = self
                .patterns
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| p.last_used)
                .map(|(i, _)| i)
                .expect("space at capacity has at least one pattern");
            // `remove`, not `swap_remove`: storage order is the DO-I
            // retrain order, so survivors must keep their positions.
            let victim = self.patterns.remove(lru);
            accumulate_outer(&mut self.counts, &victim.spins, -1);
            evicted = 1;
        }
        accumulate_outer(&mut self.counts, &spins, 1);
        self.clock += 1;
        self.patterns.push(StoredPattern {
            spins,
            last_used: self.clock,
        });
        let t0 = Instant::now();
        let (delta_entries, quantization_error) = self.reprogram();
        Ok(StoreOutcome {
            duplicate: false,
            evicted,
            patterns: self.patterns.len(),
            capacity: self.capacity,
            delta_entries,
            quantization_error,
            delta_latency: t0.elapsed(),
        })
    }

    /// Remove one stored pattern (matched up to inversion).  A pattern
    /// that is not stored is a structured error, not a no-op — the
    /// client's model of the space diverged from the server's.
    pub fn forget(&mut self, spins: &[i8]) -> Result<ForgetOutcome> {
        self.check_pattern(spins)?;
        let idx = self
            .position_of(spins)
            .ok_or_else(|| anyhow!("pattern is not stored in this space"))?;
        let victim = self.patterns.remove(idx);
        accumulate_outer(&mut self.counts, &victim.spins, -1);
        let t0 = Instant::now();
        let (delta_entries, quantization_error) = self.reprogram();
        Ok(ForgetOutcome {
            patterns: self.patterns.len(),
            delta_entries,
            quantization_error,
            delta_latency: t0.elapsed(),
        })
    }

    /// Snapshot for one recall: quantized weights + match targets +
    /// version, all captured atomically (the caller holds the registry
    /// lock).
    pub fn snapshot(&self) -> RecallSnapshot {
        RecallSnapshot {
            n: self.n,
            weights_f32: self.quantized.to_f32(),
            patterns: self.stored_patterns(),
            version: self.version,
        }
    }

    /// Refresh the recency of the stored pattern matching `spins`
    /// (a successful recall keeps its memory warm in the LRU order).
    fn touch(&mut self, spins: &[i8]) {
        if let Some(idx) = self.position_of(spins) {
            self.clock += 1;
            self.patterns[idx].last_used = self.clock;
        }
    }

    fn position_of(&self, spins: &[i8]) -> Option<usize> {
        self.patterns
            .iter()
            .position(|p| spins_match_up_to_inversion(&p.spins, spins))
    }

    fn check_pattern(&self, spins: &[i8]) -> Result<()> {
        if spins.len() != self.n {
            return Err(anyhow!(
                "pattern has {} spins, space stores {}",
                spins.len(),
                self.n
            ));
        }
        if !spins.iter().all(|&s| s == 1 || s == -1) {
            return Err(anyhow!("pattern spins must be +1/-1"));
        }
        Ok(())
    }

    /// Requantize the quantized matrix from the current master and bump
    /// the version.  Returns (changed entries, rms error).
    fn reprogram(&mut self) -> (usize, f64) {
        let master = self.master();
        let (changed, rms) = self.quantized.apply_delta(&master, &self.cfg);
        self.quantization_error = rms;
        self.version += 1;
        (changed, rms)
    }
}

/// The shared registry of live memory spaces.  Store/forget mutate
/// synchronously under the lock (an O(n^2) master update — the wire cap
/// on n bounds it); recalls snapshot under the lock and settle outside
/// it on the assoc worker's engine.
#[derive(Debug, Default)]
pub struct AssocRegistry {
    spaces: Mutex<BTreeMap<String, MemorySpace>>,
}

impl AssocRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stored spaces (diagnostics).
    pub fn space_count(&self) -> usize {
        self.spaces.lock().unwrap().len()
    }

    /// Store a pattern, creating the space on first touch (capacity
    /// defaults to [`capacity_for`], rule to Hebbian).  On an existing
    /// space an explicit capacity/rule must match what the space was
    /// created with — silently switching either would invalidate every
    /// pattern already stored.
    pub fn store(
        &self,
        space: &str,
        spins: Vec<i8>,
        capacity: Option<usize>,
        rule: Option<LearningRule>,
        metrics: &Metrics,
    ) -> Result<StoreOutcome> {
        // Validate before the space-creation branch so a malformed
        // first store never leaves an empty space behind.
        if !spins.iter().all(|&s| s == 1 || s == -1) {
            return Err(anyhow!("pattern spins must be +1/-1"));
        }
        let mut spaces = self.spaces.lock().unwrap();
        if let Some(ms) = spaces.get(space) {
            if let Some(c) = capacity {
                if c != ms.capacity {
                    return Err(anyhow!(
                        "space '{space}' was created with capacity {}",
                        ms.capacity
                    ));
                }
            }
            if let Some(r) = rule {
                if r != ms.rule {
                    return Err(anyhow!(
                        "space '{space}' was created with rule '{}'",
                        ms.rule.name()
                    ));
                }
            }
        } else {
            let n = spins.len();
            if n == 0 {
                return Err(anyhow!("cannot create a space from an empty pattern"));
            }
            let cap = capacity.unwrap_or_else(|| capacity_for(n));
            if cap == 0 {
                return Err(anyhow!("capacity must be positive"));
            }
            spaces.insert(
                space.to_string(),
                MemorySpace::new(n, cap, rule.unwrap_or(LearningRule::Hebbian)),
            );
        }
        let ms = spaces.get_mut(space).expect("space exists or was created");
        let out = ms.store(spins)?;
        metrics.record_store(
            out.duplicate,
            out.evicted > 0,
            out.delta_latency,
            out.delta_entries as u64,
        );
        Ok(out)
    }

    /// Forget a stored pattern.  Unknown spaces and unknown patterns
    /// are structured errors.
    pub fn forget(&self, space: &str, spins: &[i8], metrics: &Metrics) -> Result<ForgetOutcome> {
        let mut spaces = self.spaces.lock().unwrap();
        let ms = spaces
            .get_mut(space)
            .ok_or_else(|| anyhow!("no memory space '{space}'"))?;
        let out = ms.forget(spins)?;
        metrics.record_forget(out.delta_latency, out.delta_entries as u64);
        Ok(out)
    }

    /// Snapshot a space for one recall (taken under the lock, so the
    /// weights and match targets are mutually consistent).
    pub fn snapshot(&self, space: &str) -> Result<RecallSnapshot> {
        let spaces = self.spaces.lock().unwrap();
        let ms = spaces
            .get(space)
            .ok_or_else(|| anyhow!("no memory space '{space}'"))?;
        Ok(ms.snapshot())
    }

    /// Refresh the LRU recency of the pattern a recall settled onto —
    /// only if the space's master is still the version the recall was
    /// served against (a stale touch would warm a pattern based on a
    /// matrix that no longer exists).
    pub fn touch_matched(&self, space: &str, version: u64, spins: &[i8]) {
        let mut spaces = self.spaces.lock().unwrap();
        if let Some(ms) = spaces.get_mut(space) {
            if ms.version == version {
                ms.touch(spins);
            }
        }
    }

    /// Drop every space (coordinator shutdown).
    pub fn clear(&self) {
        self.spaces.lock().unwrap().clear();
    }
}

/// The engine fabric a recall's wire overrides resolve to — the same
/// mapping the solve path uses (`rtl` + `shards >= 2` is the emulated
/// cluster, `shards >= 2` alone the row-sharded float fabric).
pub fn recall_select(shards: Option<usize>, rtl: bool) -> EngineSelect {
    let k = shards.unwrap_or(1);
    match (rtl, k) {
        (true, k) if k >= 2 => EngineSelect::RtlCluster { shards: k },
        (true, _) => EngineSelect::Rtl,
        (false, k) if k >= 2 => EngineSelect::Sharded { shards: k },
        _ => EngineSelect::Native,
    }
}

/// The associative worker: owns a warm [`EngineArena`] (engines are not
/// `Send`, so recall engines live and die on this thread) and serves
/// recall jobs until the channel closes.
pub fn assoc_worker_loop(
    rx: Receiver<RecallJob>,
    registry: Arc<AssocRegistry>,
    metrics: Arc<Metrics>,
    arena_capacity: usize,
) -> Result<()> {
    let mut arena = EngineArena::new(arena_capacity);
    while let Ok(job) = rx.recv() {
        let RecallJob {
            req,
            snapshot,
            submitted,
            reply,
        } = job;
        let res = serve_recall(&req, &snapshot, submitted, &registry, &metrics, &mut arena);
        // Receiver may have hung up (client gave up) — that's fine.
        let _ = reply.send(res);
    }
    Ok(())
}

/// Serve one recall: check out a warm engine for the space's geometry,
/// reprogram it with the snapshot's quantized weights, settle the probe
/// deterministically, and read the result out as spins.  The engine is
/// checked back in warm on success and discarded on error (a failed
/// settle may leave the fabric undefined).
fn serve_recall(
    req: &RecallRequest,
    snapshot: &RecallSnapshot,
    submitted: Instant,
    registry: &AssocRegistry,
    metrics: &Metrics,
    arena: &mut EngineArena,
) -> Result<RecallResult> {
    let n = snapshot.n;
    if req.spins.len() != n {
        return Err(anyhow!(
            "recall {}: probe has {} spins, space stores {n}",
            req.id,
            req.spins.len()
        ));
    }
    let cfg = NetworkConfig::paper(n);
    let select = recall_select(req.shards, req.rtl);
    let key = ArenaKey::for_recall(n, select);
    let mut engine = arena.checkout(key, metrics, || {
        build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select)
    })?;
    let period = cfg.period() as i32;
    let init: Vec<i32> = req
        .spins
        .iter()
        .map(|&s| spin_to_phase(s, period))
        .collect();
    // On error the engine is dropped here instead of checked back in —
    // a failed reprogram/settle may leave the fabric undefined.
    let (phases, settled) = engine
        .set_weights(&snapshot.weights_f32)
        .and_then(|()| drive_retrieval(engine.as_mut(), &init, req.max_periods))?;
    let kind = engine.kind();
    arena.checkin(key, engine, metrics);
    let spins = state_to_spins(&phases, period);
    let matched = snapshot
        .patterns
        .iter()
        .any(|p| spins_match_up_to_inversion(p, &spins));
    if matched {
        registry.touch_matched(&req.space, snapshot.version, &spins);
    }
    let total_latency = submitted.elapsed();
    metrics.record_recall(total_latency, matched);
    Ok(RecallResult {
        id: req.id,
        spins,
        settled,
        matched,
        engine: kind,
        version: snapshot.version,
        total_latency,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onn::learning::hebbian;
    use crate::onn::patterns::dataset_3x3;
    use crate::util::rng::Rng;

    fn random_pattern(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.spin()).collect()
    }

    #[test]
    fn capacity_tracks_hopfield_bound() {
        assert_eq!(capacity_for(9), 2, "floor of 2");
        assert_eq!(capacity_for(100), 13);
        assert_eq!(capacity_for(484), 66);
        assert_eq!(capacity_for(506), 69, "the paper's hybrid fabric");
    }

    #[test]
    fn rule_parse_roundtrip() {
        assert_eq!(LearningRule::parse("hebbian").unwrap(), LearningRule::Hebbian);
        assert_eq!(LearningRule::parse("doi").unwrap(), LearningRule::Doi);
        assert!(LearningRule::parse("perceptron").is_err());
        assert_eq!(LearningRule::Doi.name(), "doi");
    }

    #[test]
    fn incremental_quantized_bit_identical_to_cold_retrain() {
        // The tentpole contract at the MemorySpace level: after any
        // store/forget sequence the delta-maintained quantized matrix
        // equals quantizing hebbian(survivors) cold, bit for bit.
        let mut rng = Rng::new(33);
        let n = 20;
        let mut ms = MemorySpace::new(n, 4, LearningRule::Hebbian);
        let pats: Vec<Vec<i8>> = (0..4).map(|_| random_pattern(&mut rng, n)).collect();
        for p in &pats {
            ms.store(p.clone()).unwrap();
        }
        ms.forget(&pats[1]).unwrap();
        ms.store(random_pattern(&mut rng, n)).unwrap();
        let survivors = ms.stored_patterns();
        let cold = WeightMatrix::quantize(&hebbian(&survivors), n, &NetworkConfig::paper(n));
        assert_eq!(ms.weights(), &cold, "delta path diverged from cold rebuild");
    }

    #[test]
    fn duplicate_store_is_idempotent_including_inverse() {
        let n = 12;
        let mut rng = Rng::new(5);
        let mut ms = MemorySpace::new(n, 4, LearningRule::Hebbian);
        let p = random_pattern(&mut rng, n);
        let first = ms.store(p.clone()).unwrap();
        assert!(!first.duplicate);
        let w_before = ms.weights().clone();
        let again = ms.store(p.clone()).unwrap();
        assert!(again.duplicate, "exact re-store is a duplicate");
        assert_eq!(again.delta_entries, 0);
        assert_eq!(ms.pattern_count(), 1);
        let inv: Vec<i8> = p.iter().map(|&x| -x).collect();
        let inverted = ms.store(inv).unwrap();
        assert!(inverted.duplicate, "an inverted pattern's outer product is identical");
        assert_eq!(ms.pattern_count(), 1);
        assert_eq!(ms.weights(), &w_before, "duplicates never inflate couplings");
        // The master still matches a single-pattern retrain (i.e. the
        // old double-count bug is gone).
        let cold = WeightMatrix::quantize(&hebbian(&[p]), n, &NetworkConfig::paper(n));
        assert_eq!(ms.weights(), &cold);
    }

    /// `count` distinct 16-spin patterns, pairwise distinct up to
    /// inversion by construction (each flips a different single index
    /// of the all-up pattern).
    fn distinct_patterns(count: usize, n: usize) -> Vec<Vec<i8>> {
        assert!(count <= n && n >= 3);
        (0..count)
            .map(|i| {
                let mut p = vec![1i8; n];
                p[i] = -1;
                p
            })
            .collect()
    }

    #[test]
    fn lru_eviction_prefers_recently_recalled() {
        let n = 16;
        let mut ms = MemorySpace::new(n, 2, LearningRule::Hebbian);
        let pats = distinct_patterns(3, n);
        let (a, b, c) = (pats[0].clone(), pats[1].clone(), pats[2].clone());
        ms.store(a.clone()).unwrap();
        ms.store(b.clone()).unwrap();
        // A matched recall refreshes a's recency, so b is now LRU.
        ms.touch(&a);
        let out = ms.store(c.clone()).unwrap();
        assert_eq!(out.evicted, 1);
        assert_eq!(ms.pattern_count(), 2);
        let stored = ms.stored_patterns();
        assert!(stored.iter().any(|p| p == &a), "touched pattern survives");
        assert!(stored.iter().any(|p| p == &c));
        assert!(!stored.iter().any(|p| p == &b), "LRU pattern evicted");
        // And the master reflects exactly the survivors.
        let cold = WeightMatrix::quantize(
            &hebbian(&ms.stored_patterns()),
            n,
            &NetworkConfig::paper(n),
        );
        assert_eq!(ms.weights(), &cold);
    }

    #[test]
    fn forget_unknown_pattern_is_an_error() {
        let n = 9;
        let mut ms = MemorySpace::new(n, 2, LearningRule::Hebbian);
        let pats = distinct_patterns(2, n);
        ms.store(pats[0].clone()).unwrap();
        assert!(ms.forget(&pats[1]).is_err(), "never-stored pattern");
        assert!(ms.forget(&[1i8; 4]).is_err(), "wrong length");
        // Draining the space entirely is legal and leaves zero weights.
        ms.forget(&pats[0]).unwrap();
        assert_eq!(ms.pattern_count(), 0);
        assert_eq!(ms.weights(), &WeightMatrix::zeros(n));
    }

    #[test]
    fn doi_rule_refines_and_stays_deterministic() {
        // The paper's 3x3 glyph pair through the DO-I rule: the space's
        // delta-maintained matrix must equal `train_quantized` cold, and
        // the glyphs must be fixed points of it (the property the
        // existing learning tests pin for the same pipeline).
        let n = 9;
        let mut ms = MemorySpace::new(n, 2, LearningRule::Doi);
        let ds = dataset_3x3();
        let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
        for p in &pats {
            ms.store(p.clone()).unwrap();
        }
        // Cold rebuild: DO-I over the same patterns in storage order.
        let res = diederich_opper_i(&ms.stored_patterns(), DOI_MARGIN, DOI_MAX_EPOCHS);
        let cold = WeightMatrix::quantize(&res.weights, n, &NetworkConfig::paper(n));
        assert_eq!(ms.weights(), &cold, "DO-I delta != deterministic retrain");
        // Stored patterns are fixed points of the refined matrix.
        for p in &pats {
            assert!(crate::onn::learning::is_fixed_point(ms.weights(), p));
        }
    }

    #[test]
    fn registry_creates_validates_and_clears() {
        let metrics = Metrics::new();
        let reg = AssocRegistry::new();
        let t = dataset_3x3().patterns[0].spins.clone();
        let l = dataset_3x3().patterns[1].spins.clone();
        let out = reg.store("glyphs", t.clone(), None, None, &metrics).unwrap();
        assert_eq!(out.capacity, capacity_for(9));
        reg.store("glyphs", l, None, None, &metrics).unwrap();
        assert_eq!(reg.space_count(), 1);
        // Wrong-size patterns, conflicting capacity/rule: structured errors.
        assert!(reg.store("glyphs", vec![1i8; 4], None, None, &metrics).is_err());
        assert!(reg
            .store("glyphs", t.clone(), Some(7), None, &metrics)
            .is_err());
        assert!(reg
            .store("glyphs", t.clone(), None, Some(LearningRule::Doi), &metrics)
            .is_err());
        assert!(reg.store("bad", vec![1, 0, -1], None, None, &metrics).is_err());
        assert!(reg.forget("nope", &t, &metrics).is_err());
        let snap = reg.snapshot("glyphs").unwrap();
        assert_eq!(snap.n, 9);
        assert_eq!(snap.patterns.len(), 2);
        assert_eq!(snap.weights_f32.len(), 81);
        let s = metrics.snapshot();
        assert_eq!(s.patterns_stored, 2);
        reg.clear();
        assert!(reg.snapshot("glyphs").is_err());
    }

    #[test]
    fn recall_select_mirrors_the_solve_mapping() {
        assert_eq!(recall_select(None, false), EngineSelect::Native);
        assert_eq!(recall_select(Some(1), false), EngineSelect::Native);
        assert_eq!(
            recall_select(Some(3), false),
            EngineSelect::Sharded { shards: 3 }
        );
        assert_eq!(recall_select(None, true), EngineSelect::Rtl);
        assert_eq!(recall_select(Some(1), true), EngineSelect::Rtl);
        assert_eq!(
            recall_select(Some(2), true),
            EngineSelect::RtlCluster { shards: 2 }
        );
    }

    #[test]
    fn serve_recall_settles_stored_pattern_on_warm_engine() {
        // End-to-end in-module: store the 3x3 glyphs under the DO-I
        // rule and recall the T glyph on a (cold, then warm) native
        // engine.  The exact stored pattern is a fixed point of the
        // trained matrix, so the settle is deterministic.
        let metrics = Metrics::new();
        let reg = AssocRegistry::new();
        let ds = dataset_3x3();
        for p in &ds.patterns {
            reg.store("g", p.spins.clone(), None, Some(LearningRule::Doi), &metrics)
                .unwrap();
        }
        let req = RecallRequest {
            id: 7,
            space: "g".to_string(),
            spins: ds.patterns[0].spins.clone(),
            max_periods: 256,
            shards: None,
            rtl: false,
        };
        let snapshot = reg.snapshot("g").unwrap();
        let mut arena = EngineArena::new(2);
        let res = serve_recall(&req, &snapshot, Instant::now(), &reg, &metrics, &mut arena)
            .unwrap();
        assert_eq!(res.id, 7);
        assert!(res.matched, "stored T glyph must recall itself");
        assert!(res.settled.is_some());
        assert_eq!(res.engine, "native");
        assert!(spins_match_up_to_inversion(&res.spins, &ds.patterns[0].spins));
        let s = metrics.snapshot();
        assert_eq!(s.recalls, 1);
        assert_eq!(s.recalls_matched, 1);
        assert_eq!(s.arena_misses, 1);
        // A second recall reuses the warm engine — and is bit-identical.
        let res2 = serve_recall(&req, &snapshot, Instant::now(), &reg, &metrics, &mut arena)
            .unwrap();
        assert_eq!(res2.spins, res.spins, "warm recall == cold recall");
        assert_eq!(res2.settled, res.settled);
        assert_eq!(metrics.snapshot().arena_hits, 1);
    }
}
