//! Job/request/result types flowing through the coordinator.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::onn::patterns::Pattern;
use crate::onn::phase::spin_to_phase;
use crate::runtime::HardwareCost;
use crate::solver::anneal::Schedule;
use crate::solver::problem::IsingProblem;
use crate::telemetry::TraceRecord;

/// A retrieval request: initial oscillator phases for one trial.
#[derive(Debug, Clone)]
pub struct RetrievalRequest {
    pub id: u64,
    /// Network size this request targets (routing key).
    pub n: usize,
    /// Initial phases, length n, values in [0, P).
    pub phases: Vec<i32>,
    /// Give up after this many oscillation periods.
    pub max_periods: usize,
}

impl RetrievalRequest {
    /// Build a request from a (corrupted) binary pattern: +1 -> phase 0,
    /// -1 -> phase P/2.
    pub fn from_pattern(id: u64, pattern: &Pattern, p: i32, max_periods: usize) -> Self {
        Self {
            id,
            n: pattern.len(),
            phases: pattern
                .spins
                .iter()
                .map(|&s| spin_to_phase(s, p))
                .collect(),
            max_periods,
        }
    }
}

/// The settled (or timed-out) outcome of one retrieval request.
#[derive(Debug, Clone)]
pub struct RetrievalResult {
    pub id: u64,
    pub phases: Vec<i32>,
    /// Periods until the fixed point, or None on timeout.
    pub settled: Option<usize>,
    /// Time spent queued before entering a batch.
    pub queue_latency: Duration,
    /// Submission-to-completion wall time.
    pub total_latency: Duration,
    /// How many real jobs shared the batch (occupancy diagnostics).
    pub batch_occupancy: usize,
}

/// Internal envelope: request + reply channel + timestamps.
#[derive(Debug)]
pub struct Job {
    pub req: RetrievalRequest,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<RetrievalResult>,
}

/// An associative-memory recall: a corrupted ±1 probe pattern settled
/// on the engine fabric programmed with a memory space's live quantized
/// weights (`coordinator::assoc`).  The third wire traffic class, next
/// to retrieval and solve.
#[derive(Debug, Clone)]
pub struct RecallRequest {
    pub id: u64,
    /// Memory-space name the probe recalls against.
    pub space: String,
    /// Probe spins (±1, length = the space's n).
    pub spins: Vec<i8>,
    /// Give up after this many oscillation periods.
    pub max_periods: usize,
    /// Explicit shard-count override for the recall engine (mirrors the
    /// solve wire; `None`/`Some(1)` is single-device).
    pub shards: Option<usize>,
    /// Serve the recall on the bit-true emulated-hardware engine;
    /// combined with `shards: K >= 2` it runs the emulated rtl cluster.
    pub rtl: bool,
}

/// The settled outcome of one recall.
#[derive(Debug, Clone)]
pub struct RecallResult {
    pub id: u64,
    /// Settled state read out as spins relative to oscillator 0.
    pub spins: Vec<i8>,
    /// Periods until the fixed point, or None on timeout.
    pub settled: Option<usize>,
    /// Whether the settled state equals a stored pattern of the space
    /// (up to global inversion) — the recall-accuracy numerator.
    pub matched: bool,
    /// Engine kind that served the recall.
    pub engine: &'static str,
    /// Master-matrix version the recall was served against (snapshotted
    /// at submit; concurrent stores bump it).
    pub version: u64,
    /// Submission-to-completion wall time.
    pub total_latency: Duration,
}

/// An optimization request: one Ising instance solved by the annealed
/// replica portfolio (`solver::portfolio`) on a worker-owned engine.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub id: u64,
    pub problem: IsingProblem,
    /// Random-init replicas run as one batch.
    pub replicas: usize,
    /// Periods driven per replica (whole chunks).
    pub max_periods: usize,
    pub schedule: Schedule,
    pub seed: u64,
    /// Explicit shard-count override; `None` lets the solver pool pick
    /// the engine by its oscillator threshold (1 forces native).
    pub shards: Option<usize>,
    /// Force the bit-true emulated-hardware engine for this request.
    /// Combined with `shards: K >= 2` the request runs on the emulated
    /// `K`-device rtl cluster (row-split weight memory, priced phase
    /// all-gather); `shards: 1` is plain single-device rtl.
    pub rtl: bool,
    /// Precision-sweep override of the quantized weight width (3..=8
    /// bits); `None` runs the paper's 5-bit weights.  Only legal with
    /// `rtl: true` — the float fabrics have no quantized datapath.
    pub weight_bits: Option<u32>,
    /// Precision-sweep override of the phase-wheel resolution (3..=6
    /// bits); `None` runs the paper's 4-bit wheel.  Only legal with
    /// `rtl: true`.
    pub phase_bits: Option<u32>,
    /// Attach a compact solve-lifecycle trace to the result
    /// (DESIGN_SOLVER.md §9).  Traced requests run solo — they never
    /// coalesce onto packed lane-block engines.
    pub trace: bool,
    /// Stream `{"type":"progress"}` lines to the client while the
    /// anneal runs (DESIGN_SOLVER.md §10).  Only the evented front end
    /// honors this; the thread-per-connection server ignores it.
    pub stream: bool,
}

impl SolveRequest {
    pub fn new(id: u64, problem: IsingProblem) -> Self {
        Self {
            id,
            problem,
            replicas: 32,
            max_periods: 256,
            schedule: Schedule::Geometric {
                start: 0.6,
                factor: 0.8,
            },
            seed: 1,
            shards: None,
            rtl: false,
            weight_bits: None,
            phase_bits: None,
            trace: false,
            stream: false,
        }
    }

    /// The request's precision sweep point, or `None` for the paper's
    /// reference precision (5-bit weights, 4-bit phase wheel).  Only
    /// `Some` when at least one of the two fields was overridden.
    pub fn precision(&self) -> Option<(u32, u32)> {
        if self.weight_bits.is_none() && self.phase_bits.is_none() {
            return None;
        }
        Some((self.weight_bits.unwrap_or(5), self.phase_bits.unwrap_or(4)))
    }
}

/// One mid-anneal progress report, routed back to the submitting
/// connection by `token` (the front end's connection identifier).
#[derive(Debug, Clone, Copy)]
pub struct ProgressEvent {
    /// Connection token of the submitting client.
    pub token: u64,
    /// Request id the progress belongs to.
    pub id: u64,
    /// Best energy found so far across all replicas.
    pub best_energy: f64,
    /// Periods driven so far.
    pub periods: usize,
}

/// The outcome of one solve request.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub id: u64,
    /// Best decoded spins (length `problem.n`).
    pub spins: Vec<i8>,
    /// Best phase state (length `problem.n`) for sector decoders.
    pub phases: Vec<i32>,
    /// `problem.energy` of the best state (offset excluded).
    pub energy: f64,
    /// Objective value (energy + reduction offset).
    pub objective: f64,
    /// Total chunk-periods the engine drove.
    pub periods: usize,
    pub replicas: usize,
    pub settled_replicas: usize,
    /// Engine kind that served the solve ("native" / "sharded" /
    /// "rtl").
    pub engine: &'static str,
    /// All-gather synchronization rounds the engine performed (0 on the
    /// native path) — the multi-device sync-cost metric.
    pub sync_rounds: u64,
    /// RMS rounding loss of the quantized coupling embedding, as a
    /// fraction of the quantization full scale.
    pub quantization_error: f64,
    /// True when the solve ran on the engine's CSR sparse fabric (or
    /// was answered trivially as a zero-interaction sparse request).
    pub sparse: bool,
    /// Emulated hardware cost — present when the bit-true rtl engine
    /// served the solve.
    pub hardware: Option<HardwareCost>,
    /// Solve-lifecycle trace — present when the request set `trace`.
    pub trace: Option<Vec<TraceRecord>>,
    pub queue_latency: Duration,
    pub total_latency: Duration,
}

/// Internal envelope for solve traffic.
#[derive(Debug)]
pub struct SolveJob {
    pub req: SolveRequest,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<SolveResult>,
    /// Set by the front end when the submitting client disconnects; the
    /// portfolio driver checks it at every chunk boundary and abandons
    /// the solve (`None` = not cancellable).
    pub cancel: Option<Arc<AtomicBool>>,
    /// Progress sink + connection token for streaming requests: the
    /// worker sends one [`ProgressEvent`] per chunk and the front end
    /// routes it to the token's connection (`None` = no streaming).
    pub progress: Option<(std::sync::mpsc::Sender<ProgressEvent>, u64)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pattern_maps_spins() {
        let pat = Pattern::from_art("t", &["#.", ".#"]);
        let r = RetrievalRequest::from_pattern(7, &pat, 16, 100);
        assert_eq!(r.id, 7);
        assert_eq!(r.n, 4);
        assert_eq!(r.phases, vec![0, 8, 8, 0]);
        assert_eq!(r.max_periods, 100);
    }
}
