//! Job/request/result types flowing through the coordinator.

use std::time::{Duration, Instant};

use crate::onn::patterns::Pattern;
use crate::onn::phase::spin_to_phase;

/// A retrieval request: initial oscillator phases for one trial.
#[derive(Debug, Clone)]
pub struct RetrievalRequest {
    pub id: u64,
    /// Network size this request targets (routing key).
    pub n: usize,
    /// Initial phases, length n, values in [0, P).
    pub phases: Vec<i32>,
    /// Give up after this many oscillation periods.
    pub max_periods: usize,
}

impl RetrievalRequest {
    /// Build a request from a (corrupted) binary pattern: +1 -> phase 0,
    /// -1 -> phase P/2.
    pub fn from_pattern(id: u64, pattern: &Pattern, p: i32, max_periods: usize) -> Self {
        Self {
            id,
            n: pattern.len(),
            phases: pattern
                .spins
                .iter()
                .map(|&s| spin_to_phase(s, p))
                .collect(),
            max_periods,
        }
    }
}

/// The settled (or timed-out) outcome of one retrieval request.
#[derive(Debug, Clone)]
pub struct RetrievalResult {
    pub id: u64,
    pub phases: Vec<i32>,
    /// Periods until the fixed point, or None on timeout.
    pub settled: Option<usize>,
    /// Time spent queued before entering a batch.
    pub queue_latency: Duration,
    /// Submission-to-completion wall time.
    pub total_latency: Duration,
    /// How many real jobs shared the batch (occupancy diagnostics).
    pub batch_occupancy: usize,
}

/// Internal envelope: request + reply channel + timestamps.
#[derive(Debug)]
pub struct Job {
    pub req: RetrievalRequest,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<RetrievalResult>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pattern_maps_spins() {
        let pat = Pattern::from_art("t", &["#.", ".#"]);
        let r = RetrievalRequest::from_pattern(7, &pat, 16, 100);
        assert_eq!(r.id, 7);
        assert_eq!(r.n, 4);
        assert_eq!(r.phases, vec![0, 8, 8, 0]);
        assert_eq!(r.max_periods, 100);
    }
}
