//! The evented streaming front end: one readiness loop over
//! nonblocking sockets multiplexing every connection — no thread per
//! connection — with mid-anneal `{"type":"progress"}` JSON lines for
//! streaming solves and cancellation of a solve whose client
//! disconnected (DESIGN_SOLVER.md §10).
//!
//! This is the serving shape the paper's endgame needs: the
//! fully connected ONN as a network *service* (laptop UI -> PYNQ link),
//! where thousands of idle-ish clients must not cost a thread each and
//! an abandoned request must not burn engine time.  The loop is a
//! std-only poll(2) readiness loop (tokio/mio are unavailable offline);
//! requests are submitted to the same router/solver pool as the
//! thread-per-connection server ([`serve_tcp`]), and responses are
//! byte-identical — only the transport changes.
//!
//! Per connection the loop keeps a read buffer (JSON lines are cut at
//! `\n`), a bounded write buffer (a slow or dead consumer is
//! disconnected rather than allowed to wedge the loop), and a `token`
//! identifying it in the in-flight tables.  A solve submitted from a
//! connection carries a cancel flag (set the moment the connection
//! drops — the portfolio driver checks it at every chunk boundary) and,
//! for `"stream": true` requests, a progress sender that routes
//! per-chunk `{"type":"progress","id":...,"best_energy":...,
//! "periods":...}` lines back to the submitting connection.
//!
//! [`serve_tcp`]: crate::coordinator::server::serve_tcp

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::job::{ProgressEvent, RecallResult, RetrievalResult, SolveResult};
use crate::coordinator::router::Router;
use crate::coordinator::server::{
    error_line, handle_forget_value, handle_store_value, metrics_line, parse_recall_request,
    parse_request, parse_solve_request, recall_result_json, retrieval_result_json,
    solve_result_json,
};
use crate::util::json::Json;

/// Write-buffer cap per connection: a consumer that falls this far
/// behind (or stopped reading entirely) is disconnected instead of
/// growing the buffer without bound.
const MAX_WBUF: usize = 1 << 20;

/// Bytes read per connection per loop iteration (bounds how long one
/// flooding connection can hold the loop).
const READ_CHUNK: usize = 16 * 1024;

/// Readiness-wait bound: the loop also has to drain worker reply
/// channels (mpsc, invisible to poll), so it never sleeps longer than
/// this even with no socket activity.
const POLL_TIMEOUT_MS: i32 = 1;

#[cfg(unix)]
mod sys {
    //! Minimal poll(2) binding.  std links libc already; declaring the
    //! one symbol we need avoids a vendored libc crate.

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    /// Wait until any fd is ready or the timeout elapses.  The loop
    /// treats readiness as advisory (every socket op is nonblocking),
    /// so errors are folded into "nothing ready".
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) {
        if fds.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms.max(1) as u64));
            return;
        }
        unsafe {
            poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms);
        }
    }
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    token: u64,
    rbuf: Vec<u8>,
    wbuf: VecDeque<u8>,
    dead: bool,
}

impl Conn {
    fn push_line(&mut self, line: &str) {
        self.wbuf.extend(line.as_bytes());
        self.wbuf.push_back(b'\n');
        if self.wbuf.len() > MAX_WBUF {
            // Slow consumer: drop the connection rather than buffer
            // without bound (its in-flight solves get cancelled like
            // any other disconnect).
            self.dead = true;
        }
    }
}

/// An outstanding request whose reply will arrive on a worker channel.
enum InFlight {
    Solve {
        token: u64,
        id: u64,
        cancel: Arc<AtomicBool>,
        rx: Receiver<SolveResult>,
    },
    Retrieve {
        token: u64,
        id: u64,
        rx: Receiver<RetrievalResult>,
    },
    /// An associative-memory recall served by the assoc worker; stores
    /// and forgets are answered inline (they mutate the registry, no
    /// engine time), so only recalls go in flight.
    Recall {
        token: u64,
        rx: Receiver<Result<RecallResult>>,
    },
}

impl InFlight {
    fn token(&self) -> u64 {
        match self {
            InFlight::Solve { token, .. }
            | InFlight::Retrieve { token, .. }
            | InFlight::Recall { token, .. } => *token,
        }
    }
}

/// Serve the JSON-lines protocol on an evented readiness loop until the
/// router is shut down.  Protocol-compatible with
/// [`serve_tcp`](crate::coordinator::server::serve_tcp) plus two
/// serving-lifecycle behaviors only this front end provides:
/// `"stream": true` solves emit `{"type":"progress"}` lines mid-anneal,
/// and a client disconnect cancels its outstanding solves at the next
/// chunk boundary.  Responses to a connection that pipelines several
/// requests come back in completion order (ids disambiguate).
/// Associative-memory `store`/`forget` lines are answered inline (a
/// registry mutation, no engine time); `recall` lines go in flight to
/// the assoc worker like any other engine-served request.
pub fn serve_evented(router: Arc<Router>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<Conn> = Vec::new();
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut next_token: u64 = 1;
    // One shared progress channel: workers tag events with the
    // submitting connection's token, the loop routes them back.
    let (ptx, prx) = channel::<ProgressEvent>();

    loop {
        if router.is_shutdown() {
            return Ok(());
        }

        wait_for_readiness(&listener, &conns);

        // Accept every pending connection.
        loop {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    stream.set_nonblocking(true)?;
                    conns.push(Conn {
                        stream,
                        token: next_token,
                        rbuf: Vec::new(),
                        wbuf: VecDeque::new(),
                        dead: false,
                    });
                    next_token += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => break,
                Err(e) => return Err(e.into()),
            }
        }

        // Read sweep: pull bytes, cut complete lines, dispatch each.
        // One flooding connection is bounded to READ_CHUNK bytes per
        // iteration, so its malformed lines can't stall the others.
        let mut chunk = [0u8; READ_CHUNK];
        for conn in conns.iter_mut() {
            if conn.dead {
                continue;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => conn.dead = true,
                Ok(got) => conn.rbuf.extend_from_slice(&chunk[..got]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => conn.dead = true,
            }
            while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&raw[..pos]);
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                if let Some(resp) = dispatch_line(&router, line, conn.token, &ptx, &mut inflight)
                {
                    conn.push_line(&resp);
                }
            }
        }

        // Route progress events to their owner connections.
        while let Ok(ev) = prx.try_recv() {
            if let Some(conn) = conns.iter_mut().find(|c| c.token == ev.token && !c.dead) {
                conn.push_line(&progress_line(&ev));
            }
        }

        // Reply sweep: poll every in-flight request without blocking.
        let mut still = Vec::with_capacity(inflight.len());
        for entry in inflight.drain(..) {
            if let Some(entry) = poll_inflight(entry, &mut conns) {
                still.push(entry);
            }
        }
        inflight = still;

        // Flush write buffers.
        for conn in conns.iter_mut() {
            flush_conn(conn);
        }

        // Reap dead connections: cancel their outstanding solves (the
        // worker abandons the anneal at the next chunk boundary) and
        // drop their reply channels.
        if conns.iter().any(|c| c.dead) {
            let dead: Vec<u64> = conns.iter().filter(|c| c.dead).map(|c| c.token).collect();
            inflight.retain(|entry| {
                let gone = dead.contains(&entry.token());
                if gone {
                    if let InFlight::Solve { cancel, .. } = entry {
                        cancel.store(true, Ordering::Relaxed);
                    }
                }
                !gone
            });
            conns.retain(|c| !c.dead);
        }
    }
}

/// Block until a socket is ready or the timeout elapses — poll(2) on
/// unix, a plain bounded sleep elsewhere (every socket op in the loop
/// is nonblocking, so readiness is a latency optimization, not a
/// correctness requirement).
#[cfg(unix)]
fn wait_for_readiness(listener: &TcpListener, conns: &[Conn]) {
    use std::os::unix::io::AsRawFd;
    let mut fds: Vec<sys::PollFd> = Vec::with_capacity(conns.len() + 1);
    fds.push(sys::PollFd {
        fd: listener.as_raw_fd(),
        events: sys::POLLIN,
        revents: 0,
    });
    for conn in conns {
        let mut events = sys::POLLIN;
        if !conn.wbuf.is_empty() {
            events |= sys::POLLOUT;
        }
        fds.push(sys::PollFd {
            fd: conn.stream.as_raw_fd(),
            events,
            revents: 0,
        });
    }
    sys::wait(&mut fds, POLL_TIMEOUT_MS);
}

#[cfg(not(unix))]
fn wait_for_readiness(_listener: &TcpListener, _conns: &[Conn]) {
    std::thread::sleep(std::time::Duration::from_millis(POLL_TIMEOUT_MS.max(1) as u64));
}

/// One `{"type":"progress"}` line (DESIGN_SOLVER.md §10).
fn progress_line(ev: &ProgressEvent) -> String {
    Json::obj(vec![
        ("type", Json::str("progress")),
        ("id", Json::num(ev.id as f64)),
        ("best_energy", Json::num(ev.best_energy)),
        ("periods", Json::num(ev.periods as f64)),
    ])
    .to_string()
}

/// Dispatch one request line.  Returns `Some(response)` for immediate
/// replies (metrics, parse/routing errors); queues an [`InFlight`]
/// entry and returns `None` when a worker owns the reply.
fn dispatch_line(
    router: &Router,
    line: &str,
    token: u64,
    ptx: &Sender<ProgressEvent>,
    inflight: &mut Vec<InFlight>,
) -> Option<String> {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return Some(error_line(&format!("bad json: {e}"))),
    };
    match parsed.get("type").and_then(Json::as_str) {
        Some("metrics") => Some(metrics_line(router)),
        Some("solve") => {
            let req = match parse_solve_request(&parsed) {
                Ok(req) => req,
                Err(e) => return Some(error_line(&e.to_string())),
            };
            let id = req.id;
            let cancel = Arc::new(AtomicBool::new(false));
            let progress = req.stream.then(|| (ptx.clone(), token));
            match router.submit_solve_hooked(req, Some(cancel.clone()), progress) {
                Ok(rx) => {
                    inflight.push(InFlight::Solve {
                        token,
                        id,
                        cancel,
                        rx,
                    });
                    None
                }
                Err(e) => Some(error_line(&e.to_string())),
            }
        }
        Some("store") => Some(handle_store_value(router, &parsed)),
        Some("forget") => Some(handle_forget_value(router, &parsed)),
        Some("recall") => {
            let req = match parse_recall_request(&parsed) {
                Ok(req) => req,
                Err(e) => return Some(error_line(&e.to_string())),
            };
            match router.submit_recall(req) {
                Ok(rx) => {
                    inflight.push(InFlight::Recall { token, rx });
                    None
                }
                Err(e) => Some(error_line(&e.to_string())),
            }
        }
        None | Some("retrieve") => {
            let req = match parse_request(&parsed) {
                Ok(req) => req,
                Err(e) => return Some(error_line(&e.to_string())),
            };
            let id = req.id;
            match router.submit(req) {
                Ok(rx) => {
                    inflight.push(InFlight::Retrieve { token, id, rx });
                    None
                }
                Err(e) => Some(error_line(&e.to_string())),
            }
        }
        Some(other) => Some(error_line(&format!("unknown request type '{other}'"))),
    }
}

/// Poll one in-flight request: route its reply (or its worker's
/// disappearance) to the owner connection.  Returns the entry when the
/// reply is still pending.
fn poll_inflight(entry: InFlight, conns: &mut [Conn]) -> Option<InFlight> {
    let push = |conns: &mut [Conn], token: u64, line: String| {
        if let Some(conn) = conns.iter_mut().find(|c| c.token == token && !c.dead) {
            conn.push_line(&line);
        }
    };
    match entry {
        InFlight::Solve {
            token,
            id,
            cancel,
            rx,
        } => match rx.try_recv() {
            Ok(res) => {
                push(conns, token, solve_result_json(id, &res).to_string());
                None
            }
            Err(TryRecvError::Empty) => Some(InFlight::Solve {
                token,
                id,
                cancel,
                rx,
            }),
            Err(TryRecvError::Disconnected) => {
                // The worker dropped the reply: an internal failure or
                // a cancelled solve racing the disconnect sweep.
                push(conns, token, error_line("solver dropped reply"));
                None
            }
        },
        InFlight::Retrieve { token, id, rx } => match rx.try_recv() {
            Ok(res) => {
                push(conns, token, retrieval_result_json(id, &res).to_string());
                None
            }
            Err(TryRecvError::Empty) => Some(InFlight::Retrieve { token, id, rx }),
            Err(TryRecvError::Disconnected) => {
                push(conns, token, error_line("worker dropped reply"));
                None
            }
        },
        InFlight::Recall { token, rx } => match rx.try_recv() {
            Ok(Ok(res)) => {
                push(conns, token, recall_result_json(&res).to_string());
                None
            }
            Ok(Err(e)) => {
                push(conns, token, error_line(&e.to_string()));
                None
            }
            Err(TryRecvError::Empty) => Some(InFlight::Recall { token, rx }),
            Err(TryRecvError::Disconnected) => {
                push(conns, token, error_line("assoc worker dropped reply"));
                None
            }
        },
    }
}

/// Write as much of the connection's buffered output as the socket
/// accepts right now.
fn flush_conn(conn: &mut Conn) {
    while !conn.wbuf.is_empty() && !conn.dead {
        let (front, _) = conn.wbuf.as_slices();
        match conn.stream.write(front) {
            Ok(0) => {
                conn.dead = true;
            }
            Ok(wrote) => {
                conn.wbuf.drain(..wrote);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => conn.dead = true,
        }
    }
}
