//! The retrieval service — the L3 coordination layer.
//!
//! External clients (the benchmark harness, the examples, or TCP
//! connections) submit corrupted patterns as retrieval jobs; a router
//! dispatches each job to the engine pool for its network size, where a
//! dynamic batcher packs jobs into the fixed batch dimension of the AOT
//! artifact and a worker thread drives the PJRT executable to a fixed
//! point.  Python is never on this path.
//!
//! std threads + channels stand in for tokio (unavailable offline); the
//! batcher implements the same size-or-deadline policy a vLLM-style
//! router uses.
//!
//! Since PR 1 the same front-end also serves *optimization* traffic:
//! `"type": "solve"` JSON lines become `job::SolveRequest`s handled by a
//! shared solver pool driving `solver::portfolio` (see
//! `DESIGN_SOLVER.md`).  Solves whose embedding exceeds the pool's
//! oscillator threshold run on the row-sharded multi-device engine
//! (`server::SolverPoolConfig`), bit-exact with the native path, and
//! report their all-gather `sync_rounds` in results and metrics.
//!
//! The third traffic class is *online-learning associative memory*:
//! `"type": "store"` / `"recall"` / `"forget"` lines maintain named
//! live pattern spaces (`assoc::AssocRegistry`) whose quantized weight
//! matrices are delta-reprogrammed into warm recall engines instead of
//! rebuilt (DESIGN_SOLVER.md §13).

pub mod arena;
pub mod assoc;
pub mod batcher;
pub mod job;
pub mod metrics;
pub mod router;
pub mod server;
pub mod stream;
