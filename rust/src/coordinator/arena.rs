//! The warm engine arena: a per-worker cache of standing engine
//! fabrics, reprogrammed between requests instead of rebuilt.
//!
//! Building an engine is the expensive part of a small solve — a
//! matrix allocation for the native fabric, a register-file build for
//! the rtl model, and for the sharded fabric a full spawn (and later
//! join) of every shard thread.  The serving hot path the paper's
//! hardware targets is *reprogramming a standing fabric*: weights and
//! noise change per request, the fabric does not.  The arena makes the
//! same move in software: engines are checked out by geometry key,
//! reprogrammed via `set_weights`/`set_noise` inside the portfolio
//! driver, and checked back in warm — shard threads stay alive across
//! requests.
//!
//! [`ChunkEngine`] is deliberately not `Send` (PJRT stream affinity),
//! so an arena is owned by exactly one solver worker thread and never
//! shared; only the hit/miss/evict counters ride the shared
//! [`Metrics`].
//!
//! The load-bearing contract: an arena-served solve is **bit-identical**
//! to a cold-engine solve at equal seed.  `set_weights` fully
//! reprograms every fabric (the portfolio reports `sync_rounds` as a
//! delta so a warm sharded engine's counter carry-over is invisible),
//! and the portfolio re-draws all replica state per solve, so nothing
//! of a previous tenant survives but the allocation itself.
//! `rust/tests/integration_streaming.rs` holds the proof obligation.

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::runtime::ChunkEngine;
use crate::solver::portfolio::EngineSelect;

/// Geometry key identifying which standing engine can serve a solve:
/// the fabric kind with everything that is baked in at construction
/// time (oscillator count, batch lanes, chunk length, shard count) —
/// plus which *weight fabric* (dense matrix vs CSR) the solve will
/// install.  Anything *not* in the key — weights, noise, replica
/// state — is reprogrammed per request.
///
/// `sparse` is part of the key even though both fabrics run on the
/// same engine type: a dense solve reprograms via `set_weights` and a
/// sparse one via `set_weights_sparse`, and keeping the populations
/// separate means a warm engine is always reprogrammed through the
/// same install path a cold build would use — the arena's
/// bit-identity contract never has to reason about cross-fabric
/// reinstalls.
///
/// The hardware-model keys (`Rtl`, `RtlCluster`) carry the precision
/// point (`weight_bits`, `phase_bits`) too: precision is baked into an
/// rtl engine at construction (register widths, phase wheel), so a
/// warm 4-bit fabric must never serve a paper-precision request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArenaKey {
    Native { n: usize, batch: usize, chunk: usize, sparse: bool },
    Sharded { n: usize, shards: usize, batch: usize, chunk: usize, sparse: bool },
    Rtl { n: usize, batch: usize, chunk: usize, weight_bits: u32, phase_bits: u32 },
    RtlCluster {
        n: usize,
        shards: usize,
        batch: usize,
        chunk: usize,
        weight_bits: u32,
        phase_bits: u32,
    },
}

/// The paper's reference precision (`NetworkConfig::paper`, 5w/4p):
/// what an rtl solve runs at when the request carries no sweep point.
const PAPER_PRECISION: (u32, u32) = (5, 4);

impl ArenaKey {
    /// The key a solo solve resolves to: mirrors
    /// [`crate::solver::portfolio::build_engine_cfg`]'s fabric choice so
    /// a checked-out engine is exactly what a cold build would
    /// construct.  `sparse` is `solver::portfolio::wants_sparse(problem)`
    /// — the rtl engines have no sparse kernel, so their keys ignore the
    /// flag (the portfolio falls back to the dense install there).
    /// `precision` is the request's sweep point; only the hardware-model
    /// keys carry it (the float fabrics always run the paper wheel).
    pub fn for_solve(
        m: usize,
        batch: usize,
        chunk: usize,
        select: EngineSelect,
        sparse: bool,
        precision: Option<(u32, u32)>,
    ) -> Self {
        let (weight_bits, phase_bits) = precision.unwrap_or(PAPER_PRECISION);
        if select == EngineSelect::Rtl {
            return ArenaKey::Rtl { n: m, batch, chunk, weight_bits, phase_bits };
        }
        if let EngineSelect::RtlCluster { shards } = select {
            return ArenaKey::RtlCluster { n: m, shards, batch, chunk, weight_bits, phase_bits };
        }
        let shards = select.shards_for(m);
        if shards <= 1 {
            ArenaKey::Native { n: m, batch, chunk, sparse }
        } else {
            ArenaKey::Sharded { n: m, shards, batch, chunk, sparse }
        }
    }

    /// The key an associative-memory recall resolves to: identical to
    /// [`ArenaKey::for_solve`] at the recall path's fixed geometry
    /// (single-trial batch, default chunk, dense fabric, paper
    /// precision).  Recalls install a fully quantized matrix via
    /// `set_weights`, so the dense install path and paper phase wheel
    /// are part of the serving contract, not a per-request choice.
    pub fn for_recall(n: usize, select: EngineSelect) -> Self {
        Self::for_solve(
            n,
            1,
            crate::solver::portfolio::DEFAULT_CHUNK,
            select,
            false,
            None,
        )
    }
}

/// One parked warm engine with its LRU stamp.
struct Slot {
    key: ArenaKey,
    engine: Box<dyn ChunkEngine>,
    last_used: u64,
}

/// A bounded LRU pool of warm engines, owned by one solver worker
/// thread.  `capacity` 0 disables warming entirely (every checkout is
/// a miss, every checkin a drop) — the cold-engine baseline the
/// connection-scale bench measures against.
pub struct EngineArena {
    capacity: usize,
    slots: Vec<Slot>,
    clock: u64,
}

impl EngineArena {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            slots: Vec::new(),
            clock: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Warm engines currently parked.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Check out an engine for `key`: a parked match is removed and
    /// returned warm (hit); otherwise `build` constructs a cold one
    /// (miss).  Either way the caller owns the engine until
    /// [`checkin`](Self::checkin).
    pub fn checkout(
        &mut self,
        key: ArenaKey,
        metrics: &Metrics,
        build: impl FnOnce() -> Result<Box<dyn ChunkEngine>>,
    ) -> Result<Box<dyn ChunkEngine>> {
        if let Some(idx) = self.slots.iter().position(|s| s.key == key) {
            metrics.record_arena_hit();
            return Ok(self.slots.swap_remove(idx).engine);
        }
        metrics.record_arena_miss();
        build()
    }

    /// Park an engine for reuse.  With the arena at capacity the
    /// least-recently-used slot is evicted (shard threads join on
    /// drop); with capacity 0 the engine is dropped immediately.
    ///
    /// Only check in *healthy* engines: a solve that failed mid-flight
    /// may leave the fabric in an undefined state — discard it instead.
    /// A *cancelled* solve is healthy by contract (the portfolio bails
    /// only at chunk boundaries and detaches any trace sink first).
    pub fn checkin(&mut self, key: ArenaKey, engine: Box<dyn ChunkEngine>, metrics: &Metrics) {
        if self.capacity == 0 {
            metrics.record_arena_eviction();
            return;
        }
        self.clock += 1;
        self.slots.push(Slot {
            key,
            engine,
            last_used: self.clock,
        });
        if self.slots.len() > self.capacity {
            let lru = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i)
                .expect("arena over capacity implies at least one slot");
            self.slots.swap_remove(lru);
            metrics.record_arena_eviction();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::portfolio::build_engine;

    fn build(key: ArenaKey) -> Result<Box<dyn ChunkEngine>> {
        let (m, batch, chunk, select) = match key {
            ArenaKey::Native { n, batch, chunk, .. } => (n, batch, chunk, EngineSelect::Native),
            ArenaKey::Sharded { n, shards, batch, chunk, .. } => {
                (n, batch, chunk, EngineSelect::Sharded { shards })
            }
            ArenaKey::Rtl { n, batch, chunk, .. } => (n, batch, chunk, EngineSelect::Rtl),
            ArenaKey::RtlCluster { n, shards, batch, chunk, .. } => {
                (n, batch, chunk, EngineSelect::RtlCluster { shards })
            }
        };
        build_engine(m, batch, chunk, select)
    }

    #[test]
    fn key_resolution_mirrors_build_engine() {
        let auto = EngineSelect::Auto { threshold: 100, max_shards: 4 };
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, auto, false, None),
            ArenaKey::Native { n: 24, batch: 8, chunk: 8, sparse: false }
        );
        assert_eq!(
            ArenaKey::for_solve(250, 8, 8, auto, true, None),
            ArenaKey::Sharded { n: 250, shards: 3, batch: 8, chunk: 8, sparse: true }
        );
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Rtl, false, None),
            ArenaKey::Rtl { n: 24, batch: 8, chunk: 8, weight_bits: 5, phase_bits: 4 },
            "no sweep point resolves to the paper precision"
        );
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Rtl, true, None),
            ArenaKey::Rtl { n: 24, batch: 8, chunk: 8, weight_bits: 5, phase_bits: 4 },
            "the rtl fabric has no sparse kernel; its key ignores the flag"
        );
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Rtl, false, Some((4, 4))),
            ArenaKey::Rtl { n: 24, batch: 8, chunk: 8, weight_bits: 4, phase_bits: 4 },
            "precision is part of the rtl geometry"
        );
        assert_ne!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Rtl, false, Some((4, 4))),
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Rtl, false, None),
            "a warm sweep-point engine must never serve a paper request"
        );
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::RtlCluster { shards: 2 }, false, None),
            ArenaKey::RtlCluster {
                n: 24,
                shards: 2,
                batch: 8,
                chunk: 8,
                weight_bits: 5,
                phase_bits: 4
            }
        );
        assert_eq!(
            ArenaKey::for_solve(24, 8, 8, EngineSelect::Sharded { shards: 1 }, false, None),
            ArenaKey::Native { n: 24, batch: 8, chunk: 8, sparse: false },
            "a single-shard selection collapses to the native fabric"
        );
        // The recall key is the solve key at the recall path's fixed
        // geometry: batch 1, default chunk, dense, paper precision.
        assert_eq!(
            ArenaKey::for_recall(9, EngineSelect::Native),
            ArenaKey::Native { n: 9, batch: 1, chunk: 8, sparse: false }
        );
        assert_eq!(
            ArenaKey::for_recall(9, EngineSelect::Sharded { shards: 2 }),
            ArenaKey::Sharded { n: 9, shards: 2, batch: 1, chunk: 8, sparse: false }
        );
        assert_eq!(
            ArenaKey::for_recall(9, EngineSelect::Rtl),
            ArenaKey::Rtl { n: 9, batch: 1, chunk: 8, weight_bits: 5, phase_bits: 4 }
        );
    }

    #[test]
    fn sparse_and_dense_fabrics_never_share_a_slot() {
        // A warm dense engine must not be checked out for a sparse solve
        // (or vice versa): the keys differ, so the sparse checkout is a
        // miss even with a same-geometry dense engine parked.
        let metrics = Metrics::new();
        let mut arena = EngineArena::new(2);
        let kd = ArenaKey::Native { n: 8, batch: 4, chunk: 8, sparse: false };
        let ks = ArenaKey::Native { n: 8, batch: 4, chunk: 8, sparse: true };
        assert_ne!(kd, ks);
        let e = arena.checkout(kd, &metrics, || build(kd)).unwrap();
        arena.checkin(kd, e, &metrics);
        let e = arena.checkout(ks, &metrics, || build(ks)).unwrap();
        arena.checkin(ks, e, &metrics);
        let snap = metrics.snapshot();
        assert_eq!(snap.arena_hits, 0, "cross-fabric checkout must miss");
        assert_eq!(snap.arena_misses, 2);
        assert_eq!(arena.len(), 2, "both fabrics park side by side");
        // Each population still hits its own key.
        arena.checkout(kd, &metrics, || build(kd)).unwrap();
        arena.checkout(ks, &metrics, || build(ks)).unwrap();
        assert_eq!(metrics.snapshot().arena_hits, 2);
    }

    #[test]
    fn hit_miss_evict_lifecycle() {
        let metrics = Metrics::new();
        let mut arena = EngineArena::new(2);
        let ka = ArenaKey::Native { n: 8, batch: 4, chunk: 8, sparse: false };
        let kb = ArenaKey::Native { n: 16, batch: 4, chunk: 8, sparse: false };
        let kc = ArenaKey::Native { n: 32, batch: 4, chunk: 8, sparse: false };

        // Cold start: miss, then the checked-in engine hits.
        let ea = arena.checkout(ka, &metrics, || build(ka)).unwrap();
        arena.checkin(ka, ea, &metrics);
        assert_eq!(arena.len(), 1);
        let ea = arena.checkout(ka, &metrics, || build(ka)).unwrap();
        assert_eq!(ea.n(), 8);
        assert!(arena.is_empty(), "checkout removes the parked slot");
        arena.checkin(ka, ea, &metrics);

        // Fill to capacity, then overflow evicts the LRU slot (ka —
        // parked earliest).
        let eb = arena.checkout(kb, &metrics, || build(kb)).unwrap();
        arena.checkin(kb, eb, &metrics);
        let ec = arena.checkout(kc, &metrics, || build(kc)).unwrap();
        arena.checkin(kc, ec, &metrics);
        assert_eq!(arena.len(), 2);
        let snap = metrics.snapshot();
        assert_eq!(snap.arena_hits, 1);
        assert_eq!(snap.arena_misses, 3);
        assert_eq!(snap.arena_evictions, 1);
        // ka was evicted; kb and kc still hit.
        assert_eq!(arena.checkout(kb, &metrics, || build(kb)).unwrap().n(), 16);
        assert_eq!(arena.checkout(kc, &metrics, || build(kc)).unwrap().n(), 32);
        assert_eq!(metrics.snapshot().arena_hits, 3);
    }

    #[test]
    fn capacity_zero_disables_warming() {
        let metrics = Metrics::new();
        let mut arena = EngineArena::new(0);
        let k = ArenaKey::Native { n: 8, batch: 4, chunk: 8, sparse: false };
        let e = arena.checkout(k, &metrics, || build(k)).unwrap();
        arena.checkin(k, e, &metrics);
        assert!(arena.is_empty());
        let snap = metrics.snapshot();
        assert_eq!(snap.arena_hits, 0);
        assert_eq!(snap.arena_misses, 1);
        assert_eq!(snap.arena_evictions, 1, "capacity 0 drops on checkin");
    }
}
