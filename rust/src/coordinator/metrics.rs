//! Service metrics: counters, exact latency sums (means), and
//! log-bucketed histograms (percentiles), cheap enough for the hot path
//! (every record is a handful of relaxed atomic adds).
//!
//! Snapshots export two ways (DESIGN_SOLVER.md §9): a JSON object
//! ([`MetricsSnapshot::to_json`]) and Prometheus-style text
//! ([`MetricsSnapshot::prometheus`]), both served by the wire command
//! `{"type": "metrics"}`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::telemetry::{LatencyHistogram, LatencySummary};
use crate::util::json::Json;

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub timeouts: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of real jobs over all batches (occupancy numerator).
    pub batched_jobs: AtomicU64,
    /// Total latency sums in microseconds (exact means).
    queue_us: AtomicU64,
    total_us: AtomicU64,
    /// Latency histograms (p50/p90/p99 at snapshot time).
    queue_hist: LatencyHistogram,
    total_hist: LatencyHistogram,
    /// Per-engine-kind retrieval latency + counts, keyed by the engine
    /// that actually served the batch (`ChunkEngine::kind`).  Retrieval
    /// pools run the single-device float fabrics only, so the kinds are
    /// "native" and "pjrt" — solve traffic has its own per-kind set
    /// below.
    total_hist_native: LatencyHistogram,
    total_hist_pjrt: LatencyHistogram,
    /// Retrievals served by the in-process float fabric.
    pub retrievals_native: AtomicU64,
    /// Retrievals served by the PJRT-backed fabric.
    pub retrievals_pjrt: AtomicU64,
    // --- associative-memory traffic (store/recall/forget) ---
    /// Patterns accepted into a memory space by `store` (duplicates and
    /// evicted victims excluded).
    pub patterns_stored: AtomicU64,
    /// Patterns evicted by the LRU capacity policy on store.
    pub patterns_evicted: AtomicU64,
    /// Patterns removed by explicit `forget` commands.
    pub patterns_forgotten: AtomicU64,
    /// Idempotent re-stores of an already-present pattern (exact or
    /// inverse — the Hebbian sum must not double-count either).
    pub store_duplicates: AtomicU64,
    /// Recall requests completed (matched or not).
    pub recalls: AtomicU64,
    /// Recalls whose settled state matched a stored pattern up to
    /// global inversion.
    pub recalls_matched: AtomicU64,
    /// Quantized weight entries rewritten by delta reprograms (the
    /// exact write set `WeightMatrix::apply_delta` reports, summed).
    pub delta_entries: AtomicU64,
    /// End-to-end recall latency (submit to settled spins).
    recall_hist: LatencyHistogram,
    /// Master-update + requantize latency per store/forget mutation —
    /// the delta-reprogram cost the tentpole surfaces.
    delta_hist: LatencyHistogram,
    // --- solve traffic (the optimization job class) ---
    pub solves_submitted: AtomicU64,
    pub solves_completed: AtomicU64,
    pub solves_failed: AtomicU64,
    solve_us: AtomicU64,
    solve_hist: LatencyHistogram,
    /// Per-engine-kind solve latency, keyed by the engine that actually
    /// served the job (`SolveOutcome::engine`).
    solve_hist_native: LatencyHistogram,
    solve_hist_sharded: LatencyHistogram,
    solve_hist_rtl: LatencyHistogram,
    /// Engine chunk-periods spent on solve jobs (effort accounting).
    pub solve_periods: AtomicU64,
    /// Solves served by the single-device float fabrics (native/pjrt).
    pub solves_native: AtomicU64,
    /// Solves served by the sharded multi-device fabric.
    pub solves_sharded: AtomicU64,
    /// All-gather synchronization rounds spent on sharded solves (the
    /// multi-device sync-cost metric, summed over completed jobs).
    pub solve_sync_rounds: AtomicU64,
    /// Solve batches collected by the pool's workers (a solo request
    /// counts as a batch of one).
    pub solve_batches: AtomicU64,
    /// Sum of real solve jobs over all solve batches (occupancy
    /// numerator; occupancy > 1 means requests coalesced onto shared
    /// lane-block engines).
    pub solve_batched_jobs: AtomicU64,
    /// Lanes of packed solves that retired before their period budget
    /// (per-lane plateau / all-settled early exit) — capacity the
    /// batcher handed back for backfill.
    pub solve_lanes_retired: AtomicU64,
    /// Solves served by the bit-true emulated-hardware (rtl) engine,
    /// including its emulated multi-device cluster front end.
    pub solves_rtl: AtomicU64,
    /// Completed rtl solves that shared a packed lane-block engine
    /// (small `rtl: true` requests coalesced by the batcher).
    pub solves_rtl_packed: AtomicU64,
    /// Emulated fast-clock cycles spent on the cluster's per-period
    /// phase all-gather (`HardwareCost::sync_fast_cycles`, summed over
    /// completed rtl-cluster jobs) — the priced cost of scaling past
    /// one device.
    pub rtl_cluster_sync_cycles: AtomicU64,
    /// Emulated fast-clock cycles those solves consumed — the hardware
    /// time-to-solution meter, summed over completed rtl jobs.
    pub solve_fast_cycles: AtomicU64,
    /// Solves abandoned mid-run because their client went away (the
    /// evented front end's cancel-on-disconnect).  Not failures: the
    /// work was healthy, nobody wanted the answer anymore.
    pub solves_cancelled: AtomicU64,
    /// Packed batches that fell back to per-job solo solves after an
    /// internal packed-path error (the blast-radius containment of the
    /// coalescing batcher).
    pub solve_pack_fallbacks: AtomicU64,
    /// Zero-interaction solves answered trivially by the router (every
    /// coupling and field exactly zero: any state is a ground state, so
    /// no engine time is spent).
    pub solves_trivial: AtomicU64,
    /// Completed solves that ran on a CSR sparse fabric
    /// (`SolveOutcome::sparse`).
    pub solves_sparse: AtomicU64,
    /// Warm-engine arena checkouts that reused a standing engine
    /// (reprogram instead of rebuild).
    pub arena_hits: AtomicU64,
    /// Arena checkouts that had to build a fresh engine.
    pub arena_misses: AtomicU64,
    /// Warm engines evicted to respect the arena's capacity cap.
    pub arena_evictions: AtomicU64,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub timeouts: u64,
    pub batches: u64,
    pub mean_queue_ms: f64,
    pub mean_total_ms: f64,
    /// Mean real jobs per batch / batch capacity is the caller's to
    /// compute; this is the mean real jobs per batch.
    pub mean_occupancy: f64,
    /// Retrieval latency percentiles (histogram estimates; the exact
    /// means above come from the running sums).
    pub queue: LatencySummary,
    pub total: LatencySummary,
    /// Per-engine-kind retrieval latency + counts.
    pub total_native: LatencySummary,
    pub total_pjrt: LatencySummary,
    pub retrievals_native: u64,
    pub retrievals_pjrt: u64,
    // --- associative-memory traffic ---
    pub patterns_stored: u64,
    pub patterns_evicted: u64,
    pub patterns_forgotten: u64,
    pub store_duplicates: u64,
    pub recalls: u64,
    pub recalls_matched: u64,
    pub delta_entries: u64,
    pub recall: LatencySummary,
    pub delta_reprogram: LatencySummary,
    // --- solve traffic ---
    pub solves_submitted: u64,
    pub solves_completed: u64,
    pub solves_failed: u64,
    pub mean_solve_ms: f64,
    /// Solve latency percentiles, pool-wide and per engine kind.
    pub solve: LatencySummary,
    pub solve_native: LatencySummary,
    pub solve_sharded: LatencySummary,
    pub solve_rtl: LatencySummary,
    pub solve_periods: u64,
    pub solves_native: u64,
    pub solves_sharded: u64,
    pub solve_sync_rounds: u64,
    pub solve_batches: u64,
    /// Mean real solve jobs per solve batch (> 1 iff requests shared
    /// lane-block engines).
    pub solve_batch_occupancy: f64,
    pub solve_lanes_retired: u64,
    pub solves_rtl: u64,
    pub solves_rtl_packed: u64,
    pub rtl_cluster_sync_cycles: u64,
    pub solve_fast_cycles: u64,
    pub solves_cancelled: u64,
    pub solve_pack_fallbacks: u64,
    pub solves_trivial: u64,
    pub solves_sparse: u64,
    pub arena_hits: u64,
    pub arena_misses: u64,
    pub arena_evictions: u64,
}

impl Metrics {
    /// Fresh zeroed counters (alias for `Default` — tests and
    /// standalone arenas construct metrics directly).
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, real_jobs: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_jobs
            .fetch_add(real_jobs as u64, Ordering::Relaxed);
    }

    /// A completed retrieval.  `engine` is the kind that actually
    /// served the batch (`ChunkEngine::kind`: "native"/"pjrt") — the
    /// legacy `RetrievalRequest` path classifies per engine kind just
    /// like solve traffic does, instead of vanishing into the pool-wide
    /// totals only.
    pub fn record_completion(
        &self,
        queue: Duration,
        total: Duration,
        timed_out: bool,
        engine: &str,
    ) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if timed_out {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_us
            .fetch_add(queue.as_micros() as u64, Ordering::Relaxed);
        self.total_us
            .fetch_add(total.as_micros() as u64, Ordering::Relaxed);
        self.queue_hist.record(queue);
        self.total_hist.record(total);
        match engine {
            "pjrt" => {
                self.retrievals_pjrt.fetch_add(1, Ordering::Relaxed);
                self.total_hist_pjrt.record(total);
            }
            _ => {
                self.retrievals_native.fetch_add(1, Ordering::Relaxed);
                self.total_hist_native.record(total);
            }
        }
    }

    /// A `store` mutation: `duplicate` stores are idempotent no-ops
    /// (counted, master untouched), `evicted` flags an LRU victim, and
    /// `delta`/`entries` meter the requantize-and-reprogram write.
    pub fn record_store(&self, duplicate: bool, evicted: bool, delta: Duration, entries: u64) {
        if duplicate {
            self.store_duplicates.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.patterns_stored.fetch_add(1, Ordering::Relaxed);
        if evicted {
            self.patterns_evicted.fetch_add(1, Ordering::Relaxed);
        }
        self.delta_entries.fetch_add(entries, Ordering::Relaxed);
        self.delta_hist.record(delta);
    }

    /// A `forget` mutation that removed a stored pattern.
    pub fn record_forget(&self, delta: Duration, entries: u64) {
        self.patterns_forgotten.fetch_add(1, Ordering::Relaxed);
        self.delta_entries.fetch_add(entries, Ordering::Relaxed);
        self.delta_hist.record(delta);
    }

    /// A completed recall; `matched` means the settled state equals a
    /// stored pattern up to global inversion.
    pub fn record_recall(&self, total: Duration, matched: bool) {
        self.recalls.fetch_add(1, Ordering::Relaxed);
        if matched {
            self.recalls_matched.fetch_add(1, Ordering::Relaxed);
        }
        self.recall_hist.record(total);
    }

    pub fn record_solve_submit(&self) {
        self.solves_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A completed solve.  `engine` is the kind that actually served it
    /// (`SolveOutcome::engine`: "native"/"pjrt"/"sharded"/"rtl") — the
    /// classification is explicit, not inferred from side channels like
    /// sync-round counts, so a sharded run that happened to sync zero
    /// times still lands in the sharded column.
    pub fn record_solve_completion(
        &self,
        total: Duration,
        periods: usize,
        sync_rounds: u64,
        engine: &str,
    ) {
        self.solves_completed.fetch_add(1, Ordering::Relaxed);
        self.solve_us
            .fetch_add(total.as_micros() as u64, Ordering::Relaxed);
        self.solve_hist.record(total);
        self.solve_periods
            .fetch_add(periods as u64, Ordering::Relaxed);
        self.solve_sync_rounds
            .fetch_add(sync_rounds, Ordering::Relaxed);
        match engine {
            "sharded" => {
                self.solves_sharded.fetch_add(1, Ordering::Relaxed);
                self.solve_hist_sharded.record(total);
            }
            "rtl" | "rtl-cluster" => {
                self.solves_rtl.fetch_add(1, Ordering::Relaxed);
                self.solve_hist_rtl.record(total);
            }
            _ => {
                self.solves_native.fetch_add(1, Ordering::Relaxed);
                self.solve_hist_native.record(total);
            }
        }
    }

    pub fn record_solve_failure(&self) {
        self.solves_failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_solve_batch(&self, real_jobs: usize) {
        self.solve_batches.fetch_add(1, Ordering::Relaxed);
        self.solve_batched_jobs
            .fetch_add(real_jobs as u64, Ordering::Relaxed);
    }

    pub fn record_solve_lanes_retired(&self, lanes: u64) {
        self.solve_lanes_retired.fetch_add(lanes, Ordering::Relaxed);
    }

    /// A solve abandoned because its client disconnected mid-run.
    pub fn record_solve_cancelled(&self) {
        self.solves_cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// A packed batch that fell back to per-job solo solves.
    pub fn record_solve_pack_fallback(&self) {
        self.solve_pack_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// A zero-interaction solve answered trivially (no engine ran).
    pub fn record_solve_trivial(&self) {
        self.solves_trivial.fetch_add(1, Ordering::Relaxed);
    }

    /// A completed solve that ran on a CSR sparse fabric.
    pub fn record_solve_sparse(&self) {
        self.solves_sparse.fetch_add(1, Ordering::Relaxed);
    }

    /// An arena checkout served by a standing warm engine.
    pub fn record_arena_hit(&self) {
        self.arena_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// An arena checkout that built a fresh engine.
    pub fn record_arena_miss(&self) {
        self.arena_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// A warm engine evicted by the arena's capacity cap.
    pub fn record_arena_eviction(&self) {
        self.arena_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Meter the emulated fast-clock cycles of a completed rtl solve.
    /// The rtl job *count* comes from [`Self::record_solve_completion`]
    /// classifying on the engine kind.
    pub fn record_solve_hardware(&self, fast_cycles: u64) {
        self.solve_fast_cycles
            .fetch_add(fast_cycles, Ordering::Relaxed);
    }

    /// A completed rtl solve that shared a packed lane-block engine.
    pub fn record_solve_rtl_packed(&self) {
        self.solves_rtl_packed.fetch_add(1, Ordering::Relaxed);
    }

    /// Meter the emulated cluster's phase all-gather cycles (the
    /// `sync_fast_cycles` share of a completed rtl-cluster solve).
    pub fn record_rtl_cluster_sync(&self, sync_fast_cycles: u64) {
        self.rtl_cluster_sync_cycles
            .fetch_add(sync_fast_cycles, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let solves_completed = self.solves_completed.load(Ordering::Relaxed);
        let solve_batches = self.solve_batches.load(Ordering::Relaxed);
        let div = |num: u64, den: u64| if den == 0 { 0.0 } else { num as f64 / den as f64 };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            timeouts: self.timeouts.load(Ordering::Relaxed),
            batches,
            mean_queue_ms: div(self.queue_us.load(Ordering::Relaxed), completed) / 1000.0,
            mean_total_ms: div(self.total_us.load(Ordering::Relaxed), completed) / 1000.0,
            mean_occupancy: div(self.batched_jobs.load(Ordering::Relaxed), batches),
            queue: self.queue_hist.summary(),
            total: self.total_hist.summary(),
            total_native: self.total_hist_native.summary(),
            total_pjrt: self.total_hist_pjrt.summary(),
            retrievals_native: self.retrievals_native.load(Ordering::Relaxed),
            retrievals_pjrt: self.retrievals_pjrt.load(Ordering::Relaxed),
            patterns_stored: self.patterns_stored.load(Ordering::Relaxed),
            patterns_evicted: self.patterns_evicted.load(Ordering::Relaxed),
            patterns_forgotten: self.patterns_forgotten.load(Ordering::Relaxed),
            store_duplicates: self.store_duplicates.load(Ordering::Relaxed),
            recalls: self.recalls.load(Ordering::Relaxed),
            recalls_matched: self.recalls_matched.load(Ordering::Relaxed),
            delta_entries: self.delta_entries.load(Ordering::Relaxed),
            recall: self.recall_hist.summary(),
            delta_reprogram: self.delta_hist.summary(),
            solves_submitted: self.solves_submitted.load(Ordering::Relaxed),
            solves_completed,
            solves_failed: self.solves_failed.load(Ordering::Relaxed),
            mean_solve_ms: div(self.solve_us.load(Ordering::Relaxed), solves_completed) / 1000.0,
            solve: self.solve_hist.summary(),
            solve_native: self.solve_hist_native.summary(),
            solve_sharded: self.solve_hist_sharded.summary(),
            solve_rtl: self.solve_hist_rtl.summary(),
            solve_periods: self.solve_periods.load(Ordering::Relaxed),
            solves_native: self.solves_native.load(Ordering::Relaxed),
            solves_sharded: self.solves_sharded.load(Ordering::Relaxed),
            solve_sync_rounds: self.solve_sync_rounds.load(Ordering::Relaxed),
            solve_batches,
            solve_batch_occupancy: div(
                self.solve_batched_jobs.load(Ordering::Relaxed),
                solve_batches,
            ),
            solve_lanes_retired: self.solve_lanes_retired.load(Ordering::Relaxed),
            solves_rtl: self.solves_rtl.load(Ordering::Relaxed),
            solves_rtl_packed: self.solves_rtl_packed.load(Ordering::Relaxed),
            rtl_cluster_sync_cycles: self.rtl_cluster_sync_cycles.load(Ordering::Relaxed),
            solve_fast_cycles: self.solve_fast_cycles.load(Ordering::Relaxed),
            solves_cancelled: self.solves_cancelled.load(Ordering::Relaxed),
            solve_pack_fallbacks: self.solve_pack_fallbacks.load(Ordering::Relaxed),
            solves_trivial: self.solves_trivial.load(Ordering::Relaxed),
            solves_sparse: self.solves_sparse.load(Ordering::Relaxed),
            arena_hits: self.arena_hits.load(Ordering::Relaxed),
            arena_misses: self.arena_misses.load(Ordering::Relaxed),
            arena_evictions: self.arena_evictions.load(Ordering::Relaxed),
        }
    }
}

fn summary_json(s: &LatencySummary) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("mean_ms", Json::num(s.mean_ms)),
        ("p50_ms", Json::num(s.p50_ms)),
        ("p90_ms", Json::num(s.p90_ms)),
        ("p99_ms", Json::num(s.p99_ms)),
    ])
}

impl MetricsSnapshot {
    /// Fraction of arena checkouts served by a standing warm engine
    /// (0.0 on an empty or disabled arena, never NaN).
    pub fn arena_hit_rate(&self) -> f64 {
        let total = self.arena_hits + self.arena_misses;
        if total == 0 {
            0.0
        } else {
            self.arena_hits as f64 / total as f64
        }
    }

    /// Fraction of recalls that settled onto a stored pattern (up to
    /// global inversion).  0.0 before any recall ran, never NaN.
    pub fn recall_accuracy(&self) -> f64 {
        if self.recalls == 0 {
            0.0
        } else {
            self.recalls_matched as f64 / self.recalls as f64
        }
    }

    /// The snapshot as one JSON object — counters at the top level,
    /// latency summaries as nested objects (each with `count`/`mean_ms`/
    /// `p50_ms`/`p90_ms`/`p99_ms`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("timeouts", Json::num(self.timeouts as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_queue_ms", Json::num(self.mean_queue_ms)),
            ("mean_total_ms", Json::num(self.mean_total_ms)),
            ("mean_occupancy", Json::num(self.mean_occupancy)),
            ("queue", summary_json(&self.queue)),
            ("total", summary_json(&self.total)),
            ("total_native", summary_json(&self.total_native)),
            ("total_pjrt", summary_json(&self.total_pjrt)),
            ("retrievals_native", Json::num(self.retrievals_native as f64)),
            ("retrievals_pjrt", Json::num(self.retrievals_pjrt as f64)),
            ("patterns_stored", Json::num(self.patterns_stored as f64)),
            ("patterns_evicted", Json::num(self.patterns_evicted as f64)),
            (
                "patterns_forgotten",
                Json::num(self.patterns_forgotten as f64),
            ),
            ("store_duplicates", Json::num(self.store_duplicates as f64)),
            ("recalls", Json::num(self.recalls as f64)),
            ("recalls_matched", Json::num(self.recalls_matched as f64)),
            ("recall_accuracy", Json::num(self.recall_accuracy())),
            ("delta_entries", Json::num(self.delta_entries as f64)),
            ("recall", summary_json(&self.recall)),
            ("delta_reprogram", summary_json(&self.delta_reprogram)),
            ("solves_submitted", Json::num(self.solves_submitted as f64)),
            ("solves_completed", Json::num(self.solves_completed as f64)),
            ("solves_failed", Json::num(self.solves_failed as f64)),
            ("mean_solve_ms", Json::num(self.mean_solve_ms)),
            ("solve", summary_json(&self.solve)),
            ("solve_native", summary_json(&self.solve_native)),
            ("solve_sharded", summary_json(&self.solve_sharded)),
            ("solve_rtl", summary_json(&self.solve_rtl)),
            ("solve_periods", Json::num(self.solve_periods as f64)),
            ("solves_native", Json::num(self.solves_native as f64)),
            ("solves_sharded", Json::num(self.solves_sharded as f64)),
            ("solve_sync_rounds", Json::num(self.solve_sync_rounds as f64)),
            ("solve_batches", Json::num(self.solve_batches as f64)),
            (
                "solve_batch_occupancy",
                Json::num(self.solve_batch_occupancy),
            ),
            (
                "solve_lanes_retired",
                Json::num(self.solve_lanes_retired as f64),
            ),
            ("solves_rtl", Json::num(self.solves_rtl as f64)),
            ("solves_rtl_packed", Json::num(self.solves_rtl_packed as f64)),
            (
                "rtl_cluster_sync_cycles",
                Json::num(self.rtl_cluster_sync_cycles as f64),
            ),
            ("solve_fast_cycles", Json::num(self.solve_fast_cycles as f64)),
            ("solves_cancelled", Json::num(self.solves_cancelled as f64)),
            (
                "solve_pack_fallbacks",
                Json::num(self.solve_pack_fallbacks as f64),
            ),
            ("solves_trivial", Json::num(self.solves_trivial as f64)),
            ("solves_sparse", Json::num(self.solves_sparse as f64)),
            ("arena_hits", Json::num(self.arena_hits as f64)),
            ("arena_misses", Json::num(self.arena_misses as f64)),
            ("arena_evictions", Json::num(self.arena_evictions as f64)),
            ("arena_hit_rate", Json::num(self.arena_hit_rate())),
        ])
    }

    /// Prometheus-style text exposition: `onn_`-prefixed counters and
    /// gauges plus quantile'd latency summaries.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let counters: [(&str, u64); 29] = [
            ("onn_jobs_submitted", self.submitted),
            ("onn_jobs_completed", self.completed),
            ("onn_jobs_timeouts", self.timeouts),
            ("onn_batches", self.batches),
            ("onn_patterns_stored", self.patterns_stored),
            ("onn_patterns_evicted", self.patterns_evicted),
            ("onn_patterns_forgotten", self.patterns_forgotten),
            ("onn_store_duplicates", self.store_duplicates),
            ("onn_recalls", self.recalls),
            ("onn_recalls_matched", self.recalls_matched),
            ("onn_delta_entries", self.delta_entries),
            ("onn_solves_submitted", self.solves_submitted),
            ("onn_solves_completed", self.solves_completed),
            ("onn_solves_failed", self.solves_failed),
            ("onn_solve_periods", self.solve_periods),
            ("onn_solve_sync_rounds", self.solve_sync_rounds),
            ("onn_solve_batches", self.solve_batches),
            ("onn_solve_lanes_retired", self.solve_lanes_retired),
            ("onn_solves_rtl_packed", self.solves_rtl_packed),
            ("onn_rtl_cluster_sync_cycles", self.rtl_cluster_sync_cycles),
            ("onn_solve_fast_cycles", self.solve_fast_cycles),
            ("onn_solves_cancelled", self.solves_cancelled),
            ("onn_solve_pack_fallbacks", self.solve_pack_fallbacks),
            ("onn_solves_trivial", self.solves_trivial),
            ("onn_solves_sparse", self.solves_sparse),
            ("onn_arena_hits", self.arena_hits),
            ("onn_arena_misses", self.arena_misses),
            ("onn_arena_evictions", self.arena_evictions),
            ("onn_solves_total_all_engines", self.solves_completed),
        ];
        for (name, v) in counters {
            let _ = writeln!(out, "# TYPE {name} counter\n{name} {v}");
        }
        for (kind, v) in [
            ("native", self.solves_native),
            ("sharded", self.solves_sharded),
            ("rtl", self.solves_rtl),
        ] {
            let _ = writeln!(
                out,
                "# TYPE onn_solves_by_engine counter\nonn_solves_by_engine{{engine=\"{kind}\"}} {v}"
            );
        }
        for (kind, v) in [
            ("native", self.retrievals_native),
            ("pjrt", self.retrievals_pjrt),
        ] {
            let _ = writeln!(
                out,
                "# TYPE onn_retrievals_by_engine counter\nonn_retrievals_by_engine{{engine=\"{kind}\"}} {v}"
            );
        }
        for (name, v) in [
            ("onn_batch_occupancy", self.mean_occupancy),
            ("onn_solve_batch_occupancy", self.solve_batch_occupancy),
            ("onn_arena_hit_rate", self.arena_hit_rate()),
            ("onn_recall_accuracy", self.recall_accuracy()),
        ] {
            let _ = writeln!(out, "# TYPE {name} gauge\n{name} {v}");
        }
        for (name, s) in [
            ("onn_queue_latency", &self.queue),
            ("onn_total_latency", &self.total),
            ("onn_total_latency_native", &self.total_native),
            ("onn_total_latency_pjrt", &self.total_pjrt),
            ("onn_recall_latency", &self.recall),
            ("onn_delta_reprogram_latency", &self.delta_reprogram),
            ("onn_solve_latency", &self.solve),
            ("onn_solve_latency_native", &self.solve_native),
            ("onn_solve_latency_sharded", &self.solve_sharded),
            ("onn_solve_latency_rtl", &self.solve_rtl),
        ] {
            let _ = writeln!(out, "# TYPE {name}_ms summary");
            for (q, v) in [("0.5", s.p50_ms), ("0.9", s.p90_ms), ("0.99", s.p99_ms)] {
                let _ = writeln!(out, "{name}_ms{{quantile=\"{q}\"}} {v}");
            }
            let _ = writeln!(out, "{name}_ms_sum {}", s.mean_ms * s.count as f64);
            let _ = writeln!(out, "{name}_ms_count {}", s.count);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_submit();
        m.record_submit();
        m.record_batch(2);
        m.record_completion(Duration::from_millis(2), Duration::from_millis(10), false, "native");
        m.record_completion(Duration::from_millis(4), Duration::from_millis(20), true, "pjrt");
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_queue_ms - 3.0).abs() < 0.01);
        assert!((s.mean_total_ms - 15.0).abs() < 0.01);
        assert!((s.mean_occupancy - 2.0).abs() < 1e-9);
        // Histograms saw the same samples as the sums.
        assert_eq!(s.queue.count, 2);
        assert_eq!(s.total.count, 2);
        assert!(s.total.p50_ms >= 10.0, "p50 never under-reports");
        // Retrieval traffic classifies per engine kind like solves do.
        assert_eq!(s.retrievals_native, 1);
        assert_eq!(s.retrievals_pjrt, 1);
        assert_eq!(s.total_native.count, 1);
        assert_eq!(s.total_pjrt.count, 1);
    }

    #[test]
    fn assoc_counters_aggregate() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.recall_accuracy(), 0.0, "no recalls never NaNs");
        m.record_store(false, false, Duration::from_millis(1), 40);
        m.record_store(false, true, Duration::from_millis(1), 24);
        m.record_store(true, false, Duration::from_millis(1), 99);
        m.record_forget(Duration::from_millis(2), 16);
        m.record_recall(Duration::from_millis(5), true);
        m.record_recall(Duration::from_millis(6), true);
        m.record_recall(Duration::from_millis(7), false);
        let s = m.snapshot();
        assert_eq!(s.patterns_stored, 2, "duplicates are not stores");
        assert_eq!(s.patterns_evicted, 1);
        assert_eq!(s.patterns_forgotten, 1);
        assert_eq!(s.store_duplicates, 1);
        assert_eq!(s.recalls, 3);
        assert_eq!(s.recalls_matched, 2);
        assert!((s.recall_accuracy() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.delta_entries, 80, "duplicate stores write no entries");
        assert_eq!(s.recall.count, 3);
        assert_eq!(s.delta_reprogram.count, 3);
        let j = s.to_json();
        for key in [
            "patterns_stored",
            "patterns_evicted",
            "patterns_forgotten",
            "store_duplicates",
            "recalls",
            "recalls_matched",
            "recall_accuracy",
            "delta_entries",
            "retrievals_native",
            "retrievals_pjrt",
        ] {
            assert!(j.get(key).and_then(Json::as_f64).is_some(), "{key}");
        }
        for key in ["recall", "delta_reprogram", "total_native", "total_pjrt"] {
            assert!(
                j.get(key).and_then(|s| s.get("p50_ms")).is_some(),
                "{key} summary"
            );
        }
        let text = s.prometheus();
        assert!(text.contains("onn_patterns_stored 2"));
        assert!(text.contains("onn_patterns_evicted 1"));
        assert!(text.contains("onn_store_duplicates 1"));
        assert!(text.contains("onn_recalls 3"));
        assert!(text.contains("onn_delta_entries 80"));
        assert!(text.contains("onn_recall_accuracy"));
        assert!(text.contains("onn_recall_latency_ms{quantile=\"0.99\"}"));
        assert!(text.contains("onn_delta_reprogram_latency_ms_count 3"));
        assert!(text.contains("onn_retrievals_by_engine{engine=\"native\"} 0"));
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_total_ms, 0.0);
        assert_eq!(s.mean_occupancy, 0.0);
        assert_eq!(s.mean_solve_ms, 0.0);
        for sum in [s.queue, s.total, s.solve, s.solve_native, s.solve_sharded, s.solve_rtl] {
            assert_eq!(sum, LatencySummary::default());
            for v in [sum.mean_ms, sum.p50_ms, sum.p90_ms, sum.p99_ms] {
                assert!(v.is_finite(), "empty summaries stay finite");
            }
        }
    }

    #[test]
    fn solve_counters_aggregate() {
        let m = Metrics::default();
        m.record_solve_submit();
        m.record_solve_submit();
        m.record_solve_completion(Duration::from_millis(8), 128, 0, "native");
        m.record_solve_failure();
        let s = m.snapshot();
        assert_eq!(s.solves_submitted, 2);
        assert_eq!(s.solves_completed, 1);
        assert_eq!(s.solves_failed, 1);
        assert_eq!(s.solve_periods, 128);
        assert!((s.mean_solve_ms - 8.0).abs() < 0.01);
        assert_eq!(s.solves_native, 1);
        assert_eq!(s.solves_sharded, 0, "native solves are not sharded");
        // A sharded completion adds its sync rounds to the pool totals
        // — and classifies by its engine kind even if it never synced.
        m.record_solve_completion(Duration::from_millis(4), 64, 96, "sharded");
        m.record_solve_completion(Duration::from_millis(4), 64, 0, "sharded");
        let s = m.snapshot();
        assert_eq!(s.solves_completed, 3);
        assert_eq!(s.solves_sharded, 2, "kind is explicit, not sync-inferred");
        assert_eq!(s.solve_sync_rounds, 96);
        assert_eq!(s.solve_sharded.count, 2);
        // An rtl completion meters its emulated fast-clock cycles.
        assert_eq!(s.solves_rtl, 0);
        m.record_solve_completion(Duration::from_millis(2), 32, 0, "rtl");
        m.record_solve_hardware(512);
        let s = m.snapshot();
        assert_eq!(s.solves_rtl, 1);
        assert_eq!(s.solve_fast_cycles, 512);
        assert_eq!(s.solve_rtl.count, 1);
        assert_eq!(s.solve.count, 4, "pool-wide histogram sees every kind");
        // The emulated cluster front end lands in the rtl column too.
        m.record_solve_completion(Duration::from_millis(2), 32, 8, "rtl-cluster");
        let s = m.snapshot();
        assert_eq!(s.solves_rtl, 2, "rtl-cluster classifies as rtl");
        assert_eq!(s.solve.count, 5);
        // Per-kind counts and histograms agree.
        assert_eq!(s.solves_native, s.solve_native.count);
        assert_eq!(s.solves_sharded, s.solve_sharded.count);
        assert_eq!(s.solves_rtl, s.solve_rtl.count);
    }

    #[test]
    fn solve_batch_occupancy_aggregates() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.solve_batches, 0);
        assert_eq!(s.solve_batch_occupancy, 0.0, "no NaN on the empty pool");
        m.record_solve_batch(3);
        m.record_solve_batch(1);
        m.record_solve_lanes_retired(8);
        let s = m.snapshot();
        assert_eq!(s.solve_batches, 2);
        assert!((s.solve_batch_occupancy - 2.0).abs() < 1e-9);
        assert_eq!(s.solve_lanes_retired, 8);
    }

    #[test]
    fn lifecycle_and_arena_counters_aggregate() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.arena_hit_rate(), 0.0, "empty arena never NaNs");
        m.record_solve_cancelled();
        m.record_solve_pack_fallback();
        m.record_solve_trivial();
        m.record_solve_sparse();
        m.record_solve_sparse();
        m.record_solve_rtl_packed();
        m.record_rtl_cluster_sync(768);
        m.record_arena_miss();
        m.record_arena_hit();
        m.record_arena_hit();
        m.record_arena_eviction();
        let s = m.snapshot();
        assert_eq!(s.solves_cancelled, 1);
        assert_eq!(s.solve_pack_fallbacks, 1);
        assert_eq!(s.solves_trivial, 1);
        assert_eq!(s.solves_sparse, 2);
        assert_eq!(s.solves_rtl_packed, 1);
        assert_eq!(s.rtl_cluster_sync_cycles, 768);
        assert_eq!(s.arena_hits, 2);
        assert_eq!(s.arena_misses, 1);
        assert_eq!(s.arena_evictions, 1);
        assert!((s.arena_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let j = s.to_json();
        for key in [
            "solves_cancelled",
            "solve_pack_fallbacks",
            "solves_trivial",
            "solves_sparse",
            "solves_rtl_packed",
            "rtl_cluster_sync_cycles",
            "arena_hits",
            "arena_misses",
            "arena_evictions",
            "arena_hit_rate",
        ] {
            assert!(j.get(key).and_then(Json::as_f64).is_some(), "{key}");
        }
        let text = s.prometheus();
        assert!(text.contains("onn_solves_cancelled 1"));
        assert!(text.contains("onn_solves_trivial 1"));
        assert!(text.contains("onn_solves_sparse 2"));
        assert!(text.contains("onn_solves_rtl_packed 1"));
        assert!(text.contains("onn_rtl_cluster_sync_cycles 768"));
        assert!(text.contains("onn_arena_hits 2"));
        assert!(text.contains("onn_arena_hit_rate"));
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let m = Arc::new(Metrics::default());
        let threads = 4;
        let per_thread = 250u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let kinds = ["native", "sharded", "rtl"];
                    let retrieval_kinds = ["native", "pjrt"];
                    for i in 0..per_thread {
                        let d = Duration::from_micros(1 + (i % 1000) * 17);
                        m.record_completion(
                            d,
                            d * 2,
                            false,
                            retrieval_kinds[((t as u64 + i) % 2) as usize],
                        );
                        m.record_solve_completion(
                            d,
                            8,
                            0,
                            kinds[((t as u64 + i) % 3) as usize],
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        let n = threads as u64 * per_thread;
        assert_eq!(s.completed, n);
        assert_eq!(s.solves_completed, n);
        // Every sample landed in exactly one bucket of each histogram.
        assert_eq!(s.queue.count, n);
        assert_eq!(s.total.count, n);
        assert_eq!(
            s.retrievals_native + s.retrievals_pjrt,
            n,
            "per-kind retrieval counters partition the total"
        );
        assert_eq!(s.total_native.count + s.total_pjrt.count, n);
        assert_eq!(s.solve.count, n);
        assert_eq!(
            s.solve_native.count + s.solve_sharded.count + s.solve_rtl.count,
            n,
            "per-kind histograms partition the pool-wide one"
        );
        assert_eq!(s.solves_native + s.solves_sharded + s.solves_rtl, n);
        // Percentile invariants hold under concurrency and never NaN.
        for sum in [s.queue, s.total, s.solve, s.solve_native, s.solve_sharded, s.solve_rtl] {
            assert!(sum.p50_ms <= sum.p90_ms && sum.p90_ms <= sum.p99_ms);
            for v in [sum.mean_ms, sum.p50_ms, sum.p90_ms, sum.p99_ms] {
                assert!(v.is_finite());
            }
        }
        assert_eq!(s.solve_periods, n * 8);
    }

    #[test]
    fn exports_carry_percentiles_and_per_engine_counters() {
        let m = Metrics::default();
        m.record_completion(Duration::from_millis(1), Duration::from_millis(3), false, "native");
        m.record_solve_completion(Duration::from_millis(5), 16, 0, "native");
        m.record_solve_completion(Duration::from_millis(7), 16, 12, "sharded");
        m.record_solve_completion(Duration::from_millis(9), 16, 0, "rtl");
        let s = m.snapshot();
        let j = s.to_json();
        for key in ["solve", "solve_native", "solve_sharded", "solve_rtl"] {
            let sub = j.get(key).expect(key);
            for field in ["count", "mean_ms", "p50_ms", "p90_ms", "p99_ms"] {
                assert!(sub.get(field).and_then(Json::as_f64).is_some(), "{key}.{field}");
            }
        }
        assert_eq!(j.get("solves_native").and_then(Json::as_f64), Some(1.0));
        // Round-trips through the hand-rolled parser.
        let back = Json::parse(&j.to_string()).unwrap();
        let count = back.get("solve").and_then(|s| s.get("count"));
        assert_eq!(count.and_then(Json::as_f64), Some(3.0));
        let text = s.prometheus();
        assert!(text.contains("onn_solve_latency_ms{quantile=\"0.99\"}"));
        assert!(text.contains("onn_solves_by_engine{engine=\"sharded\"} 1"));
        assert!(text.contains("onn_solves_by_engine{engine=\"rtl\"} 1"));
        assert!(text.contains("# TYPE onn_solve_latency_ms summary"));
    }
}
