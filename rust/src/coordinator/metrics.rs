//! Service metrics: counters and latency aggregates, cheap enough for
//! the hot path (atomics; latencies accumulate as running sums).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub timeouts: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of real jobs over all batches (occupancy numerator).
    pub batched_jobs: AtomicU64,
    /// Total latency sums in microseconds.
    queue_us: AtomicU64,
    total_us: AtomicU64,
    // --- solve traffic (the optimization job class) ---
    pub solves_submitted: AtomicU64,
    pub solves_completed: AtomicU64,
    pub solves_failed: AtomicU64,
    solve_us: AtomicU64,
    /// Engine chunk-periods spent on solve jobs (effort accounting).
    pub solve_periods: AtomicU64,
    /// Solves served by the sharded multi-device fabric.
    pub solves_sharded: AtomicU64,
    /// All-gather synchronization rounds spent on sharded solves (the
    /// multi-device sync-cost metric, summed over completed jobs).
    pub solve_sync_rounds: AtomicU64,
    /// Solve batches collected by the pool's workers (a solo request
    /// counts as a batch of one).
    pub solve_batches: AtomicU64,
    /// Sum of real solve jobs over all solve batches (occupancy
    /// numerator; occupancy > 1 means requests coalesced onto shared
    /// lane-block engines).
    pub solve_batched_jobs: AtomicU64,
    /// Lanes of packed solves that retired before their period budget
    /// (per-lane plateau / all-settled early exit) — capacity the
    /// batcher handed back for backfill.
    pub solve_lanes_retired: AtomicU64,
    /// Solves served by the bit-true emulated-hardware (rtl) engine.
    pub solves_rtl: AtomicU64,
    /// Emulated fast-clock cycles those solves consumed — the hardware
    /// time-to-solution meter, summed over completed rtl jobs.
    pub solve_fast_cycles: AtomicU64,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub timeouts: u64,
    pub batches: u64,
    pub mean_queue_ms: f64,
    pub mean_total_ms: f64,
    /// Mean real jobs per batch / batch capacity is the caller's to
    /// compute; this is the mean real jobs per batch.
    pub mean_occupancy: f64,
    // --- solve traffic ---
    pub solves_submitted: u64,
    pub solves_completed: u64,
    pub solves_failed: u64,
    pub mean_solve_ms: f64,
    pub solve_periods: u64,
    pub solves_sharded: u64,
    pub solve_sync_rounds: u64,
    pub solve_batches: u64,
    /// Mean real solve jobs per solve batch (> 1 iff requests shared
    /// lane-block engines).
    pub solve_batch_occupancy: f64,
    pub solve_lanes_retired: u64,
    pub solves_rtl: u64,
    pub solve_fast_cycles: u64,
}

impl Metrics {
    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, real_jobs: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_jobs
            .fetch_add(real_jobs as u64, Ordering::Relaxed);
    }

    pub fn record_completion(&self, queue: Duration, total: Duration, timed_out: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if timed_out {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
        }
        self.queue_us
            .fetch_add(queue.as_micros() as u64, Ordering::Relaxed);
        self.total_us
            .fetch_add(total.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn record_solve_submit(&self) {
        self.solves_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_solve_completion(&self, total: Duration, periods: usize, sync_rounds: u64) {
        self.solves_completed.fetch_add(1, Ordering::Relaxed);
        self.solve_us
            .fetch_add(total.as_micros() as u64, Ordering::Relaxed);
        self.solve_periods
            .fetch_add(periods as u64, Ordering::Relaxed);
        if sync_rounds > 0 {
            self.solves_sharded.fetch_add(1, Ordering::Relaxed);
            self.solve_sync_rounds
                .fetch_add(sync_rounds, Ordering::Relaxed);
        }
    }

    pub fn record_solve_failure(&self) {
        self.solves_failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_solve_batch(&self, real_jobs: usize) {
        self.solve_batches.fetch_add(1, Ordering::Relaxed);
        self.solve_batched_jobs
            .fetch_add(real_jobs as u64, Ordering::Relaxed);
    }

    pub fn record_solve_lanes_retired(&self, lanes: u64) {
        self.solve_lanes_retired.fetch_add(lanes, Ordering::Relaxed);
    }

    /// A completed solve that ran on the emulated-hardware engine:
    /// count it and meter its fast-clock cycles.
    pub fn record_solve_hardware(&self, fast_cycles: u64) {
        self.solves_rtl.fetch_add(1, Ordering::Relaxed);
        self.solve_fast_cycles
            .fetch_add(fast_cycles, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let solves_completed = self.solves_completed.load(Ordering::Relaxed);
        let div = |num: u64, den: u64| if den == 0 { 0.0 } else { num as f64 / den as f64 };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            timeouts: self.timeouts.load(Ordering::Relaxed),
            batches,
            mean_queue_ms: div(self.queue_us.load(Ordering::Relaxed), completed) / 1000.0,
            mean_total_ms: div(self.total_us.load(Ordering::Relaxed), completed) / 1000.0,
            mean_occupancy: div(self.batched_jobs.load(Ordering::Relaxed), batches),
            solves_submitted: self.solves_submitted.load(Ordering::Relaxed),
            solves_completed,
            solves_failed: self.solves_failed.load(Ordering::Relaxed),
            mean_solve_ms: div(self.solve_us.load(Ordering::Relaxed), solves_completed) / 1000.0,
            solve_periods: self.solve_periods.load(Ordering::Relaxed),
            solves_sharded: self.solves_sharded.load(Ordering::Relaxed),
            solve_sync_rounds: self.solve_sync_rounds.load(Ordering::Relaxed),
            solve_batches: self.solve_batches.load(Ordering::Relaxed),
            solve_batch_occupancy: div(
                self.solve_batched_jobs.load(Ordering::Relaxed),
                self.solve_batches.load(Ordering::Relaxed),
            ),
            solve_lanes_retired: self.solve_lanes_retired.load(Ordering::Relaxed),
            solves_rtl: self.solves_rtl.load(Ordering::Relaxed),
            solve_fast_cycles: self.solve_fast_cycles.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_submit();
        m.record_submit();
        m.record_batch(2);
        m.record_completion(Duration::from_millis(2), Duration::from_millis(10), false);
        m.record_completion(Duration::from_millis(4), Duration::from_millis(20), true);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_queue_ms - 3.0).abs() < 0.01);
        assert!((s.mean_total_ms - 15.0).abs() < 0.01);
        assert!((s.mean_occupancy - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_total_ms, 0.0);
        assert_eq!(s.mean_occupancy, 0.0);
        assert_eq!(s.mean_solve_ms, 0.0);
    }

    #[test]
    fn solve_counters_aggregate() {
        let m = Metrics::default();
        m.record_solve_submit();
        m.record_solve_submit();
        m.record_solve_completion(Duration::from_millis(8), 128, 0);
        m.record_solve_failure();
        let s = m.snapshot();
        assert_eq!(s.solves_submitted, 2);
        assert_eq!(s.solves_completed, 1);
        assert_eq!(s.solves_failed, 1);
        assert_eq!(s.solve_periods, 128);
        assert!((s.mean_solve_ms - 8.0).abs() < 0.01);
        assert_eq!(s.solves_sharded, 0, "native solves are not sharded");
        // A sharded completion adds its sync rounds to the pool totals.
        m.record_solve_completion(Duration::from_millis(4), 64, 96);
        let s = m.snapshot();
        assert_eq!(s.solves_completed, 2);
        assert_eq!(s.solves_sharded, 1);
        assert_eq!(s.solve_sync_rounds, 96);
        // An rtl completion meters its emulated fast-clock cycles.
        assert_eq!(s.solves_rtl, 0);
        m.record_solve_completion(Duration::from_millis(2), 32, 0);
        m.record_solve_hardware(512);
        let s = m.snapshot();
        assert_eq!(s.solves_rtl, 1);
        assert_eq!(s.solve_fast_cycles, 512);
    }

    #[test]
    fn solve_batch_occupancy_aggregates() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.solve_batches, 0);
        assert_eq!(s.solve_batch_occupancy, 0.0, "no NaN on the empty pool");
        m.record_solve_batch(3);
        m.record_solve_batch(1);
        m.record_solve_lanes_retired(8);
        let s = m.snapshot();
        assert_eq!(s.solve_batches, 2);
        assert!((s.solve_batch_occupancy - 2.0).abs() < 1e-9);
        assert_eq!(s.solve_lanes_retired, 8);
    }
}
