//! Request router: dispatches retrieval jobs to the worker pool serving
//! the job's network size, solve jobs to the shared solver pool (solver
//! workers build an engine per request, so one pool serves every
//! problem size), and associative-memory traffic to the live pattern
//! registry (stores/forgets mutate synchronously under its lock;
//! recalls snapshot there and settle on the assoc worker's warm
//! engines).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::assoc::{
    AssocRegistry, ForgetOutcome, LearningRule, RecallJob, StoreOutcome,
};
use crate::coordinator::job::{
    Job, ProgressEvent, RecallRequest, RecallResult, RetrievalRequest, RetrievalResult, SolveJob,
    SolveRequest, SolveResult,
};
use crate::coordinator::metrics::Metrics;

/// Routing table: one job queue per network size.
pub struct Router {
    queues: Mutex<BTreeMap<usize, Sender<Job>>>,
    solver: Mutex<Option<Sender<SolveJob>>>,
    /// The live associative-memory spaces (shared with the assoc worker
    /// so matched recalls can refresh LRU recency).
    pub assoc: Arc<AssocRegistry>,
    assoc_tx: Mutex<Option<Sender<RecallJob>>>,
    /// Latched by [`shutdown`](Self::shutdown); serve loops poll it so
    /// a shut-down coordinator's listener exits without needing one
    /// more client to connect.
    shutdown: AtomicBool,
    pub metrics: Arc<Metrics>,
}

impl Router {
    pub fn new(metrics: Arc<Metrics>) -> Self {
        Self {
            queues: Mutex::new(BTreeMap::new()),
            solver: Mutex::new(None),
            assoc: Arc::new(AssocRegistry::new()),
            assoc_tx: Mutex::new(None),
            shutdown: AtomicBool::new(false),
            metrics,
        }
    }

    /// Whether [`shutdown`](Self::shutdown) has been called.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Register a worker queue for network size `n`.  Replacing an
    /// existing route is an error (shut down first).
    pub fn register(&self, n: usize, tx: Sender<Job>) -> Result<()> {
        let mut q = self.queues.lock().unwrap();
        if q.contains_key(&n) {
            return Err(anyhow!("route for n={n} already registered"));
        }
        q.insert(n, tx);
        Ok(())
    }

    pub fn routes(&self) -> Vec<usize> {
        self.queues.lock().unwrap().keys().copied().collect()
    }

    /// Submit a request; the returned channel yields the result.
    pub fn submit(&self, req: RetrievalRequest) -> Result<Receiver<RetrievalResult>> {
        if req.phases.len() != req.n {
            return Err(anyhow!(
                "request {}: phases len {} != n {}",
                req.id,
                req.phases.len(),
                req.n
            ));
        }
        let q = self.queues.lock().unwrap();
        let tx = q
            .get(&req.n)
            .ok_or_else(|| anyhow!("no engine registered for n={} (have {:?})", req.n, q.keys()))?;
        let (rtx, rrx) = channel();
        self.metrics.record_submit();
        tx.send(Job {
            req,
            submitted: Instant::now(),
            reply: rtx,
        })
        .map_err(|_| anyhow!("worker queue closed"))?;
        Ok(rrx)
    }

    /// Register the solver worker pool's queue.  Replacing an existing
    /// route is an error (shut down first).
    pub fn register_solver(&self, tx: Sender<SolveJob>) -> Result<()> {
        let mut s = self.solver.lock().unwrap();
        if s.is_some() {
            return Err(anyhow!("solver pool already registered"));
        }
        *s = Some(tx);
        Ok(())
    }

    pub fn has_solver(&self) -> bool {
        self.solver.lock().unwrap().is_some()
    }

    /// Submit a solve request; the returned channel yields the result.
    pub fn submit_solve(&self, req: SolveRequest) -> Result<Receiver<SolveResult>> {
        self.submit_solve_hooked(req, None, None)
    }

    /// [`submit_solve`](Self::submit_solve) with serving-lifecycle
    /// hooks: a cancel flag the front end sets when the client
    /// disconnects, and a progress sink + connection token for
    /// streaming requests.
    pub fn submit_solve_hooked(
        &self,
        req: SolveRequest,
        cancel: Option<Arc<AtomicBool>>,
        progress: Option<(Sender<ProgressEvent>, u64)>,
    ) -> Result<Receiver<SolveResult>> {
        if let Err(e) = req.problem.validate() {
            return Err(anyhow!("solve request {}: {e}", req.id));
        }
        if req.replicas == 0 || req.max_periods == 0 {
            return Err(anyhow!(
                "solve request {}: replicas and max_periods must be positive",
                req.id
            ));
        }
        // Reject sector encodings wider than the request's phase wheel
        // (the paper's 16 steps, or the sweep point's `2^phase_bits`)
        // here so the worker never fails internally on a client mistake.
        let wheel = 1usize << req.phase_bits.unwrap_or(4);
        if req.problem.sectors > wheel {
            return Err(anyhow!(
                "solve request {}: {} sectors exceed the {wheel}-step phase wheel",
                req.id,
                req.problem.sectors
            ));
        }
        // Precision sweep points only exist on the quantized rtl
        // datapath; the float fabrics have no weight/phase wheel to
        // narrow.  (Range validation is the wire layer's.)
        if !req.rtl && (req.weight_bits.is_some() || req.phase_bits.is_some()) {
            return Err(anyhow!(
                "solve request {}: 'weight_bits'/'phase_bits' require 'rtl': true",
                req.id
            ));
        }
        // An explicit shard override must leave every shard at least one
        // row of the embedded coupling matrix.
        if let Some(shards) = req.shards {
            let m = req.problem.embed_dim();
            if shards == 0 || shards > m {
                return Err(anyhow!(
                    "solve request {}: {shards} shards invalid for an \
                     {m}-oscillator embedding (want 1..={m})",
                    req.id
                ));
            }
        }
        let s = self.solver.lock().unwrap();
        let tx = s
            .as_ref()
            .ok_or_else(|| anyhow!("no solver pool registered"))?;
        let (rtx, rrx) = channel();
        self.metrics.record_solve_submit();
        // Zero-interaction degenerate problems (every coupling and
        // field exactly zero — e.g. `"edges": []` with no `"h"`) have
        // *every* state as a ground state; annealing noise for the full
        // period budget would return an arbitrary state at great
        // expense.  Answer immediately with the canonical trivial
        // ground state instead of burning engine time.
        if req.problem.is_zero_interaction() {
            self.metrics.record_solve_trivial();
            let result = trivial_solve_result(&req);
            // The receiver is returned below; the send cannot fail.
            let _ = rtx.send(result);
            return Ok(rrx);
        }
        tx.send(SolveJob {
            req,
            submitted: Instant::now(),
            reply: rtx,
            cancel,
            progress,
        })
        .map_err(|_| anyhow!("solver queue closed"))?;
        Ok(rrx)
    }

    /// Register the associative worker's recall queue.  Replacing an
    /// existing route is an error (shut down first).
    pub fn register_assoc(&self, tx: Sender<RecallJob>) -> Result<()> {
        let mut a = self.assoc_tx.lock().unwrap();
        if a.is_some() {
            return Err(anyhow!("assoc worker already registered"));
        }
        *a = Some(tx);
        Ok(())
    }

    pub fn has_assoc(&self) -> bool {
        self.assoc_tx.lock().unwrap().is_some()
    }

    /// Store one pattern into a memory space (created on first touch).
    /// Synchronous: the master update + delta reprogram runs under the
    /// registry lock and the outcome comes straight back.
    pub fn submit_store(
        &self,
        space: &str,
        spins: Vec<i8>,
        capacity: Option<usize>,
        rule: Option<LearningRule>,
    ) -> Result<StoreOutcome> {
        if self.is_shutdown() {
            return Err(anyhow!("coordinator is shut down"));
        }
        self.assoc.store(space, spins, capacity, rule, &self.metrics)
    }

    /// Remove one stored pattern from a memory space (synchronous, like
    /// [`submit_store`](Self::submit_store)).
    pub fn submit_forget(&self, space: &str, spins: &[i8]) -> Result<ForgetOutcome> {
        if self.is_shutdown() {
            return Err(anyhow!("coordinator is shut down"));
        }
        self.assoc.forget(space, spins, &self.metrics)
    }

    /// Submit a recall; the returned channel yields the settled result
    /// (or a structured error, e.g. an engine failure).  The space's
    /// quantized weights and match targets are snapshotted here, under
    /// the registry lock, so the recall is served against one consistent
    /// master version even while stores keep mutating the space.
    pub fn submit_recall(&self, req: RecallRequest) -> Result<Receiver<Result<RecallResult>>> {
        if !req.spins.iter().all(|&s| s == 1 || s == -1) {
            return Err(anyhow!("recall {}: probe spins must be +1/-1", req.id));
        }
        if req.max_periods == 0 {
            return Err(anyhow!("recall {}: max_periods must be positive", req.id));
        }
        let snapshot = self.assoc.snapshot(&req.space)?;
        if req.spins.len() != snapshot.n {
            return Err(anyhow!(
                "recall {}: probe has {} spins, space '{}' stores {}",
                req.id,
                req.spins.len(),
                req.space,
                snapshot.n
            ));
        }
        // An explicit shard override must leave every shard at least
        // one weight-matrix row (the solve path's rule).
        if let Some(shards) = req.shards {
            if shards == 0 || shards > snapshot.n {
                return Err(anyhow!(
                    "recall {}: {shards} shards invalid for an \
                     {}-oscillator space (want 1..={})",
                    req.id,
                    snapshot.n,
                    snapshot.n
                ));
            }
        }
        let a = self.assoc_tx.lock().unwrap();
        let tx = a
            .as_ref()
            .ok_or_else(|| anyhow!("no assoc worker registered"))?;
        let (rtx, rrx) = channel();
        tx.send(RecallJob {
            req,
            snapshot,
            submitted: Instant::now(),
            reply: rtx,
        })
        .map_err(|_| anyhow!("assoc worker queue closed"))?;
        Ok(rrx)
    }

    /// Drop all routes (workers drain and exit) and latch the shutdown
    /// flag the serve loops poll.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queues.lock().unwrap().clear();
        *self.solver.lock().unwrap() = None;
        *self.assoc_tx.lock().unwrap() = None;
        self.assoc.clear();
    }
}

/// The canonical answer to a zero-interaction problem: all spins up
/// (phase 0), energy exactly 0 — as good as any other state, found with
/// zero engine periods.  Counted in `solves_trivial`, not in the
/// per-engine solve columns (no engine ran).
fn trivial_solve_result(req: &SolveRequest) -> SolveResult {
    use std::time::Duration;
    SolveResult {
        id: req.id,
        spins: vec![1i8; req.problem.n],
        phases: vec![0i32; req.problem.n],
        energy: 0.0,
        objective: req.problem.metadata.offset,
        periods: 0,
        replicas: req.replicas,
        settled_replicas: req.replicas,
        engine: "trivial",
        sync_rounds: 0,
        quantization_error: 0.0,
        sparse: req.problem.is_sparse(),
        hardware: None,
        // A requested trace is honored with an empty lifecycle: no
        // waves, no chunks, nothing ran.
        trace: req.trace.then(Vec::new),
        queue_latency: Duration::ZERO,
        total_latency: Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n: usize) -> RetrievalRequest {
        RetrievalRequest {
            id: 1,
            n,
            phases: vec![0; n],
            max_periods: 8,
        }
    }

    #[test]
    fn routes_by_network_size() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx9, rx9) = channel();
        let (tx20, _rx20) = channel();
        r.register(9, tx9).unwrap();
        r.register(20, tx20).unwrap();
        assert_eq!(r.routes(), vec![9, 20]);
        let _pending = r.submit(req(9)).unwrap();
        let job = rx9.try_recv().unwrap();
        assert_eq!(job.req.n, 9);
    }

    #[test]
    fn unknown_size_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        assert!(r.submit(req(5)).is_err());
    }

    #[test]
    fn duplicate_route_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx.clone()).unwrap();
        assert!(r.register(9, tx).is_err());
    }

    #[test]
    fn malformed_request_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx).unwrap();
        let mut bad = req(9);
        bad.phases.pop();
        assert!(r.submit(bad).is_err());
    }

    #[test]
    fn shutdown_clears_routes() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx).unwrap();
        assert!(!r.is_shutdown());
        r.shutdown();
        assert!(r.is_shutdown(), "serve loops poll this latch to exit");
        assert!(r.submit(req(9)).is_err());
    }

    fn solve_req(n: usize) -> SolveRequest {
        use crate::solver::problem::IsingProblem;
        // A real coupling so the request is not the zero-interaction
        // degenerate case (which the router answers inline).
        let mut p = IsingProblem::new(n);
        p.set_j(0, 1, 1.0);
        SolveRequest::new(1, p)
    }

    #[test]
    fn solver_route_lifecycle() {
        let r = Router::new(Arc::new(Metrics::default()));
        assert!(!r.has_solver());
        assert!(r.submit_solve(solve_req(4)).is_err(), "no pool yet");
        let (tx, rx) = channel();
        r.register_solver(tx).unwrap();
        assert!(r.has_solver());
        let (tx2, _rx2) = channel();
        assert!(r.register_solver(tx2).is_err(), "duplicate pool");
        let _pending = r.submit_solve(solve_req(4)).unwrap();
        assert_eq!(rx.try_recv().unwrap().req.problem.n, 4);
        assert_eq!(r.metrics.solves_submitted.load(std::sync::atomic::Ordering::Relaxed), 1);
        r.shutdown();
        assert!(!r.has_solver());
    }

    #[test]
    fn zero_interaction_solve_answered_inline() {
        use crate::solver::problem::IsingProblem;
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, rx) = channel();
        r.register_solver(tx).unwrap();
        // `"edges": []` with no `"h"`: every state is a ground state.
        let mut req = SolveRequest::new(7, IsingProblem::from_edges(5, &[]).unwrap());
        req.trace = true;
        let result = r.submit_solve(req).unwrap().try_recv().unwrap();
        assert!(rx.try_recv().is_err(), "no job reaches the solver pool");
        assert_eq!(result.id, 7);
        assert_eq!(result.spins, vec![1i8; 5]);
        assert_eq!(result.phases, vec![0i32; 5]);
        assert_eq!(result.energy, 0.0);
        assert_eq!(result.periods, 0, "no engine periods were burned");
        assert_eq!(result.engine, "trivial");
        assert!(result.sparse, "sparse-form request stays flagged sparse");
        assert_eq!(result.settled_replicas, result.replicas);
        assert_eq!(result.trace.map(|t| t.len()), Some(0), "empty lifecycle");
        // Dense zero problems take the same shortcut.
        let dense = SolveRequest::new(8, IsingProblem::new(4));
        let result = r.submit_solve(dense).unwrap().try_recv().unwrap();
        assert_eq!(result.engine, "trivial");
        assert!(!result.sparse);
        let m = r.metrics.snapshot();
        assert_eq!(m.solves_trivial, 2);
        assert_eq!(m.solves_submitted, 2);
        assert_eq!(m.solves_completed, 0, "no engine solve completed");
        // A nonzero field keeps the solve on the real path.
        let mut p = IsingProblem::from_edges(5, &[]).unwrap();
        p.h[0] = 1.0;
        let _pending = r.submit_solve(SolveRequest::new(9, p)).unwrap();
        assert_eq!(rx.try_recv().unwrap().req.id, 9, "field problems anneal");
    }

    #[test]
    fn assoc_store_recall_forget_lifecycle() {
        let r = Router::new(Arc::new(Metrics::default()));
        let a = vec![1i8, -1, 1, -1, 1, -1, 1, -1, 1];
        let b = vec![1i8, 1, -1, -1, 1, 1, -1, -1, 1];
        let out = r.submit_store("g", a.clone(), Some(3), None).unwrap();
        assert!(!out.duplicate);
        assert_eq!((out.patterns, out.capacity), (1, 3));
        r.submit_store("g", b.clone(), None, None).unwrap();

        // Recall routes through the assoc worker queue with a snapshot
        // taken at submit time.
        let recall = |id: u64, spins: Vec<i8>| RecallRequest {
            id,
            space: "g".to_string(),
            spins,
            max_periods: 64,
            shards: None,
            rtl: false,
        };
        assert!(!r.has_assoc());
        assert!(r.submit_recall(recall(1, a.clone())).is_err(), "no worker");
        let (tx, rx) = channel();
        r.register_assoc(tx).unwrap();
        assert!(r.has_assoc());
        let (tx2, _rx2) = channel();
        assert!(r.register_assoc(tx2).is_err(), "duplicate worker");
        let _pending = r.submit_recall(recall(2, a.clone())).unwrap();
        let job = rx.try_recv().unwrap();
        assert_eq!(job.req.id, 2);
        assert_eq!(job.snapshot.n, 9);
        assert_eq!(job.snapshot.patterns.len(), 2);
        assert_eq!(job.snapshot.version, 2, "two stores bumped the master");

        r.submit_forget("g", &b).unwrap();
        assert!(r.submit_forget("g", &b).is_err(), "already forgotten");

        r.shutdown();
        assert!(r.submit_store("g", a.clone(), None, None).is_err());
        assert!(r.submit_forget("g", &a).is_err());
        assert!(r.submit_recall(recall(3, a)).is_err(), "queue cleared");
        assert!(!r.has_assoc());
    }

    #[test]
    fn malformed_recall_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register_assoc(tx).unwrap();
        let a = vec![1i8, -1, 1, -1];
        r.submit_store("s", a.clone(), None, None).unwrap();
        let base = RecallRequest {
            id: 1,
            space: "s".to_string(),
            spins: a,
            max_periods: 64,
            shards: None,
            rtl: false,
        };
        let mut bad = base.clone();
        bad.space = "nope".to_string();
        assert!(r.submit_recall(bad).is_err(), "unknown space");
        let mut bad = base.clone();
        bad.spins.pop();
        assert!(r.submit_recall(bad).is_err(), "probe length");
        let mut bad = base.clone();
        bad.spins[0] = 0;
        assert!(r.submit_recall(bad).is_err(), "non-spin probe");
        let mut bad = base.clone();
        bad.max_periods = 0;
        assert!(r.submit_recall(bad).is_err(), "zero budget");
        let mut bad = base.clone();
        bad.shards = Some(0);
        assert!(r.submit_recall(bad).is_err(), "zero shards");
        let mut bad = base.clone();
        bad.shards = Some(5); // more shards than oscillators
        assert!(r.submit_recall(bad).is_err());
        let mut ok = base.clone();
        ok.shards = Some(2);
        ok.rtl = true;
        assert!(r.submit_recall(ok).is_ok(), "rtl cluster recall is valid");
        assert!(r.submit_recall(base).is_ok());
    }

    #[test]
    fn malformed_solve_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register_solver(tx).unwrap();
        let mut bad = solve_req(3);
        bad.problem.j.pop();
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.replicas = 0;
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.problem.sectors = 17; // beyond the 16-step phase wheel
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.shards = Some(0);
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.shards = Some(4); // more shards than oscillators
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.weight_bits = Some(4); // sweep points need the quantized fabric
        assert!(r.submit_solve(bad).is_err());
        let mut bad = solve_req(3);
        bad.phase_bits = Some(5);
        assert!(r.submit_solve(bad).is_err());
        let mut ok = solve_req(3);
        ok.shards = Some(3);
        assert!(r.submit_solve(ok).is_ok());
        let mut ok = solve_req(3);
        ok.rtl = true;
        ok.trace = true;
        assert!(r.submit_solve(ok).is_ok(), "rtl + trace is a valid combo");
        let mut ok = solve_req(3);
        ok.rtl = true;
        ok.shards = Some(2); // emulated two-device rtl cluster
        assert!(r.submit_solve(ok).is_ok(), "rtl + shards is the cluster");
        let mut ok = solve_req(3);
        ok.rtl = true;
        ok.weight_bits = Some(3);
        ok.phase_bits = Some(5);
        assert!(r.submit_solve(ok).is_ok(), "precision sweep rides on rtl");
        // A wider phase wheel admits wider sector encodings — and the
        // check tracks the sweep point, not the paper constant.
        let mut wide = solve_req(3);
        wide.problem.sectors = 17;
        assert!(r.submit_solve(wide.clone()).is_err(), "17 > 2^4");
        wide.rtl = true;
        wide.phase_bits = Some(5);
        assert!(r.submit_solve(wide).is_ok(), "17 sectors fit a 32-step wheel");
    }
}
