//! Request router: dispatches retrieval jobs to the worker pool serving
//! the job's network size.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::job::{Job, RetrievalRequest, RetrievalResult};
use crate::coordinator::metrics::Metrics;

/// Routing table: one job queue per network size.
pub struct Router {
    queues: Mutex<BTreeMap<usize, Sender<Job>>>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    pub fn new(metrics: Arc<Metrics>) -> Self {
        Self {
            queues: Mutex::new(BTreeMap::new()),
            metrics,
        }
    }

    /// Register a worker queue for network size `n`.  Replacing an
    /// existing route is an error (shut down first).
    pub fn register(&self, n: usize, tx: Sender<Job>) -> Result<()> {
        let mut q = self.queues.lock().unwrap();
        if q.contains_key(&n) {
            return Err(anyhow!("route for n={n} already registered"));
        }
        q.insert(n, tx);
        Ok(())
    }

    pub fn routes(&self) -> Vec<usize> {
        self.queues.lock().unwrap().keys().copied().collect()
    }

    /// Submit a request; the returned channel yields the result.
    pub fn submit(&self, req: RetrievalRequest) -> Result<Receiver<RetrievalResult>> {
        if req.phases.len() != req.n {
            return Err(anyhow!(
                "request {}: phases len {} != n {}",
                req.id,
                req.phases.len(),
                req.n
            ));
        }
        let q = self.queues.lock().unwrap();
        let tx = q
            .get(&req.n)
            .ok_or_else(|| anyhow!("no engine registered for n={} (have {:?})", req.n, q.keys()))?;
        let (rtx, rrx) = channel();
        self.metrics.record_submit();
        tx.send(Job {
            req,
            submitted: Instant::now(),
            reply: rtx,
        })
        .map_err(|_| anyhow!("worker queue closed"))?;
        Ok(rrx)
    }

    /// Drop all routes (workers drain and exit).
    pub fn shutdown(&self) {
        self.queues.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n: usize) -> RetrievalRequest {
        RetrievalRequest {
            id: 1,
            n,
            phases: vec![0; n],
            max_periods: 8,
        }
    }

    #[test]
    fn routes_by_network_size() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx9, rx9) = channel();
        let (tx20, _rx20) = channel();
        r.register(9, tx9).unwrap();
        r.register(20, tx20).unwrap();
        assert_eq!(r.routes(), vec![9, 20]);
        let _pending = r.submit(req(9)).unwrap();
        let job = rx9.try_recv().unwrap();
        assert_eq!(job.req.n, 9);
    }

    #[test]
    fn unknown_size_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        assert!(r.submit(req(5)).is_err());
    }

    #[test]
    fn duplicate_route_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx.clone()).unwrap();
        assert!(r.register(9, tx).is_err());
    }

    #[test]
    fn malformed_request_rejected() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx).unwrap();
        let mut bad = req(9);
        bad.phases.pop();
        assert!(r.submit(bad).is_err());
    }

    #[test]
    fn shutdown_clears_routes() {
        let r = Router::new(Arc::new(Metrics::default()));
        let (tx, _rx) = channel();
        r.register(9, tx).unwrap();
        r.shutdown();
        assert!(r.submit(req(9)).is_err());
    }
}
