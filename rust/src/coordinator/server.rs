//! The coordinator service: wires router + batchers + engine workers +
//! the shared solver pool, and optionally speaks a JSON-lines protocol
//! over TCP (the stand-in for the paper's laptop-UI -> PYNQ network
//! link).  Two job classes share the front-end: pattern retrieval
//! (routed by network size to a fixed-weights engine pool) and Ising
//! optimization (`"type": "solve"`, handled by the solver pool — see
//! `DESIGN_SOLVER.md` for the wire format).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::assoc::{
    assoc_worker_loop, ForgetOutcome, LearningRule, StoreOutcome,
};
use crate::coordinator::batcher::{
    solve_worker_loop, worker_loop, BatchPolicy, SolvePackPolicy, SolvePending,
};
use crate::coordinator::job::{
    RecallRequest, RecallResult, RetrievalRequest, RetrievalResult, SolveRequest, SolveResult,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::router::Router;
use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;
use crate::runtime::native::NativeEngine;
use crate::runtime::EngineFactory;
use crate::solver::anneal::Schedule;
use crate::solver::portfolio::{EngineSelect, DEFAULT_MAX_SHARDS, DEFAULT_SHARD_THRESHOLD};
use crate::solver::problem::IsingProblem;
use crate::util::json::Json;

#[cfg(feature = "pjrt")]
use crate::runtime::artifact::Manifest;
#[cfg(feature = "pjrt")]
use crate::runtime::engine::{PjrtContext, PjrtEngine};

/// Solver workers sharing the solve queue (engines are built per
/// request geometry and parked warm in each worker's arena, so this
/// bounds concurrent solves, not problem sizes).
const SOLVE_WORKERS: usize = 2;

/// Solver pool configuration: worker count, the engine-selection rule,
/// and the multi-problem packing policy.  Requests whose embedding
/// reaches `shard_threshold` oscillators run on the row-sharded cluster
/// (one shard per `shard_threshold` rows, capped at `max_shards`)
/// instead of a single native engine; *small* requests (embedding
/// bucket at most `pack_max_oscillators`, replicas at most
/// `pack_max_lanes`) coalesce onto shared lane-block engines after
/// waiting up to `pack_max_wait` for company.  Neither placement nor
/// packing ever changes the answer, only where the lanes live.
///
/// Setting `rtl` serves solve traffic on the bit-true emulated-hardware
/// engine instead — a different *dynamics* (cycle-accurate serial MACs
/// at paper precision, still deterministic at equal seed), with the
/// emulated hardware cost reported per result and in the pool metrics.
#[derive(Debug, Clone, Copy)]
pub struct SolverPoolConfig {
    pub workers: usize,
    pub shard_threshold: usize,
    pub max_shards: usize,
    /// Largest embedding bucket (power of two) that still packs; 0
    /// disables solve-side batching (every request gets its own engine).
    pub pack_max_oscillators: usize,
    /// Lane capacity of one packed engine (and the per-request replica
    /// cap for packing).
    pub pack_max_lanes: usize,
    /// How long the first small solve in a window waits for company.
    pub pack_max_wait: Duration,
    /// Serve solve traffic on `runtime::rtl::RtlEngine`.  Overrides the
    /// shard threshold (the emulated device is single-fabric); small
    /// requests still coalesce — the rtl engine packs them into
    /// per-block weight banks (lane blocks).  An explicit per-request
    /// `shards` override still wins (with `rtl` it selects the emulated
    /// multi-device cluster).
    pub rtl: bool,
    /// Warm engines each solver worker parks between requests
    /// (`coordinator::arena`): a request whose geometry matches a
    /// parked engine reprograms it via `set_weights`/`set_noise`
    /// instead of building a fresh one (shard threads stay alive across
    /// requests).  0 disables warming — every request builds cold, the
    /// pre-arena behavior.
    pub arena_capacity: usize,
}

/// Warm engines parked per solver worker by default: enough for a
/// handful of hot request geometries without hoarding memory.
pub const DEFAULT_ARENA_CAPACITY: usize = 8;

impl Default for SolverPoolConfig {
    fn default() -> Self {
        let pack = SolvePackPolicy::default();
        Self {
            workers: SOLVE_WORKERS,
            shard_threshold: DEFAULT_SHARD_THRESHOLD,
            max_shards: DEFAULT_MAX_SHARDS,
            pack_max_oscillators: pack.max_oscillators,
            pack_max_lanes: pack.max_lanes,
            pack_max_wait: pack.max_wait,
            rtl: false,
            arena_capacity: DEFAULT_ARENA_CAPACITY,
        }
    }
}

impl SolverPoolConfig {
    /// The selection rule the pool's workers apply per request.  A
    /// `max_shards` below 2 disables sharding (every size runs native);
    /// an rtl pool pins every request to the emulated-hardware engine.
    pub fn select(&self) -> EngineSelect {
        if self.rtl {
            return EngineSelect::Rtl;
        }
        EngineSelect::Auto {
            threshold: self.shard_threshold.max(1),
            max_shards: self.max_shards,
        }
    }

    /// The packing policy the pool's workers apply per batch window.
    /// Packing yields to sharding: a request big enough for the
    /// row-sharded fabric (embedding at or above `shard_threshold`)
    /// must never be diverted onto a packed native engine, so the
    /// packable bucket is clamped below the threshold.  An rtl pool
    /// packs too: the emulated device carries per-block weight banks
    /// (lane blocks), so small requests coalesce onto one shared
    /// emulated fabric, bit-exact with their solo runs.
    pub fn pack(&self) -> SolvePackPolicy {
        SolvePackPolicy {
            max_oscillators: self
                .pack_max_oscillators
                .min(self.shard_threshold.saturating_sub(1)),
            max_lanes: self.pack_max_lanes,
            max_wait: self.pack_max_wait,
        }
    }
}

/// Which engine implementation a pool should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT artifact through PJRT (production path; needs the `pjrt`
    /// build feature).
    Pjrt,
    /// In-process functional engine (fallback / oracle).
    Native,
}

/// One engine pool specification: a trained network at one size.
pub struct PoolSpec {
    pub cfg: NetworkConfig,
    pub weights: WeightMatrix,
    pub kind: EngineKind,
    /// Batch/chunk for native engines (PJRT takes them from the
    /// artifact).
    pub native_batch: usize,
    pub native_chunk: usize,
    /// Worker threads sharing this pool's queue.  Batch collection is
    /// serialized; batch execution parallelizes across workers.
    pub workers: usize,
}

impl PoolSpec {
    pub fn new(cfg: NetworkConfig, weights: WeightMatrix, kind: EngineKind) -> Self {
        Self {
            cfg,
            weights,
            kind,
            native_batch: 32,
            native_chunk: 16,
            workers: 1,
        }
    }

    /// Builder: run `workers` parallel engine workers on this pool.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }
}

/// The running service.
pub struct Coordinator {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<Result<()>>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spin up one worker per pool spec, plus the shared solver pool
    /// (always present: solve traffic needs no pre-registered weights)
    /// with the default engine-selection rule.
    pub fn start(specs: Vec<PoolSpec>, policy: BatchPolicy) -> Result<Coordinator> {
        Self::start_with_solver(specs, policy, SolverPoolConfig::default())
    }

    /// [`Coordinator::start`] with an explicit solver-pool configuration
    /// (worker count + the shard threshold for large solves).
    pub fn start_with_solver(
        specs: Vec<PoolSpec>,
        policy: BatchPolicy,
        solver: SolverPoolConfig,
    ) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let router = Arc::new(Router::new(metrics.clone()));
        let mut workers = Vec::new();
        // Manifest is loaded once here (cheap); each PJRT worker compiles
        // its own executable in-thread.
        #[cfg(feature = "pjrt")]
        let manifest = if specs.iter().any(|s| s.kind == EngineKind::Pjrt) {
            Some(Manifest::load(&crate::runtime::artifact::default_dir())?)
        } else {
            None
        };

        for spec in specs {
            let n = spec.cfg.n;
            let (tx, rx) = channel();
            router.register(n, tx)?;
            let rx = Arc::new(Mutex::new(rx));
            for _ in 0..spec.workers {
                let factory: EngineFactory = match spec.kind {
                    EngineKind::Native => {
                        let cfg = spec.cfg;
                        let (b, c) = (spec.native_batch, spec.native_chunk);
                        Box::new(move || {
                            Ok(Box::new(NativeEngine::new(cfg, b, c))
                                as Box<dyn crate::runtime::ChunkEngine>)
                        })
                    }
                    #[cfg(feature = "pjrt")]
                    EngineKind::Pjrt => {
                        let info = manifest
                            .as_ref()
                            .unwrap()
                            .chunk_for(n)
                            .ok_or_else(|| anyhow!("no chunk artifact for n={n}"))?
                            .clone();
                        Box::new(move || {
                            let ctx = PjrtContext::cpu()?;
                            Ok(Box::new(PjrtEngine::load(ctx, &info)?)
                                as Box<dyn crate::runtime::ChunkEngine>)
                        })
                    }
                    #[cfg(not(feature = "pjrt"))]
                    EngineKind::Pjrt => {
                        return Err(anyhow!(
                            "pool for n={n} wants the pjrt engine, but this \
                             binary was built without the 'pjrt' feature"
                        ))
                    }
                };
                let weights = spec.weights.to_f32();
                let m = metrics.clone();
                let rx = rx.clone();
                workers.push(std::thread::spawn(move || {
                    worker_loop(factory, weights, rx, m, policy)
                }));
            }
        }

        // The shared solver pool: optimization traffic for any size;
        // the selection rule places each request on the native or
        // sharded fabric, and the packing policy coalesces small
        // compatible requests onto shared lane-block engines.
        let (stx, srx) = channel();
        router.register_solver(stx)?;
        let srx = Arc::new(Mutex::new(srx));
        let pending: SolvePending = Arc::new(Mutex::new(None));
        let select = solver.select();
        let pack = solver.pack();
        let arena_capacity = solver.arena_capacity;
        for _ in 0..solver.workers.max(1) {
            let m = metrics.clone();
            let rx = srx.clone();
            let pend = pending.clone();
            workers.push(std::thread::spawn(move || {
                solve_worker_loop(rx, pend, m, select, pack, arena_capacity)
            }));
        }

        // The associative worker: serves `"type": "recall"` traffic on
        // its own warm engine arena (engines are not Send, so recall
        // fabrics live and die on this thread).  Stores and forgets
        // never queue here — they mutate the registry synchronously on
        // the submitting connection's thread.
        let (atx, arx) = channel();
        router.register_assoc(atx)?;
        let assoc_registry = router.assoc.clone();
        let am = metrics.clone();
        workers.push(std::thread::spawn(move || {
            assoc_worker_loop(arx, assoc_registry, am, arena_capacity)
        }));

        Ok(Coordinator {
            router,
            metrics,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn retrieve_sync(&self, req: RetrievalRequest) -> Result<RetrievalResult> {
        let rx = self.router.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped reply"))
    }

    /// Submit an optimization job and wait.
    pub fn solve_sync(&self, req: SolveRequest) -> Result<SolveResult> {
        let rx = self.router.submit_solve(req)?;
        rx.recv().map_err(|_| anyhow!("solver dropped reply"))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) -> Result<()> {
        self.router.shutdown();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

// ---- TCP JSON-lines front-end ------------------------------------------------

/// Retrieval request line:
///   {"id": 1, "n": 9, "phases": [0,8,...], "max_periods": 256}
///   -> {"id": 1, "phases": [...], "settled": 12}
/// Solve request line (see DESIGN_SOLVER.md):
///   {"type": "solve", "id": 2, "n": 6, "edges": [[0,3,1],...], ...}
///   -> {"id": 2, "spins": [...], "energy": -9, ...}
/// Associative-memory lines (DESIGN_SOLVER.md §13):
///   {"type": "store", "space": "g", "spins": [1,-1,...]}
///   -> {"type": "stored", "space": "g", "patterns": 2, ...}
///   {"type": "recall", "space": "g", "spins": [1,1,...]}
///   -> {"type": "recall", "spins": [...], "matched": true, ...}
///   {"type": "forget", "space": "g", "spins": [1,-1,...]}
///   -> {"type": "forgotten", "space": "g", "patterns": 1, ...}
/// Metrics scrape (DESIGN_SOLVER.md §9):
///   {"type": "metrics"}
///   -> {"type": "metrics", "snapshot": {...}, "prometheus": "..."}
/// Errors come back as {"error": "..."} either way.
pub fn handle_line(router: &Router, line: &str) -> String {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Json::obj(vec![("error", Json::str(format!("bad json: {e}")))]).to_string()
        }
    };
    match parsed.get("type").and_then(Json::as_str) {
        Some("solve") => handle_solve_value(router, &parsed),
        Some("store") => handle_store_value(router, &parsed),
        Some("recall") => handle_recall_value(router, &parsed),
        Some("forget") => handle_forget_value(router, &parsed),
        Some("metrics") => metrics_line(router),
        None | Some("retrieve") => handle_retrieval_value(router, &parsed),
        Some(other) => error_line(&format!("unknown request type '{other}'")),
    }
}

/// One `{"error": ...}` response line (shared by both front ends).
pub fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// The `{"type": "metrics"}` response line (shared by both front ends).
pub fn metrics_line(router: &Router) -> String {
    let snap = router.metrics.snapshot();
    Json::obj(vec![
        ("type", Json::str("metrics")),
        ("snapshot", snap.to_json()),
        ("prometheus", Json::str(snap.prometheus())),
    ])
    .to_string()
}

/// Serialize one retrieval result for the wire (shared by both front
/// ends so the evented server's responses are byte-identical to the
/// thread-per-connection server's).
pub fn retrieval_result_json(id: u64, res: &RetrievalResult) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("phases", Json::arr_i32(&res.phases)),
        (
            "settled",
            res.settled
                .map(|s| Json::num(s as f64))
                .unwrap_or(Json::Null),
        ),
    ])
}

/// Serialize one solve result for the wire (shared by both front ends).
pub fn solve_result_json(id: u64, res: &SolveResult) -> Json {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        (
            "spins",
            Json::arr_i32(&res.spins.iter().map(|&s| s as i32).collect::<Vec<_>>()),
        ),
        ("phases", Json::arr_i32(&res.phases)),
        ("energy", Json::num(res.energy)),
        ("objective", Json::num(res.objective)),
        ("periods", Json::num(res.periods as f64)),
        ("replicas", Json::num(res.replicas as f64)),
        ("settled_replicas", Json::num(res.settled_replicas as f64)),
        ("engine", Json::str(res.engine)),
        ("sync_rounds", Json::num(res.sync_rounds as f64)),
        ("quantization_error", Json::num(res.quantization_error)),
        ("sparse", Json::Bool(res.sparse)),
    ];
    if let Some(hw) = &res.hardware {
        fields.push(("hw_fast_cycles", Json::num(hw.fast_cycles as f64)));
        fields.push(("hw_sync_fast_cycles", Json::num(hw.sync_fast_cycles as f64)));
        fields.push(("hw_emulated_s", Json::num(hw.emulated_s)));
        fields.push(("hw_fits_device", Json::Bool(hw.fits_device)));
    }
    // Present only when the request asked for it, so untraced
    // responses are byte-identical to the pre-telemetry wire.
    let trace = res
        .trace
        .as_ref()
        .map(|t| Json::Arr(t.iter().map(|r| r.to_json()).collect()));
    if let Some(trace) = trace {
        fields.push(("trace", trace));
    }
    Json::obj(fields)
}

/// Serialize one store outcome for the wire (shared by both front ends
/// so responses are byte-identical across servers).
pub fn store_result_json(id: u64, space: &str, out: &StoreOutcome) -> Json {
    Json::obj(vec![
        ("type", Json::str("stored")),
        ("id", Json::num(id as f64)),
        ("space", Json::str(space)),
        ("duplicate", Json::Bool(out.duplicate)),
        ("evicted", Json::num(out.evicted as f64)),
        ("patterns", Json::num(out.patterns as f64)),
        ("capacity", Json::num(out.capacity as f64)),
        ("delta_entries", Json::num(out.delta_entries as f64)),
        ("quantization_error", Json::num(out.quantization_error)),
        ("delta_us", Json::num(out.delta_latency.as_secs_f64() * 1e6)),
    ])
}

/// Serialize one forget outcome for the wire (shared by both front
/// ends).
pub fn forget_result_json(id: u64, space: &str, out: &ForgetOutcome) -> Json {
    Json::obj(vec![
        ("type", Json::str("forgotten")),
        ("id", Json::num(id as f64)),
        ("space", Json::str(space)),
        ("patterns", Json::num(out.patterns as f64)),
        ("delta_entries", Json::num(out.delta_entries as f64)),
        ("quantization_error", Json::num(out.quantization_error)),
        ("delta_us", Json::num(out.delta_latency.as_secs_f64() * 1e6)),
    ])
}

/// Serialize one recall result for the wire (shared by both front
/// ends).
pub fn recall_result_json(res: &RecallResult) -> Json {
    Json::obj(vec![
        ("type", Json::str("recall")),
        ("id", Json::num(res.id as f64)),
        (
            "spins",
            Json::arr_i32(&res.spins.iter().map(|&s| s as i32).collect::<Vec<_>>()),
        ),
        (
            "settled",
            res.settled
                .map(|s| Json::num(s as f64))
                .unwrap_or(Json::Null),
        ),
        ("matched", Json::Bool(res.matched)),
        ("engine", Json::str(res.engine)),
        ("version", Json::num(res.version as f64)),
    ])
}

/// Handle one `"type": "store"` line synchronously (shared with the
/// evented front end — stores mutate the registry inline, no worker).
pub(crate) fn handle_store_value(router: &Router, v: &Json) -> String {
    let id = v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
    match parse_store_request(v).and_then(|(space, spins, cap, rule)| {
        let out = router.submit_store(&space, spins, cap, rule)?;
        Ok((space, out))
    }) {
        Ok((space, out)) => store_result_json(id, &space, &out).to_string(),
        Err(e) => error_line(&e.to_string()),
    }
}

/// Handle one `"type": "forget"` line synchronously (shared with the
/// evented front end).
pub(crate) fn handle_forget_value(router: &Router, v: &Json) -> String {
    let id = v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
    match parse_forget_request(v).and_then(|(space, spins)| {
        let out = router.submit_forget(&space, &spins)?;
        Ok((space, out))
    }) {
        Ok((space, out)) => forget_result_json(id, &space, &out).to_string(),
        Err(e) => error_line(&e.to_string()),
    }
}

fn handle_recall_value(router: &Router, v: &Json) -> String {
    match parse_recall_request(v).and_then(|req| {
        let rx = router.submit_recall(req)?;
        rx.recv()
            .map_err(|_| anyhow!("assoc worker dropped reply"))?
    }) {
        Ok(res) => recall_result_json(&res).to_string(),
        Err(e) => error_line(&e.to_string()),
    }
}

fn handle_retrieval_value(router: &Router, v: &Json) -> String {
    match parse_request(v).and_then(|req| {
        let id = req.id;
        let rx = router.submit(req)?;
        let res = rx.recv().map_err(|_| anyhow!("worker dropped reply"))?;
        Ok((id, res))
    }) {
        Ok((id, res)) => retrieval_result_json(id, &res).to_string(),
        Err(e) => error_line(&e.to_string()),
    }
}

fn handle_solve_value(router: &Router, v: &Json) -> String {
    match parse_solve_request(v).and_then(|req| {
        let id = req.id;
        let rx = router.submit_solve(req)?;
        let res = rx.recv().map_err(|_| anyhow!("solver dropped reply"))?;
        Ok((id, res))
    }) {
        Ok((id, res)) => solve_result_json(id, &res).to_string(),
        Err(e) => error_line(&e.to_string()),
    }
}

pub(crate) fn parse_request(v: &Json) -> Result<RetrievalRequest> {
    let n = v
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing 'n'"))?;
    // The retrieval path enforces the same wire ceilings as the solve
    // path: an unbounded 'n' or 'max_periods' would let one request
    // line allocate or busy the coordinator to death.
    if n > MAX_WIRE_N {
        return Err(anyhow!("'n' = {n} exceeds the wire limit {MAX_WIRE_N}"));
    }
    let phases: Vec<i32> = v
        .get("phases")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'phases'"))?
        .iter()
        .map(|x| x.as_i64().map(|v| v as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or_else(|| anyhow!("non-numeric phase"))?;
    let max_periods = v
        .get("max_periods")
        .and_then(Json::as_usize)
        .unwrap_or(256);
    if max_periods > MAX_WIRE_PERIODS {
        return Err(anyhow!(
            "'max_periods' = {max_periods} exceeds the wire limit {MAX_WIRE_PERIODS}"
        ));
    }
    Ok(RetrievalRequest {
        id: v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
        n,
        phases,
        max_periods,
    })
}

/// Largest problem size accepted from the wire: the dense coupling
/// matrix is n^2 f64s, so an unbounded `n` would let one request line
/// allocate the coordinator to death.  4096 oscillators is ~134 MB of
/// couplings — far beyond any current engine, cheap enough to reject.
const MAX_WIRE_N: usize = 4096;
/// Effort ceilings for wire requests (a local caller can exceed them by
/// using `Coordinator::solve_sync` directly).
const MAX_WIRE_REPLICAS: usize = 4096;
const MAX_WIRE_PERIODS: usize = 65_536;
/// Shard-override ceiling: every shard is a worker thread on the
/// serving host, so cap what one request line may demand.
const MAX_WIRE_SHARDS: usize = 64;
/// Memory-space name ceiling: spaces are BTreeMap keys held for the
/// coordinator's lifetime, so bound what one request line may mint.
const MAX_WIRE_SPACE_NAME: usize = 256;
/// Pattern-capacity ceiling for one memory space (each slot pins n
/// spins plus its share of two n^2 matrices).
const MAX_WIRE_CAPACITY: usize = 1024;

/// The `"space"` field shared by the associative-memory requests.
fn parse_space(v: &Json) -> Result<String> {
    let space = v
        .get("space")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'space'"))?;
    if space.is_empty() || space.len() > MAX_WIRE_SPACE_NAME {
        return Err(anyhow!(
            "'space' must be 1..={MAX_WIRE_SPACE_NAME} characters"
        ));
    }
    Ok(space.to_string())
}

/// The `"spins"` field shared by the associative-memory requests:
/// strictly ±1 entries, length within the wire size cap.
fn parse_spins(v: &Json) -> Result<Vec<i8>> {
    let arr = v
        .get("spins")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'spins'"))?;
    if arr.is_empty() || arr.len() > MAX_WIRE_N {
        return Err(anyhow!("'spins' must have 1..={MAX_WIRE_N} entries"));
    }
    arr.iter()
        .map(|x| match x.as_i64() {
            Some(1) => Ok(1i8),
            Some(-1) => Ok(-1i8),
            _ => Err(anyhow!("'spins' entries must be +1/-1")),
        })
        .collect()
}

/// Parse a `"type": "store"` line: `"space"` + `"spins"`, with optional
/// `"capacity"` (pattern slots; only honored at space creation, must
/// match afterwards) and `"rule"` (`"hebbian"` | `"doi"`, ditto).
pub(crate) fn parse_store_request(
    v: &Json,
) -> Result<(String, Vec<i8>, Option<usize>, Option<LearningRule>)> {
    let space = parse_space(v)?;
    let spins = parse_spins(v)?;
    let capacity = match v.get("capacity") {
        None => None,
        Some(c) => {
            let cap = c
                .as_usize()
                .ok_or_else(|| anyhow!("'capacity' must be a positive integer"))?;
            if cap == 0 || cap > MAX_WIRE_CAPACITY {
                return Err(anyhow!("'capacity' = {cap} outside 1..={MAX_WIRE_CAPACITY}"));
            }
            Some(cap)
        }
    };
    let rule = match v.get("rule") {
        None => None,
        Some(r) => {
            let name = r
                .as_str()
                .ok_or_else(|| anyhow!("'rule' must be a string"))?;
            Some(LearningRule::parse(name)?)
        }
    };
    Ok((space, spins, capacity, rule))
}

/// Parse a `"type": "forget"` line: `"space"` + `"spins"`.
pub(crate) fn parse_forget_request(v: &Json) -> Result<(String, Vec<i8>)> {
    Ok((parse_space(v)?, parse_spins(v)?))
}

/// Parse a `"type": "recall"` line: `"space"` + probe `"spins"`, with
/// the solve wire's optional engine overrides (`"shards"`, `"rtl"`) and
/// `"max_periods"`.
pub(crate) fn parse_recall_request(v: &Json) -> Result<RecallRequest> {
    let space = parse_space(v)?;
    let spins = parse_spins(v)?;
    let max_periods = v
        .get("max_periods")
        .and_then(Json::as_usize)
        .unwrap_or(256);
    if max_periods == 0 || max_periods > MAX_WIRE_PERIODS {
        return Err(anyhow!(
            "'max_periods' = {max_periods} outside 1..={MAX_WIRE_PERIODS}"
        ));
    }
    let shards = match v.get("shards") {
        None => None,
        Some(s) => {
            let k = s
                .as_usize()
                .ok_or_else(|| anyhow!("'shards' must be a non-negative integer"))?;
            if k == 0 || k > MAX_WIRE_SHARDS {
                return Err(anyhow!("'shards' = {k} outside 1..={MAX_WIRE_SHARDS}"));
            }
            Some(k)
        }
    };
    let rtl = match v.get("rtl") {
        None => false,
        Some(b) => b
            .as_bool()
            .ok_or_else(|| anyhow!("'rtl' must be a boolean"))?,
    };
    Ok(RecallRequest {
        id: v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
        space,
        spins,
        max_periods,
        shards,
        rtl,
    })
}

/// Parse a solve request.  Couplings come either dense
/// (`"j": [n*n floats]`) or sparse (`"edges": [[i, j, J_ij], ...]`);
/// optional fields: `"h"` (length n), `"sectors"` (default 2),
/// `"replicas"`, `"max_periods"`, `"schedule"` (geometric | linear |
/// constant), `"noise"` (starting amplitude), `"seed"`, `"offset"`,
/// `"shards"` (explicit engine override; absent = threshold rule),
/// `"rtl"` (force the emulated-hardware engine; with `"shards": K >= 2`
/// it selects the emulated K-device rtl cluster), `"weight_bits"` /
/// `"phase_bits"` (precision sweep point, 3..=8 / 3..=6; require
/// `"rtl": true`), `"trace"` (attach a solve-lifecycle trace to the
/// result), `"stream"` (emit `{"type":"progress"}` lines mid-anneal —
/// honored by the evented front end, DESIGN_SOLVER.md §10).
pub(crate) fn parse_solve_request(v: &Json) -> Result<SolveRequest> {
    let n = v
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing 'n'"))?;
    if n == 0 {
        return Err(anyhow!("'n' must be positive"));
    }
    if n > MAX_WIRE_N {
        return Err(anyhow!("'n' = {n} exceeds the wire limit {MAX_WIRE_N}"));
    }
    let mut problem = match (v.get("j"), v.get("edges")) {
        (Some(j), _) => {
            let mut problem = IsingProblem::new(n);
            let arr = j.as_arr().ok_or_else(|| anyhow!("'j' must be an array"))?;
            if arr.len() != n * n {
                return Err(anyhow!("'j' has {} entries, want n^2 = {}", arr.len(), n * n));
            }
            for (idx, x) in arr.iter().enumerate() {
                problem.j[idx] = x.as_f64().ok_or_else(|| anyhow!("non-numeric 'j' entry"))?;
            }
            // The Hamiltonian ignores the diagonal, so a client putting
            // biases there would silently lose them — reject instead.
            for i in 0..n {
                if problem.j[i * n + i] != 0.0 {
                    return Err(anyhow!("'j' diagonal must be zero; use 'h' for biases"));
                }
            }
            problem
        }
        (None, Some(edges)) => {
            let arr = edges
                .as_arr()
                .ok_or_else(|| anyhow!("'edges' must be an array"))?;
            let mut triplets = Vec::with_capacity(arr.len());
            for e in arr {
                let t = e.as_arr().ok_or_else(|| anyhow!("edge must be [i, j, J]"))?;
                if t.len() != 3 {
                    return Err(anyhow!("edge must be [i, j, J]"));
                }
                let (i, k) = (
                    t[0].as_usize().ok_or_else(|| anyhow!("bad edge index"))?,
                    t[1].as_usize().ok_or_else(|| anyhow!("bad edge index"))?,
                );
                let w = t[2].as_f64().ok_or_else(|| anyhow!("bad edge weight"))?;
                triplets.push((i, k, w));
            }
            // Build the sparse (CSR) coupling form directly — the
            // request stays sparse end-to-end.  `from_edges` rejects
            // out-of-range indices, self loops, and duplicate pairs
            // (either orientation: [i,k] after [k,i] is a duplicate,
            // not a second coupling — the old dense arm silently
            // last-writer-wins'd both).
            IsingProblem::from_edges(n, &triplets).map_err(|e| anyhow!("bad 'edges': {e}"))?
        }
        (None, None) => return Err(anyhow!("missing couplings: provide 'j' or 'edges'")),
    };
    problem.metadata.kind = "wire".to_string();
    if let Some(h) = v.get("h") {
        let arr = h.as_arr().ok_or_else(|| anyhow!("'h' must be an array"))?;
        if arr.len() != n {
            return Err(anyhow!("'h' has {} entries, want n = {}", arr.len(), n));
        }
        for (i, x) in arr.iter().enumerate() {
            problem.h[i] = x.as_f64().ok_or_else(|| anyhow!("non-numeric 'h' entry"))?;
        }
    }
    problem.sectors = v.get("sectors").and_then(Json::as_usize).unwrap_or(2);
    problem.metadata.offset = v.get("offset").and_then(Json::as_f64).unwrap_or(0.0);

    let noise = v.get("noise").and_then(Json::as_f64).unwrap_or(0.6);
    let schedule_name = v
        .get("schedule")
        .and_then(Json::as_str)
        .unwrap_or("geometric");
    let schedule = Schedule::parse(schedule_name, noise)
        .ok_or_else(|| anyhow!("unknown schedule '{schedule_name}'"))?;

    let replicas = v.get("replicas").and_then(Json::as_usize).unwrap_or(32);
    let max_periods = v.get("max_periods").and_then(Json::as_usize).unwrap_or(256);
    if replicas > MAX_WIRE_REPLICAS || max_periods > MAX_WIRE_PERIODS {
        return Err(anyhow!(
            "effort caps exceeded: replicas <= {MAX_WIRE_REPLICAS}, \
             max_periods <= {MAX_WIRE_PERIODS}"
        ));
    }
    let shards = match v.get("shards") {
        None => None,
        Some(s) => {
            let k = s
                .as_usize()
                .ok_or_else(|| anyhow!("'shards' must be a non-negative integer"))?;
            if k == 0 || k > MAX_WIRE_SHARDS {
                return Err(anyhow!("'shards' = {k} outside 1..={MAX_WIRE_SHARDS}"));
            }
            Some(k)
        }
    };
    let bool_field = |key: &str| match v.get(key) {
        None => Ok(false),
        Some(b) => b
            .as_bool()
            .ok_or_else(|| anyhow!("'{key}' must be a boolean")),
    };
    let rtl = bool_field("rtl")?;
    let trace = bool_field("trace")?;
    let stream = bool_field("stream")?;
    // Precision sweep fields: only the quantized rtl datapath has a
    // weight width / phase wheel to narrow, so they require
    // `"rtl": true` (a `"shards"` override then selects the cluster).
    let bits_field = |key: &str, lo: u32, hi: u32| -> Result<Option<u32>> {
        match v.get(key) {
            None => Ok(None),
            Some(b) => {
                let bits =
                    b.as_usize().ok_or_else(|| anyhow!("'{key}' must be an integer"))? as u32;
                if !(lo..=hi).contains(&bits) {
                    return Err(anyhow!("'{key}' = {bits} outside {lo}..={hi}"));
                }
                Ok(Some(bits))
            }
        }
    };
    let weight_bits = bits_field("weight_bits", 3, 8)?;
    let phase_bits = bits_field("phase_bits", 3, 6)?;
    if !rtl && (weight_bits.is_some() || phase_bits.is_some()) {
        return Err(anyhow!("'weight_bits'/'phase_bits' require 'rtl': true"));
    }
    // Validate sectors here so a bad request fails with a clear message
    // instead of deep in the worker (which would drop the reply and
    // count a client mistake as an internal failure).  The wheel is the
    // paper's 16 steps unless the request swept `phase_bits`.
    let wheel = 1usize << phase_bits.unwrap_or(4);
    if !(2..=wheel).contains(&problem.sectors) {
        return Err(anyhow!(
            "'sectors' = {} outside 2..={wheel} (the phase wheel has {wheel} steps)",
            problem.sectors
        ));
    }
    Ok(SolveRequest {
        id: v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
        problem,
        replicas,
        max_periods,
        schedule,
        seed: v.get("seed").and_then(Json::as_usize).unwrap_or(1) as u64,
        shards,
        rtl,
        weight_bits,
        phase_bits,
        trace,
        stream,
    })
}

/// Serve JSON-lines over TCP until the listener errors or the router is
/// shut down.  One thread per connection (the evented front end,
/// `coordinator::stream::serve_evented`, is the scalable alternative —
/// this loop stays as the baseline the connection-scale bench measures
/// against).
///
/// The listener runs nonblocking and the loop polls the router's
/// shutdown latch between accepts, so `Coordinator::shutdown` stops the
/// serve thread without needing one more client to connect (the old
/// loop blocked in accept and only ever checked a condition —
/// `!has_solver()` — that a live pool never satisfies).
pub fn serve_tcp(router: Arc<Router>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    loop {
        if router.is_shutdown() {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, _addr)) => {
                // Connection handlers do blocking line-at-a-time I/O.
                stream.set_nonblocking(false)?;
                let conn_router = Arc::clone(&router);
                std::thread::spawn(move || {
                    let _ = handle_conn(&conn_router, stream);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn handle_conn(router: &Router, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(router, &line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_str(s: &str) -> Result<RetrievalRequest> {
        parse_request(&Json::parse(s).map_err(|e| anyhow!("bad json: {e}"))?)
    }

    #[test]
    fn parse_request_roundtrip() {
        let r = parse_str(r#"{"id": 3, "n": 2, "phases": [0, 8], "max_periods": 64}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.n, 2);
        assert_eq!(r.phases, vec![0, 8]);
        assert_eq!(r.max_periods, 64);
    }

    #[test]
    fn parse_request_defaults_and_errors() {
        let r = parse_str(r#"{"n": 1, "phases": [0]}"#).unwrap();
        assert_eq!(r.max_periods, 256);
        assert!(parse_str("{}").is_err());
        assert!(parse_str(r#"{"n": 1, "phases": ["x"]}"#).is_err());
        // The retrieval path enforces the same wire ceilings as the
        // solve path.
        assert!(
            parse_str(r#"{"n": 100000000, "phases": []}"#).is_err(),
            "'n' over the wire size cap must be rejected"
        );
        assert!(
            parse_str(r#"{"n": 1, "phases": [0], "max_periods": 100000000}"#).is_err(),
            "'max_periods' over the wire effort cap must be rejected"
        );
        // At-the-cap requests still parse.
        assert!(parse_str(r#"{"n": 1, "phases": [0], "max_periods": 65536}"#).is_ok());
    }

    #[test]
    fn handle_line_reports_routing_errors() {
        let router = Router::new(Arc::new(Metrics::default()));
        let resp = handle_line(&router, r#"{"n": 5, "phases": [0,0,0,0,0]}"#);
        assert!(resp.contains("error"), "{resp}");
        let resp = handle_line(&router, "not json");
        assert!(resp.contains("bad json"), "{resp}");
        let resp = handle_line(&router, r#"{"type": "frobnicate"}"#);
        assert!(resp.contains("unknown request type"), "{resp}");
    }

    #[test]
    fn parse_store_and_forget_requests() {
        let line = r#"{"type":"store","space":"g","spins":[1,-1,1],"capacity":5,"rule":"doi"}"#;
        let (space, spins, cap, rule) =
            parse_store_request(&Json::parse(line).unwrap()).unwrap();
        assert_eq!(space, "g");
        assert_eq!(spins, vec![1, -1, 1]);
        assert_eq!(cap, Some(5));
        assert_eq!(rule, Some(LearningRule::Doi));
        let (_, _, cap, rule) = parse_store_request(
            &Json::parse(r#"{"type":"store","space":"g","spins":[1,-1]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cap, None, "capacity defaults to the Hopfield bound");
        assert_eq!(rule, None, "rule defaults to hebbian");
        for bad in [
            r#"{"type":"store","spins":[1,-1]}"#,              // missing space
            r#"{"type":"store","space":"","spins":[1,-1]}"#,   // empty space
            r#"{"type":"store","space":"g"}"#,                 // missing spins
            r#"{"type":"store","space":"g","spins":[]}"#,      // empty pattern
            r#"{"type":"store","space":"g","spins":[1,0]}"#,   // non-spin entry
            r#"{"type":"store","space":"g","spins":[1,2]}"#,   // non-spin entry
            r#"{"type":"store","space":"g","spins":[1,-1],"capacity":0}"#,
            r#"{"type":"store","space":"g","spins":[1,-1],"capacity":100000}"#,
            r#"{"type":"store","space":"g","spins":[1,-1],"rule":"x"}"#,
            r#"{"type":"store","space":"g","spins":[1,-1],"rule":3}"#,
        ] {
            assert!(
                parse_store_request(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
        let (space, spins) = parse_forget_request(
            &Json::parse(r#"{"type":"forget","space":"g","spins":[-1,1]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!((space.as_str(), spins), ("g", vec![-1, 1]));
        assert!(
            parse_forget_request(&Json::parse(r#"{"type":"forget","space":"g"}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn parse_recall_request_overrides_and_errors() {
        let r = parse_recall_request(
            &Json::parse(
                r#"{"type":"recall","id":4,"space":"g","spins":[1,-1],
                    "max_periods":64,"shards":2,"rtl":true}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(r.id, 4);
        assert_eq!(r.space, "g");
        assert_eq!(r.spins, vec![1, -1]);
        assert_eq!(r.max_periods, 64);
        assert_eq!(r.shards, Some(2));
        assert!(r.rtl);
        let d = parse_recall_request(
            &Json::parse(r#"{"type":"recall","space":"g","spins":[1,-1]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(d.max_periods, 256, "default period budget");
        assert_eq!(d.shards, None);
        assert!(!d.rtl);
        for bad in [
            r#"{"type":"recall","space":"g"}"#,                    // missing spins
            r#"{"type":"recall","spins":[1,-1]}"#,                 // missing space
            r#"{"type":"recall","space":"g","spins":[1,-1],"max_periods":0}"#,
            r#"{"type":"recall","space":"g","spins":[1,-1],"max_periods":100000000}"#,
            r#"{"type":"recall","space":"g","spins":[1,-1],"shards":0}"#,
            r#"{"type":"recall","space":"g","spins":[1,-1],"shards":1000}"#,
            r#"{"type":"recall","space":"g","spins":[1,-1],"rtl":1}"#,
        ] {
            assert!(
                parse_recall_request(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn handle_line_serves_store_and_forget_synchronously() {
        // Stores and forgets need no worker pool: they mutate the
        // router's registry inline, so a bare Router serves them.
        let router = Router::new(Arc::new(Metrics::default()));
        let resp = handle_line(
            &router,
            r#"{"type":"store","id":1,"space":"g","spins":[1,-1,1,-1]}"#,
        );
        assert!(resp.contains(r#""type":"stored""#), "{resp}");
        assert!(resp.contains(r#""id":1"#), "{resp}");
        assert!(resp.contains(r#""patterns":1"#), "{resp}");
        assert!(resp.contains(r#""duplicate":false"#), "{resp}");
        assert!(resp.contains(r#""delta_entries":"#), "{resp}");
        // Re-storing the inverse is an idempotent duplicate.
        let resp = handle_line(
            &router,
            r#"{"type":"store","space":"g","spins":[-1,1,-1,1]}"#,
        );
        assert!(resp.contains(r#""duplicate":true"#), "{resp}");
        assert!(resp.contains(r#""patterns":1"#), "{resp}");
        // A recall without the assoc worker reports a structured error.
        let resp = handle_line(
            &router,
            r#"{"type":"recall","space":"g","spins":[1,-1,1,-1]}"#,
        );
        assert!(resp.contains("no assoc worker"), "{resp}");
        let resp = handle_line(
            &router,
            r#"{"type":"forget","id":9,"space":"g","spins":[1,-1,1,-1]}"#,
        );
        assert!(resp.contains(r#""type":"forgotten""#), "{resp}");
        assert!(resp.contains(r#""patterns":0"#), "{resp}");
        let resp = handle_line(
            &router,
            r#"{"type":"forget","space":"g","spins":[1,-1,1,-1]}"#,
        );
        assert!(resp.contains("error"), "forgetting twice: {resp}");
        // Associative counters rode the shared metrics.
        let snap = router.metrics.snapshot();
        assert_eq!(snap.patterns_stored, 1);
        assert_eq!(snap.store_duplicates, 1);
        assert_eq!(snap.patterns_forgotten, 1);
    }

    #[test]
    fn pack_policy_yields_to_the_shard_threshold() {
        // A pool that shards at 12 oscillators must not divert 12+
        // requests onto packed native engines.
        let cfg = SolverPoolConfig {
            shard_threshold: 12,
            ..Default::default()
        };
        assert_eq!(cfg.pack().max_oscillators, 11);
        assert_eq!(SolverPoolConfig::default().pack().max_oscillators, 64);
        let off = SolverPoolConfig {
            pack_max_oscillators: 0,
            ..Default::default()
        };
        assert_eq!(off.pack().max_oscillators, 0, "packing stays disableable");
    }

    #[test]
    fn rtl_pool_pins_selection_and_still_packs() {
        let cfg = SolverPoolConfig {
            rtl: true,
            ..Default::default()
        };
        assert_eq!(cfg.select(), EngineSelect::Rtl);
        assert_eq!(
            cfg.pack().max_oscillators,
            SolverPoolConfig::default().pack().max_oscillators,
            "the rtl engine has lane blocks, so small requests coalesce"
        );
        assert_ne!(SolverPoolConfig::default().select(), EngineSelect::Rtl);
    }

    #[test]
    fn parse_solve_request_edges_form() {
        let r = parse_solve_request(
            &Json::parse(
                r#"{"type":"solve","id":7,"n":3,
                    "edges":[[0,1,-1],[1,2,-1]],
                    "replicas":4,"max_periods":32,"shards":2,
                    "schedule":"linear","noise":0.4,"seed":9}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.problem.n, 3);
        assert_eq!(r.problem.get_j(0, 1), -1.0);
        assert_eq!(r.problem.get_j(1, 0), -1.0);
        assert_eq!(r.problem.get_j(0, 2), 0.0);
        assert!(
            r.problem.is_sparse(),
            "'edges' requests must stay in the sparse coupling form"
        );
        assert_eq!(r.problem.metadata.kind, "wire");
        assert_eq!(r.replicas, 4);
        assert_eq!(r.max_periods, 32);
        assert_eq!(r.schedule, Schedule::Linear { start: 0.4 });
        assert_eq!(r.seed, 9);
        assert_eq!(r.shards, Some(2));
    }

    #[test]
    fn parse_solve_request_rejects_duplicate_edges() {
        // The old dense-scatter arm silently last-writer-wins'd repeated
        // pairs; the wire contract now rejects them so a client bug
        // can't half-apply a coupling list.
        let dup = parse_solve_request(
            &Json::parse(r#"{"n":3,"edges":[[0,1,1],[0,1,2]]}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(dup.contains("duplicate edge"), "{dup}");
        // The reversed orientation names the same undirected pair.
        let rev = parse_solve_request(
            &Json::parse(r#"{"n":3,"edges":[[0,1,1],[1,0,1]]}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(rev.contains("duplicate edge"), "{rev}");
        let loop_ = parse_solve_request(&Json::parse(r#"{"n":3,"edges":[[2,2,1]]}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(loop_.contains("self-loop"), "{loop_}");
        // An empty edge list is a *valid* (degenerate) request — the
        // router answers it trivially without burning an anneal budget.
        let empty = parse_solve_request(&Json::parse(r#"{"n":3,"edges":[]}"#).unwrap()).unwrap();
        assert!(empty.problem.is_sparse());
        assert!(empty.problem.is_zero_interaction());
    }

    #[test]
    fn parse_solve_request_dense_form_and_errors() {
        let ok = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"h":[0.5,0]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(ok.problem.get_j(0, 1), -1.0);
        assert_eq!(ok.problem.h[0], 0.5);
        assert_eq!(ok.schedule.name(), "geometric");
        assert_eq!(ok.shards, None, "no override by default");
        assert!(!ok.rtl && !ok.trace, "observability flags default off");
        assert!(!ok.stream, "streaming defaults off");
        let flagged = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"rtl":true,"trace":true}"#).unwrap(),
        )
        .unwrap();
        assert!(flagged.rtl && flagged.trace);
        let streaming = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"stream":true}"#).unwrap(),
        )
        .unwrap();
        assert!(streaming.stream);
        // rtl composes with shards: K >= 2 is the emulated K-device
        // cluster, no longer a wire error.
        let cluster = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"rtl":true,"shards":2}"#).unwrap(),
        )
        .unwrap();
        assert!(cluster.rtl);
        assert_eq!(cluster.shards, Some(2));
        // Precision sweep fields parse, validate their ranges, and
        // require the quantized rtl datapath.
        let swept = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"rtl":true,"weight_bits":4,"phase_bits":5}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(swept.weight_bits, Some(4));
        assert_eq!(swept.phase_bits, Some(5));
        assert_eq!(swept.precision(), Some((4, 5)));
        let default_precision = parse_solve_request(
            &Json::parse(r#"{"n":2,"j":[0,-1,-1,0],"rtl":true}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(default_precision.precision(), None, "paper precision");
        // A swept phase wheel widens the sector ceiling.
        let wide = parse_solve_request(
            &Json::parse(
                r#"{"n":2,"j":[0,-1,-1,0],"rtl":true,"phase_bits":6,"sectors":32}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(wide.problem.sectors, 32);
        for bad in [
            r#"{"j":[0,0,0,0]}"#,                      // missing n
            r#"{"n":2}"#,                              // missing couplings
            r#"{"n":2,"j":[0,1]}"#,                    // wrong j length
            r#"{"n":2,"j":[1,0,0,0]}"#,                // nonzero diagonal
            r#"{"n":2,"j":[0,1,1,0],"h":[1]}"#,        // wrong h length
            r#"{"n":2,"edges":[[0,0,1]]}"#,            // self-loop
            r#"{"n":2,"edges":[[0,5,1]]}"#,            // out of range
            r#"{"n":2,"j":[0,1,1,0],"schedule":"x"}"#, // unknown schedule
            r#"{"n":100000000,"edges":[]}"#,           // over the wire size cap
            r#"{"n":2,"j":[0,1,1,0],"replicas":1000000}"#, // over the effort cap
            r#"{"n":2,"j":[0,1,1,0],"sectors":17}"#,   // beyond the phase wheel
            r#"{"n":2,"j":[0,1,1,0],"sectors":1}"#,    // degenerate sector count
            r#"{"n":2,"j":[0,1,1,0],"shards":0}"#,     // zero shards
            r#"{"n":2,"j":[0,1,1,0],"shards":1000}"#,  // over the shard cap
            r#"{"n":2,"j":[0,1,1,0],"rtl":1}"#,        // rtl must be boolean
            r#"{"n":2,"j":[0,1,1,0],"trace":"yes"}"#,  // trace must be boolean
            r#"{"n":2,"j":[0,1,1,0],"stream":0}"#,     // stream must be boolean
            r#"{"n":2,"j":[0,1,1,0],"weight_bits":4}"#, // precision needs rtl
            r#"{"n":2,"j":[0,1,1,0],"phase_bits":5}"#,  // precision needs rtl
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"weight_bits":2}"#, // below 3 bits
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"weight_bits":9}"#, // above 8 bits
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"phase_bits":2}"#,  // below 3 bits
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"phase_bits":7}"#,  // above 6 bits
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"weight_bits":"x"}"#, // non-integer
            r#"{"n":2,"j":[0,1,1,0],"rtl":true,"phase_bits":3,"sectors":10}"#, // 10 > 2^3
        ] {
            assert!(
                parse_solve_request(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }
}
