//! The coordinator service: wires router + batchers + engine workers,
//! and optionally speaks a JSON-lines protocol over TCP (the stand-in
//! for the paper's laptop-UI -> PYNQ network link).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::{worker_loop, BatchPolicy};
use crate::coordinator::job::{RetrievalRequest, RetrievalResult};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::router::Router;
use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;
use crate::runtime::artifact::Manifest;
use crate::runtime::engine::{PjrtContext, PjrtEngine};
use crate::runtime::native::NativeEngine;
use crate::runtime::EngineFactory;
use crate::util::json::Json;

/// Which engine implementation a pool should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT artifact through PJRT (production path).
    Pjrt,
    /// In-process functional engine (fallback / oracle).
    Native,
}

/// One engine pool specification: a trained network at one size.
pub struct PoolSpec {
    pub cfg: NetworkConfig,
    pub weights: WeightMatrix,
    pub kind: EngineKind,
    /// Batch/chunk for native engines (PJRT takes them from the
    /// artifact).
    pub native_batch: usize,
    pub native_chunk: usize,
    /// Worker threads sharing this pool's queue.  Batch collection is
    /// serialized; batch execution parallelizes across workers.
    pub workers: usize,
}

impl PoolSpec {
    pub fn new(cfg: NetworkConfig, weights: WeightMatrix, kind: EngineKind) -> Self {
        Self {
            cfg,
            weights,
            kind,
            native_batch: 32,
            native_chunk: 16,
            workers: 1,
        }
    }

    /// Builder: run `workers` parallel engine workers on this pool.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }
}

/// The running service.
pub struct Coordinator {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<Result<()>>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spin up one worker per pool spec.
    pub fn start(specs: Vec<PoolSpec>, policy: BatchPolicy) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::default());
        let router = Arc::new(Router::new(metrics.clone()));
        let mut workers = Vec::new();
        // Manifest is loaded once here (cheap); each PJRT worker compiles
        // its own executable in-thread.
        let manifest = if specs.iter().any(|s| s.kind == EngineKind::Pjrt) {
            Some(Manifest::load(&crate::runtime::artifact::default_dir())?)
        } else {
            None
        };

        for spec in specs {
            let n = spec.cfg.n;
            let (tx, rx) = channel();
            router.register(n, tx)?;
            let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
            for _ in 0..spec.workers {
                let factory: EngineFactory = match spec.kind {
                    EngineKind::Native => {
                        let cfg = spec.cfg;
                        let (b, c) = (spec.native_batch, spec.native_chunk);
                        Box::new(move || {
                            Ok(Box::new(NativeEngine::new(cfg, b, c))
                                as Box<dyn crate::runtime::ChunkEngine>)
                        })
                    }
                    EngineKind::Pjrt => {
                        let info = manifest
                            .as_ref()
                            .unwrap()
                            .chunk_for(n)
                            .ok_or_else(|| anyhow!("no chunk artifact for n={n}"))?
                            .clone();
                        Box::new(move || {
                            let ctx = PjrtContext::cpu()?;
                            Ok(Box::new(PjrtEngine::load(ctx, &info)?)
                                as Box<dyn crate::runtime::ChunkEngine>)
                        })
                    }
                };
                let weights = spec.weights.to_f32();
                let m = metrics.clone();
                let rx = rx.clone();
                workers.push(std::thread::spawn(move || {
                    worker_loop(factory, weights, rx, m, policy)
                }));
            }
        }
        Ok(Coordinator {
            router,
            metrics,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn retrieve_sync(&self, req: RetrievalRequest) -> Result<RetrievalResult> {
        let rx = self.router.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped reply"))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) -> Result<()> {
        self.router.shutdown();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

// ---- TCP JSON-lines front-end ------------------------------------------------

/// Request line: {"id": 1, "n": 9, "phases": [0,8,...], "max_periods": 256}
/// Response line: {"id": 1, "phases": [...], "settled": 12} (settled
/// null on timeout, "error" on failure).
pub fn handle_line(router: &Router, line: &str) -> String {
    match parse_request(line).and_then(|req| {
        let id = req.id;
        let rx = router.submit(req)?;
        let res = rx.recv().map_err(|_| anyhow!("worker dropped reply"))?;
        Ok((id, res))
    }) {
        Ok((id, res)) => Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("phases", Json::arr_i32(&res.phases)),
            (
                "settled",
                res.settled
                    .map(|s| Json::num(s as f64))
                    .unwrap_or(Json::Null),
            ),
        ])
        .to_string(),
        Err(e) => Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
    }
}

fn parse_request(line: &str) -> Result<RetrievalRequest> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let n = v
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing 'n'"))?;
    let phases: Vec<i32> = v
        .get("phases")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'phases'"))?
        .iter()
        .map(|x| x.as_i64().map(|v| v as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or_else(|| anyhow!("non-numeric phase"))?;
    Ok(RetrievalRequest {
        id: v.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
        n,
        phases,
        max_periods: v
            .get("max_periods")
            .and_then(Json::as_usize)
            .unwrap_or(256),
    })
}

/// Serve JSON-lines over TCP until the listener errors or the router is
/// shut down.  One thread per connection (std-only substitute for the
/// async accept loop).
pub fn serve_tcp(router: Arc<Router>, listener: TcpListener) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream?;
        let conn_router = Arc::clone(&router);
        std::thread::spawn(move || {
            let _ = handle_conn(&conn_router, stream);
        });
        if router.routes().is_empty() {
            break;
        }
    }
    Ok(())
}

fn handle_conn(router: &Router, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(router, &line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_roundtrip() {
        let r =
            parse_request(r#"{"id": 3, "n": 2, "phases": [0, 8], "max_periods": 64}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.n, 2);
        assert_eq!(r.phases, vec![0, 8]);
        assert_eq!(r.max_periods, 64);
    }

    #[test]
    fn parse_request_defaults_and_errors() {
        let r = parse_request(r#"{"n": 1, "phases": [0]}"#).unwrap();
        assert_eq!(r.max_periods, 256);
        assert!(parse_request("{}").is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"n": 1, "phases": ["x"]}"#).is_err());
    }

    #[test]
    fn handle_line_reports_routing_errors() {
        let router = Router::new(Arc::new(Metrics::default()));
        let resp = handle_line(&router, r#"{"n": 5, "phases": [0,0,0,0,0]}"#);
        assert!(resp.contains("error"), "{resp}");
    }
}
