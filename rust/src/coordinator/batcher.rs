//! Dynamic batcher + worker: packs retrieval jobs into the fixed batch
//! dimension of a chunk engine using a size-or-deadline policy, drives
//! the engine to a fixed point, and replies per job.
//!
//! Policy: the first job opens a batch window; the window closes when
//! either the batch is full or `max_wait` elapses — the same policy a
//! serving router uses to trade latency for occupancy.  Unused batch
//! slots are padded with a copy of the first job's phases (the engine's
//! batch shape is baked into the AOT artifact).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::job::{Job, RetrievalResult, SolveJob, SolveResult};
use crate::coordinator::metrics::Metrics;
use crate::runtime::EngineFactory;
use crate::solver::portfolio::{solve_with, EngineSelect, PortfolioParams};

/// Batch-window policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum time the first job in a window waits for company.
    pub max_wait: Duration,
    /// Hard cap on periods driven per batch (safety).
    pub max_periods_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_periods_cap: 1024,
        }
    }
}

/// Collect one batch according to the policy. Exposed for testing.
pub fn collect_batch(
    rx: &Receiver<Job>,
    capacity: usize,
    policy: &BatchPolicy,
) -> Option<Vec<Job>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut jobs = vec![first];
    while jobs.len() < capacity {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(j) => jobs.push(j),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(jobs)
}

/// The worker loop: owns the engine (constructed in-thread; PJRT handles
/// are thread-affine), pulls batches, runs them, replies.
///
/// Several workers may share one queue (`Arc<Mutex<Receiver>>`): batch
/// *collection* is serialized by the lock, batch *execution* runs in
/// parallel across workers — the occupancy/throughput trade a serving
/// pool makes.
pub fn worker_loop(
    factory: EngineFactory,
    weights_f32: Vec<f32>,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
) -> Result<()> {
    let mut engine = factory()?;
    engine.set_weights(&weights_f32)?;
    let n = engine.n();
    let capacity = engine.batch();
    let chunk = engine.chunk_len();

    let mut phases = vec![0i32; capacity * n];
    let mut settled = vec![-1i32; capacity];

    loop {
        let jobs = {
            let guard = rx.lock().expect("queue lock poisoned");
            collect_batch(&guard, capacity, &policy)
        };
        let Some(jobs) = jobs else { break };
        let batch_start = Instant::now();
        metrics.record_batch(jobs.len());
        let max_periods = jobs
            .iter()
            .map(|j| j.req.max_periods)
            .max()
            .unwrap_or(chunk)
            .min(policy.max_periods_cap);

        // Pack: real jobs then padding (repeat job 0 so the padded work
        // is well-formed; its results are discarded).
        for (slot, job) in jobs.iter().enumerate() {
            debug_assert_eq!(job.req.phases.len(), n, "router sent wrong-size job");
            phases[slot * n..(slot + 1) * n].copy_from_slice(&job.req.phases);
        }
        for slot in jobs.len()..capacity {
            let src = jobs[0].req.phases.clone();
            phases[slot * n..(slot + 1) * n].copy_from_slice(&src);
        }
        settled.iter_mut().for_each(|s| *s = -1);

        // Drive chunks until every *real* slot either settles or is
        // provably hopeless.  A trial whose phases are unchanged across
        // a whole chunk without having settled is in a limit cycle
        // whose length divides the chunk (e.g. the synchronous
        // 2-cycle): it can never settle, so stop burning periods on it.
        // This is the L3 early-exit of EXPERIMENTS.md section Perf.
        let mut period = 0usize;
        let mut hopeless = vec![false; jobs.len()];
        let mut before = vec![0i32; n];
        while period < max_periods {
            let snapshot: Vec<i32> = phases[..jobs.len() * n].to_vec();
            engine.run_chunk(&mut phases, &mut settled, period as i32)?;
            period += chunk;
            let mut active = false;
            for (slot, h) in hopeless.iter_mut().enumerate() {
                if settled[slot] >= 0 || *h {
                    continue;
                }
                before.copy_from_slice(&snapshot[slot * n..(slot + 1) * n]);
                if phases[slot * n..(slot + 1) * n] == before[..] {
                    *h = true; // limit cycle: unchanged over a full chunk
                } else {
                    active = true;
                }
            }
            if !active {
                break;
            }
        }

        let done = Instant::now();
        let occupancy = jobs.len();
        for (slot, job) in jobs.into_iter().enumerate() {
            let s = settled[slot];
            let result = RetrievalResult {
                id: job.req.id,
                phases: phases[slot * n..(slot + 1) * n].to_vec(),
                settled: (s >= 0).then_some(s as usize),
                queue_latency: batch_start.duration_since(job.submitted),
                total_latency: done.duration_since(job.submitted),
                batch_occupancy: occupancy,
            };
            let timed_out = result.settled.is_none();
            metrics.record_completion(result.queue_latency, result.total_latency, timed_out);
            // Receiver may have hung up (client gave up) — that's fine.
            let _ = job.reply.send(result);
        }
    }
    Ok(())
}

/// The solver worker loop: pulls [`SolveJob`]s from the shared queue and
/// runs each through the annealed replica portfolio on a fresh engine
/// sized for the request (solve traffic spans arbitrary problem sizes,
/// so engines are per-request rather than per-pool — the request itself
/// is the batch: its replicas fill the engine's batch dimension).
/// `select` is the pool's engine-selection rule: requests embedding
/// above the configured oscillator threshold run on the row-sharded
/// cluster instead of a single native engine; a request's explicit
/// `shards` field overrides the rule.
///
/// Several workers may share one queue; each request runs on exactly one
/// worker, so concurrency scales across requests.
pub fn solve_worker_loop(
    rx: Arc<Mutex<Receiver<SolveJob>>>,
    metrics: Arc<Metrics>,
    select: EngineSelect,
) -> Result<()> {
    loop {
        let job = {
            let guard = rx.lock().expect("solve queue lock poisoned");
            guard.recv()
        };
        let Ok(job) = job else { break };
        let dequeued = Instant::now();
        let params = PortfolioParams {
            replicas: job.req.replicas,
            max_periods: job.req.max_periods,
            schedule: job.req.schedule,
            seed: job.req.seed,
            ..Default::default()
        };
        let job_select = match job.req.shards {
            Some(1) => EngineSelect::Native,
            Some(k) => EngineSelect::Sharded { shards: k },
            None => select,
        };
        match solve_with(&job.req.problem, &params, job_select) {
            Ok(out) => {
                let done = Instant::now();
                let result = SolveResult {
                    id: job.req.id,
                    objective: out.best_energy + job.req.problem.metadata.offset,
                    spins: out.best_spins,
                    phases: out.best_phases,
                    energy: out.best_energy,
                    periods: out.periods,
                    replicas: out.replicas,
                    settled_replicas: out.settled_replicas,
                    engine: out.engine,
                    sync_rounds: out.sync_rounds,
                    queue_latency: dequeued.duration_since(job.submitted),
                    total_latency: done.duration_since(job.submitted),
                };
                metrics.record_solve_completion(
                    result.total_latency,
                    result.periods,
                    result.sync_rounds,
                );
                // Receiver may have hung up (client gave up) — fine.
                let _ = job.reply.send(result);
            }
            Err(e) => {
                // Router validation catches malformed requests, so this
                // is an internal failure; drop the reply (the client
                // surfaces "worker dropped reply") and count it.
                metrics.record_solve_failure();
                eprintln!("solve job {} failed: {e:#}", job.req.id);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn dummy_job(id: u64, reply: std::sync::mpsc::Sender<RetrievalResult>) -> Job {
        Job {
            req: crate::coordinator::job::RetrievalRequest {
                id,
                n: 2,
                phases: vec![0, 8],
                max_periods: 16,
            },
            submitted: Instant::now(),
            reply,
        }
    }

    #[test]
    fn collect_waits_until_full() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..3 {
            tx.send(dummy_job(i, rtx.clone())).unwrap();
        }
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let jobs = collect_batch(&rx, 3, &policy).unwrap();
        assert_eq!(jobs.len(), 3);
    }

    #[test]
    fn collect_respects_deadline() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(dummy_job(0, rtx)).unwrap();
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let t0 = Instant::now();
        let jobs = collect_batch(&rx, 64, &policy).unwrap();
        assert_eq!(jobs.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn collect_none_after_disconnect() {
        let (tx, rx) = channel::<Job>();
        drop(tx);
        assert!(collect_batch(&rx, 4, &BatchPolicy::default()).is_none());
    }
}
