//! Dynamic batcher + worker: packs retrieval jobs into the fixed batch
//! dimension of a chunk engine using a size-or-deadline policy, drives
//! the engine to a fixed point, and replies per job.
//!
//! Policy: the first job opens a batch window; the window closes when
//! either the batch is full or `max_wait` elapses — the same policy a
//! serving router uses to trade latency for occupancy.  Unused batch
//! slots are padded with a copy of the first job's phases (the engine's
//! batch shape is baked into the AOT artifact).
//!
//! Solve traffic batches the same way ([`collect_solve_batch`]): small
//! compatible `SolveRequest`s coalesce into one lane-block engine whose
//! batch lanes carry *different problems* (DESIGN_SOLVER.md §7), packed
//! and driven by `solver::portfolio::solve_packed` — bit-exact with the
//! one-engine-per-request path at equal seed.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::arena::{ArenaKey, EngineArena};
use crate::coordinator::job::{
    Job, ProgressEvent, RetrievalResult, SolveJob, SolveRequest, SolveResult,
};
use crate::coordinator::metrics::Metrics;
use crate::onn::config::NetworkConfig;
use crate::runtime::EngineFactory;
use crate::solver::portfolio::{
    build_engine_cfg, is_cancelled, solve_packed_hooked, solve_portfolio_hooked, wants_sparse,
    EngineSelect, PortfolioParams, SolveHooks, DEFAULT_CHUNK, MAX_WAVE_REPLICAS,
};
use crate::solver::problem::IsingProblem;
use crate::telemetry::{sink, DEFAULT_TRACE_CAP};

/// Batch-window policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum time the first job in a window waits for company.
    pub max_wait: Duration,
    /// Hard cap on periods driven per batch (safety).
    pub max_periods_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_periods_cap: 1024,
        }
    }
}

/// Collect one batch according to the policy. Exposed for testing.
pub fn collect_batch(
    rx: &Receiver<Job>,
    capacity: usize,
    policy: &BatchPolicy,
) -> Option<Vec<Job>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut jobs = vec![first];
    while jobs.len() < capacity {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(j) => jobs.push(j),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(jobs)
}

/// The worker loop: owns the engine (constructed in-thread; PJRT handles
/// are thread-affine), pulls batches, runs them, replies.
///
/// Several workers may share one queue (`Arc<Mutex<Receiver>>`): batch
/// *collection* is serialized by the lock, batch *execution* runs in
/// parallel across workers — the occupancy/throughput trade a serving
/// pool makes.
pub fn worker_loop(
    factory: EngineFactory,
    weights_f32: Vec<f32>,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    policy: BatchPolicy,
) -> Result<()> {
    let mut engine = factory()?;
    engine.set_weights(&weights_f32)?;
    let n = engine.n();
    let capacity = engine.batch();
    let chunk = engine.chunk_len();
    let engine_kind = engine.kind();

    let mut phases = vec![0i32; capacity * n];
    let mut settled = vec![-1i32; capacity];

    loop {
        let jobs = {
            let guard = rx.lock().expect("queue lock poisoned");
            collect_batch(&guard, capacity, &policy)
        };
        let Some(jobs) = jobs else { break };
        let batch_start = Instant::now();
        metrics.record_batch(jobs.len());
        let max_periods = jobs
            .iter()
            .map(|j| j.req.max_periods)
            .max()
            .unwrap_or(chunk)
            .min(policy.max_periods_cap);

        // Pack: real jobs then padding (repeat job 0 so the padded work
        // is well-formed; its results are discarded).
        for (slot, job) in jobs.iter().enumerate() {
            debug_assert_eq!(job.req.phases.len(), n, "router sent wrong-size job");
            phases[slot * n..(slot + 1) * n].copy_from_slice(&job.req.phases);
        }
        for slot in jobs.len()..capacity {
            let src = jobs[0].req.phases.clone();
            phases[slot * n..(slot + 1) * n].copy_from_slice(&src);
        }
        settled.iter_mut().for_each(|s| *s = -1);

        // Drive chunks until every *real* slot either settles or is
        // provably hopeless.  A trial whose phases are unchanged across
        // a whole chunk without having settled is in a limit cycle
        // whose length divides the chunk (e.g. the synchronous
        // 2-cycle): it can never settle, so stop burning periods on it.
        // This is the L3 early-exit of EXPERIMENTS.md section Perf.
        let mut period = 0usize;
        let mut hopeless = vec![false; jobs.len()];
        let mut before = vec![0i32; n];
        while period < max_periods {
            let snapshot: Vec<i32> = phases[..jobs.len() * n].to_vec();
            engine.run_chunk(&mut phases, &mut settled, period as i32)?;
            period += chunk;
            let mut active = false;
            for (slot, h) in hopeless.iter_mut().enumerate() {
                if settled[slot] >= 0 || *h {
                    continue;
                }
                before.copy_from_slice(&snapshot[slot * n..(slot + 1) * n]);
                if phases[slot * n..(slot + 1) * n] == before[..] {
                    *h = true; // limit cycle: unchanged over a full chunk
                } else {
                    active = true;
                }
            }
            if !active {
                break;
            }
        }

        let done = Instant::now();
        let occupancy = jobs.len();
        for (slot, job) in jobs.into_iter().enumerate() {
            let s = settled[slot];
            let result = RetrievalResult {
                id: job.req.id,
                phases: phases[slot * n..(slot + 1) * n].to_vec(),
                settled: (s >= 0).then_some(s as usize),
                queue_latency: batch_start.duration_since(job.submitted),
                total_latency: done.duration_since(job.submitted),
                batch_occupancy: occupancy,
            };
            let timed_out = result.settled.is_none();
            metrics.record_completion(
                result.queue_latency,
                result.total_latency,
                timed_out,
                engine_kind,
            );
            // Receiver may have hung up (client gave up) — that's fine.
            let _ = job.reply.send(result);
        }
    }
    Ok(())
}

/// Packing policy of the solver pool: which solve requests may share
/// one lane-block engine, and how long the first request in a window
/// waits for company.
#[derive(Debug, Clone, Copy)]
pub struct SolvePackPolicy {
    /// Largest oscillator-count bucket (power of two) that still packs;
    /// bigger embeddings run one engine per request.  0 disables
    /// packing entirely.
    pub max_oscillators: usize,
    /// Lane capacity of one packed engine (also the per-request replica
    /// cap for packing; bounded by the portfolio's 64-replica wave).
    pub max_lanes: usize,
    /// Maximum time the first solve in a window waits for company.
    pub max_wait: Duration,
}

impl Default for SolvePackPolicy {
    fn default() -> Self {
        Self {
            max_oscillators: 64,
            max_lanes: MAX_WAVE_REPLICAS,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Batching compatibility key of a packable solve request.  Two
/// requests coalesce iff their keys are equal: same oscillator-count
/// bucket, chunk-count budget, engine family (native vs rtl) and —
/// for rtl — the same precision sweep point, since co-scheduled lanes
/// share one quantized fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SolvePackKey {
    /// Embedding rounded up to a power of two.
    pub bucket: usize,
    /// Chunk-count budget (`max_periods` in whole chunks).
    pub chunks: usize,
    /// Bit-true emulated-hardware engine vs the native float fabric.
    pub rtl: bool,
    /// Quantized weight width of the shared fabric (rtl only; the
    /// paper's 5 bits when the request carries no sweep point).
    pub weight_bits: u32,
    /// Phase-wheel resolution of the shared fabric (rtl only).
    pub phase_bits: u32,
}

/// Batching compatibility key of a packable solve request, or `None`
/// when the request must run solo.  Two requests coalesce iff their
/// keys are equal ([`SolvePackKey`]) — per-lane weights, noise streams,
/// and plateau exits take care of every other difference (seeds,
/// schedules, replica counts).  Both the native and the rtl engine
/// implement lane blocks, so small `rtl: true` requests coalesce too
/// (onto a shared emulated fabric at their precision point); requests
/// with an explicit `shards` placement never pack (engine topology is
/// theirs), and traced requests run solo so the trace describes one
/// solve, not a shared engine.
pub fn solve_pack_key(req: &SolveRequest, policy: &SolvePackPolicy) -> Option<SolvePackKey> {
    if policy.max_oscillators == 0 || policy.max_lanes == 0 {
        return None;
    }
    if req.shards.is_some() || req.trace {
        return None;
    }
    // Sparse-form problems run solo: lane blocks are programmed with
    // dense per-block matrices (the zero-padded packing layout), and
    // densifying would defeat the point of keeping the request sparse
    // end-to-end (DESIGN_SOLVER.md §11).
    if req.problem.is_sparse() {
        return None;
    }
    if req.replicas == 0 || req.replicas > policy.max_lanes.min(MAX_WAVE_REPLICAS) {
        return None;
    }
    let bucket = req.problem.embed_dim().next_power_of_two();
    if bucket > policy.max_oscillators {
        return None;
    }
    let (weight_bits, phase_bits) = req.precision().unwrap_or((5, 4));
    Some(SolvePackKey {
        bucket,
        chunks: req.max_periods.div_ceil(DEFAULT_CHUNK).max(1),
        rtl: req.rtl,
        weight_bits,
        phase_bits,
    })
}

/// Collect one solve batch: `pending` (a job carried over from the
/// previous window) or the next received job opens the window; packable
/// jobs with the same compatibility key join until the deadline, the
/// lane budget (2x one engine — the overflow backfills retired lanes
/// mid-run), or an incompatible job closes it.  The incompatible job is
/// returned as the next window's seed, never dropped.  `None` means the
/// queue disconnected with nothing left to serve.
pub fn collect_solve_batch(
    rx: &Receiver<SolveJob>,
    pending: Option<SolveJob>,
    policy: &SolvePackPolicy,
) -> Option<(Vec<SolveJob>, Option<SolveJob>)> {
    let first = match pending {
        Some(j) => j,
        None => rx.recv().ok()?,
    };
    let Some(key) = solve_pack_key(&first.req, policy) else {
        return Some((vec![first], None));
    };
    let deadline = Instant::now() + policy.max_wait;
    let mut lanes = first.req.replicas;
    let mut jobs = vec![first];
    while lanes < policy.max_lanes * 2 {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(j) => {
                if solve_pack_key(&j.req, policy) == Some(key) {
                    lanes += j.req.replicas;
                    jobs.push(j);
                } else {
                    return Some((jobs, Some(j)));
                }
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some((jobs, None))
}

fn solve_result_from(job: &SolveJob, out: crate::solver::portfolio::SolveOutcome) -> SolveResult {
    let done = Instant::now();
    SolveResult {
        id: job.req.id,
        objective: out.best_energy + job.req.problem.metadata.offset,
        spins: out.best_spins,
        phases: out.best_phases,
        energy: out.best_energy,
        periods: out.periods,
        replicas: out.replicas,
        settled_replicas: out.settled_replicas,
        engine: out.engine,
        sync_rounds: out.sync_rounds,
        quantization_error: out.quantization_error,
        sparse: out.sparse,
        hardware: out.hardware,
        trace: None,
        queue_latency: Duration::ZERO,
        total_latency: done.duration_since(job.submitted),
    }
}

/// The per-chunk progress closure of a streaming job: forwards
/// `(best_energy, periods)` to the front end's progress channel, tagged
/// with the connection token and request id.
fn progress_fn(job: &SolveJob) -> Option<Box<dyn Fn(f64, usize)>> {
    job.progress.clone().map(|(tx, token)| {
        let id = job.req.id;
        Box::new(move |best_energy: f64, periods: usize| {
            // The front end may have gone away mid-solve — fine.
            let _ = tx.send(ProgressEvent {
                token,
                id,
                best_energy,
                periods,
            });
        }) as Box<dyn Fn(f64, usize)>
    })
}

/// Run one solve solo on its own engine (the one-engine-per-request
/// path: oversized, sharded, overridden, or simply lonely requests).
/// The engine comes from the worker's warm `arena` when a standing one
/// matches the request's geometry, and goes back in warm after the
/// solve (also after a *cancelled* solve — the portfolio bails at chunk
/// boundaries, leaving the fabric healthy); only a failed solve
/// discards it.
fn solve_one(job: SolveJob, metrics: &Metrics, select: EngineSelect, arena: &mut EngineArena) {
    let dequeued = Instant::now();
    let params = PortfolioParams {
        replicas: job.req.replicas,
        max_periods: job.req.max_periods,
        schedule: job.req.schedule,
        seed: job.req.seed,
        precision: job.req.precision(),
        ..Default::default()
    };
    let job_select = if job.req.rtl {
        // `shards` composes with `rtl`: K >= 2 emulates a K-device
        // cluster (row-split weight memory, priced all-gather); 1 pins
        // the plain single-device engine.
        match job.req.shards {
            Some(k) if k >= 2 => EngineSelect::RtlCluster { shards: k },
            _ => EngineSelect::Rtl,
        }
    } else {
        match job.req.shards {
            Some(1) => EngineSelect::Native,
            Some(k) => EngineSelect::Sharded { shards: k },
            None => select,
        }
    };
    let m = job.req.problem.embed_dim();
    let batch = params.replicas.clamp(1, MAX_WAVE_REPLICAS);
    // The key carries the weight-fabric choice (dense vs CSR) and — on
    // the rtl fabrics — the precision point, so a warm dense engine is
    // never checked out for a sparse solve and a warm 5-bit fabric
    // never serves a 3-bit sweep request.
    let key = ArenaKey::for_solve(
        m,
        batch,
        params.chunk,
        job_select,
        wants_sparse(&job.req.problem),
        params.precision,
    );
    let mut engine = match arena.checkout(key, metrics, || {
        build_engine_cfg(params.cfg(m), batch, params.chunk, job_select)
    }) {
        Ok(engine) => engine,
        Err(e) => {
            metrics.record_solve_failure();
            eprintln!("solve job {} failed to build an engine: {e:#}", job.req.id);
            return;
        }
    };
    let progress = progress_fn(&job);
    let hooks = SolveHooks {
        cancel: job.cancel.as_deref(),
        progress: progress.as_deref(),
    };
    let trace_sink = job.req.trace.then(|| sink(DEFAULT_TRACE_CAP));
    match solve_portfolio_hooked(
        engine.as_mut(),
        &job.req.problem,
        &params,
        trace_sink.as_ref(),
        hooks,
    ) {
        Ok(out) => {
            arena.checkin(key, engine, metrics);
            let mut result = solve_result_from(&job, out);
            result.trace = trace_sink.map(|s| s.borrow_mut().take());
            result.queue_latency = dequeued.duration_since(job.submitted);
            metrics.record_solve_completion(
                result.total_latency,
                result.periods,
                result.sync_rounds,
                result.engine,
            );
            if result.sparse {
                metrics.record_solve_sparse();
            }
            if let Some(hw) = &result.hardware {
                metrics.record_solve_hardware(hw.fast_cycles);
                if hw.sync_fast_cycles > 0 {
                    metrics.record_rtl_cluster_sync(hw.sync_fast_cycles);
                }
            }
            // Receiver may have hung up (client gave up) — fine.
            let _ = job.reply.send(result);
        }
        Err(e) if is_cancelled(&e) => {
            // The client went away; nobody is waiting on the reply.
            // The engine stopped at a chunk boundary and is healthy.
            arena.checkin(key, engine, metrics);
            metrics.record_solve_cancelled();
        }
        Err(e) => {
            // Router validation catches malformed requests, so this is
            // an internal failure; drop the reply (the client surfaces
            // "worker dropped reply") and count it.  The engine's state
            // is suspect — discard it rather than park it warm.
            metrics.record_solve_failure();
            eprintln!("solve job {} failed: {e:#}", job.req.id);
        }
    }
}

/// Run a coalesced batch on one shared lane-block engine.  Every job
/// receives exactly the `SolveResult` its solo run would produce (the
/// packed driver is bit-exact lane by lane); jobs beyond the engine's
/// lane capacity backfill lanes as earlier problems retire.
///
/// The engine comes from the worker's warm `arena`, keyed at the fixed
/// `(bucket, policy.max_lanes)` geometry so every batch in a bucket
/// reuses one standing engine regardless of its composition — lane
/// blocks beyond the batch stay unprogrammed and uncoupled, so the
/// per-lane results don't depend on the lane count.
///
/// A packed-driver error must not take down unrelated clients: the
/// blast radius of one bad entry is contained by falling back to solo
/// [`solve_one`] per job (counted in `solve_pack_fallbacks`), so an
/// unrelated neighbor can't fail your request.
fn solve_packed_batch(
    jobs: Vec<SolveJob>,
    metrics: &Metrics,
    policy: &SolvePackPolicy,
    select: EngineSelect,
    arena: &mut EngineArena,
) {
    let dequeued = Instant::now();
    let bucket = jobs
        .iter()
        .map(|j| j.req.problem.embed_dim())
        .max()
        .unwrap_or(1)
        .next_power_of_two();
    let lanes = policy.max_lanes.max(1);
    // Collection guarantees a homogeneous batch (the pack key carries
    // the engine family and precision point), so the first job decides
    // the shared fabric for all of them; an rtl *pool* (`select`) pins
    // every batch to the emulated fabric even when no request asked.
    let rtl = select == EngineSelect::Rtl || jobs.first().is_some_and(|j| j.req.rtl);
    let precision = jobs.first().and_then(|j| j.req.precision());
    let entries: Vec<(IsingProblem, PortfolioParams)> = jobs
        .iter()
        .map(|j| {
            (
                j.req.problem.clone(),
                PortfolioParams {
                    replicas: j.req.replicas,
                    max_periods: j.req.max_periods,
                    schedule: j.req.schedule,
                    seed: j.req.seed,
                    precision,
                    ..Default::default()
                },
            )
        })
        .collect();
    let (weight_bits, phase_bits) = precision.unwrap_or((5, 4));
    let cfg = match precision {
        Some((wb, pb)) => NetworkConfig::with_precision(bucket, wb, pb),
        None => NetworkConfig::paper(bucket),
    };
    let (key, pack_select) = if rtl {
        (
            ArenaKey::Rtl {
                n: bucket,
                batch: lanes,
                chunk: DEFAULT_CHUNK,
                weight_bits,
                phase_bits,
            },
            EngineSelect::Rtl,
        )
    } else {
        (
            ArenaKey::Native {
                n: bucket,
                batch: lanes,
                chunk: DEFAULT_CHUNK,
                sparse: false,
            },
            EngineSelect::Native,
        )
    };
    let mut engine = match arena.checkout(key, metrics, || {
        build_engine_cfg(cfg, lanes, DEFAULT_CHUNK, pack_select)
    }) {
        Ok(engine) => engine,
        Err(e) => {
            eprintln!("packed engine build failed, falling back to solo solves: {e:#}");
            metrics.record_solve_pack_fallback();
            for job in jobs {
                solve_one(job, metrics, select, arena);
            }
            return;
        }
    };
    let progress_fns: Vec<Option<Box<dyn Fn(f64, usize)>>> =
        jobs.iter().map(progress_fn).collect();
    let hooks: Vec<SolveHooks<'_>> = jobs
        .iter()
        .zip(&progress_fns)
        .map(|(job, progress)| SolveHooks {
            cancel: job.cancel.as_deref(),
            progress: progress.as_deref(),
        })
        .collect();
    match solve_packed_hooked(engine.as_mut(), &entries, &hooks) {
        Ok(outs) => {
            drop(hooks);
            arena.checkin(key, engine, metrics);
            for (job, out) in jobs.into_iter().zip(outs) {
                let Some(out) = out else {
                    // Cancelled mid-pack: lanes were freed, nobody is
                    // waiting on the reply.
                    metrics.record_solve_cancelled();
                    continue;
                };
                if out.early_exit {
                    metrics.record_solve_lanes_retired(out.replicas as u64);
                }
                let mut result = solve_result_from(&job, out);
                result.queue_latency = dequeued.duration_since(job.submitted);
                metrics.record_solve_completion(
                    result.total_latency,
                    result.periods,
                    result.sync_rounds,
                    result.engine,
                );
                if rtl {
                    metrics.record_solve_rtl_packed();
                }
                if let Some(hw) = &result.hardware {
                    metrics.record_solve_hardware(hw.fast_cycles);
                }
                let _ = job.reply.send(result);
            }
        }
        Err(e) => {
            // One bad entry (or an internal packed-driver fault) must
            // not drop every coalesced client's reply: discard the
            // suspect engine and rerun each job solo on its own engine.
            eprintln!("packed solve batch failed, falling back to solo solves: {e:#}");
            metrics.record_solve_pack_fallback();
            drop(hooks);
            for job in jobs {
                solve_one(job, metrics, select, arena);
            }
        }
    }
}

/// The parked-job slot a solver pool's workers share: a job that
/// closed a batch window (incompatible with it) waits here and is
/// picked up by *whichever* worker collects next — not necessarily the
/// one that parked it, so an idle worker never waits behind a busy
/// neighbor's batch.
pub type SolvePending = Arc<Mutex<Option<SolveJob>>>;

/// The solver worker loop: pulls [`SolveJob`]s from the shared queue.
/// Small compatible requests coalesce ([`collect_solve_batch`]) into
/// one lane-block engine whose batch lanes carry different problems;
/// everything else runs one engine per request, where `select` places
/// the request on the native or row-sharded fabric (a request's
/// explicit `shards` field overrides the rule).
///
/// Several workers may share one queue: batch *collection* is
/// serialized by the lock, batch *execution* runs in parallel across
/// workers — the same occupancy/throughput trade the retrieval pool
/// makes.  The `pending` slot (shared, accessed only under the queue
/// lock) carries a window-closing job to the next collection, on any
/// worker.
pub fn solve_worker_loop(
    rx: Arc<Mutex<Receiver<SolveJob>>>,
    pending: SolvePending,
    metrics: Arc<Metrics>,
    select: EngineSelect,
    pack: SolvePackPolicy,
    arena_capacity: usize,
) -> Result<()> {
    // Engines are thread-affine (`ChunkEngine` is not `Send`), so each
    // worker owns its warm arena outright; only the hit/miss/evict
    // counters are shared, through `metrics`.
    let mut arena = EngineArena::new(arena_capacity);
    loop {
        // The pending slot is only touched while holding the queue
        // lock, so take-collect-park is one atomic step: the next
        // collector (whichever worker gets the lock) always sees the
        // parked job before it can block on the queue.
        let jobs = {
            let guard = rx.lock().expect("solve queue lock poisoned");
            let carry_in = pending.lock().expect("pending slot poisoned").take();
            match collect_solve_batch(&guard, carry_in, &pack) {
                None => None,
                Some((jobs, carry)) => {
                    if carry.is_some() {
                        *pending.lock().expect("pending slot poisoned") = carry;
                    }
                    Some(jobs)
                }
            }
        };
        let Some(jobs) = jobs else { break };
        metrics.record_solve_batch(jobs.len());
        if jobs.len() == 1 {
            solve_one(
                jobs.into_iter().next().expect("len checked"),
                &metrics,
                select,
                &mut arena,
            );
        } else {
            solve_packed_batch(jobs, &metrics, &pack, select, &mut arena);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn dummy_job(id: u64, reply: std::sync::mpsc::Sender<RetrievalResult>) -> Job {
        Job {
            req: crate::coordinator::job::RetrievalRequest {
                id,
                n: 2,
                phases: vec![0, 8],
                max_periods: 16,
            },
            submitted: Instant::now(),
            reply,
        }
    }

    #[test]
    fn collect_waits_until_full() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..3 {
            tx.send(dummy_job(i, rtx.clone())).unwrap();
        }
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let jobs = collect_batch(&rx, 3, &policy).unwrap();
        assert_eq!(jobs.len(), 3);
    }

    #[test]
    fn collect_respects_deadline() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(dummy_job(0, rtx)).unwrap();
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let t0 = Instant::now();
        let jobs = collect_batch(&rx, 64, &policy).unwrap();
        assert_eq!(jobs.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn collect_none_after_disconnect() {
        let (tx, rx) = channel::<Job>();
        drop(tx);
        assert!(collect_batch(&rx, 4, &BatchPolicy::default()).is_none());
    }

    fn solve_job(
        n: usize,
        replicas: usize,
        max_periods: usize,
        reply: std::sync::mpsc::Sender<SolveResult>,
    ) -> SolveJob {
        let mut req = SolveRequest::new(n as u64, IsingProblem::new(n));
        req.replicas = replicas;
        req.max_periods = max_periods;
        SolveJob {
            req,
            submitted: Instant::now(),
            reply,
            cancel: None,
            progress: None,
        }
    }

    #[test]
    fn pack_key_encodes_the_compatibility_rules() {
        let policy = SolvePackPolicy::default();
        let (rtx, _rrx) = channel();
        let a = solve_job(10, 8, 64, rtx.clone());
        let b = solve_job(14, 4, 57, rtx.clone()); // same bucket (16), same 8-chunk budget
        let key = solve_pack_key(&a.req, &policy).unwrap();
        assert_eq!((key.bucket, key.chunks), (16, 8));
        assert!(!key.rtl);
        assert_eq!((key.weight_bits, key.phase_bits), (5, 4));
        assert_eq!(solve_pack_key(&b.req, &policy), Some(key));
        // Different bucket or different chunk budget: incompatible.
        assert_ne!(solve_pack_key(&solve_job(20, 8, 64, rtx.clone()).req, &policy), Some(key));
        assert_ne!(solve_pack_key(&solve_job(10, 8, 72, rtx.clone()).req, &policy), Some(key));
        // Small rtl requests coalesce too — onto a *different* fabric
        // than the native key, split further by precision point.
        let mut r = solve_job(10, 8, 64, rtx.clone());
        r.req.rtl = true;
        let rkey = solve_pack_key(&r.req, &policy).unwrap();
        assert!(rkey.rtl);
        assert_ne!(rkey, key, "rtl and native requests never share an engine");
        let mut r3 = solve_job(10, 8, 64, rtx.clone());
        r3.req.rtl = true;
        r3.req.weight_bits = Some(3);
        assert_ne!(
            solve_pack_key(&r3.req, &policy),
            Some(rkey),
            "sweep points never share a quantized fabric"
        );
        // Never packable: shards override, oversized embedding or
        // replica count, packing disabled.
        let mut c = solve_job(10, 8, 64, rtx.clone());
        c.req.shards = Some(2);
        assert_eq!(solve_pack_key(&c.req, &policy), None);
        assert_eq!(solve_pack_key(&solve_job(100, 8, 64, rtx.clone()).req, &policy), None);
        assert_eq!(solve_pack_key(&solve_job(10, 100, 64, rtx.clone()).req, &policy), None);
        let off = SolvePackPolicy {
            max_oscillators: 0,
            ..Default::default()
        };
        assert_eq!(solve_pack_key(&a.req, &off), None);
        // Sparse-form problems never pack: lane blocks are dense.
        let mut s = solve_job(10, 8, 64, rtx.clone());
        s.req.problem = IsingProblem::from_edges(10, &[(0, 1, 1.0)]).unwrap();
        assert_eq!(solve_pack_key(&s.req, &policy), None);
    }

    #[test]
    fn rtl_batch_packs_onto_one_emulated_fabric() {
        // Two small rtl requests coalesce onto one lane-block rtl
        // engine: each reply reports the emulated-hardware engine, a
        // per-block SerialMac hardware share, and the packed-rtl meter
        // advances once per job.
        let metrics = Metrics::default();
        let policy = SolvePackPolicy {
            max_lanes: 8,
            ..Default::default()
        };
        let mut arena = EngineArena::new(4);
        let (rtx, rrx) = channel();
        let mut jobs = vec![
            solve_job(6, 4, 32, rtx.clone()),
            solve_job(6, 4, 32, rtx.clone()),
        ];
        for j in &mut jobs {
            j.req.rtl = true;
        }
        solve_packed_batch(jobs, &metrics, &policy, EngineSelect::Native, &mut arena);
        for _ in 0..2 {
            let r = rrx.try_recv().expect("packed rtl job must reply");
            assert_eq!(r.engine, "rtl");
            let hw = r.hardware.expect("rtl lanes report their hardware share");
            assert!(hw.fast_cycles > 0);
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.solves_rtl_packed, 2);
        assert_eq!(snap.solve_pack_fallbacks, 0);
    }

    #[test]
    fn solve_collect_coalesces_compatible_jobs() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for _ in 0..3 {
            tx.send(solve_job(12, 4, 64, rtx.clone())).unwrap();
        }
        let policy = SolvePackPolicy {
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let (jobs, carry) = collect_solve_batch(&rx, None, &policy).unwrap();
        assert_eq!(jobs.len(), 3);
        assert!(carry.is_none());
    }

    #[test]
    fn solve_collect_parks_the_incompatible_job() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(solve_job(12, 4, 64, rtx.clone())).unwrap();
        tx.send(solve_job(12, 4, 64, rtx.clone())).unwrap();
        tx.send(solve_job(40, 4, 64, rtx.clone())).unwrap(); // other bucket
        let policy = SolvePackPolicy {
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let (jobs, carry) = collect_solve_batch(&rx, None, &policy).unwrap();
        assert_eq!(jobs.len(), 2);
        let carry = carry.expect("incompatible job seeds the next window");
        assert_eq!(carry.req.problem.n, 40);
        // The carried job opens the next window without another recv.
        let (jobs, carry) = collect_solve_batch(&rx, Some(carry), &policy).unwrap();
        assert_eq!(jobs.len(), 1);
        assert!(carry.is_none());
    }

    #[test]
    fn solve_collect_unpackable_job_goes_straight_through() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        let mut j = solve_job(12, 4, 64, rtx.clone());
        j.req.shards = Some(2); // explicit placement: never packs
        tx.send(j).unwrap();
        let policy = SolvePackPolicy {
            max_wait: Duration::from_millis(200),
            ..Default::default()
        };
        let t0 = Instant::now();
        let (jobs, carry) = collect_solve_batch(&rx, None, &policy).unwrap();
        assert_eq!(jobs.len(), 1);
        assert!(carry.is_none());
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "solo jobs must not wait out the batch window"
        );
    }

    #[test]
    fn solve_collect_none_after_disconnect() {
        let (tx, rx) = channel::<SolveJob>();
        drop(tx);
        assert!(collect_solve_batch(&rx, None, &SolvePackPolicy::default()).is_none());
    }

    #[test]
    fn packed_batch_failure_falls_back_to_solo_per_job() {
        // A batch whose packed run *must* fail internally: one job's
        // replica count exceeds the packed engine's lane capacity
        // (collect would normally reject it, but the blast-radius
        // contract is about internal failures, whatever their source).
        // Every coalesced job must still get its reply via the solo
        // fallback — one bad neighbor can't blackhole the batch.
        let metrics = Metrics::default();
        let policy = SolvePackPolicy {
            max_lanes: 8,
            ..Default::default()
        };
        let mut arena = EngineArena::new(4);
        let (rtx, rrx) = channel();
        let jobs = vec![
            solve_job(6, 16, 16, rtx.clone()), // 16 replicas > 8 lanes
            solve_job(6, 4, 16, rtx.clone()),
        ];
        solve_packed_batch(jobs, &metrics, &policy, EngineSelect::Native, &mut arena);
        let mut ids: Vec<u64> = (0..2).map(|_| rrx.try_recv().unwrap().id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![6, 6], "both jobs replied through the fallback");
        let snap = metrics.snapshot();
        assert_eq!(snap.solve_pack_fallbacks, 1);
        assert_eq!(snap.solves_failed, 0, "fallback is not a failure");
        assert_eq!(snap.solves_completed, 2);
    }

    #[test]
    fn cancelled_solo_job_is_counted_and_dropped() {
        use std::sync::atomic::AtomicBool;
        let metrics = Metrics::default();
        let mut arena = EngineArena::new(4);
        let (rtx, rrx) = channel();
        let mut job = solve_job(8, 4, 64, rtx);
        job.cancel = Some(Arc::new(AtomicBool::new(true))); // pre-cancelled
        solve_one(job, &metrics, EngineSelect::Native, &mut arena);
        assert!(rrx.try_recv().is_err(), "no reply for a cancelled solve");
        let snap = metrics.snapshot();
        assert_eq!(snap.solves_cancelled, 1);
        assert_eq!(snap.solves_failed, 0, "cancellation is not a failure");
        assert_eq!(
            arena.len(),
            1,
            "a cancelled solve's engine goes back in warm"
        );
    }
}
